//! Offline stub of the slice of the `xla` (xla-rs) API that
//! `photogan::runtime` uses. Every entry point type-checks exactly like
//! the real bindings but fails at runtime with [`Error::Unavailable`], so
//! `--features pjrt` builds (and degrades gracefully) without the XLA
//! shared libraries. Swap this path dependency for a real xla-rs checkout
//! to run actual inference — see `vendor/xla-stub/Cargo.toml`.

use std::fmt;

/// Stub error: always "PJRT unavailable".
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} unavailable (offline build without the real xla-rs; \
                 see vendor/xla-stub/Cargo.toml)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Host-side literal (stub: shape-less placeholder).
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        // reshaping a placeholder is harmless; execution is what fails
        Ok(Literal)
    }

    /// Unwrap a 1-tuple result.
    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module (stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation (stub).
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (stub).
#[derive(Debug, Clone)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable (stub).
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client (stub). `cpu()` fails, so nothing downstream ever runs.
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_fails_loudly_not_silently() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        assert!(Literal::vec1(&[1.0]).to_vec::<f32>().is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("xla stub"), "{msg}");
    }

    #[test]
    fn literal_construction_is_cheap_and_infallible() {
        let l = Literal::vec1(&[1.0, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(l.to_tuple1().is_err());
    }
}
