//! Golden parity: the rust PJRT runtime must reproduce the jax-computed
//! outputs for every artifact (same weights, same inputs, bit-level modulo
//! compiler reassociation).
//!
//! These tests need `make artifacts` to have run; they skip (pass with a
//! notice) when `artifacts/` is absent so `cargo test` stays green on a
//! fresh checkout.

use std::sync::OnceLock;
use photogan::runtime::artifacts::{read_f32_file, ArtifactSet};
use photogan::runtime::Engine;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    ArtifactSet::discover(&artifacts_dir()).map(|v| !v.is_empty()).unwrap_or(false)
}

/// One engine shared across tests — PJRT compilation of the artifacts is
/// the dominant cost, pay it once.
static ENGINE: OnceLock<Engine> = OnceLock::new();

fn engine() -> &'static Engine {
    ENGINE.get_or_init(|| Engine::load(&artifacts_dir()).expect("engine loads"))
}

/// Max |a−b| over paired outputs.
fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Cross-batch coupling bound: the MVM kernel calibrates its quantization
/// full-scale over the *whole batch* (one shared DAC calibration per
/// tensor, as the ECU would), so changing one batch slot can shift other
/// slots by a few 8-bit LSBs. 3 LSB of the tanh output range ≈ 0.05.
const BATCH_COUPLING_TOL: f32 = 0.05;

#[test]
fn golden_outputs_match_jax() {
    if !have_artifacts() {
        eprintln!("[skip] no artifacts — run `make artifacts` first");
        return;
    }
    let dir = artifacts_dir();
    let engine = engine();
    for set in ArtifactSet::discover(&dir).unwrap() {
        let input = set.read_f32("golden_in.bin").expect("golden_in");
        let label = set.read_f32("golden_label.bin").ok();
        let expect = set.read_f32("golden_out.bin").expect("golden_out");
        let got = engine
            .run_raw(&set.name, &input, label.as_deref())
            .unwrap_or_else(|e| panic!("{}: {e:#}", set.name));
        assert_eq!(got.len(), expect.len(), "{}: output length", set.name);
        let mut max_err = 0f32;
        let mut sum_err = 0f64;
        let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
        for (a, b) in got.iter().zip(&expect) {
            max_err = max_err.max((a - b).abs());
            sum_err += (a - b).abs() as f64;
            dot += (*a as f64) * (*b as f64);
            na += (*a as f64) * (*a as f64);
            nb += (*b as f64) * (*b as f64);
        }
        let mean_err = sum_err / expect.len() as f64;
        let cosine = dot / (na.sqrt() * nb.sqrt()).max(1e-30);
        // Criteria: XLA-CPU-in-rust vs jax-CPU reassociation can flip 8-bit
        // quantization roundings (jnp.round at half-LSB boundaries); a flip
        // cascading through many InstanceNorm rescalings (cyclegan: 15) can
        // push single pixels by several LSB, so the binding checks are
        // ensemble-level (mean error ≤ 1 LSB, cosine ≈ 1) with a loose
        // per-pixel cap on the tanh output range.
        assert!(max_err <= 0.15, "{}: max |Δ| = {max_err}", set.name);
        assert!(mean_err <= 2.0 / 127.0, "{}: mean |Δ| = {mean_err}", set.name);
        assert!(cosine >= 0.995, "{}: cosine = {cosine}", set.name);
        println!(
            "[golden] {}: max |Δ| = {max_err:.3e}, mean |Δ| = {mean_err:.3e}, cosine = {cosine:.6} over {} values",
            set.name,
            expect.len()
        );
    }
}

#[test]
fn seeded_generation_is_deterministic() {
    if !have_artifacts() {
        eprintln!("[skip] no artifacts — run `make artifacts` first");
        return;
    }
    let engine = engine();
    let name = engine.model_names()[0].clone();
    let a = engine.generate_sync(&name, &[(7, Some(3)), (8, Some(1))]).unwrap();
    let b = engine.generate_sync(&name, &[(7, Some(3)), (8, Some(1))]).unwrap();
    assert_eq!(a, b, "same seeds must give identical images");
    let c = engine.generate_sync(&name, &[(9, Some(3)), (8, Some(1))]).unwrap();
    let n = engine.meta(&name).unwrap().output_elements;
    let changed = max_abs_diff(&a[..n], &c[..n]);
    assert!(changed > BATCH_COUPLING_TOL, "different seed must change the image: {changed}");
    let coupling = max_abs_diff(&a[n..], &c[n..]);
    assert!(
        coupling <= BATCH_COUPLING_TOL,
        "other slot moved {coupling} > shared-calibration bound"
    );
}

#[test]
fn batch_padding_slices_correctly() {
    if !have_artifacts() {
        eprintln!("[skip] no artifacts — run `make artifacts` first");
        return;
    }
    let engine = engine();
    let name = engine.model_names()[0].clone();
    let n = engine.meta(&name).unwrap().output_elements;
    // single entry vs the same entry within a larger call
    let solo = engine.generate_sync(&name, &[(42, Some(0))]).unwrap();
    let multi = engine
        .generate_sync(&name, &[(42, Some(0)), (43, Some(1)), (44, Some(2))])
        .unwrap();
    assert_eq!(solo.len(), n);
    assert_eq!(multi.len(), 3 * n);
    let coupling = max_abs_diff(&solo, &multi[..n]);
    assert!(
        coupling <= BATCH_COUPLING_TOL,
        "slot 0 moved {coupling} with batch fill (shared-calibration bound)"
    );
}

#[test]
fn oversized_batch_chunks_transparently() {
    if !have_artifacts() {
        eprintln!("[skip] no artifacts — run `make artifacts` first");
        return;
    }
    let engine = engine();
    let name = engine.model_names()[0].clone();
    let meta = engine.meta(&name).unwrap().clone();
    let entries: Vec<(u64, Option<u32>)> =
        (0..meta.batch as u64 + 3).map(|i| (i, Some((i % 10) as u32))).collect();
    let out = engine.generate_sync(&name, &entries).unwrap();
    assert_eq!(out.len(), entries.len() * meta.output_elements);
}

#[test]
fn weights_bin_respects_manifest() {
    if !have_artifacts() {
        eprintln!("[skip] no artifacts — run `make artifacts` first");
        return;
    }
    for set in ArtifactSet::discover(&artifacts_dir()).unwrap() {
        let bufs = set.weights().expect("weight slicing");
        let n = set.manifest.get_usize("weight_buffers").unwrap();
        assert_eq!(bufs.len(), n, "{}", set.name);
        let total: usize = bufs.iter().map(|b| b.len()).sum();
        let file = read_f32_file(&set.dir.join("weights.bin")).unwrap();
        assert_eq!(total, file.len(), "{}", set.name);
        // params field should match total weight elements
        let params = set.manifest.get_usize("params").unwrap();
        assert_eq!(params, total, "{}", set.name);
    }
}
