//! Cross-module integration tests (no artifacts needed): the analytical
//! stack end-to-end — models → mapper → simulator → metrics → baselines →
//! DSE — plus coordinator serving over a simulated executor.

use photogan::arch::accelerator::Accelerator;
use photogan::arch::config::ArchConfig;
use photogan::baselines::platform::all_platforms;
use photogan::coordinator::server::{BatchExecutor, Server, ServerConfig};
use photogan::coordinator::BatchPolicy;
use photogan::dse::{explore, Grid};
use photogan::models::zoo;
use photogan::sim::{simulate, OptFlags};
use photogan::sparse::{tconv2d_dense, tconv2d_sparse, TconvSpec};
use photogan::util::prop::check;
use photogan::util::rng::Pcg32;
use std::sync::Arc;
use std::time::Duration;

#[test]
fn paper_pipeline_end_to_end() {
    // the full Fig. 13/14 pipeline: chip + 4 models + 5 baselines
    let acc = Accelerator::new(ArchConfig::paper_optimum()).unwrap();
    let models = zoo::all_generators();
    for m in &models {
        let pg = simulate(m, &acc, 1, OptFlags::all());
        assert!(pg.gops() > 0.0);
        for p in all_platforms() {
            let b = p.evaluate(m, 1);
            assert!(pg.gops() > b.gops(), "{} must lose to PhotoGAN on {}", p.name, m.name);
            assert!(pg.epb() < b.epb(), "{} EPB must exceed PhotoGAN on {}", p.name, m.name);
        }
    }
}

#[test]
fn optimization_flags_compose_monotonically() {
    // adding an optimization on top of any subset must not increase energy
    let acc = Accelerator::new(ArchConfig::paper_optimum()).unwrap();
    let m = zoo::artgan();
    let e = |s: bool, p: bool, g: bool| {
        simulate(&m, &acc, 1, OptFlags { sparse: s, pipelined: p, power_gated: g, overlap: false, fuse: false })
            .energy
            .total()
    };
    for s in [false, true] {
        for p in [false, true] {
            for g in [false, true] {
                let base = e(s, p, g);
                if !s {
                    assert!(e(true, p, g) <= base * 1.0001, "sparse regressed at ({s},{p},{g})");
                }
                if !p {
                    assert!(e(s, true, g) <= base * 1.0001, "pipeline regressed at ({s},{p},{g})");
                }
                if !g {
                    assert!(e(s, p, true) <= base * 1.0001, "gating regressed at ({s},{p},{g})");
                }
            }
        }
    }
}

#[test]
fn sparse_dataflow_property_random_specs() {
    check("sparse == dense over random tconvs", 48, |gen| {
        let k = gen.usize_in(1, 6);
        let s = gen.usize_in(1, 4);
        let p = gen.usize_in(0, (k - 1) / 2);
        let h = gen.usize_in(1, 9);
        let w = gen.usize_in(1, 9);
        let spec = TconvSpec::new(k, s, p, h, w);
        let input = gen.vec_f32(h * w, -1.0, 1.0);
        let kernel = gen.vec_f32(k * k, -1.0, 1.0);
        let a = tconv2d_dense(&spec, &input, &kernel);
        let b = tconv2d_sparse(&spec, &input, &kernel);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4);
        }
        // census consistency with the executed tap count
        let c = spec.census();
        assert!(c.sparse_macs <= c.dense_macs);
    });
}

#[test]
fn dse_respects_cap_under_tight_power_budget() {
    // artificially tighten the cap and verify the explorer prunes configs
    let mut models = vec![zoo::condgan()];
    let grid = Grid { n: vec![16, 36], k: vec![2, 8], l: vec![3, 13], m: vec![1, 5] };
    let pts = explore(&grid, &models, OptFlags::all(), 2);
    assert!(!pts.is_empty());
    // same grid with a 0.5 W cap must yield strictly fewer valid points
    for m in &mut models {
        // models carry no power info; tighten via the config's params in
        // a bespoke sweep instead
        let _ = m;
    }
    let mut tight = 0;
    let mut loose = 0;
    for &(n, k, l, mm) in
        &[(16usize, 2usize, 3usize, 1usize), (36, 8, 13, 5), (36, 2, 3, 1), (16, 8, 13, 5)]
    {
        let mut cfg = ArchConfig::new(n, k, l, mm);
        let acc = Accelerator::new(cfg.clone()).unwrap();
        if acc.validate(true).is_ok() {
            loose += 1;
        }
        cfg.params.system.power_cap_w = 0.5;
        let acc2 = Accelerator::new(cfg).unwrap();
        if acc2.validate(true).is_ok() {
            tight += 1;
        }
    }
    assert!(tight < loose, "a 0.5 W cap must reject some configs ({tight} vs {loose})");
}

/// Simulated executor: serving latency is driven by the *photonic
/// simulator's* predicted batch latency — ties the coordinator and the
/// analytical model together without PJRT.
struct SimExec {
    acc: Accelerator,
}

impl BatchExecutor for SimExec {
    fn models(&self) -> Vec<String> {
        vec!["CondGAN".into()]
    }

    fn elements_per_sample(&self, _m: &str) -> usize {
        784
    }

    fn generate(&self, _m: &str, entries: &[(u64, Option<u32>)]) -> Vec<f32> {
        let model = zoo::condgan();
        let r = simulate(&model, &self.acc, entries.len(), OptFlags::all());
        // "execute" for the simulated duration (scaled 1000x down to keep
        // the test fast), then emit seed-stamped pixels
        std::thread::sleep(Duration::from_secs_f64(r.latency / 1000.0));
        let mut out = Vec::with_capacity(entries.len() * 784);
        for &(seed, _) in entries {
            let mut rng = Pcg32::new(seed);
            out.extend((0..784).map(|_| rng.f32() * 2.0 - 1.0));
        }
        out
    }
}

#[test]
fn coordinator_over_simulated_photonic_executor() {
    let acc = Accelerator::new(ArchConfig::paper_optimum()).unwrap();
    let server = Server::start(
        Arc::new(SimExec { acc }),
        ServerConfig {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
            workers: 2,
            ..Default::default()
        },
    );
    let rxs: Vec<_> = (0..32)
        .map(|i| server.submit("CondGAN", i, Some((i % 10) as u32), 1).unwrap())
        .collect();
    let mut served_batches = Vec::new();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.images.len(), 784);
        served_batches.push(resp.served_batch);
    }
    assert!(served_batches.iter().any(|&b| b > 1), "batching engaged");
    let stats = server.shutdown();
    assert_eq!(stats.total_requests, 32);
}

#[test]
fn batching_improves_simulated_throughput() {
    // the simulator's weight-reload amortization must show up as better
    // per-image latency at batch 8 vs 1 — the premise of the batcher
    let acc = Accelerator::new(ArchConfig::paper_optimum()).unwrap();
    let m = zoo::condgan();
    let r1 = simulate(&m, &acc, 1, OptFlags::all());
    let r8 = simulate(&m, &acc, 8, OptFlags::all());
    let speedup = r1.latency / (r8.latency / 8.0);
    assert!(speedup > 1.2, "batching speedup only {speedup:.2}x");
}
