//! Integration tests for the `photogan::api` Session layer: builder
//! validation (every `ApiError` variant is reachable), mapping-cache
//! equivalence (cached results bit-identical to direct `sim::simulate`
//! calls and to the pre-Session DSE path), and JSON/table round-trips.

use photogan::api::{ApiError, Session, SimRequest, SweepRequest};
use photogan::arch::config::{ArchConfig, ConfigError};
use photogan::dse::{explore, Grid};
use photogan::models::zoo;
use photogan::sim::{simulate, OptFlags};
use photogan::util::cli::{CliError, ParsedFlags};
use photogan::util::json;

// ------------------------------------------------------- builder validation

#[test]
fn every_api_error_variant_is_reachable() {
    let session = Session::new().unwrap();

    // UnknownModel — from name resolution (8 registered: Table 1 + zoo)
    let req = SimRequest::builder().model("biggan").build().unwrap();
    assert!(matches!(
        session.simulate(&req).unwrap_err(),
        ApiError::UnknownModel { ref name, ref available }
            if name == "biggan" && available.len() == 8
    ));

    // InvalidConfig — from builder-time structural validation
    assert_eq!(
        SimRequest::builder().config(ArchConfig::new(40, 2, 11, 3)).build().unwrap_err(),
        ApiError::InvalidConfig(ConfigError::TooManyWavelengths(40, 36))
    );

    // PowerCapExceeded — strict power validation against a tightened cap
    let mut cfg = ArchConfig::paper_optimum();
    cfg.params.system.power_cap_w = 0.5;
    let tight = Session::with_config(cfg).unwrap();
    let req = SimRequest::builder().strict_power(true).build().unwrap();
    assert!(matches!(
        tight.simulate(&req).unwrap_err(),
        ApiError::PowerCapExceeded { cap_w, .. } if cap_w == 0.5
    ));

    // InvalidBatch
    assert_eq!(
        SimRequest::builder().batch(0).build().unwrap_err(),
        ApiError::InvalidBatch(0)
    );

    // EmptyGrid
    let empty = Grid { n: vec![], k: vec![2], l: vec![11], m: vec![3] };
    assert_eq!(
        SweepRequest::builder().grid(empty).build().unwrap_err(),
        ApiError::EmptyGrid
    );

    // InvalidGrid — zeroed axis values are typed at the Session boundary
    // too (requests built field-by-field bypass the builder)
    let zeroed = SweepRequest {
        grid: Grid { n: vec![16], k: vec![2], l: vec![11, 0], m: vec![3] },
        opts: OptFlags::all(),
        threads: 2,
    };
    assert_eq!(
        session.sweep(&zeroed).unwrap_err(),
        ApiError::InvalidGrid { reason: "axis l contains 0".into() }
    );

    // InvalidThreads
    assert_eq!(
        SweepRequest::builder().threads(0).build().unwrap_err(),
        ApiError::InvalidThreads(0)
    );

    // InvalidFlag — CLI errors funnel into the API error channel
    let cli_err = ParsedFlags::parse(&["--batch".to_string()], &[photogan::util::cli::value("batch")])
        .unwrap_err();
    assert_eq!(cli_err, CliError::MissingValue { flag: "batch".into() });
    let api_err: ApiError = cli_err.into();
    assert!(matches!(api_err, ApiError::InvalidFlag { ref flag, .. } if flag == "batch"));

    // ArtifactError / Internal — runtime-failure variants (exit code 1)
    for e in [
        ApiError::ArtifactError("no artifacts".into()),
        ApiError::Internal("worker died".into()),
    ] {
        assert_eq!(e.exit_code(), 1);
        assert!(!e.to_string().is_empty());
    }
    // all validation errors are usage errors (exit code 2)
    assert_eq!(ApiError::InvalidBatch(0).exit_code(), 2);
    assert_eq!(ApiError::EmptyGrid.exit_code(), 2);

    // serving builder validation (backend-independent, no artifacts)
    use photogan::api::ServeRequest;
    assert_eq!(
        ServeRequest::builder().workers(0).build().unwrap_err(),
        ApiError::InvalidWorkers(0)
    );
    assert_eq!(
        ServeRequest::builder().shards(0).build().unwrap_err(),
        ApiError::InvalidShards(0)
    );
    assert_eq!(
        ServeRequest::builder().max_batch(0).build().unwrap_err(),
        ApiError::InvalidBatch(0)
    );
    assert_eq!(
        ServeRequest::builder().time_scale(-2.0).build().unwrap_err(),
        ApiError::InvalidTimeScale(-2.0)
    );
    assert!(matches!(
        ServeRequest::builder().queue_depth(0).build().unwrap_err(),
        ApiError::InvalidFlag { ref flag, .. } if flag == "queue-depth"
    ));
    assert_eq!(ApiError::InvalidWorkers(0).exit_code(), 2);
    // backpressure is a runtime condition, not a usage error
    assert_eq!(
        ApiError::Backpressure { shard: 0, outstanding: 4, limit: 4 }.exit_code(),
        1
    );
}

#[test]
fn bad_config_string_is_typed_not_silent() {
    // the pre-Session CLI silently fell back to the paper optimum on a
    // malformed --config; the API surfaces it
    let err = "16,2,eleven,3".parse::<ArchConfig>().unwrap_err();
    assert_eq!(err, ConfigError::BadQuad("16,2,eleven,3".into()));
    let api: ApiError = err.into();
    assert!(matches!(api, ApiError::InvalidConfig(_)));
}

// --------------------------------------------------- cache equivalence

#[test]
fn session_results_bit_identical_to_direct_simulate() {
    let session = Session::new().unwrap();
    let acc = session.accelerator().clone();
    for model in zoo::all_generators() {
        for (batch, opts) in [
            (1, OptFlags::all()),
            (8, OptFlags::all()),
            (1, OptFlags::baseline()),
            (2, OptFlags::sw_optimized()),
        ] {
            let direct = simulate(&model, &acc, batch, opts);
            let cached = session.sim_report(&model, batch, opts);
            assert_eq!(direct.latency, cached.latency, "{} b{batch}", model.name);
            assert_eq!(
                direct.energy.total(),
                cached.energy.total(),
                "{} b{batch}",
                model.name
            );
            assert_eq!(direct.gops(), cached.gops(), "{} b{batch}", model.name);
            assert_eq!(direct.epb(), cached.epb(), "{} b{batch}", model.name);
            // and a second (cache-hit) call is identical again
            let hit = session.sim_report(&model, batch, opts);
            assert_eq!(cached.latency, hit.latency);
            assert_eq!(cached.energy.total(), hit.energy.total());
        }
    }
    // 4 models × 4 (batch, opts) points
    assert_eq!(session.mapping_cache_entries(), 16);
}

#[test]
fn session_sweep_matches_seed_dse_path() {
    // the session sweeps its full 8-model registry; feed the seed path
    // the same set so the objectives are comparable bit-for-bit. The
    // builder's default opts now engage the overlap scheduler, so the
    // seed path gets the same flags.
    let models = zoo::extended_generators();
    let direct = explore(&Grid::smoke(), &models, OptFlags::overlapped(), 4);
    let session = Session::new().unwrap();
    let outcome = session
        .sweep(&SweepRequest::builder().grid(Grid::smoke()).threads(4).build().unwrap())
        .unwrap();
    assert_eq!(direct.len(), outcome.points.len());
    let best = outcome.optimum().expect("smoke grid has valid points");
    assert_eq!(
        (direct[0].n, direct[0].k, direct[0].l, direct[0].m),
        (best.n, best.k, best.l, best.m),
        "cached sweep must find the same optimum"
    );
    for (a, b) in direct.iter().zip(&outcome.points) {
        assert_eq!((a.n, a.k, a.l, a.m), (b.n, b.k, b.l, b.m));
        assert_eq!(a.objective, b.objective, "objective must be bit-identical");
        assert_eq!(a.gops, b.gops);
        assert_eq!(a.epb, b.epb);
    }
}

#[test]
fn custom_config_requests_share_the_cache() {
    let session = Session::new().unwrap();
    let base = session
        .simulate(&SimRequest::builder().model("dcgan").build().unwrap())
        .unwrap();
    let entries_after_first = session.mapping_cache_entries();
    // same model, different chip: mapping is config-independent → no new entry
    let custom = session
        .simulate(
            &SimRequest::builder()
                .model("dcgan")
                .config(ArchConfig::new(8, 1, 3, 1))
                .build()
                .unwrap(),
        )
        .unwrap();
    assert_eq!(session.mapping_cache_entries(), entries_after_first);
    assert_eq!(custom.config, (8, 1, 3, 1));
    // a smaller chip must not be faster than the paper chip
    assert!(custom.rows[0].latency_s >= base.rows[0].latency_s);
}

// ------------------------------------------------------ JSON round-trips

#[test]
fn simulate_json_round_trips_and_matches_table() {
    let session = Session::new().unwrap();
    let outcome = session
        .simulate(&SimRequest::builder().batch(2).build().unwrap())
        .unwrap();
    let doc = json::parse(&outcome.to_json()).expect("to_json output must parse");
    assert_eq!(doc.get("command").and_then(|v| v.as_str()), Some("simulate"));
    assert_eq!(doc.get("batch").and_then(|v| v.as_usize()), Some(2));
    let results = doc.get("results").and_then(|v| v.as_array()).unwrap();
    let table = outcome.to_table();
    assert_eq!(results.len(), table.len());
    for (row, j) in table.rows().iter().zip(results) {
        assert_eq!(row[0], j.get("model").unwrap().as_str().unwrap());
        assert_eq!(row[3], format!("{:.1}", j.get("gops").unwrap().as_f64().unwrap()));
        assert_eq!(row[4], format!("{:.2}", j.get("epb_fj").unwrap().as_f64().unwrap()));
        assert_eq!(
            row[5],
            format!("{:.2}", j.get("avg_power_w").unwrap().as_f64().unwrap())
        );
    }
}

#[test]
fn sweep_json_round_trips_and_matches_table() {
    let session = Session::new().unwrap();
    let outcome = session
        .sweep(&SweepRequest::builder().grid(Grid::smoke()).threads(2).build().unwrap())
        .unwrap();
    let doc = json::parse(&outcome.to_json()).expect("to_json output must parse");
    assert_eq!(doc.get("command").and_then(|v| v.as_str()), Some("dse"));
    assert_eq!(
        doc.get("valid_points").and_then(|v| v.as_usize()),
        Some(outcome.points.len())
    );
    let points = doc.get("points").and_then(|v| v.as_array()).unwrap();
    assert_eq!(points.len(), outcome.points.len());
    let table = outcome.to_table();
    for (row, j) in table.rows().iter().zip(points) {
        assert_eq!(row[1], format!("{}", j.get("n").unwrap().as_usize().unwrap()));
        assert_eq!(row[5], format!("{:.2}", j.get("peak_w").unwrap().as_f64().unwrap()));
        assert_eq!(row[6], format!("{:.2}", j.get("gops").unwrap().as_f64().unwrap()));
        assert_eq!(
            row[8],
            format!("{:.3e}", j.get("objective").unwrap().as_f64().unwrap())
        );
    }
    // optimum in JSON is the first point
    let opt = doc.get("optimum").unwrap();
    assert_eq!(
        opt.get("n").and_then(|v| v.as_usize()),
        Some(outcome.optimum().unwrap().n)
    );
}

#[test]
fn compare_json_round_trips_and_matches_tables() {
    let session = Session::new().unwrap();
    let outcome = session.compare();
    let doc = json::parse(&outcome.to_json()).expect("to_json output must parse");
    assert_eq!(doc.get("command").and_then(|v| v.as_str()), Some("compare"));
    let series = doc.get("series").and_then(|v| v.as_array()).unwrap();
    assert_eq!(series.len(), outcome.series.len());
    // PhotoGAN first, with null ratios
    assert_eq!(series[0].get("platform").and_then(|v| v.as_str()), Some("PhotoGAN"));
    assert_eq!(series[0].get("avg_gops_ratio"), Some(&json::JsonValue::Null));
    let tables = outcome.to_tables();
    assert_eq!(tables.len(), 2, "compare renders Fig. 13 + Fig. 14");
    for (i, j) in series.iter().enumerate().skip(1) {
        // JSON carries both the 8-model average and the Table-1-scoped
        // (paper-calibration) ratio; the rendered table prints the latter
        let ratio = j.get("avg_gops_ratio").and_then(|v| v.as_f64()).unwrap();
        assert_eq!(Some(ratio), outcome.avg_gops_ratio(i));
        let t1 = j.get("table1_gops_ratio").and_then(|v| v.as_f64()).unwrap();
        assert_eq!(Some(t1), outcome.table1_gops_ratio(i));
        let row = &tables[0].rows()[i];
        assert_eq!(row[row.len() - 2], format!("{t1:.2}"));
        assert!(ratio > 1.0, "PhotoGAN must win on GOPS");
        assert!(t1 > 1.0, "PhotoGAN must win on the Table 1 window too");
    }
}

// ------------------------------------------------------ CLI → API flow

#[test]
fn unknown_serve_model_is_rejected_before_submission() {
    // serve validation is feature-gated behind pjrt, but the same
    // resolution path is exercised by the session registry: an unknown
    // model never reaches the coordinator.
    let session = Session::new().unwrap();
    let err = session.model("not-a-gan").unwrap_err();
    assert!(matches!(err, ApiError::UnknownModel { .. }));
    assert_eq!(err.exit_code(), 2);
}

#[test]
fn overlap_requests_surface_resource_accounting() {
    let session = Session::new().unwrap();
    let analytic = session
        .simulate(&SimRequest::builder().model("dcgan").build().unwrap())
        .unwrap();
    let overlapped = session
        .simulate(
            &SimRequest::builder()
                .model("dcgan")
                .opts(OptFlags::overlapped())
                .build()
                .unwrap(),
        )
        .unwrap();
    let (a, o) = (&analytic.rows[0], &overlapped.rows[0]);
    assert!(o.latency_s < a.latency_s, "overlap must beat the analytical path");
    assert!(o.overlap_speedup() > 1.0);
    assert!((o.energy_j - a.energy_j).abs() <= 1e-9 * a.energy_j, "energy must not change");
    assert!(o.dominant_resource().is_some());

    // JSON carries the overlap flag and the per-resource accounting, and
    // the critical-path attribution sums to the reported latency
    let doc = json::parse(&overlapped.to_json()).expect("overlap JSON must parse");
    assert_eq!(
        doc.get("opts").and_then(|o| o.get("overlap")).and_then(|v| v.as_bool()),
        Some(true)
    );
    let row = &doc.get("results").and_then(|v| v.as_array()).unwrap()[0];
    let resources = row.get("resources").and_then(|v| v.as_array()).unwrap();
    assert_eq!(resources.len(), 8);
    let crit: f64 = resources
        .iter()
        .map(|r| r.get("critical_s").unwrap().as_f64().unwrap())
        .sum();
    let lat = row.get("latency_s").unwrap().as_f64().unwrap();
    assert!((crit - lat).abs() <= 1e-9 * lat, "Σ critical {crit} vs latency {lat}");

    // the overlap outcome renders the extra per-resource table
    assert_eq!(overlapped.to_tables().len(), 2);
    assert_eq!(analytic.to_tables().len(), 1);
}

#[test]
fn report_exhibits_share_one_cache() {
    use photogan::report;
    let session = Session::new().unwrap();
    let (_, per_model) = report::fig12(&session);
    assert_eq!(per_model.len(), 8);
    let after_fig12 = session.mapping_cache_entries();
    // Fig. 12 sweeps 5 opt-flag configs × 8 models = 40 distinct mappings
    assert_eq!(after_fig12, 40);
    let _ = session.compare();
    assert_eq!(
        session.mapping_cache_entries(),
        after_fig12,
        "compare() must reuse fig12's all-flags mappings"
    );
}
