//! Integration tests for the multi-shard serving layer: the sim-backed
//! executor end-to-end through `api::Session::serve`, routing-policy
//! distribution, typed backpressure, and batcher deadline dispatch.

use photogan::api::{ApiError, ServeBackend, ServeRequest, Session, SimExecutor};
use photogan::coordinator::server::{BatchExecutor, Server, ServerConfig, SubmitError};
use photogan::coordinator::{BatchPolicy, RoutingPolicy};
use photogan::sim::OptFlags;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Tiny deterministic stub serving two models.
struct TwoModels;

impl BatchExecutor for TwoModels {
    fn models(&self) -> Vec<String> {
        vec!["a".into(), "b".into()]
    }

    fn elements_per_sample(&self, _m: &str) -> usize {
        2
    }

    fn generate(&self, _m: &str, entries: &[(u64, Option<u32>)]) -> Vec<f32> {
        vec![1.0; entries.len() * 2]
    }
}

/// Stub whose generate call blocks long enough to hold capacity.
struct Slow;

impl BatchExecutor for Slow {
    fn models(&self) -> Vec<String> {
        vec!["slow".into()]
    }

    fn elements_per_sample(&self, _m: &str) -> usize {
        1
    }

    fn generate(&self, _m: &str, entries: &[(u64, Option<u32>)]) -> Vec<f32> {
        std::thread::sleep(Duration::from_millis(150));
        vec![0.0; entries.len()]
    }
}

// ------------------------------------------------ sim-backed serving e2e

#[test]
fn sim_backend_serves_end_to_end_without_artifacts() {
    let session = Arc::new(Session::new().unwrap());
    let req = ServeRequest::builder()
        .backend(ServeBackend::Sim)
        .model("condgan")
        .requests(32)
        .shards(4)
        .max_batch(8)
        .routing(RoutingPolicy::RoundRobin)
        .time_scale(0.0) // cost model only: keep the test fast
        .build()
        .unwrap();
    let outcome = Arc::clone(&session).serve(&req).unwrap();
    assert_eq!(outcome.backend, "sim");
    assert_eq!(outcome.model, "CondGAN", "name resolves case-insensitively");
    assert_eq!(outcome.total_requests, 32);
    assert_eq!(outcome.total_samples, 32);
    assert_eq!(outcome.shards, 4);
    assert_eq!(outcome.per_shard.len(), 4);
    assert!(outcome.throughput_img_s > 0.0);
    assert!(outcome.p50_ms <= outcome.p95_ms && outcome.p95_ms <= outcome.p99_ms);
    // the executor pulled its mappings through the *shared* session cache
    assert!(
        session.mapping_cache_entries() >= 1,
        "sim serving must populate the session mapping cache"
    );
    // JSON rendering carries the new serving dimensions
    let json = outcome.to_json();
    for key in ["\"backend\":\"sim\"", "\"shards\":4", "\"routing\":\"round-robin\"", "p99_ms"] {
        assert!(json.contains(key), "missing {key} in {json}");
    }
}

#[test]
fn sim_backend_unknown_model_is_typed_before_submission() {
    let session = Arc::new(Session::new().unwrap());
    let req = ServeRequest::builder().model("biggan").time_scale(0.0).build().unwrap();
    let err = session.serve(&req).unwrap_err();
    assert!(matches!(
        err,
        ApiError::UnknownModel { ref name, ref available }
            if name == "biggan" && available.len() == 8
    ));
}

#[test]
fn sim_backend_serves_every_zoo_model_by_name() {
    // the expanded --model set: every registered generator must be
    // servable end-to-end through the same driver
    let session = Arc::new(Session::new().unwrap());
    for name in ["srgan", "pix2pix", "stylegan2", "progan"] {
        let req = ServeRequest::builder()
            .model(name)
            .requests(4)
            .max_batch(4)
            .time_scale(0.0) // cost model only: keep the test fast
            .build()
            .unwrap();
        let outcome = Arc::clone(&session).serve(&req).unwrap();
        assert_eq!(outcome.total_requests, 4, "{name}");
        assert_eq!(outcome.total_samples, 4, "{name}");
        assert!(outcome.p99_ms >= outcome.p50_ms, "{name}");
    }
}

#[test]
fn mixed_model_load_routes_across_the_expanded_zoo() {
    // one shared server, requests interleaved across all eight models —
    // the serving smoke test for the 8-model registry
    let session = Arc::new(Session::new().unwrap());
    let exec =
        Arc::new(SimExecutor::with_options(Arc::clone(&session), OptFlags::all(), 0.0).unwrap());
    let names = exec.models();
    assert_eq!(names.len(), 8);
    let server = Server::start(
        Arc::clone(&exec),
        ServerConfig { shards: 2, routing: RoutingPolicy::ModelAffinity, ..Default::default() },
    );
    let mut rxs = Vec::new();
    for round in 0..2u64 {
        for (i, name) in names.iter().enumerate() {
            rxs.push((name.clone(), server.submit(name, round * 8 + i as u64, None, 1).unwrap()));
        }
    }
    for (name, rx) in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(
            resp.images.len(),
            exec.elements_per_sample(&name),
            "{name}: one full sample per request"
        );
    }
    let stats = server.shutdown();
    assert_eq!(stats.total_requests, 16);
    assert_eq!(stats.per_model.len(), 8, "every model must have been served");
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn pjrt_backend_without_feature_is_a_typed_error() {
    let session = Arc::new(Session::new().unwrap());
    let req = ServeRequest::builder().backend(ServeBackend::Pjrt).build().unwrap();
    let err = session.serve(&req).unwrap_err();
    assert!(matches!(err, ApiError::ArtifactError(ref msg) if msg.contains("--backend sim")));
}

#[test]
fn serve_driver_absorbs_backpressure_under_tiny_queue() {
    // queue_depth 2 with 8 requests: the driver must drain in-flight work
    // instead of failing, and still serve everything. Scale sim time so a
    // dispatched batch holds its capacity for ~20 ms — rejection of the
    // third submission is then deterministic, not a race.
    let session = Arc::new(Session::new().unwrap());
    let probe = SimExecutor::with_options(Arc::clone(&session), OptFlags::all(), 1.0).unwrap();
    let predicted = probe.batch_latency("CondGAN", 2).unwrap();
    assert!(predicted > 0.0);
    let req = ServeRequest::builder()
        .model("condgan")
        .requests(8)
        .queue_depth(2)
        .max_batch(2)
        .max_wait(Duration::from_micros(100))
        .time_scale(0.02 / predicted)
        .build()
        .unwrap();
    let outcome = Arc::clone(&session).serve(&req).unwrap();
    assert_eq!(outcome.total_requests, 8);
    assert!(outcome.rejections > 0, "a depth-2 queue must push back on 8 paced requests");
}

// ------------------------------------------------------- routing policies

#[test]
fn round_robin_distributes_uniformly() {
    let server = Server::start(
        Arc::new(TwoModels),
        ServerConfig { shards: 4, routing: RoutingPolicy::RoundRobin, ..Default::default() },
    );
    let rxs: Vec<_> = (0..20).map(|i| server.submit("a", i, None, 1).unwrap()).collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(5)).unwrap();
    }
    let stats = server.shutdown();
    for s in &stats.per_shard {
        assert_eq!(s.requests, 5, "round-robin must spread exactly: {stats:?}");
    }
}

#[test]
fn model_affinity_pins_each_model_to_one_shard() {
    let server = Server::start(
        Arc::new(TwoModels),
        ServerConfig { shards: 4, routing: RoutingPolicy::ModelAffinity, ..Default::default() },
    );
    let mut rxs = Vec::new();
    for i in 0..8 {
        rxs.push(server.submit("a", i, None, 1).unwrap());
        rxs.push(server.submit("b", i, None, 1).unwrap());
    }
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(5)).unwrap();
    }
    let stats = server.shutdown();
    // every model's 8 requests landed on exactly one shard
    for model in ["a", "b"] {
        let shards_hit: Vec<usize> = stats
            .per_shard
            .iter()
            .filter(|s| s.per_model.iter().any(|(m, _)| m == model))
            .map(|s| s.shard)
            .collect();
        assert_eq!(shards_hit.len(), 1, "model {model} hit shards {shards_hit:?}");
    }
    assert_eq!(stats.total_requests, 16);
}

#[test]
fn least_outstanding_steers_around_a_busy_shard() {
    let server = Server::start(
        Arc::new(Slow),
        ServerConfig {
            shards: 2,
            routing: RoutingPolicy::LeastOutstanding,
            workers: 1,
            policy: BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
            ..Default::default()
        },
    );
    // first submit reserves capacity on shard 0 (tie breaks low); the
    // second sees shard 0 loaded and must pick shard 1 — deterministic,
    // because outstanding counts move at submission time, not dispatch
    let rx0 = server.submit("slow", 0, None, 1).unwrap();
    let rx1 = server.submit("slow", 1, None, 1).unwrap();
    rx0.recv_timeout(Duration::from_secs(10)).unwrap();
    rx1.recv_timeout(Duration::from_secs(10)).unwrap();
    let stats = server.shutdown();
    assert_eq!(stats.per_shard.len(), 2);
    for s in &stats.per_shard {
        assert_eq!(s.requests, 1, "each shard must serve exactly one: {stats:?}");
    }
}

// --------------------------------------------------- typed backpressure

#[test]
fn queue_full_surfaces_as_typed_api_error() {
    let server = Server::start(
        Arc::new(Slow),
        ServerConfig {
            queue_depth: 1,
            workers: 1,
            policy: BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
            ..Default::default()
        },
    );
    let rx = server.submit("slow", 0, None, 1).unwrap();
    // capacity is held until the (slow) response is sent, so this is a
    // deterministic rejection
    let err = server.submit("slow", 1, None, 1).unwrap_err();
    assert_eq!(err, SubmitError::QueueFull { shard: 0, outstanding: 1, limit: 1 });
    let api: ApiError = err.into();
    assert_eq!(api, ApiError::Backpressure { shard: 0, outstanding: 1, limit: 1 });
    assert_eq!(api.exit_code(), 1);
    rx.recv_timeout(Duration::from_secs(10)).unwrap();
    server.shutdown();
}

#[test]
fn capacity_is_released_after_responses() {
    let server = Server::start(
        Arc::new(TwoModels),
        ServerConfig { queue_depth: 2, ..Default::default() },
    );
    for round in 0..5 {
        let a = server.submit("a", round, None, 1).unwrap();
        let b = server.submit("a", round + 100, None, 1).unwrap();
        a.recv_timeout(Duration::from_secs(5)).unwrap();
        b.recv_timeout(Duration::from_secs(5)).unwrap();
    }
    let stats = server.shutdown();
    assert_eq!(stats.total_requests, 10, "queue capacity must recycle");
}

// ------------------------------------------------- batcher deadline path

#[test]
fn batcher_force_dispatches_at_max_wait_deadline() {
    // max_batch 1000 can never fill from one request: only the max_wait
    // deadline (not shutdown) can dispatch it
    let server = Server::start(
        Arc::new(TwoModels),
        ServerConfig {
            policy: BatchPolicy { max_batch: 1000, max_wait: Duration::from_millis(30) },
            workers: 1,
            ..Default::default()
        },
    );
    let t0 = Instant::now();
    let rx = server.submit("a", 0, None, 1).unwrap();
    let resp = rx
        .recv_timeout(Duration::from_secs(5))
        .expect("deadline must force dispatch without more arrivals");
    let waited = t0.elapsed();
    assert!(
        waited >= Duration::from_millis(25),
        "served before the batching window elapsed: {waited:?}"
    );
    assert_eq!(resp.served_batch, 1);
    server.shutdown();
}

// ------------------------------------------- executor timing accuracy

#[test]
fn sim_executor_latency_tracks_the_simulator() {
    // with time_scale > 0 the measured wall time of a generate call must
    // be at least the simulator-predicted latency (scaled)
    let session = Arc::new(Session::new().unwrap());
    let exec =
        SimExecutor::with_options(Arc::clone(&session), OptFlags::all(), 50.0).unwrap();
    let predicted = exec.batch_latency("CondGAN", 4).unwrap();
    let t0 = Instant::now();
    let out = exec.generate("CondGAN", &[(0, None), (1, None), (2, None), (3, None)]);
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(out.len(), 4 * 784);
    assert!(
        wall >= predicted * 50.0,
        "generate must pace at the scaled sim latency (wall {wall}, predicted {predicted})"
    );
}
