//! Integration tests for the declarative scenario layer: JSON round-trip
//! fixpoint, per-field typed error paths, preset equivalence with the
//! direct Session API, and seed-determinism of full scenario runs
//! (`parse → plan → run → to_json` must be byte-identical for one seed).

use photogan::api::scenario::{
    AutoscalePolicyKind, AutoscaleSpec, CalibrationSpec, CompareStage, FailureSpec, FleetGroup,
    Scenario, ServeEngine, ServeStage, SimStage, StageSpec,
};
use photogan::api::{ApiError, Outcome, Session, SimRequest};
use photogan::sim::OptFlags;
use photogan::util::json;
use photogan::workload::ArrivalProcess;
use std::sync::Arc;

/// A representative scenario exercising every stage knob the acceptance
/// cell needs: a multi-model simulate stage with SLOs and a multi-shard
/// Poisson-mix virtual serve stage.
const MIXED: &str = r#"{
  "name": "mixed",
  "seed": 9,
  "stages": [
    {
      "kind": "simulate",
      "name": "sim",
      "models": ["dcgan", "srgan", "stylegan2"],
      "batch": 2,
      "opts": "all",
      "slo": { "max_latency_ms": 1e9 }
    },
    {
      "kind": "serve",
      "name": "fleet",
      "engine": "virtual",
      "mix": [
        { "model": "dcgan", "weight": 3.0 },
        { "model": "srgan", "weight": 1.0 },
        { "model": "stylegan2", "weight": 1.0 }
      ],
      "arrival": { "process": "poisson", "rate_hz": 800.0, "duration_s": 0.05 },
      "shards": 2,
      "workers": 2,
      "max_batch": 8,
      "max_wait_ms": 0.5,
      "queue_depth": 64,
      "routing": "least-outstanding",
      "slo": { "p99_ms": 1e9, "max_reject_frac": 1.0 }
    }
  ]
}"#;

fn session() -> Arc<Session> {
    Arc::new(Session::new().expect("session"))
}

#[test]
fn parse_plan_to_json_parse_is_a_fixpoint() {
    let scenario = Scenario::from_json(MIXED).expect("parse");
    let session = session();
    session.plan(&scenario).expect("plan must accept the canonical example");
    // parse → to_json → parse is the identity on the IR
    let rendered = scenario.to_json();
    let reparsed = Scenario::from_json(&rendered).expect("reparse");
    assert_eq!(reparsed, scenario, "IR round-trip must be lossless");
    // and the rendering itself is a fixpoint
    assert_eq!(reparsed.to_json(), rendered, "canonical rendering must be stable");
    // the rendered document is valid JSON for any consumer
    json::parse(&rendered).expect("canonical scenario JSON parses");
}

#[test]
fn unknown_model_is_typed_at_plan_time() {
    let text = MIXED.replace("\"dcgan\"", "\"notagan\"");
    let scenario = Scenario::from_json(&text).expect("parse");
    let err = session().plan(&scenario).unwrap_err();
    assert!(
        matches!(err, ApiError::UnknownModel { ref name, .. } if name == "notagan"),
        "{err:?}"
    );
}

#[test]
fn non_positive_mix_weight_names_the_field() {
    for bad in ["0.0", "-2.5"] {
        let text = MIXED.replace("\"weight\": 1.0", &format!("\"weight\": {bad}"));
        let scenario = Scenario::from_json(&text).expect("parse");
        let err = session().plan(&scenario).unwrap_err();
        assert!(
            matches!(err, ApiError::InvalidMixWeight { ref field, ref model, .. }
                if field == "stages[1].mix[1].weight" && model == "srgan"),
            "{bad}: {err:?}"
        );
    }
}

#[test]
fn zero_duration_stage_names_the_field() {
    let text = MIXED.replace("\"duration_s\": 0.05", "\"duration_s\": 0.0");
    let scenario = Scenario::from_json(&text).expect("parse");
    let err = session().plan(&scenario).unwrap_err();
    assert!(
        matches!(err, ApiError::InvalidDuration { ref field, seconds }
            if field == "stages[1].arrival.duration_s" && seconds == 0.0),
        "{err:?}"
    );
}

#[test]
fn nan_rate_names_the_field() {
    // JSON cannot carry NaN, so build the IR directly — the plan-time
    // check is what guards programmatic construction too
    let mut scenario = Scenario::from_json(MIXED).expect("parse");
    if let StageSpec::Serve(serve) = &mut scenario.stages[1] {
        serve.arrival =
            Some(ArrivalProcess::Poisson { rate_hz: f64::NAN, duration_s: 0.05 });
    } else {
        panic!("stage 1 must be the serve stage");
    }
    let err = session().plan(&scenario).unwrap_err();
    assert!(
        matches!(err, ApiError::InvalidRate { ref field, rate }
            if field == "stages[1].arrival.rate_hz" && rate.is_nan()),
        "{err:?}"
    );
    // a negative rate in the document itself takes the same path
    let text = MIXED.replace("\"rate_hz\": 800.0", "\"rate_hz\": -1.0");
    let err = session().plan(&Scenario::from_json(&text).expect("parse")).unwrap_err();
    assert!(
        matches!(err, ApiError::InvalidRate { ref field, rate }
            if field == "stages[1].arrival.rate_hz" && rate == -1.0),
        "{err:?}"
    );
}

#[test]
fn same_seed_means_byte_identical_json() {
    let scenario = Scenario::from_json(MIXED).expect("parse");
    let run_once = || {
        let session = session();
        let plan = session.plan(&scenario).expect("plan");
        session.run(&plan).expect("run").to_json()
    };
    let (a, b) = (run_once(), run_once());
    assert_eq!(a, b, "virtual scenarios must be byte-deterministic per seed");
    // a different seed produces different traffic (and different bytes)
    let mut reseeded = scenario.clone();
    reseeded.seed = 10;
    let session = session();
    let plan = session.plan(&reseeded).expect("plan");
    let c = session.run(&plan).expect("run").to_json();
    assert_ne!(a, c, "the seed must actually steer the workload");
}

#[test]
fn envelope_carries_per_stage_slo_verdicts() {
    let scenario = Scenario::from_json(MIXED).expect("parse");
    let session = session();
    let plan = session.plan(&scenario).expect("plan");
    let outcome = session.run(&plan).expect("run");
    assert_eq!(outcome.scenario, "mixed");
    assert_eq!(outcome.seed, 9);
    assert_eq!(outcome.stages.len(), 2);
    assert_eq!(outcome.stages[0].kind, "simulate");
    assert_eq!(outcome.stages[1].kind, "serve");
    // generous SLOs: both stages must pass, with real checks evaluated
    assert!(!outcome.stages[0].slo.checks.is_empty());
    assert!(!outcome.stages[1].slo.checks.is_empty());
    assert!(outcome.slo_pass(), "{:?}", outcome.to_table().render());

    // the envelope is one parseable JSON document with per-stage verdicts
    let doc = json::parse(&outcome.to_json()).expect("envelope parses");
    assert_eq!(doc.get("command").and_then(|v| v.as_str()), Some("run"));
    assert_eq!(doc.get("slo_pass").and_then(|v| v.as_bool()), Some(true));
    let stages = doc.get("stages").and_then(|v| v.as_array()).expect("stages");
    assert_eq!(stages.len(), 2);
    for stage in stages {
        let slo = stage.get("slo").expect("per-stage slo verdict");
        assert!(slo.get("pass").and_then(|v| v.as_bool()).is_some());
        assert!(stage.get("outcome").is_some());
    }
    // the serve stage outcome is the deterministic virtual engine
    assert_eq!(
        stages[1]
            .get("outcome")
            .and_then(|o| o.get("engine"))
            .and_then(|v| v.as_str()),
        Some("virtual")
    );
    let admitted = stages[1]
        .get("outcome")
        .and_then(|o| o.get("admitted"))
        .and_then(|v| v.as_f64())
        .expect("admitted");
    assert!(admitted > 0.0, "the fleet must actually serve traffic");
}

#[test]
fn failing_slo_yields_a_fail_verdict_not_an_error() {
    let text = MIXED.replace("\"p99_ms\": 1e9", "\"p99_ms\": 1e-9");
    let scenario = Scenario::from_json(&text).expect("parse");
    let session = session();
    let plan = session.plan(&scenario).expect("plan");
    let outcome = session.run(&plan).expect("an SLO miss is a verdict, not a failure");
    assert!(!outcome.slo_pass());
    assert!(!outcome.stages[1].slo.pass);
    assert!(outcome.to_json().contains("\"slo_pass\":false"));
}

#[test]
fn simulate_preset_matches_the_direct_api() {
    let session = session();
    // preset path
    let stage = SimStage {
        models: vec!["dcgan".into()],
        batch: 4,
        opts: OptFlags::all(),
        ..SimStage::default()
    };
    let plan = session
        .plan(&Scenario::single("preset", StageSpec::Simulate(stage)))
        .expect("plan");
    let outcome = Arc::clone(&session).run(&plan).expect("run");
    let Some(Outcome::Sim(via_scenario)) = outcome.stages.first().map(|s| &s.outcome) else {
        panic!("expected a sim outcome");
    };
    // direct path
    let req = SimRequest::builder().model("dcgan").batch(4).build().expect("req");
    let direct = session.simulate(&req).expect("simulate");
    assert_eq!(via_scenario.to_json(), direct.to_json(), "presets must not fork behavior");
}

#[test]
fn compare_preset_runs_and_renders() {
    let session = session();
    let plan = session
        .plan(&Scenario::single("cmp", StageSpec::Compare(CompareStage::default())))
        .expect("plan");
    let outcome = session.run(&plan).expect("run");
    assert!(matches!(outcome.stages[0].outcome, Outcome::Compare(_)));
    assert!(outcome.stages[0].slo.pass, "no SLO → vacuous pass");
    assert!(outcome.to_json().contains("\"command\":\"run\""));
}

#[test]
fn threaded_serve_stage_rejects_virtual_only_members() {
    let session = session();
    let mut stage = ServeStage {
        engine: ServeEngine::Threaded,
        mix: vec![("dcgan".into(), 1.0)],
        ..ServeStage::default()
    };
    let err = session
        .plan(&Scenario::single("bad", StageSpec::Serve(stage.clone())))
        .unwrap_err();
    assert!(
        matches!(err, ApiError::ScenarioParse { ref field, .. } if field == "stages[0].mix"),
        "{err:?}"
    );
    stage.mix.clear();
    stage.arrival = Some(ArrivalProcess::ClosedLoop { clients: 1, per_client: 1 });
    let err = session
        .plan(&Scenario::single("bad", StageSpec::Serve(stage)))
        .unwrap_err();
    assert!(
        matches!(err, ApiError::ScenarioParse { ref field, .. }
            if field == "stages[0].arrival"),
        "{err:?}"
    );
}

#[test]
fn threaded_serve_stage_runs_the_real_coordinator() {
    let session = session();
    let stage = ServeStage {
        engine: ServeEngine::Threaded,
        model: Some("condgan".into()),
        requests: 8,
        shards: 2,
        time_scale: 0.0, // cost model only — no wall-clock pacing in tests
        ..ServeStage::default()
    };
    let plan = session
        .plan(&Scenario::single("threaded", StageSpec::Serve(stage)))
        .expect("plan");
    let outcome = session.run(&plan).expect("run");
    let Some(Outcome::Serve(served)) = outcome.stages.first().map(|s| &s.outcome) else {
        panic!("expected a threaded serve outcome");
    };
    assert_eq!(served.total_requests, 8);
    assert_eq!(served.shards, 2);
    assert_eq!(served.backend, "sim");
}

#[test]
fn virtual_serve_requires_mix_and_arrival() {
    let session = session();
    let no_mix = ServeStage::default();
    let err = session
        .plan(&Scenario::single("bad", StageSpec::Serve(no_mix)))
        .unwrap_err();
    assert!(
        matches!(err, ApiError::ScenarioParse { ref field, .. } if field == "stages[0].mix"),
        "{err:?}"
    );
    let no_arrival = ServeStage { mix: vec![("dcgan".into(), 1.0)], ..ServeStage::default() };
    let err = session
        .plan(&Scenario::single("bad", StageSpec::Serve(no_arrival)))
        .unwrap_err();
    assert!(
        matches!(err, ApiError::ScenarioParse { ref field, .. }
            if field == "stages[0].arrival"),
        "{err:?}"
    );
}

#[test]
fn checked_in_starter_scenarios_plan_and_run() {
    for (file, min_stages) in [
        ("mixed_zoo.json", 2usize),
        ("closed_loop_burst.json", 2usize),
        ("noisy_fleet.json", 1usize),
        ("fleet_diurnal.json", 1usize),
    ] {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("examples/scenarios")
            .join(file);
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
        let scenario = Scenario::from_json(&text).expect(file);
        assert!(scenario.stages.len() >= min_stages, "{file}");
        let session = session();
        let plan = session.plan(&scenario).expect(file);
        let outcome = Arc::clone(&session).run(&plan).expect(file);
        // deterministic: a second full run is byte-identical
        let again = session.run(&plan).expect(file);
        assert_eq!(outcome.to_json(), again.to_json(), "{file} must be deterministic");
    }
}

#[test]
fn calibration_outages_shape_availability_and_the_envelope() {
    // the same fleet with and without the calibration process model: the
    // outages must be visible in availability, downtime, and the JSON
    let scenario = Scenario::from_json(MIXED).expect("parse");
    let mut calibrated = scenario.clone();
    let StageSpec::Serve(serve) = &mut calibrated.stages[1] else {
        panic!("stage 1 must be the serve stage");
    };
    // 50 ms of traffic with a 5 ms re-lock cadence: ~9 outages per shard
    serve.calibration = Some(CalibrationSpec { interval_ms: 5.0, outage_ms: 1.0 });

    let session = session();
    let run = |s: &Scenario| {
        let plan = session.plan(s).expect("plan");
        session.run(&plan).expect("run")
    };
    let baseline = run(&scenario);
    let noisy = run(&calibrated);

    let workload = |o: &photogan::api::ScenarioOutcome| match &o.stages[1].outcome {
        Outcome::Workload(w) => w.clone(),
        other => panic!("expected a virtual serve outcome, got {other:?}"),
    };
    let (base, cal) = (workload(&baseline), workload(&noisy));
    assert_eq!(base.outages, 0, "no calibration knob → no outages");
    assert_eq!(base.availability, 1.0);
    assert!(cal.outages > 0, "the re-lock cadence must actually fire");
    assert!(cal.downtime_s > 0.0);
    assert!(cal.availability < 1.0, "downtime must dent availability");
    assert!(cal.availability > 0.0, "but outages are brief, not total");
    // same seed, same traffic: any envelope difference is the outage model
    assert_ne!(
        noisy.to_json(),
        baseline.to_json(),
        "the calibration knob must measurably move the serve envelope"
    );
    assert!(noisy.to_json().contains("\"availability\""));
    assert!(noisy.to_json().contains("\"outages\""));
}

#[test]
fn threaded_serve_stage_rejects_the_calibration_knob() {
    let stage = ServeStage {
        engine: ServeEngine::Threaded,
        model: Some("dcgan".into()),
        requests: 1,
        time_scale: 0.0,
        calibration: Some(CalibrationSpec { interval_ms: 10.0, outage_ms: 1.0 }),
        ..ServeStage::default()
    };
    let err = session()
        .plan(&Scenario::single("bad", StageSpec::Serve(stage)))
        .unwrap_err();
    assert!(
        matches!(err, ApiError::ScenarioParse { ref field, .. }
            if field == "stages[0].calibration"),
        "{err:?}"
    );
}

/// A heterogeneous fleet under failures and autoscaling — the fleet-scale
/// serve stage exercised end to end through `parse → plan → run`.
const FLEET: &str = r#"{
  "name": "fleet",
  "seed": 21,
  "stages": [
    {
      "kind": "serve",
      "name": "het",
      "mix": [ { "model": "dcgan", "weight": 2.0 }, { "model": "srgan", "weight": 1.0 } ],
      "arrival": { "process": "poisson", "rate_hz": 2000.0, "duration_s": 0.05 },
      "workers": 2,
      "max_batch": 8,
      "max_wait_ms": 0.2,
      "queue_depth": 128,
      "routing": "least-outstanding",
      "fleet": [
        { "platform": "photonic", "count": 2, "cost_per_hour": 3.0 },
        { "platform": "gpu", "count": 1, "workers": 4, "idle_w": 80.0, "cost_per_hour": 4.0 }
      ],
      "failures": { "mtbf_ms": 10.0, "mttr_ms": 2.0 }
    }
  ]
}"#;

#[test]
fn heterogeneous_fleet_surfaces_energy_cost_and_failures() {
    let scenario = Scenario::from_json(FLEET).expect("parse");
    // the fleet members survive the canonical-JSON fixpoint
    let rendered = scenario.to_json();
    assert_eq!(Scenario::from_json(&rendered).expect("reparse"), scenario);
    let session = session();
    let plan = session.plan(&scenario).expect("plan");
    let outcome = Arc::clone(&session).run(&plan).expect("run");
    let Outcome::Workload(w) = &outcome.stages[0].outcome else {
        panic!("expected a virtual serve outcome");
    };
    assert_eq!(w.shards, 3, "fleet groups expand to 2 photonic + 1 gpu shards");
    assert_eq!(w.classes, vec!["photonic".to_string(), "GPU (A100)".to_string()]);
    assert!(w.admitted > 0, "{w:?}");
    assert!(w.energy_j > 0.0, "batch energy + idle draw must accumulate: {w:?}");
    assert!(w.cost > 0.0, "billing rates must accumulate: {w:?}");
    assert!(w.failures > 0, "a 10 ms MTBF over 50 ms of traffic must fire: {w:?}");
    assert!(w.downtime_s > 0.0 && w.availability < 1.0, "{w:?}");
    assert_eq!(w.per_shard.len(), 3);
    assert_eq!(w.per_shard[0].class, 0);
    assert_eq!(w.per_shard[2].class, 1);
    // the envelope carries the new accounting
    let json = outcome.to_json();
    for key in ["\"energy_j\"", "\"cost\"", "\"failures\"", "\"classes\"", "\"class\""] {
        assert!(json.contains(key), "missing {key}");
    }
    // and it stays byte-deterministic
    let again = session.run(&plan).expect("run");
    assert_eq!(json, again.to_json());
}

#[test]
fn unknown_fleet_platform_is_typed_at_plan_time() {
    let text = FLEET.replace("\"platform\": \"gpu\"", "\"platform\": \"quantum\"");
    let scenario = Scenario::from_json(&text).expect("parse");
    let err = session().plan(&scenario).unwrap_err();
    assert!(
        matches!(err, ApiError::UnknownPlatform { ref field, ref name }
            if field == "stages[0].fleet[1].platform" && name == "quantum"),
        "{err:?}"
    );
}

#[test]
fn autoscale_bounds_are_checked_against_the_fleet() {
    let mut scenario = Scenario::from_json(FLEET).expect("parse");
    let StageSpec::Serve(serve) = &mut scenario.stages[0] else {
        panic!("stage 0 must serve");
    };
    // the fleet has 3 shards; asking for 5 is a typed plan error
    serve.autoscale = Some(AutoscaleSpec {
        policy: AutoscalePolicyKind::QueueDepth { high: 16, low: 2 },
        min_shards: 1,
        max_shards: 5,
        initial: None,
        interval_ms: 10.0,
    });
    let err = session().plan(&scenario).unwrap_err();
    assert!(
        matches!(err, ApiError::ScenarioParse { ref field, .. }
            if field == "stages[0].autoscale.max_shards"),
        "{err:?}"
    );
    // watermarks must be ordered
    let StageSpec::Serve(serve) = &mut scenario.stages[0] else {
        panic!("stage 0 must serve");
    };
    serve.autoscale = Some(AutoscaleSpec {
        policy: AutoscalePolicyKind::QueueDepth { high: 4, low: 4 },
        min_shards: 1,
        max_shards: 3,
        initial: None,
        interval_ms: 10.0,
    });
    let err = session().plan(&scenario).unwrap_err();
    assert!(
        matches!(err, ApiError::ScenarioParse { ref field, .. }
            if field == "stages[0].autoscale.low"),
        "{err:?}"
    );
}

#[test]
fn threaded_serve_stage_rejects_fleet_failures_and_autoscale() {
    let base = ServeStage {
        engine: ServeEngine::Threaded,
        model: Some("dcgan".into()),
        requests: 1,
        time_scale: 0.0,
        ..ServeStage::default()
    };
    let cases: Vec<(ServeStage, &str)> = vec![
        (
            ServeStage {
                fleet: vec![FleetGroup {
                    platform: "gpu".into(),
                    count: 1,
                    workers: None,
                    idle_w: 0.0,
                    cost_per_hour: 0.0,
                }],
                ..base.clone()
            },
            "stages[0].fleet",
        ),
        (
            ServeStage {
                failures: Some(FailureSpec { mtbf_ms: 10.0, mttr_ms: 1.0 }),
                ..base.clone()
            },
            "stages[0].failures",
        ),
        (
            ServeStage {
                autoscale: Some(AutoscaleSpec {
                    policy: AutoscalePolicyKind::TargetUtilization { target: 0.7 },
                    min_shards: 1,
                    max_shards: 1,
                    initial: None,
                    interval_ms: 10.0,
                }),
                ..base.clone()
            },
            "stages[0].autoscale",
        ),
    ];
    for (stage, field) in cases {
        let err = session()
            .plan(&Scenario::single("bad", StageSpec::Serve(stage)))
            .unwrap_err();
        assert!(
            matches!(err, ApiError::ScenarioParse { field: ref f, .. } if f == field),
            "{field}: {err:?}"
        );
    }
}

#[test]
fn all_shed_stage_reports_zero_mean_batch_not_nan() {
    // a deadline no batch can meet: every closed-loop request is shed at
    // admission, the makespan is zero, and the zero-batch / zero-makespan
    // guards must keep the envelope finite (regression: mean_batch was
    // 0/0 = NaN, availability 1 - x/0 = -inf)
    let text = r#"{
      "name": "all-shed",
      "seed": 5,
      "stages": [
        {
          "kind": "serve",
          "name": "impossible",
          "mix": [ { "model": "dcgan", "weight": 1.0 } ],
          "arrival": { "process": "closed-loop", "clients": 3, "per_client": 5 },
          "shards": 2,
          "deadline_ms": 1e-6
        }
      ]
    }"#;
    let scenario = Scenario::from_json(text).expect("parse");
    let session = session();
    let plan = session.plan(&scenario).expect("plan");
    let outcome = session.run(&plan).expect("run");
    let Outcome::Workload(w) = &outcome.stages[0].outcome else {
        panic!("expected a virtual serve outcome");
    };
    assert_eq!(w.admitted, 0);
    assert_eq!(w.shed, 15, "every request is shed exactly once");
    assert_eq!(w.batches, 0);
    assert_eq!(w.mean_batch, 0.0, "zero batches must report 0.0, not NaN");
    assert_eq!(w.makespan_s, 0.0);
    assert_eq!(w.throughput_rps, 0.0);
    assert_eq!(w.availability, 1.0, "a zero makespan means no downtime");
    let json = outcome.to_json();
    assert!(
        !json.contains("null"),
        "no NaN/inf may leak into the envelope: {json}"
    );
}

#[test]
fn mixed_zoo_meets_the_acceptance_shape() {
    // the acceptance cell: ≥2 stages, one sim/compare stage, one
    // multi-shard Poisson-mix serve stage over ≥3 zoo models, with
    // per-stage SLO verdicts in one envelope
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("examples/scenarios/mixed_zoo.json");
    let text = std::fs::read_to_string(path).expect("mixed_zoo.json");
    let scenario = Scenario::from_json(&text).expect("parse");
    assert!(scenario.stages.len() >= 2);
    assert!(matches!(scenario.stages[0], StageSpec::Simulate(_)));
    let StageSpec::Serve(serve) = &scenario.stages[1] else {
        panic!("stage 1 must serve");
    };
    assert!(serve.shards >= 2, "multi-shard");
    assert!(serve.mix.len() >= 3, "mix over >= 3 zoo models");
    assert!(matches!(serve.arrival, Some(ArrivalProcess::Poisson { .. })));

    let session = session();
    let plan = session.plan(&scenario).expect("plan");
    let outcome = session.run(&plan).expect("run");
    let doc = json::parse(&outcome.to_json()).expect("envelope");
    let stages = doc.get("stages").and_then(|v| v.as_array()).expect("stages");
    assert!(stages.len() >= 2);
    for stage in stages {
        assert!(stage.get("slo").is_some(), "per-stage SLO verdict required");
    }
}
