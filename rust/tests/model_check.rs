//! Model-check suites for the lock-free serving core.
//!
//! Every test here runs a small protocol (2–4 model threads) under the
//! in-tree systematic scheduler (`photogan::util::check`) and asserts an
//! invariant over *all* explored interleavings — bounded CHESS-style, so
//! the whole file stays inside the tier-1 time budget. The invariants
//! mirror ARCHITECTURE.md §Concurrency invariants:
//!
//! - `completion()` has no lost wake-up (send-vs-wait, drop-vs-wait);
//! - `CapacityGuard` releases exactly once on every exit path, including
//!   panic unwind, under admission races;
//! - `JobQueue` push/drain/close conserve every value (the scheduler's
//!   node ledger additionally fails any schedule that leaks or
//!   double-frees a node), keep per-producer FIFO order, and never admit
//!   after close;
//! - the async core's park/notify refill protocol (re-check the queue
//!   under the lock before sleeping) cannot miss a wake-up.
//!
//! The `deliberately_*` tests seed a bug — a dropped condvar notify —
//! and assert the checker catches it with a token that `replay` turns
//! back into the same failure: the meta-test that the tool works.
//!
//! Budgets: `CheckOpts::default()` explores up to 2 000 schedules at
//! preemption bound 2 (milliseconds to low seconds per test). The
//! `#[ignore]`d exhaustive cell raises both; CI's checker job recompiles
//! with `--cfg model_check` and runs `--include-ignored` (see
//! EXPERIMENTS.md §CHECK).

use photogan::coordinator::completion::{completion, CapacityGuard};
use photogan::coordinator::queue::JobQueue;
use photogan::util::check::sync::{Arc, AtomicUsize, Condvar, Mutex, Ordering};
use photogan::util::check::{model, parse_token, replay, thread, CheckOpts, CheckOutcome, QuietPanic};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::PoisonError;

// ------------------------------------------------------------ completion

#[test]
fn completion_send_vs_wait_has_no_lost_wakeup() {
    // A lost notify would leave the waiter parked with the sender
    // finished: no runnable thread, no timed waiter — the scheduler
    // reports it as a deadlock, so `assert_pass` proves its absence.
    let outcome = model(CheckOpts::default(), || {
        let (tx, rx) = completion::<u32>();
        let t = thread::spawn(move || tx.send(7));
        assert_eq!(rx.wait(), Some(7), "completion value lost");
        t.join().unwrap();
    });
    outcome.assert_pass();
    assert!(outcome.schedules() >= 2, "send-vs-wait must explore both orders");
}

#[test]
fn completion_dropped_sender_wakes_with_none() {
    let outcome = model(CheckOpts::default(), || {
        let (tx, rx) = completion::<u32>();
        let t = thread::spawn(move || drop(tx));
        assert_eq!(rx.wait(), None, "dropped sender must wake the waiter with None");
        t.join().unwrap();
    });
    outcome.assert_pass();
}

#[test]
fn completion_is_ready_probe_never_wedges_the_wait() {
    // The probe takes and releases the slot lock mid-protocol; under no
    // interleaving may it corrupt the state machine or strand the wait
    // (either probe answer is consistent — readiness is terminal).
    let outcome = model(CheckOpts::default(), || {
        let (tx, rx) = completion::<u32>();
        let t = thread::spawn(move || tx.send(1));
        let _ = rx.is_ready();
        assert_eq!(rx.wait(), Some(1));
        t.join().unwrap();
    });
    outcome.assert_pass();
}

// --------------------------------------------------------- CapacityGuard

#[test]
fn capacity_guard_admission_race_releases_exactly_once() {
    // Two threads race one admission slot (limit 1). Under every
    // interleaving at least one wins, the counter never wedges, and all
    // reservations come back.
    let outcome = model(CheckOpts::default(), || {
        let counter = Arc::new(AtomicUsize::new(0));
        let wins = Arc::new(AtomicUsize::new(0));
        let (c2, w2) = (Arc::clone(&counter), Arc::clone(&wins));
        let t = thread::spawn(move || {
            if let Ok(mut g) = CapacityGuard::reserve(&c2, 1, 1) {
                w2.fetch_add(1, Ordering::SeqCst);
                g.release();
            }
        });
        if let Ok(mut g) = CapacityGuard::reserve(&counter, 1, 1) {
            wins.fetch_add(1, Ordering::SeqCst);
            g.release();
        }
        t.join().unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 0, "capacity must return to zero");
        assert!(wins.load(Ordering::SeqCst) >= 1, "the slot must admit someone");
    });
    outcome.assert_pass();
}

#[test]
fn capacity_guard_releases_on_panic_unwind_under_races() {
    // One thread's reservation unwinds out through a panic (the async
    // worker's failure path) while another reserves concurrently: every
    // exit path — explicit release and Drop-during-unwind — must give
    // the slots back exactly once under every interleaving.
    let outcome = model(CheckOpts::default(), || {
        let counter = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&counter);
        let t = thread::spawn(move || {
            let unwound = catch_unwind(AssertUnwindSafe(|| {
                let _g = CapacityGuard::reserve(&c2, 1, 2);
                std::panic::panic_any(QuietPanic("executor blew up mid-batch"));
            }));
            assert!(unwound.is_err());
        });
        if let Ok(mut g) = CapacityGuard::reserve(&counter, 1, 2) {
            g.release();
        }
        t.join().unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 0, "panic unwind must release");
    });
    outcome.assert_pass();
}

// --------------------------------------------------------------- JobQueue

#[test]
fn queue_push_drain_race_conserves_values() {
    // Two producers race a drain; the scheduler's node ledger fails any
    // schedule that leaks or double-frees a node, and the value check
    // proves each item surfaces exactly once.
    let outcome = model(CheckOpts::default(), || {
        let q = Arc::new(JobQueue::new());
        let (qa, qb) = (Arc::clone(&q), Arc::clone(&q));
        let ta = thread::spawn(move || qa.push(1u32).unwrap());
        let tb = thread::spawn(move || qb.push(2u32).unwrap());
        let mut got = q.drain(); // races both pushes
        ta.join().unwrap();
        tb.join().unwrap();
        got.extend(q.drain());
        got.sort_unstable();
        assert_eq!(got, vec![1, 2], "each pushed value must surface exactly once");
    });
    outcome.assert_pass();
}

#[test]
fn queue_per_producer_fifo_survives_arbitrary_preemption() {
    let outcome = model(CheckOpts::default(), || {
        let q = Arc::new(JobQueue::new());
        let (qa, qb) = (Arc::clone(&q), Arc::clone(&q));
        let ta = thread::spawn(move || {
            qa.push((0u8, 0u8)).unwrap();
            qa.push((0, 1)).unwrap();
        });
        let tb = thread::spawn(move || {
            qb.push((1u8, 0u8)).unwrap();
            qb.push((1, 1)).unwrap();
        });
        let mut got = q.drain(); // races the producers mid-stream
        ta.join().unwrap();
        tb.join().unwrap();
        got.extend(q.drain());
        assert_eq!(got.len(), 4);
        for p in 0..2u8 {
            let order: Vec<u8> =
                got.iter().filter(|(pp, _)| *pp == p).map(|(_, i)| *i).collect();
            assert_eq!(order, vec![0, 1], "producer {p} FIFO violated");
        }
    });
    outcome.assert_pass();
}

#[test]
fn queue_never_admits_after_close() {
    // Close-vs-push race: whatever the interleaving, an admitted value
    // comes back to the closer and a bounced value never reappears.
    let outcome = model(CheckOpts::default(), || {
        let q = Arc::new(JobQueue::new());
        let q2 = Arc::clone(&q);
        let t = thread::spawn(move || q2.push(7u32).is_ok());
        let leftovers = q.close();
        let admitted = t.join().unwrap();
        assert!(q.is_closed());
        assert!(q.drain().is_empty(), "post-close drain must be empty");
        assert_eq!(q.push(9), Err(9), "push after close must bounce");
        if admitted {
            assert_eq!(leftovers, vec![7], "admitted value must reach the closer");
        } else {
            assert!(leftovers.is_empty(), "bounced value must not reappear");
        }
    });
    outcome.assert_pass();
}

#[test]
fn queue_drain_vs_close_hands_each_value_to_exactly_one_side() {
    let outcome = model(CheckOpts::default(), || {
        let q = Arc::new(JobQueue::new());
        q.push(1u32).unwrap();
        q.push(2u32).unwrap();
        let q2 = Arc::clone(&q);
        let t = thread::spawn(move || q2.drain());
        let leftovers = q.close();
        let drained = t.join().unwrap();
        // take-all semantics: the chain detaches atomically, so one side
        // gets both values in FIFO order and the other gets none
        let mut all = drained.clone();
        all.extend(leftovers.iter().copied());
        all.sort_unstable();
        assert_eq!(all, vec![1, 2], "each value exactly once across drain and close");
        assert!(drained.is_empty() || drained == vec![1, 2]);
        assert!(leftovers.is_empty() || leftovers == vec![1, 2]);
        assert!(q.drain().is_empty());
    });
    outcome.assert_pass();
}

#[test]
fn queue_drop_with_unconsumed_nodes_satisfies_the_ledger() {
    // No explicit assertion needed beyond pass: dropping the queue with
    // live nodes must free each exactly once or the ledger fails the
    // schedule (leak at quiescence / double free at reclaim).
    let outcome = model(CheckOpts::default(), || {
        let q = Arc::new(JobQueue::new());
        let q2 = Arc::clone(&q);
        let t = thread::spawn(move || q2.push(1u32).unwrap());
        q.push(2u32).unwrap();
        t.join().unwrap();
        drop(q); // both nodes reclaimed by Drop, never drained
    });
    outcome.assert_pass();
}

// ----------------------------------------- async-core park/notify refill

#[test]
fn collector_park_notify_protocol_has_no_missed_wakeup() {
    // The distilled async_server submit/collect handshake: the producer
    // pushes lock-free, then bumps the mutex and notifies; the collector
    // re-checks the queue *under the lock* before parking untimed. The
    // re-check is load-bearing — without it, push-after-check /
    // notify-before-wait interleavings strand the collector forever
    // (which this model would report as a deadlock).
    let outcome = model(CheckOpts::default(), || {
        let q = Arc::new(JobQueue::new());
        let m = Arc::new(Mutex::new(()));
        let cv = Arc::new(Condvar::new());
        let (q2, m2, cv2) = (Arc::clone(&q), Arc::clone(&m), Arc::clone(&cv));
        let producer = thread::spawn(move || {
            q2.push(1u32).unwrap();
            drop(m2.lock()); // pair with the collector's under-lock re-check
            cv2.notify_one();
        });
        let mut got = Vec::new();
        loop {
            got.extend(q.drain());
            if !got.is_empty() {
                break;
            }
            let guard = m.lock().unwrap_or_else(PoisonError::into_inner);
            if !q.is_empty() {
                continue; // a push slipped in before we could park
            }
            drop(cv.wait(guard).unwrap_or_else(PoisonError::into_inner));
        }
        producer.join().unwrap();
        assert_eq!(got, vec![1]);
    });
    outcome.assert_pass();
}

// -------------------------------------------------- seeded-bug meta-test

/// A oneshot with the notify dropped: the waiter parks on schedules
/// where it checks the flag before the setter runs, and nothing ever
/// wakes it. The checker must catch this as a deadlock with a token.
fn buggy_oneshot_without_notify() {
    let pair = Arc::new((Mutex::new(false), Condvar::new()));
    let p2 = Arc::clone(&pair);
    let t = thread::spawn(move || {
        *p2.0.lock().unwrap_or_else(PoisonError::into_inner) = true;
        // BUG (deliberate): cv.notify_one() dropped on the floor.
    });
    let (m, cv) = (&pair.0, &pair.1);
    let mut done = m.lock().unwrap_or_else(PoisonError::into_inner);
    while !*done {
        done = cv.wait(done).unwrap_or_else(PoisonError::into_inner);
    }
    drop(done);
    t.join().unwrap();
}

#[test]
fn deliberately_dropped_notify_is_caught_with_a_replayable_token() {
    let outcome = model(CheckOpts::default(), buggy_oneshot_without_notify);
    let (token, message) = match outcome {
        CheckOutcome::Fail { token, message, .. } => (token, message),
        CheckOutcome::Pass { schedules, .. } => {
            panic!("checker missed the dropped notify after {schedules} schedules")
        }
    };
    assert!(message.contains("deadlock"), "expected a deadlock report, got: {message}");
    assert!(parse_token(&token).is_some(), "failure token must parse: {token}");

    // The token replays to the same failure, first try, no search.
    match replay(&token, buggy_oneshot_without_notify) {
        CheckOutcome::Fail { message, schedules, .. } => {
            assert!(message.contains("deadlock"), "replay diverged: {message}");
            assert_eq!(schedules, 1, "replay must run exactly one schedule");
        }
        CheckOutcome::Pass { .. } => panic!("replay token did not reproduce the deadlock"),
    }
}

// ------------------------------------------------------- exhaustive cell

/// Deeper sweep for the CI checker job (`cargo test ... -- --ignored`):
/// three producers against a close, preemption bound 3, schedule budget
/// high enough to exhaust the space. Kept out of tier-1 for time.
#[test]
#[ignore = "exhaustive cell: run via the CI checker job or locally with --ignored"]
fn exhaustive_three_producer_close_race_conserves_values() {
    let opts = CheckOpts { preemption_bound: 3, max_schedules: 500_000, ..CheckOpts::default() };
    let outcome = model(opts, || {
        let q = Arc::new(JobQueue::new());
        let producers: Vec<_> = (0..3u32)
            .map(|i| {
                let q2 = Arc::clone(&q);
                thread::spawn(move || q2.push(i).is_ok())
            })
            .collect();
        let mut surfaced = q.close();
        let admitted: Vec<bool> = producers.into_iter().map(|t| t.join().unwrap()).collect();
        surfaced.sort_unstable();
        let expected: Vec<u32> = (0..3u32).filter(|&i| admitted[i as usize]).collect();
        assert_eq!(surfaced, expected, "admitted values must reach the closer, in order");
        assert!(q.drain().is_empty());
    });
    outcome.assert_pass();
}
