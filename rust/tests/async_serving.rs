//! Cross-engine serving conformance: the same seeded traffic driven
//! through the threaded dispatch-and-wait core, the async
//! continuous-batching core, and the virtual-time discrete-event engine
//! must agree on the queueing math.
//!
//! Contract (and its documented tolerances):
//!
//! - **Admission counts are exact** when no deadline is armed: all three
//!   engines share the per-client seed streams (`fork(2 + c)`), the
//!   bounded-queue reservation rule, and the capacity-held-until-response
//!   invariant, so accepted/rejected totals and per-model admission
//!   counts must match to the request.
//! - **Latency histograms agree coarsely**: the wall-clock engines pay OS
//!   scheduling on top of service time, so the conformance claim is a
//!   shared service-time floor and agreement within an order of magnitude
//!   (factor 20 here), not equality.
//! - **Sheds conserve, but do not match**: the async core's EWMA service
//!   estimate is unseeded until the first completion (the first request
//!   always passes), while the virtual engine computes its estimate
//!   upfront and can shed from the very first arrival. With a deadline
//!   armed the cross-engine contract is conservation
//!   (`offered == completed + rejected + shed`), not equal shed counts.
//!
//! The 10^5-virtual-client stress run doubles as the deterministic-
//! interleaving test; the 10^6 variant is `#[ignore]`d for CI time.

use photogan::api::{
    Outcome, Scenario, ServeCore, ServeEngine, ServeRequest, ServeStage, Session, StageSpec,
};
use photogan::coordinator::server::{BatchExecutor, Server, ServerConfig};
use photogan::coordinator::{AsyncServer, AsyncServerConfig, BatchPolicy, RoutingPolicy};
use photogan::workload::generator::{closed_loop, open_loop};
use photogan::workload::vserve::{simulate_serve, ServiceModel, VirtualOutcome, VirtualServeConfig};
use photogan::workload::{ArrivalProcess, TrafficMix};
use std::sync::{Arc, Mutex};
use std::time::Duration;

// ----------------------------------------------------------- test stubs

/// Instant two-model stub: pure admission math, no service time.
struct Echo;

impl BatchExecutor for Echo {
    fn models(&self) -> Vec<String> {
        vec!["a".into(), "b".into()]
    }

    fn elements_per_sample(&self, _m: &str) -> usize {
        1
    }

    fn generate(&self, _m: &str, entries: &[(u64, Option<u32>)]) -> Vec<f32> {
        vec![0.0; entries.len()]
    }
}

/// Fixed service time per batch call — the wall-clock analogue of the
/// virtual engine's flat-cost service model.
struct Fixed(Duration);

impl BatchExecutor for Fixed {
    fn models(&self) -> Vec<String> {
        vec!["m".into()]
    }

    fn elements_per_sample(&self, _m: &str) -> usize {
        1
    }

    fn generate(&self, _m: &str, entries: &[(u64, Option<u32>)]) -> Vec<f32> {
        std::thread::sleep(self.0);
        vec![0.0; entries.len()]
    }
}

/// Records the seed order the executor observes (FIFO-ordering probe).
struct Recording(Mutex<Vec<u64>>);

impl BatchExecutor for Recording {
    fn models(&self) -> Vec<String> {
        vec!["m".into()]
    }

    fn elements_per_sample(&self, _m: &str) -> usize {
        1
    }

    fn generate(&self, _m: &str, entries: &[(u64, Option<u32>)]) -> Vec<f32> {
        let mut seen = self.0.lock().unwrap();
        seen.extend(entries.iter().map(|(seed, _)| *seed));
        vec![0.0; entries.len()]
    }
}

/// `per_sample × batch` seconds: the virtual twin of [`Fixed`]/[`Echo`].
struct FlatCost(f64);

impl ServiceModel for FlatCost {
    fn batch_latency_s(&self, _m: &str, batch: usize) -> f64 {
        self.0 * batch as f64
    }
}

fn wall_config() -> ServerConfig {
    ServerConfig {
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(100) },
        workers: 2,
        shards: 2,
        routing: RoutingPolicy::RoundRobin,
        queue_depth: 4096,
    }
}

fn virtual_config() -> VirtualServeConfig {
    VirtualServeConfig {
        shards: 2,
        workers: 2,
        max_batch: 8,
        max_wait_s: 1e-4,
        queue_depth: 4096,
        routing: RoutingPolicy::RoundRobin,
        calibration: None,
        deadline_s: None,
    }
}

fn conserves(v: &VirtualOutcome) {
    assert_eq!(
        v.offered,
        v.admitted + v.rejected + v.shed,
        "every submission attempt must be admitted, rejected, or shed"
    );
    assert_eq!(v.latencies_ms.len(), v.admitted, "every admitted request completes");
}

// -------------------------------------------- exact admission conformance

#[test]
fn cross_engine_admission_counts_match_exactly() {
    // deep queues, no deadline: nothing is refused, so all three engines
    // must complete every request and agree per-model to the request
    let mix = TrafficMix::new(vec![("a".to_string(), 3.0), ("b".to_string(), 1.0)]).unwrap();
    let (clients, per_client, seed) = (6usize, 50usize, 42u64);
    let total = clients * per_client;

    let threaded = Server::start(Arc::new(Echo), wall_config());
    let t = closed_loop(&threaded.handle(), &mix, clients, per_client, seed);
    threaded.shutdown();

    let asynced = AsyncServer::start(Arc::new(Echo), AsyncServerConfig::from(wall_config()));
    let a = closed_loop(&asynced.handle(), &mix, clients, per_client, seed);
    asynced.shutdown();

    let arrival = ArrivalProcess::ClosedLoop { clients, per_client };
    let v = simulate_serve(&virtual_config(), &mix, &arrival, &FlatCost(1e-4), seed);

    for (name, completed, rejected, shed) in [
        ("threaded", t.completed, t.rejections, t.sheds),
        ("async", a.completed, a.rejections, a.sheds),
        ("virtual", v.admitted, v.rejected as u64, v.shed as u64),
    ] {
        assert_eq!(completed, total, "{name}: every request must complete");
        assert_eq!(rejected, 0, "{name}: deep queues must not reject");
        assert_eq!(shed, 0, "{name}: no deadline, no sheds");
    }
    // the per-client seed streams are shared, so per-model admission
    // counts are identical — not merely statistically similar
    assert_eq!(t.per_model, a.per_model, "threaded vs async per-model counts");
    assert_eq!(t.per_model, v.per_model, "threaded vs virtual per-model counts");
    conserves(&v);
}

#[test]
fn cross_engine_bounded_queue_admits_exactly_queue_depth() {
    // a zero-offset burst of 12 against queue_depth 4 with service long
    // enough to pin capacity: every engine must admit exactly 4. Capacity
    // is held until the response is delivered, so the first dispatch does
    // not free a slot mid-burst.
    let mix = TrafficMix::new(vec![("m".to_string(), 1.0)]).unwrap();
    let offsets = vec![0.0; 12];
    let burst_cfg = ServerConfig {
        policy: BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
        workers: 1,
        shards: 1,
        routing: RoutingPolicy::RoundRobin,
        queue_depth: 4,
    };

    let threaded = Server::start(Arc::new(Fixed(Duration::from_millis(150))), burst_cfg.clone());
    let t = open_loop(&threaded.handle(), &mix, &offsets, 0.0, 7);
    threaded.shutdown();

    let asynced = AsyncServer::start(
        Arc::new(Fixed(Duration::from_millis(150))),
        AsyncServerConfig::from(burst_cfg),
    );
    let a = open_loop(&asynced.handle(), &mix, &offsets, 0.0, 7);
    asynced.shutdown();

    let cfg = VirtualServeConfig {
        shards: 1,
        workers: 1,
        max_batch: 1,
        max_wait_s: 0.0,
        queue_depth: 4,
        ..virtual_config()
    };
    let arrival = ArrivalProcess::Trace { arrivals_s: offsets };
    let v = simulate_serve(&cfg, &mix, &arrival, &FlatCost(1000.0), 7);

    for (name, submitted, completed, rejected) in [
        ("threaded", t.submitted, t.completed, t.rejections),
        ("async", a.submitted, a.completed, a.rejections),
        ("virtual", v.offered, v.admitted, v.rejected as u64),
    ] {
        assert_eq!(submitted, 12, "{name}: open loop submits the whole trace");
        assert_eq!(completed, 4, "{name}: exactly queue_depth admitted");
        assert_eq!(rejected, 8, "{name}: the overflow is rejected, not dropped silently");
    }
}

// --------------------------------------------- latency-envelope tolerance

#[test]
fn cross_engine_latency_envelopes_overlap() {
    // 5 ms of service per batch on every engine. The wall-clock cores pay
    // OS scheduling on top, so the documented tolerance is coarse: every
    // engine's p50 sits above the service floor, below a 500 ms ceiling,
    // and within a factor of 20 of its siblings.
    const SERVICE: f64 = 5e-3;
    let mix = TrafficMix::new(vec![("m".to_string(), 1.0)]).unwrap();
    let (clients, per_client, seed) = (4usize, 25usize, 11u64);
    let lat_cfg = ServerConfig {
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(200) },
        workers: 2,
        shards: 1,
        routing: RoutingPolicy::RoundRobin,
        queue_depth: 4096,
    };

    let threaded =
        Server::start(Arc::new(Fixed(Duration::from_secs_f64(SERVICE))), lat_cfg.clone());
    let t = closed_loop(&threaded.handle(), &mix, clients, per_client, seed);
    threaded.shutdown();

    let asynced = AsyncServer::start(
        Arc::new(Fixed(Duration::from_secs_f64(SERVICE))),
        AsyncServerConfig::from(lat_cfg),
    );
    let a = closed_loop(&asynced.handle(), &mix, clients, per_client, seed);
    asynced.shutdown();

    let cfg = VirtualServeConfig {
        shards: 1,
        workers: 2,
        max_batch: 4,
        max_wait_s: 2e-4,
        ..virtual_config()
    };
    let arrival = ArrivalProcess::ClosedLoop { clients, per_client };
    // flat per-batch cost: SERVICE seconds regardless of fill, like Fixed
    struct PerBatch(f64);
    impl ServiceModel for PerBatch {
        fn batch_latency_s(&self, _m: &str, _batch: usize) -> f64 {
            self.0
        }
    }
    let v = simulate_serve(&cfg, &mix, &arrival, &PerBatch(SERVICE), seed);

    let p50 = [
        ("threaded", t.latency_percentile_ms(50.0)),
        ("async", a.latency_percentile_ms(50.0)),
        ("virtual", v.latency_percentile_ms(50.0)),
    ];
    for (name, ms) in p50 {
        assert!(ms >= SERVICE * 1e3, "{name}: p50 {ms:.2}ms under the 5ms service floor");
        assert!(ms <= 500.0, "{name}: p50 {ms:.2}ms beyond the tolerance ceiling");
    }
    for (x, y) in [(0, 1), (0, 2), (1, 2)] {
        let ratio = (p50[x].1 / p50[y].1).max(p50[y].1 / p50[x].1);
        assert!(
            ratio <= 20.0,
            "{} vs {} p50 disagree beyond tolerance: {:.2}ms vs {:.2}ms",
            p50[x].0,
            p50[y].0,
            p50[x].1,
            p50[y].1
        );
    }
}

// --------------------------------------------- async-core ordering & sheds

#[test]
fn async_core_preserves_per_client_completion_order() {
    // one producer, one shard, one worker, max_batch 1: the lock-free
    // intake is FIFO per producer and the collector dispatches serially,
    // so the executor must observe seeds in exact submission order
    let recorder = Arc::new(Recording(Mutex::new(Vec::new())));
    let server = AsyncServer::start(
        Arc::clone(&recorder),
        AsyncServerConfig {
            policy: BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
            workers: 1,
            shards: 1,
            routing: RoutingPolicy::RoundRobin,
            queue_depth: 1024,
            deadline: None,
        },
    );
    let pending: Vec<_> =
        (0..64u64).map(|seed| server.submit("m", seed, None, 1).unwrap()).collect();
    for (i, p) in pending.into_iter().enumerate() {
        let resp = p.wait().unwrap_or_else(|| panic!("request {i} lost its completion"));
        assert_eq!(resp.served_batch, 1);
    }
    server.shutdown();
    let seen = recorder.0.lock().unwrap();
    assert_eq!(*seen, (0..64).collect::<Vec<u64>>(), "per-client FIFO order broke");
}

#[test]
fn shed_accounting_conserves_requests_on_both_shedding_engines() {
    // deadline far below service time: the async core sheds once its EWMA
    // is seeded by the first completion; the virtual engine sheds from the
    // first arrival (upfront estimate). Counts differ by design — the
    // cross-engine contract under a deadline is conservation.
    let mix = TrafficMix::new(vec![("m".to_string(), 1.0)]).unwrap();
    let server = AsyncServer::start(
        Arc::new(Fixed(Duration::from_millis(2))),
        AsyncServerConfig {
            deadline: Some(Duration::from_micros(10)),
            ..AsyncServerConfig::from(wall_config())
        },
    );
    let report = closed_loop(&server.handle(), &mix, 2, 10, 3);
    server.shutdown();
    assert!(report.sheds > 0, "a 10µs deadline against 2ms service must shed");
    assert_eq!(
        report.submitted as u64,
        report.completed as u64 + report.rejections + report.sheds,
        "closed loop: every attempt completes, retries, or is shed"
    );

    let cfg = VirtualServeConfig { deadline_s: Some(1e-5), ..virtual_config() };
    let arrival = ArrivalProcess::ClosedLoop { clients: 2, per_client: 10 };
    let v = simulate_serve(&cfg, &mix, &arrival, &FlatCost(2e-3), 3);
    assert!(v.shed > 0, "the virtual deadline mirror must shed");
    conserves(&v);
}

// ------------------------------------------------ API-level conformance

#[test]
fn serve_request_async_core_matches_threaded_counts() {
    // the ServeRequest driver: same request count through both cores on
    // the sim backend must complete everything with identical totals
    let session = Arc::new(Session::new().unwrap());
    let mut outcomes = Vec::new();
    for core in [ServeCore::Threaded, ServeCore::Async] {
        let req = ServeRequest::builder()
            .model("condgan")
            .core(core)
            .requests(16)
            .max_batch(4)
            .shards(2)
            .time_scale(0.0)
            .build()
            .unwrap();
        outcomes.push(Arc::clone(&session).serve(&req).unwrap());
    }
    assert_eq!(outcomes[0].core, "threaded");
    assert_eq!(outcomes[1].core, "async");
    for o in &outcomes {
        assert_eq!(o.total_requests, 16, "{}: all requests served", o.core);
        assert_eq!(o.total_samples, 16, "{}", o.core);
        assert_eq!(o.sheds, 0, "{}: no deadline, no sheds", o.core);
        assert!(o.throughput_img_s > 0.0, "{}", o.core);
    }
}

#[test]
fn stable_json_is_run_to_run_identical() {
    // the deterministic subset CI diffs with `cmp`: two runs of the same
    // async request must render byte-identical stable JSON even though
    // wall timing differs
    let session = Arc::new(Session::new().unwrap());
    let render = || {
        let req = ServeRequest::builder()
            .model("dcgan")
            .core(ServeCore::Async)
            .requests(12)
            .max_batch(4)
            .time_scale(0.0)
            .build()
            .unwrap();
        Arc::clone(&session).serve(&req).unwrap().stable_json()
    };
    let first = render();
    assert_eq!(first, render(), "stable_json must be timing-free");
    for key in ["\"core\":\"async\"", "\"sheds\":0", "\"rejections\":0"] {
        assert!(first.contains(key), "missing {key} in {first}");
    }
}

// ---------------------------------------------- scenario-layer conformance

#[test]
fn scenario_async_engine_round_trips_and_serves() {
    let stage = ServeStage {
        name: "async-stage".into(),
        engine: ServeEngine::Async,
        model: Some("condgan".into()),
        requests: 8,
        max_batch: 4,
        time_scale: 0.0,
        deadline_ms: Some(250.0),
        ..ServeStage::default()
    };
    let scenario = Scenario::single("async-conformance", StageSpec::Serve(stage));
    // the deadline and engine survive the JSON round trip exactly
    assert_eq!(Scenario::from_json(&scenario.to_json()).unwrap(), scenario);

    let session = Arc::new(Session::new().unwrap());
    let plan = session.plan(&scenario).unwrap();
    let outcome = Arc::clone(&session).run(&plan).unwrap();
    let Outcome::Serve(served) = &outcome.stages[0].outcome else {
        panic!("serve stage must produce a serve outcome");
    };
    assert_eq!(served.core, "async");
    assert_eq!(served.total_requests + served.sheds, 8, "driven requests are accounted for");
}

#[test]
fn scenario_threaded_engine_rejects_deadline_at_plan_time() {
    let stage = ServeStage {
        engine: ServeEngine::Threaded,
        model: Some("condgan".into()),
        time_scale: 0.0,
        deadline_ms: Some(5.0),
        ..ServeStage::default()
    };
    let scenario = Scenario::single("bad", StageSpec::Serve(stage));
    let session = Session::new().unwrap();
    let err = session.plan(&scenario).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("async"), "the error must steer to a shedding engine: {msg}");
}

#[test]
fn virtual_scenario_same_seed_json_is_byte_identical() {
    // the scenario envelope over a virtual serve stage is a pure function
    // of (scenario, seed): two runs must render byte-identical JSON —
    // the property the CI `cmp` smoke step relies on
    let stage = ServeStage {
        name: "fleet".into(),
        engine: ServeEngine::Virtual,
        mix: vec![("dcgan".to_string(), 4.0), ("srgan".to_string(), 1.0)],
        arrival: Some(ArrivalProcess::Poisson { rate_hz: 300.0, duration_s: 0.2 }),
        shards: 2,
        queue_depth: 64,
        deadline_ms: Some(2.0),
        ..ServeStage::default()
    };
    let scenario = Scenario::single("byte-identical", StageSpec::Serve(stage));
    let session = Arc::new(Session::new().unwrap());
    let plan = session.plan(&scenario).unwrap();
    let first = Arc::clone(&session).run(&plan).unwrap().to_json();
    let second = Arc::clone(&session).run(&plan).unwrap().to_json();
    assert_eq!(first, second, "virtual serving must be wall-clock-free");
    assert!(first.contains("\"shed\""), "the shed counter must be part of the envelope");
}

// --------------------------------------------- virtual-client stress scale

fn stress_config(queue_depth: usize) -> VirtualServeConfig {
    VirtualServeConfig {
        shards: 4,
        workers: 2,
        max_batch: 16,
        max_wait_s: 1e-4,
        queue_depth,
        routing: RoutingPolicy::LeastOutstanding,
        calibration: None,
        deadline_s: None,
    }
}

#[test]
fn vserve_100k_clients_is_deterministic_and_conserving() {
    // 10^5 closed-loop clients all arriving at virtual t=0: the event
    // engine must stay exact (conservation) and bit-for-bit reproducible
    let clients = 100_000usize;
    let mix = TrafficMix::new(vec![("a".to_string(), 2.0), ("b".to_string(), 1.0)]).unwrap();
    let arrival = ArrivalProcess::ClosedLoop { clients, per_client: 1 };
    let cfg = stress_config(32_768);
    let run = || simulate_serve(&cfg, &mix, &arrival, &FlatCost(2e-5), 9);
    let first = run();
    conserves(&first);
    assert_eq!(first.admitted, clients, "capacity covers the fleet: everything admits");
    let second = run();
    assert_eq!(first.admitted, second.admitted);
    assert_eq!(first.rejected, second.rejected);
    assert_eq!(first.shed, second.shed);
    assert_eq!(first.per_model, second.per_model);
    assert_eq!(
        first.makespan_s.to_bits(),
        second.makespan_s.to_bits(),
        "virtual time must replay bit-for-bit"
    );
    assert_eq!(first.latencies_ms, second.latencies_ms, "full latency vector must replay");
}

#[test]
#[ignore = "10^6-client stress run (~seconds of CPU): cargo test --test async_serving -- --ignored"]
fn vserve_1m_clients_conserves() {
    let clients = 1_000_000usize;
    let mix = TrafficMix::new(vec![("a".to_string(), 1.0)]).unwrap();
    let arrival = ArrivalProcess::ClosedLoop { clients, per_client: 1 };
    let v = simulate_serve(&stress_config(262_144), &mix, &arrival, &FlatCost(2e-5), 13);
    conserves(&v);
    assert_eq!(v.admitted, clients);
}
