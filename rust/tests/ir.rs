//! IR ↔ flat-mapping equivalence, verifier rejection, and fusion
//! neutrality — the static-analysis contract of `models/ir` +
//! `sim/mapper`:
//!
//! - lowering through the public IR surface (`Graph::from_model` →
//!   `map_graph`) is byte-identical to `map_model` for every zoo model
//!   and every golden flag set (all recorded at `fuse = off`);
//! - the verifier rejects each class of ill-formed graph with a typed
//!   [`IrError`] naming the offending op position;
//! - `OptFlags::fused()` strictly reduces job count on skip-connection
//!   models while total energy and closed-form latency stay put.

use photogan::arch::accelerator::Accelerator;
use photogan::arch::activation::ActKind;
use photogan::arch::config::ArchConfig;
use photogan::models::ir::{dead_ops, Graph, IrError, PassManager};
use photogan::models::layer::{Layer, Shape};
use photogan::models::{zoo, Value};
use photogan::sim::{map_graph, map_model, simulate, OptFlags};

#[test]
fn ir_lowering_matches_flat_mapping_for_every_zoo_model() {
    for model in zoo::extended_generators() {
        for (name, opts) in OptFlags::golden_sweep() {
            let flat = map_model(&model, 1, &opts);
            let graph = Graph::from_model(&model).expect("zoo models lift");
            let via_ir = map_graph(&graph, 1, &opts).expect("zoo models verify");
            assert_eq!(
                format!("{flat:?}"),
                format!("{via_ir:?}"),
                "{} / {name}: IR lowering must be byte-identical",
                model.name
            );
        }
    }
}

#[test]
fn zoo_graphs_verify_and_have_no_dead_ops() {
    for model in zoo::extended_generators() {
        let graph = Graph::from_model(&model).expect("zoo models lift");
        graph.verify().expect("zoo models verify");
        assert!(
            dead_ops(&graph).is_empty(),
            "{}: a linear lift has no dead ops",
            model.name
        );
        assert_eq!(graph.ops.len(), model.infos().unwrap().len());
    }
}

// ---------------------------------------------------- verifier rejection

#[test]
fn verifier_rejects_use_before_def() {
    let mut g = Graph::from_model(&zoo::dcgan()).unwrap();
    let ghost = g.values.len();
    g.values.push(Value { shape: g.values[g.ops[2].operands[0]].shape.clone() });
    g.ops[2].operands[0] = ghost;
    match g.verify() {
        Err(IrError::UseBeforeDef { op: 2, value }) => assert_eq!(value, ghost),
        other => panic!("expected UseBeforeDef at op 2, got {other:?}"),
    }
    // the typed diagnostic names the op position
    assert!(g.verify().unwrap_err().to_string().contains("op 2"));
}

#[test]
fn verifier_rejects_cycles() {
    let mut g = Graph::from_model(&zoo::dcgan()).unwrap();
    g.ops[0].operands[0] = g.ops[1].out;
    assert!(matches!(g.verify(), Err(IrError::Cycle { op: 0, .. })));
}

#[test]
fn verifier_rejects_dangling_values() {
    let mut g = Graph::from_model(&zoo::dcgan()).unwrap();
    let bogus = g.values.len() + 7;
    g.ops[1].operands[0] = bogus;
    match g.verify() {
        Err(IrError::DanglingValue { op: 1, value }) => assert_eq!(value, bogus),
        other => panic!("expected DanglingValue at op 1, got {other:?}"),
    }
}

#[test]
fn verifier_rejects_shape_mismatches() {
    let mut g = Graph::from_model(&zoo::dcgan()).unwrap();
    g.values[g.ops[0].out].shape = Shape::Chw(1, 1, 1);
    assert!(matches!(g.verify(), Err(IrError::ShapeMismatch { op: 0, .. })));
}

#[test]
fn verifier_rejects_double_assignment() {
    let mut g = Graph::from_model(&zoo::dcgan()).unwrap();
    g.ops[1].out = g.ops[0].out;
    assert!(matches!(g.verify(), Err(IrError::Redefined { op: 1, .. })));
}

#[test]
fn verifier_rejects_wrong_arity_and_bad_output() {
    let mut g = Graph::from_model(&zoo::dcgan()).unwrap();
    g.ops[0].operands.push(0);
    assert!(matches!(
        g.verify(),
        Err(IrError::MissingOperand { op: 0, expected: 1, got: 2 })
    ));

    let mut g = Graph::from_model(&zoo::dcgan()).unwrap();
    g.output = g.values.len();
    assert!(matches!(g.verify(), Err(IrError::BadOutput { .. })));
}

#[test]
fn ill_formed_graphs_never_lower() {
    let mut g = Graph::from_model(&zoo::dcgan()).unwrap();
    g.ops[0].operands[0] = g.ops[1].out; // cycle
    assert!(map_graph(&g, 1, &OptFlags::all()).is_err());
}

// ------------------------------------------------ dead-value elimination

#[test]
fn dce_drops_unconsumed_ops_without_changing_the_lowering() {
    let model = zoo::cyclegan();
    let baseline = map_model(&model, 1, &OptFlags::all());
    let mut g = Graph::from_model(&model).unwrap();
    // graft a dead activation onto the graph input: verifiable, but its
    // result reaches nothing
    let dead_out = g.values.len();
    g.values.push(Value { shape: g.values[g.inputs[0]].shape.clone() });
    g.ops.push(photogan::models::Op {
        index: g.ops.len(),
        layer: Layer::Act(ActKind::Relu),
        operands: vec![g.inputs[0]],
        out: dead_out,
        dense_macs: 0,
    });
    g.verify().expect("the grafted graph is still well-formed");
    assert_eq!(dead_ops(&g), vec![g.ops.len() - 1]);

    let applied = PassManager::standard().run(&mut g).expect("passes re-verify");
    assert_eq!(applied, vec!["dead-value-elimination"]);
    assert!(dead_ops(&g).is_empty());
    let cleaned = map_graph(&g, 1, &OptFlags::all()).unwrap();
    assert_eq!(
        format!("{baseline:?}"),
        format!("{cleaned:?}"),
        "DCE must restore the original lowering"
    );
}

// ------------------------------------------------------ fusion neutrality

#[test]
fn fuse_reduces_jobs_with_identical_energy_and_latency() {
    let acc = Accelerator::new(ArchConfig::paper_optimum()).unwrap();
    for model in [zoo::cyclegan(), zoo::srgan(), zoo::pix2pix()] {
        let plain = simulate(&model, &acc, 1, OptFlags::all());
        let fused = simulate(&model, &acc, 1, OptFlags::fused());
        assert!(
            fused.layers.len() < plain.layers.len(),
            "{}: fuse must strictly reduce job count ({} vs {})",
            model.name,
            fused.layers.len(),
            plain.layers.len()
        );
        // the folded ops were zero-latency: the closed-form makespan is
        // bit-identical
        assert_eq!(
            plain.latency, fused.latency,
            "{}: latency must be unchanged",
            model.name
        );
        // energy totals agree up to f64 re-association of the per-job sums
        let (ep, ef) = (plain.energy.total(), fused.energy.total());
        assert!(
            (ep - ef).abs() <= 1e-9 * ep.abs(),
            "{}: energy drifted under fuse ({ep} vs {ef})",
            model.name
        );
        assert_eq!(plain.total_ops, fused.total_ops, "{}: workload ops", model.name);
        assert_eq!(plain.total_bits, fused.total_bits, "{}: workload bits", model.name);
    }
    // a skip-free model is untouched
    let acc_jobs =
        |opts: &OptFlags| map_model(&zoo::dcgan(), 1, opts).len();
    assert_eq!(acc_jobs(&OptFlags::all()), acc_jobs(&OptFlags::fused()));
}

#[test]
fn fuse_is_neutral_across_batch_sizes() {
    let acc = Accelerator::new(ArchConfig::paper_optimum()).unwrap();
    let model = zoo::srgan();
    for batch in [1usize, 4] {
        let plain = simulate(&model, &acc, batch, OptFlags::all());
        let fused = simulate(&model, &acc, batch, OptFlags::fused());
        assert_eq!(plain.latency, fused.latency, "batch {batch}");
        let (ep, ef) = (plain.energy.total(), fused.energy.total());
        assert!((ep - ef).abs() <= 1e-9 * ep.abs(), "batch {batch}: {ep} vs {ef}");
    }
}
