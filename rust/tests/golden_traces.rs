//! Golden-trace regression suite: pins the full [`SimReport`] JSON for
//! every zoo model × every paper flag combination against snapshots in
//! `rust/tests/golden/`.
//!
//! The cost model is the load-bearing artifact of this repo — Figs. 11–14,
//! the DSE optimum, and every serving latency derive from it — and with
//! 8 models × {baseline, sparse, pipelined, all} there was previously no
//! harness catching silent drift. This suite compares **bit-exactly**:
//! numbers are rendered with shortest-round-trip float formatting, so a
//! parsed golden float equals the original bits and any cost-model change
//! shows up as a field-level diff (`layers[3].energy_j.dram: …`).
//!
//! Workflows:
//! - **Blessed regeneration**: `UPDATE_GOLDEN=1 cargo test --test
//!   golden_traces` rewrites every snapshot (then review the diff in git).
//! - **Bootstrap**: a missing snapshot is written on first run and the
//!   test passes with a note — a fresh checkout (or a checkout whose
//!   goldens were authored in an environment without a toolchain)
//!   self-pins on its first green run and regresses from there.
//! - **Mismatch**: the failing report is written to
//!   `target/golden-diff/<name>.json` (uploaded as a CI artifact) and the
//!   test panics with a readable field-level diff.
//!
//! The snapshotted flag sets all run the closed-form analytical engine
//! (`overlap` off): that path is the paper-calibrated reference and must
//! stay bit-identical across refactors. The event-driven scheduler is
//! pinned *relative* to it by the equivalence and ≤-latency suites in
//! `sim::schedule`.

use photogan::arch::accelerator::Accelerator;
use photogan::arch::config::ArchConfig;
use photogan::models::zoo;
use photogan::sim::{simulate, OptFlags};
use photogan::util::json::{parse, JsonValue};
use std::fs;
use std::path::{Path, PathBuf};

fn golden_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden")
}

fn diff_dir() -> PathBuf {
    std::env::var_os("CARGO_TARGET_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("target"))
        .join("golden-diff")
}

fn update_requested() -> bool {
    std::env::var("UPDATE_GOLDEN").map(|v| v == "1" || v == "true").unwrap_or(false)
}

/// Recursive field-level diff. Numbers compare exactly (the writer's
/// shortest-round-trip rendering makes parse(render(x)) == x bit-for-bit).
fn diff(path: &str, golden: &JsonValue, actual: &JsonValue, out: &mut Vec<String>) {
    match (golden, actual) {
        (JsonValue::Obj(gm), JsonValue::Obj(am)) => {
            for (k, gv) in gm {
                match actual.get(k) {
                    Some(av) => diff(&format!("{path}.{k}"), gv, av, out),
                    None => out.push(format!("{path}.{k}: present in golden, missing in actual")),
                }
            }
            for (k, _) in am {
                if golden.get(k).is_none() {
                    out.push(format!("{path}.{k}: new field not in golden (re-bless?)"));
                }
            }
        }
        (JsonValue::Arr(gs), JsonValue::Arr(as_)) => {
            if gs.len() != as_.len() {
                out.push(format!("{path}: length {} != {}", gs.len(), as_.len()));
            }
            for (i, (gv, av)) in gs.iter().zip(as_).enumerate() {
                diff(&format!("{path}[{i}]"), gv, av, out);
            }
        }
        (JsonValue::Num(g), JsonValue::Num(a)) => {
            if g != a {
                let rel = (g - a).abs() / g.abs().max(f64::MIN_POSITIVE);
                out.push(format!("{path}: golden {g:e} != actual {a:e} (rel {rel:.2e})"));
            }
        }
        _ => {
            if golden != actual {
                out.push(format!("{path}: golden {golden} != actual {actual}"));
            }
        }
    }
}

#[test]
fn golden_traces_for_all_models_and_flag_combos() {
    let dir = golden_dir();
    fs::create_dir_all(&dir).expect("golden dir must be creatable");
    let acc = Accelerator::new(ArchConfig::paper_optimum()).expect("paper optimum is valid");
    let update = update_requested();

    let mut bootstrapped = Vec::new();
    let mut updated = Vec::new();
    let mut failures: Vec<String> = Vec::new();
    let mut checked = 0usize;

    for model in zoo::extended_generators() {
        for (combo, flags) in OptFlags::golden_sweep() {
            assert!(!flags.overlap, "golden combos pin the analytical engine");
            let report = simulate(&model, &acc, 1, flags);
            let actual = report.json();
            let mut rendered = actual.render();
            rendered.push('\n');
            let name = format!("{}__{}.json", model.name.to_lowercase(), combo);
            let file = dir.join(&name);

            if update {
                fs::write(&file, &rendered).expect("write golden");
                updated.push(name);
                continue;
            }
            if !file.exists() {
                // first run on a fresh checkout: self-pin and report it
                fs::write(&file, &rendered).expect("bootstrap golden");
                bootstrapped.push(name);
                continue;
            }
            let text = fs::read_to_string(&file).expect("read golden");
            let golden = match parse(&text) {
                Ok(v) => v,
                Err(e) => {
                    failures.push(format!("{name}: golden file does not parse: {e}"));
                    continue;
                }
            };
            let mut field_diffs = Vec::new();
            diff("$", &golden, &actual, &mut field_diffs);
            checked += 1;
            if !field_diffs.is_empty() {
                let dd = diff_dir();
                let _ = fs::create_dir_all(&dd);
                let _ = fs::write(dd.join(&name), &rendered);
                let shown = field_diffs.len().min(20);
                failures.push(format!(
                    "{name}: {} field(s) drifted:\n    {}{}",
                    field_diffs.len(),
                    field_diffs[..shown].join("\n    "),
                    if field_diffs.len() > shown { "\n    …" } else { "" },
                ));
            }
        }
    }

    if !updated.is_empty() {
        eprintln!("[golden] UPDATE_GOLDEN=1: re-blessed {} snapshot(s)", updated.len());
    }
    if !bootstrapped.is_empty() {
        eprintln!(
            "[golden] bootstrapped {} missing snapshot(s): {}",
            bootstrapped.len(),
            bootstrapped.join(", ")
        );
    }
    assert!(
        failures.is_empty(),
        "cost-model drift against {} checked golden trace(s) \
         (actual reports written to {}; if the change is intentional, \
         re-bless with UPDATE_GOLDEN=1 and commit the diff):\n\n{}",
        checked,
        diff_dir().display(),
        failures.join("\n\n")
    );
}

#[test]
fn golden_snapshots_carry_the_full_report_shape() {
    // independent of snapshot state: the JSON a golden pins must expose
    // every field a regression would care about
    let acc = Accelerator::new(ArchConfig::paper_optimum()).expect("valid");
    let r = simulate(&zoo::dcgan(), &acc, 1, OptFlags::all());
    let doc = r.json();
    for key in [
        "model",
        "opts",
        "batch",
        "latency_s",
        "serial_latency_s",
        "total_ops",
        "total_bits",
        "gops",
        "epb",
        "avg_power_w",
        "energy_j",
        "resources",
        "layers",
    ] {
        assert!(doc.get(key).is_some(), "report JSON must carry '{key}'");
    }
    let layers = doc.get("layers").and_then(|v| v.as_array()).expect("layers array");
    assert_eq!(layers.len(), r.layers.len());
    for key in ["index", "name", "start_s", "latency_s", "critical_s", "energy_j"] {
        assert!(layers[0].get(key).is_some(), "layer JSON must carry '{key}'");
    }
    // and it round-trips through the parser bit-exactly
    let back = parse(&doc.render()).expect("render must parse");
    let mut diffs = Vec::new();
    diff("$", &doc, &back, &mut diffs);
    assert!(diffs.is_empty(), "round-trip drift: {diffs:?}");
}
