//! Fleet-scale DES equivalence suite: the indexed event wheel and the
//! reference `BinaryHeap` event queue must be observationally identical —
//! same-seed runs across the queue swap produce equal [`VirtualOutcome`]s
//! and byte-identical JSON, over randomized fleet shapes, arrival
//! processes, and fault/autoscale injection.

use photogan::coordinator::RoutingPolicy;
use photogan::workload::vserve::{
    simulate_fleet, AutoscaleConfig, AutoscalePolicy, CalibrationConfig, FailureConfig,
    FleetConfig, FleetCost, QueueKind, ShardClass, VirtualServeConfig,
};
use photogan::workload::{ArrivalProcess, TrafficMix};

/// Class-tiered deterministic cost model: class 0 is an order of
/// magnitude faster than class 1, with per-sample energy.
struct Tiered;

impl FleetCost for Tiered {
    fn batch_latency_s(&self, class: usize, model: &str, batch: usize) -> f64 {
        let per_sample = match class {
            0 => 2e-5,
            _ => 1.2e-4,
        };
        // a mild per-model skew so the mix matters
        let skew = if model == "b" { 1.5 } else { 1.0 };
        per_sample * skew * batch as f64
    }

    fn batch_energy_j(&self, class: usize, _model: &str, batch: usize) -> f64 {
        let per_sample = match class {
            0 => 1e-3,
            _ => 6e-3,
        };
        per_sample * batch as f64
    }
}

fn mix_ab() -> TrafficMix {
    TrafficMix::new(vec![("a".into(), 3.0), ("b".into(), 1.0)]).expect("mix")
}

/// A deterministic family of fleet shapes indexed by `variant`: sizes,
/// routing, arrival processes, and fault/autoscale injection all vary.
fn fleet_variant(variant: usize) -> (FleetConfig, ArrivalProcess) {
    let shards_per_class = 1 + variant % 3; // 2, 4, or 6 shards total
    let routing = match variant % 3 {
        0 => RoutingPolicy::RoundRobin,
        1 => RoutingPolicy::LeastOutstanding,
        _ => RoutingPolicy::ModelAffinity,
    };
    let base = VirtualServeConfig {
        shards: shards_per_class * 2,
        workers: 2,
        max_batch: 4 + (variant % 2) * 4,
        max_wait_s: 1e-4,
        queue_depth: 64 + 32 * (variant % 4),
        routing,
        calibration: if variant % 2 == 0 {
            Some(CalibrationConfig { interval_s: 2e-2, outage_s: 3e-3 })
        } else {
            None
        },
        deadline_s: if variant % 4 == 3 { Some(2e-3) } else { None },
    };
    let classes = vec![
        ShardClass {
            name: "photonic".into(),
            workers: 2,
            idle_w: 1.5,
            cost_per_hour: 3.0,
        },
        ShardClass {
            name: "gpu".into(),
            workers: 4,
            idle_w: 80.0,
            cost_per_hour: 4.0,
        },
    ];
    let mut shard_class = vec![0; shards_per_class];
    shard_class.extend(vec![1; shards_per_class]);
    let fleet = FleetConfig {
        base,
        classes,
        shard_class,
        failures: if variant % 3 != 1 {
            Some(FailureConfig { mtbf_s: 3e-2, mttr_s: 4e-3 })
        } else {
            None
        },
        autoscale: if variant % 2 == 1 {
            Some(AutoscaleConfig {
                policy: if variant % 4 == 1 {
                    AutoscalePolicy::QueueDepth { high: 24, low: 2 }
                } else {
                    AutoscalePolicy::TargetUtilization { target: 0.6 }
                },
                min_shards: 1,
                max_shards: shards_per_class * 2,
                initial: shards_per_class,
                interval_s: 5e-3,
            })
        } else {
            None
        },
        queue: QueueKind::Wheel,
    };
    let arrival = match variant % 4 {
        0 => ArrivalProcess::Poisson { rate_hz: 6_000.0, duration_s: 0.08 },
        1 => ArrivalProcess::ClosedLoop { clients: 12, per_client: 40 },
        2 => ArrivalProcess::Diurnal {
            base_hz: 1_000.0,
            peak_hz: 9_000.0,
            period_s: 0.04,
            duration_s: 0.08,
        },
        _ => ArrivalProcess::FlashCrowd {
            base_hz: 2_000.0,
            spike_hz: 20_000.0,
            spike_at_s: 0.02,
            spike_s: 0.01,
            duration_s: 0.06,
        },
    };
    (fleet, arrival)
}

/// The acceptance property: for every variant and seed, swapping the
/// event wheel for the reference heap changes nothing observable.
#[test]
fn wheel_and_heap_agree_on_randomized_fleets() {
    let mix = mix_ab();
    for variant in 0..8 {
        let (mut fleet, arrival) = fleet_variant(variant);
        for seed in [1u64, 77, 4242] {
            fleet.queue = QueueKind::Wheel;
            let wheel = simulate_fleet(&fleet, &mix, &arrival, &Tiered, seed);
            fleet.queue = QueueKind::Heap;
            let heap = simulate_fleet(&fleet, &mix, &arrival, &Tiered, seed);
            assert_eq!(
                wheel, heap,
                "variant {variant} seed {seed}: queue swap changed the outcome"
            );
            assert_eq!(
                wheel.json().render(),
                heap.json().render(),
                "variant {variant} seed {seed}: queue swap changed the JSON bytes"
            );
            // sanity: the variants actually generate traffic
            assert!(wheel.offered > 0, "variant {variant} seed {seed}");
        }
    }
}

/// Same-seed runs are byte-identical; different seeds actually differ.
#[test]
fn same_seed_fleet_runs_are_byte_identical() {
    let mix = mix_ab();
    let (fleet, arrival) = fleet_variant(2);
    let a = simulate_fleet(&fleet, &mix, &arrival, &Tiered, 9).json().render();
    let b = simulate_fleet(&fleet, &mix, &arrival, &Tiered, 9).json().render();
    assert_eq!(a, b);
    let c = simulate_fleet(&fleet, &mix, &arrival, &Tiered, 10).json().render();
    assert_ne!(a, c, "the seed must steer the workload");
}
