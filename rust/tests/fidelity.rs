//! Fidelity-engine contract tests (the tier-1 face of `fidelity/`):
//!
//! - **Ideal neutrality**: `NoiseModel::ideal()` must be a pure
//!   pass-through — for every golden combo (8 models × 4 flag sets) the
//!   fidelity path reports exactly the simulator's latency/energy/GOPS,
//!   full converter precision, and leaves the `SimReport` JSON untouched
//!   bit-for-bit. This is what lets the golden-trace suite stay green
//!   with the fidelity engine in the tree.
//! - **Determinism**: same-seed Monte Carlo envelopes are byte-identical
//!   JSON; a different seed moves the envelope.
//! - **Physics sanity**: more thermal drift ⇒ strictly fewer effective
//!   bits; longer symbol integration ⇒ strictly more bits at strictly
//!   less throughput (the Pareto frontier is non-degenerate).

use photogan::api::Session;
use photogan::fidelity::{evaluate, MonteCarlo, NoiseModel};
use photogan::models::zoo;
use photogan::report;
use photogan::sim::OptFlags;

#[test]
fn ideal_noise_is_a_bit_exact_pass_through_for_every_golden_combo() {
    let session = Session::new().expect("paper optimum config is valid");
    let mc = MonteCarlo {
        noise: NoiseModel::ideal(),
        trials: 4,
        integration: 1.0,
        seed: 0,
    };
    let cap_bits = mc.noise.quantization_bits as f64;
    let cap_db = mc.noise.snr_cap_db();

    for model in zoo::extended_generators() {
        for (combo, flags) in OptFlags::golden_sweep() {
            let report = session.sim_report(&model, 1, flags);
            let before = report.json().render();

            let fr = session.fidelity_report(&model, 1, flags, &mc);

            // the fidelity pass reads the report; it must not perturb it
            let after = session.sim_report(&model, 1, flags).json().render();
            assert_eq!(before, after, "{}/{combo}: SimReport JSON drifted", model.name);

            assert_eq!(fr.latency_s, report.latency, "{}/{combo}: latency", model.name);
            assert_eq!(fr.energy_j, report.energy.total(), "{}/{combo}: energy", model.name);
            assert_eq!(fr.gops, report.gops(), "{}/{combo}: gops", model.name);
            // SNR/bits go through trial averaging and the ENOB formula,
            // so "exactly the cap" means up-to-rounding, not bit-equal
            assert!(
                (fr.snr_db - cap_db).abs() < 1e-9,
                "{}/{combo}: ideal SNR must sit at the cap, got {}",
                model.name,
                fr.snr_db
            );
            assert!(
                (fr.effective_bits - cap_bits).abs() < 1e-9,
                "{}/{combo}: ideal bits, got {}",
                model.name,
                fr.effective_bits
            );
            assert!(
                (fr.min_effective_bits - cap_bits).abs() < 1e-9,
                "{}/{combo}: worst layer, got {}",
                model.name,
                fr.min_effective_bits
            );
            for layer in &fr.layers {
                assert!((layer.effective_bits - cap_bits).abs() < 1e-9);
                assert!((layer.snr_db - cap_db).abs() < 1e-9);
            }
        }
    }
}

#[test]
fn same_seed_monte_carlo_envelopes_are_byte_identical() {
    let session = Session::new().expect("paper optimum config is valid");
    let model = zoo::cyclegan();
    let mc = MonteCarlo { noise: NoiseModel::paper(), trials: 16, integration: 1.0, seed: 42 };

    let a = session.fidelity_report(&model, 1, OptFlags::all(), &mc).json().render();
    let b = session.fidelity_report(&model, 1, OptFlags::all(), &mc).json().render();
    assert_eq!(a, b, "same seed must reproduce the envelope byte-for-byte");

    // the seed is live: a different fork lineage moves the envelope
    let other = MonteCarlo { seed: 43, ..mc.clone() };
    let c = session.fidelity_report(&model, 1, OptFlags::all(), &other).json().render();
    assert_ne!(a, c, "different seeds must draw different noise");

    // and the standalone evaluate() entry point agrees with the session path
    let jobs = session.mapped(&model, 1, OptFlags::all());
    let report = session.sim_report(&model, 1, OptFlags::all());
    let d = evaluate(&mc, &jobs, &report).json().render();
    assert_eq!(a, d, "Session::fidelity_report must be evaluate() verbatim");
}

#[test]
fn effective_bits_degrade_monotonically_with_drift_magnitude() {
    let session = Session::new().expect("paper optimum config is valid");
    let model = zoo::dcgan();
    let mut last = f64::INFINITY;
    for scale in [0.5, 1.0, 2.0, 4.0] {
        let mut noise = NoiseModel::paper();
        noise.drift_linewidths_per_s *= scale;
        let mc = MonteCarlo { noise, trials: 16, integration: 1.0, seed: 9 };
        let fr = session.fidelity_report(&model, 1, OptFlags::all(), &mc);
        assert!(
            fr.effective_bits < last,
            "drift x{scale}: {} bits must be below {last}",
            fr.effective_bits
        );
        assert!(fr.effective_bits > 0.0, "drift x{scale}: bits must stay positive");
        last = fr.effective_bits;
    }
}

#[test]
fn pareto_frontier_trades_throughput_for_accuracy() {
    let session = Session::new().expect("paper optimum config is valid");
    let (_, rows) = report::fidelity_pareto(&session);
    assert_eq!(
        rows.len(),
        session.models().len() * report::PARETO_INTEGRATIONS.len(),
        "one Pareto point per model per integration setting"
    );
    for want in ["SRGAN", "CycleGAN"] {
        let pts: Vec<_> = rows.iter().filter(|(m, _, _, _)| m == want).collect();
        assert_eq!(pts.len(), report::PARETO_INTEGRATIONS.len());
        for pair in pts.windows(2) {
            let (a, b) = (pair[0], pair[1]);
            assert!(b.1 > a.1, "{want}: rows ordered by integration");
            assert!(b.2 < a.2, "{want}: longer symbols must cost throughput");
            assert!(b.3 > a.3, "{want}: longer symbols must buy accuracy");
        }
        let lo = pts.first().expect("non-empty").3;
        let hi = pts.last().expect("non-empty").3;
        assert!(hi - lo > 0.01, "{want}: frontier must be non-degenerate ({lo}..{hi})");
    }
}
