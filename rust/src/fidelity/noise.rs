//! Typed noise model for the analog photonic datapath (ROADMAP item 4).
//!
//! Every number the simulator produces elsewhere assumes ideal analog
//! behavior. This module types the four places where that assumption
//! breaks — shot noise at the photodetector, inter-channel crosstalk on
//! the MR banks, thermal drift of MR resonances, and PCM conductance
//! drift with time-since-program — plus the ADC/DAC quantization floor
//! they all sit on. "Harnessing Optoelectronic Noises in a Photonic
//! Generative Network" (arXiv 2109.08622) motivates treating these as
//! first-class for GAN workloads.
//!
//! Every parameter is **derived from the device constants that already
//! drive the timing/energy simulator** ([`PhotonicParams`], [`Microring`],
//! `photonics::crosstalk`) — no new magic numbers:
//!
//! | source        | derivation                                              |
//! |---------------|---------------------------------------------------------|
//! | shot noise    | photons per symbol at PD sensitivity over one ADC symbol |
//! | crosstalk     | 2nd-order MR filter skirts at the layer's channel count  |
//! | thermal drift | TED residual fraction of a TO tuner, in linewidths/s     |
//! | PCM drift     | one weight LSB of conductance error per decade of age    |
//! | quantization  | ENOB floor of the 8-bit DAC→ADC conversion pair          |
//!
//! A single [`NoiseModel::scale`] multiplier scales every error
//! *amplitude*: `scale = 0.0` is [`NoiseModel::ideal`] (bit-exact with
//! the noiseless simulator, pinned by the golden-trace suite), `1.0` is
//! the paper-parameterized model, and intermediate values support
//! sensitivity sweeps. Sampling itself lives in
//! [`crate::fidelity::montecarlo`]; this module is pure parameters.

use crate::photonics::constants::PhotonicParams;
use crate::photonics::crosstalk;
use crate::photonics::mr::Microring;
use crate::util::units::dbm_to_watts;

/// Planck constant (J·s), for photon energy at the MR resonance.
const PLANCK_J_S: f64 = 6.626_070_15e-34;
/// Speed of light in vacuum (m/s) — same constant `arch::unit` uses for
/// waveguide time-of-flight.
const LIGHT_SPEED_M_S: f64 = 299_792_458.0;
/// ENOB relation `SNR_dB = 6.02·bits + 1.76` — the same constants behind
/// [`crosstalk::required_sxr_db`], inverted here to turn an SNR back into
/// effective bits.
const ENOB_SLOPE_DB_PER_BIT: f64 = 6.02;
const ENOB_OFFSET_DB: f64 = 1.76;

/// Invert the ENOB relation: effective bits delivered by `snr_db`,
/// clamped to `[0, cap_bits]` (an analog channel can never beat its own
/// converters).
pub fn effective_bits_for_snr_db(snr_db: f64, cap_bits: u32) -> f64 {
    ((snr_db - ENOB_OFFSET_DB) / ENOB_SLOPE_DB_PER_BIT).clamp(0.0, f64::from(cap_bits))
}

/// Analog noise parameters for one photonic MVM datapath.
///
/// All error terms are expressed as *relative* amplitudes on a
/// full-scale symbol, so variances add and `10·log10(1/σ²)` is directly
/// an SNR in dB.
#[derive(Debug, Clone, PartialEq)]
pub struct NoiseModel {
    /// The resonator the MR banks are built from — carries the resonance
    /// wavelength, linewidth, and filter-skirt shape every term below
    /// references.
    pub ring: Microring,
    /// Photons detected per analog symbol at the photodetector
    /// sensitivity floor (shot-noise statistics: relative variance is
    /// `1/photons`).
    pub photons_per_symbol: f64,
    /// Channel-count ceiling for the crosstalk term (the §IV 36-MR
    /// waveguide bound).
    pub max_channels: usize,
    /// Thermal resonance walk, in MR linewidths per second of operation:
    /// the TED-cancelled residual fraction of the TO tuner's thermal
    /// authority.
    pub drift_linewidths_per_s: f64,
    /// PCM conductance drift amplitude per decade of time-since-program:
    /// one weight LSB per decade.
    pub pcm_drift_per_decade: f64,
    /// Reference age for the PCM drift logarithm — the programming pulse
    /// width itself.
    pub pcm_program_s: f64,
    /// Time to thermally re-lock one MR bank during re-calibration (the
    /// TO tuner settle time).
    pub retune_s: f64,
    /// DAC/ADC precision (bits) — both the quantization noise floor and
    /// the cap on achievable effective bits.
    pub quantization_bits: u32,
    /// Global multiplier on every error *amplitude*. `0.0` disables all
    /// noise ([`NoiseModel::ideal`]); `1.0` is the paper model. With a
    /// fixed seed, realized errors scale monotonically with this knob.
    pub scale: f64,
}

impl NoiseModel {
    /// Derive every parameter from an existing device-constant bundle.
    pub fn from_params(p: &PhotonicParams) -> NoiseModel {
        let ring = Microring::default();
        // Photon energy at the MR resonance; one symbol lasts as long as
        // the slower converter in the DAC→MVM→ADC chain.
        let photon_j = PLANCK_J_S * LIGHT_SPEED_M_S / ring.resonant_wavelength();
        let symbol_s = p.device.dac_latency.max(p.device.adc_latency);
        let photons_per_symbol =
            dbm_to_watts(p.system.pd_sensitivity_dbm) * symbol_s / photon_j;
        // TED cancels most of a TO tuner's thermal authority; the
        // residual (0.75 / 27.5 mW per FSR) keeps walking the resonance.
        let drift_linewidths_per_s =
            p.device.to_ted_power_per_fsr / p.device.to_tuning_power_per_fsr;
        NoiseModel {
            photons_per_symbol,
            max_channels: p.system.max_mrs_per_waveguide,
            drift_linewidths_per_s,
            pcm_drift_per_decade: ring.max_quantization_error(p.system.precision_bits),
            pcm_program_s: p.device.pcmc_switch_latency,
            retune_s: p.device.to_tuning_latency,
            quantization_bits: p.system.precision_bits,
            scale: 1.0,
            ring,
        }
    }

    /// The paper-parameterized model ([`PhotonicParams::default`]).
    pub fn paper() -> NoiseModel {
        NoiseModel::from_params(&PhotonicParams::default())
    }

    /// The zero-noise model: identical parameters, `scale = 0.0`. Under
    /// this model the Monte Carlo driver reports exactly
    /// `quantization_bits` effective bits for every layer and leaves
    /// every golden trace bit-exact.
    pub fn ideal() -> NoiseModel {
        NoiseModel::paper().with_scale(0.0)
    }

    /// Same model with a different global error-amplitude multiplier.
    pub fn with_scale(mut self, scale: f64) -> NoiseModel {
        self.scale = scale;
        self
    }

    /// True when no noise is injected at all.
    pub fn is_ideal(&self) -> bool {
        self.scale == 0.0
    }

    /// The SNR ceiling (dB) imposed by the converters — no analog trial
    /// can report better than the quantization limit of the DAC/ADC
    /// pair, and capping here keeps infinities out of the JSON writer.
    pub fn snr_cap_db(&self) -> f64 {
        crosstalk::required_sxr_db(self.quantization_bits)
    }

    /// Relative shot-noise variance for one detection integrated over
    /// `integration` symbol times (Poisson statistics: `1/N` at `N`
    /// detected photons; longer integration collects more photons).
    pub fn shot_variance(&self, integration: f64) -> f64 {
        1.0 / (self.photons_per_symbol * integration)
    }

    /// Relative quantization-noise variance of the DAC→ADC pair: each
    /// converter contributes the ENOB floor at `quantization_bits`.
    pub fn quantization_variance(&self) -> f64 {
        2.0 * 10f64.powf(-self.snr_cap_db() / 10.0)
    }

    /// Relative crosstalk variance with `channels` active WDM channels
    /// on the waveguide (2nd-order MR filter skirts, §IV analysis).
    pub fn crosstalk_variance(&self, channels: usize) -> f64 {
        crosstalk::crosstalk_fraction(&self.ring, channels.min(self.max_channels))
    }

    /// Deterministic relative error of an MR programmed to full
    /// extinction after `age_s` seconds of uncorrected thermal drift:
    /// the through-port transmission leaked at the walked-off detuning.
    pub fn drift_error(&self, age_s: f64) -> f64 {
        let detuning = self.drift_linewidths_per_s * age_s * self.ring.linewidth();
        self.ring.through_transmission(detuning)
    }

    /// Relative PCM conductance error after `age_s` seconds since the
    /// programming pulse: one weight LSB per decade of normalized age.
    pub fn pcm_sigma(&self, age_s: f64) -> f64 {
        self.pcm_drift_per_decade * (1.0 + age_s / self.pcm_program_s).log10()
    }
}

impl Default for NoiseModel {
    fn default() -> Self {
        NoiseModel::paper()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parameters_derive_from_device_constants() {
        let p = PhotonicParams::default();
        let n = NoiseModel::paper();
        // −20 dBm over an 0.82 ns ADC symbol at ~1.55 µm is a few 1e4
        // photons — shot-limited near the 8-bit floor, as the paper's
        // precision choice implies.
        assert!(
            n.photons_per_symbol > 1e4 && n.photons_per_symbol < 1e6,
            "photons/symbol {}",
            n.photons_per_symbol
        );
        let ted_residual =
            p.device.to_ted_power_per_fsr / p.device.to_tuning_power_per_fsr;
        assert!((n.drift_linewidths_per_s - ted_residual).abs() < 1e-15);
        assert_eq!(
            n.pcm_drift_per_decade,
            n.ring.max_quantization_error(p.system.precision_bits)
        );
        assert_eq!(n.max_channels, p.system.max_mrs_per_waveguide);
        assert_eq!(n.quantization_bits, p.system.precision_bits);
        assert_eq!(n.pcm_program_s, p.device.pcmc_switch_latency);
        assert_eq!(n.retune_s, p.device.to_tuning_latency);
    }

    #[test]
    fn ideal_is_scale_zero_with_paper_parameters() {
        let ideal = NoiseModel::ideal();
        assert!(ideal.is_ideal());
        assert_eq!(ideal.with_scale(1.0), NoiseModel::paper());
        assert!(!NoiseModel::paper().is_ideal());
    }

    #[test]
    fn quantization_floor_matches_the_enob_relation() {
        let n = NoiseModel::paper();
        // one converter at the cap SNR has variance 10^(-cap/10); the
        // DAC→ADC pair doubles it
        let one = 10f64.powf(-n.snr_cap_db() / 10.0);
        assert!((n.quantization_variance() - 2.0 * one).abs() < 1e-18);
        // and the inverse relation recovers the bit budget at the cap
        let bits = effective_bits_for_snr_db(n.snr_cap_db(), n.quantization_bits);
        assert!((bits - 8.0).abs() < 1e-9, "cap SNR must map back to 8 bits, got {bits}");
        assert_eq!(effective_bits_for_snr_db(-3.0, 8), 0.0);
        assert_eq!(effective_bits_for_snr_db(1e6, 8), 8.0);
    }

    #[test]
    fn crosstalk_grows_with_channel_count_and_is_capped() {
        let n = NoiseModel::paper();
        assert_eq!(n.crosstalk_variance(1), 0.0);
        let few = n.crosstalk_variance(4);
        let many = n.crosstalk_variance(36);
        assert!(few > 0.0 && many > few, "few {few} many {many}");
        // past the §IV waveguide bound the model clamps
        assert_eq!(n.crosstalk_variance(400), many);
    }

    #[test]
    fn drift_and_pcm_errors_grow_monotonically_with_age() {
        let n = NoiseModel::paper();
        assert_eq!(n.drift_error(0.0), 0.0);
        assert_eq!(n.pcm_sigma(0.0), 0.0);
        let mut last_d = 0.0;
        let mut last_p = 0.0;
        for age in [1e-3, 1e-1, 1.0, 10.0] {
            let d = n.drift_error(age);
            let p = n.pcm_sigma(age);
            assert!(d > last_d, "drift at {age}s: {d} <= {last_d}");
            assert!(p > last_p, "pcm at {age}s: {p} <= {last_p}");
            last_d = d;
            last_p = p;
        }
        // drift saturates at full transmission leak
        assert!(n.drift_error(1e9) <= 1.0);
    }
}
