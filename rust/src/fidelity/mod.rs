//! Noise- and variation-aware fidelity engine (ROADMAP item 4).
//!
//! Turns the repo's latency/energy story into a latency/energy/accuracy
//! story. Three pieces:
//!
//! - [`noise`] — a typed [`noise::NoiseModel`] for the analog error
//!   sources of the photonic datapath (shot noise, MR crosstalk, thermal
//!   drift, PCM conductance drift, converter quantization), every
//!   parameter derived from the `photonics` device constants.
//! - [`montecarlo`] — a deterministic Monte Carlo driver that threads
//!   per-layer noise through the mapped jobs and the timing schedule,
//!   reporting SNR / effective bits per layer and per model alongside
//!   the untouched latency/energy numbers. Sweeping the symbol
//!   integration factor yields the accuracy-vs-throughput Pareto
//!   frontier ([`crate::report::fidelity_pareto`]).
//! - [`calibration`] — the drift-budget schedule: how long a shard can
//!   serve before re-calibration, feeding the availability dynamics of
//!   [`crate::workload::vserve`].
//!
//! Determinism: all sampling forks [`crate::util::rng::Pcg32`] child
//! streams, so envelopes are byte-identical per seed, and
//! [`noise::NoiseModel::ideal`] leaves every golden trace bit-exact.

pub mod calibration;
pub mod montecarlo;
pub mod noise;

pub use calibration::CalibrationModel;
pub use montecarlo::{evaluate, FidelityReport, LayerFidelity, MonteCarlo};
pub use noise::NoiseModel;
