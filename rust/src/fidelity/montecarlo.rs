//! Monte Carlo fidelity driver: per-layer SNR / effective-bits envelopes.
//!
//! Threads a [`NoiseModel`] through the same artifacts the timing
//! simulator already produces — the mapped [`LayerJob`]s (for each
//! layer's WDM channel count) and the [`SimReport`] layer schedule (for
//! each layer's position inside the drift window) — and reports an
//! accuracy proxy alongside the existing latency/energy numbers. The
//! proxy is an SNR: per trial, the relative error variances of shot
//! noise, crosstalk, thermal drift, PCM drift, and quantization add on a
//! full-scale symbol, and `10·log10(1/σ²)` (capped at the converter
//! limit) is the layer's delivered SNR, converted to effective bits via
//! the ENOB relation.
//!
//! Determinism contract: all sampling flows through [`Pcg32::fork`]
//! child streams — stream `seed → trial → layer` — so envelopes are
//! byte-identical per seed, independent of layer count or trial order
//! changes elsewhere. The driver never mutates the [`SimReport`]; with
//! [`NoiseModel::ideal`] the reported accuracy is exactly the
//! quantization bit budget and every golden trace stays bit-exact.
//!
//! The **integration factor** is the accuracy/throughput knob: holding a
//! symbol on the detector `f×` longer collects `f×` more photons
//! (shot variance `∝ 1/f`) but stretches the pipeline to `f×` the
//! latency (`gops ∝ 1/f`). Sweeping it yields the
//! [`crate::report::fidelity_pareto`] frontier.

use crate::fidelity::calibration::CalibrationModel;
use crate::fidelity::noise::{effective_bits_for_snr_db, NoiseModel};
use crate::sim::{LayerJob, SimReport};
use crate::util::json::{obj, JsonValue};
use crate::util::rng::Pcg32;

/// A Monte Carlo fidelity experiment: which noise model, how many
/// trials, how long each symbol integrates, and the root seed.
#[derive(Debug, Clone, PartialEq)]
pub struct MonteCarlo {
    /// Noise parameters (see [`NoiseModel`]).
    pub noise: NoiseModel,
    /// Independent noise realizations to average the envelope over.
    pub trials: usize,
    /// Symbol integration-time multiplier (`1.0` = the converter-paced
    /// symbol the timing model assumes).
    pub integration: f64,
    /// Root seed; all sampling forks from `Pcg32::new(seed)`.
    pub seed: u64,
}

impl Default for MonteCarlo {
    fn default() -> Self {
        MonteCarlo { noise: NoiseModel::paper(), trials: 32, integration: 1.0, seed: 0 }
    }
}

/// Fidelity envelope for one mapped layer.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerFidelity {
    /// Layer index (matches [`LayerJob::index`] / the `SimReport` trace).
    pub index: usize,
    /// Layer name.
    pub name: String,
    /// Active WDM channels the layer's widest MVM drives (the crosstalk
    /// operand), capped at the §IV waveguide bound.
    pub channels: usize,
    /// Mean delivered SNR over the trials (dB, capped at the converter
    /// limit).
    pub snr_db: f64,
    /// ENOB-equivalent bits at that SNR, in `[0, precision_bits]`.
    pub effective_bits: f64,
}

/// Fidelity + throughput summary for one model under one noise model.
#[derive(Debug, Clone, PartialEq)]
pub struct FidelityReport {
    /// Model name (from the underlying [`SimReport`]).
    pub model: String,
    /// Trials averaged into the envelope.
    pub trials: usize,
    /// Symbol integration-time multiplier the run used.
    pub integration: f64,
    /// Root seed.
    pub seed: u64,
    /// Batch latency stretched by the integration factor (s).
    pub latency_s: f64,
    /// Batch energy (J) — unchanged from the timing model.
    pub energy_j: f64,
    /// Throughput at the stretched symbol time (GOPS).
    pub gops: f64,
    /// MAC-weighted mean SNR across layers (dB).
    pub snr_db: f64,
    /// MAC-weighted mean effective bits across layers.
    pub effective_bits: f64,
    /// Worst layer's effective bits — the error a generated image
    /// actually sees is bounded by the weakest stage.
    pub min_effective_bits: f64,
    /// Per-layer envelopes, in mapping order.
    pub layers: Vec<LayerFidelity>,
}

impl FidelityReport {
    /// JSON form (order-stable; rendered byte-identically per seed).
    pub fn json(&self) -> JsonValue {
        let layers: Vec<JsonValue> = self
            .layers
            .iter()
            .map(|l| {
                obj(vec![
                    ("index", JsonValue::Num(l.index as f64)),
                    ("name", JsonValue::Str(l.name.clone())),
                    ("channels", JsonValue::Num(l.channels as f64)),
                    ("snr_db", JsonValue::Num(l.snr_db)),
                    ("effective_bits", JsonValue::Num(l.effective_bits)),
                ])
            })
            .collect();
        obj(vec![
            ("model", JsonValue::Str(self.model.clone())),
            ("trials", JsonValue::Num(self.trials as f64)),
            ("integration", JsonValue::Num(self.integration)),
            ("seed", JsonValue::Num(self.seed as f64)),
            ("latency_s", JsonValue::Num(self.latency_s)),
            ("energy_j", JsonValue::Num(self.energy_j)),
            ("gops", JsonValue::Num(self.gops)),
            ("snr_db", JsonValue::Num(self.snr_db)),
            ("effective_bits", JsonValue::Num(self.effective_bits)),
            ("min_effective_bits", JsonValue::Num(self.min_effective_bits)),
            ("layers", JsonValue::Arr(layers)),
        ])
    }
}

/// SNR (dB) for a realized total relative error variance, capped at the
/// converter limit (also the zero-variance answer, so the ideal model
/// never pushes an infinity toward the JSON writer).
fn snr_db_for_variance(variance: f64, cap_db: f64) -> f64 {
    if variance > 0.0 {
        (10.0 * (1.0 / variance).log10()).min(cap_db)
    } else {
        cap_db
    }
}

/// Run the Monte Carlo envelope for one mapped model.
///
/// `jobs` and `report` must come from the same `(model, batch, opts)`
/// mapping — the driver pairs `jobs[i]` with `report.layers[i]` to place
/// each layer inside the drift window. The report is only read; latency
/// and energy pass through untouched (stretched by the integration
/// factor for the throughput proxy).
pub fn evaluate(mc: &MonteCarlo, jobs: &[LayerJob], report: &SimReport) -> FidelityReport {
    assert!(mc.trials > 0, "Monte Carlo needs at least one trial");
    assert!(
        mc.integration.is_finite() && mc.integration > 0.0,
        "integration factor must be positive and finite: {}",
        mc.integration
    );
    let noise = &mc.noise;
    let cap_db = noise.snr_cap_db();
    // Drift and PCM ages are uniform over one calibration interval: the
    // serving layer re-locks resonances and re-programs weights each
    // outage, so steady state sees every phase of the window equally.
    let interval_s = CalibrationModel::from_noise(noise).interval_s();
    let window_s = if interval_s.is_finite() { interval_s } else { 0.0 };
    let root = Pcg32::new(mc.seed);
    let shot_sigma = noise.shot_variance(mc.integration).sqrt();
    let quant_var = noise.quantization_variance();
    let amplitude_sq = noise.scale * noise.scale;

    let mut layers = Vec::with_capacity(jobs.len());
    let mut weighted_snr = 0.0;
    let mut weighted_bits = 0.0;
    let mut weight = 0.0;
    let mut min_bits = f64::INFINITY;
    for (li, job) in jobs.iter().enumerate() {
        let channels = job
            .mvms
            .iter()
            .map(|m| m.reduction)
            .max()
            .unwrap_or(1)
            .clamp(1, noise.max_channels);
        let xt_sigma = noise.crosstalk_variance(channels).sqrt();
        let start_s = report.layers.get(li).map(|l| l.start).unwrap_or(0.0);
        let mut snr_sum = 0.0;
        for trial in 0..mc.trials {
            // stream: seed → trial → layer, so every (trial, layer)
            // cell draws from its own child stream
            let mut rng = root.fork(trial as u64).fork(li as u64);
            let drift_age = rng.f64() * window_s + start_s;
            let pcm_age = rng.f64() * window_s + start_s;
            let e_shot = rng.normal() * shot_sigma;
            let e_xt = rng.normal() * xt_sigma;
            let e_drift = noise.drift_error(drift_age);
            let e_pcm = noise.pcm_sigma(pcm_age);
            let variance = amplitude_sq
                * (e_shot * e_shot
                    + e_xt * e_xt
                    + quant_var
                    + e_drift * e_drift
                    + e_pcm * e_pcm);
            snr_sum += snr_db_for_variance(variance, cap_db);
        }
        let snr_db = snr_sum / mc.trials as f64;
        let effective_bits = effective_bits_for_snr_db(snr_db, noise.quantization_bits);
        let w = (job.dense_macs as f64).max(1.0);
        weighted_snr += w * snr_db;
        weighted_bits += w * effective_bits;
        weight += w;
        min_bits = min_bits.min(effective_bits);
        layers.push(LayerFidelity {
            index: job.index,
            name: job.name.clone(),
            channels,
            snr_db,
            effective_bits,
        });
    }

    let bit_budget = f64::from(noise.quantization_bits);
    let (snr_db, effective_bits) = if weight > 0.0 {
        (weighted_snr / weight, weighted_bits / weight)
    } else {
        (cap_db, bit_budget)
    };
    FidelityReport {
        model: report.model.clone(),
        trials: mc.trials,
        integration: mc.integration,
        seed: mc.seed,
        latency_s: report.latency * mc.integration,
        energy_j: report.energy.total(),
        gops: report.gops() / mc.integration,
        snr_db,
        effective_bits,
        min_effective_bits: if min_bits.is_finite() { min_bits } else { bit_budget },
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::accelerator::Accelerator;
    use crate::arch::config::ArchConfig;
    use crate::models::zoo;
    use crate::sim::mapper::map_model;
    use crate::sim::{simulate, OptFlags};

    fn fixtures() -> (Vec<LayerJob>, SimReport) {
        let model = zoo::dcgan();
        let acc = Accelerator::new(ArchConfig::paper_optimum()).expect("paper optimum");
        let jobs = map_model(&model, 1, &OptFlags::all());
        let report = simulate(&model, &acc, 1, OptFlags::all());
        (jobs, report)
    }

    #[test]
    fn same_seed_is_byte_identical_and_seeds_differ() {
        let (jobs, report) = fixtures();
        let mc = MonteCarlo { trials: 8, seed: 42, ..MonteCarlo::default() };
        let a = evaluate(&mc, &jobs, &report).json().render();
        let b = evaluate(&mc, &jobs, &report).json().render();
        assert_eq!(a, b, "same seed must be byte-identical");
        let other = MonteCarlo { seed: 43, ..mc };
        assert_ne!(a, evaluate(&other, &jobs, &report).json().render());
    }

    #[test]
    fn ideal_noise_reports_the_full_bit_budget() {
        let (jobs, report) = fixtures();
        let mc = MonteCarlo { noise: NoiseModel::ideal(), trials: 4, ..MonteCarlo::default() };
        let fr = evaluate(&mc, &jobs, &report);
        for l in &fr.layers {
            assert!((l.effective_bits - 8.0).abs() < 1e-9, "{}: {}", l.name, l.effective_bits);
            assert!((l.snr_db - mc.noise.snr_cap_db()).abs() < 1e-9);
        }
        assert!((fr.effective_bits - 8.0).abs() < 1e-9);
        assert!((fr.min_effective_bits - 8.0).abs() < 1e-9);
        // latency/energy pass straight through from the timing model
        assert_eq!(fr.latency_s, report.latency);
        assert_eq!(fr.energy_j, report.energy.total());
        assert_eq!(fr.gops, report.gops());
    }

    #[test]
    fn longer_integration_buys_accuracy_and_costs_throughput() {
        let (jobs, report) = fixtures();
        let mut last_bits = 0.0;
        let mut last_gops = f64::INFINITY;
        for f in [0.25, 1.0, 4.0] {
            let mc = MonteCarlo { trials: 8, integration: f, ..MonteCarlo::default() };
            let fr = evaluate(&mc, &jobs, &report);
            assert!(
                fr.effective_bits > last_bits,
                "integration {f}: {} <= {last_bits}",
                fr.effective_bits
            );
            assert!(fr.gops < last_gops, "integration {f}: gops must fall");
            last_bits = fr.effective_bits;
            last_gops = fr.gops;
        }
    }
}
