//! Calibration process model: how long drift may accumulate before a
//! shard must take a re-calibration outage, and how long that outage
//! lasts.
//!
//! Thermal drift walks every MR resonance off its programmed detuning at
//! [`NoiseModel::drift_linewidths_per_s`]. A deployment tolerates that
//! walk until the transmission error it induces reaches one weight LSB —
//! past that point the analog error is no longer hidden under the
//! quantization floor and the shard re-locks its rings (TO tuner settle)
//! and re-programs its PCM cells (programming pulse). The interval and
//! outage derived here are the physics-grounded defaults behind the
//! `calibration` knob of virtual-serve scenarios
//! ([`crate::workload::vserve::CalibrationConfig`]); scenarios may also
//! set the knob directly in milliseconds.

use crate::fidelity::noise::NoiseModel;

/// Drift-budget calibration schedule derived from a [`NoiseModel`].
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationModel {
    /// Resonance walk rate (linewidths/s) — copied from the noise model.
    pub drift_linewidths_per_s: f64,
    /// Accumulated detuning (in linewidths) at which the drift-induced
    /// transmission error equals one weight LSB.
    pub budget_linewidths: f64,
    /// Time to re-lock one MR bank and re-program its PCM cells (s).
    pub bank_retune_s: f64,
}

impl CalibrationModel {
    /// Derive the schedule: the budget is the detuning where the MR
    /// through-port leak equals the quantization step, and the per-bank
    /// retune cost is TO settle + PCM programming pulse.
    pub fn from_noise(noise: &NoiseModel) -> CalibrationModel {
        let lsb = noise.ring.max_quantization_error(noise.quantization_bits);
        let budget_linewidths =
            noise.ring.detuning_for_transmission(lsb) / noise.ring.linewidth();
        CalibrationModel {
            drift_linewidths_per_s: noise.drift_linewidths_per_s,
            budget_linewidths,
            bank_retune_s: noise.retune_s + noise.pcm_program_s,
        }
    }

    /// Seconds of operation before the drift budget is spent
    /// (`∞` when the model does not drift).
    pub fn interval_s(&self) -> f64 {
        if self.drift_linewidths_per_s > 0.0 {
            self.budget_linewidths / self.drift_linewidths_per_s
        } else {
            f64::INFINITY
        }
    }

    /// Outage length for a shard that re-calibrates `banks` MR banks
    /// sequentially.
    pub fn outage_s(&self, banks: usize) -> f64 {
        banks as f64 * self.bank_retune_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_model_recalibrates_on_a_sub_second_cadence() {
        let cal = CalibrationModel::from_noise(&NoiseModel::paper());
        let interval = cal.interval_s();
        // ~0.022 linewidths of budget against ~0.027 linewidths/s of
        // drift: the shard re-locks about once a second
        assert!(
            interval > 0.1 && interval < 10.0,
            "interval {interval}s is outside the physical ballpark"
        );
        assert!(cal.budget_linewidths > 0.0 && cal.budget_linewidths < 1.0);
        // outage scales linearly with bank count and is µs-class per bank
        let one = cal.outage_s(1);
        assert!(one > 1e-6 && one < 1e-4, "per-bank retune {one}s");
        assert!((cal.outage_s(8) - 8.0 * one).abs() < 1e-18);
    }

    #[test]
    fn a_drift_free_model_never_needs_recalibration() {
        let mut noise = NoiseModel::paper();
        noise.drift_linewidths_per_s = 0.0;
        assert_eq!(CalibrationModel::from_noise(&noise).interval_s(), f64::INFINITY);
    }
}
