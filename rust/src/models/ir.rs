//! SSA-style dataflow IR with a static verifier, pass framework, and
//! fusion-legality analysis.
//!
//! [`Graph::from_model`] lifts a [`Model`]'s flat layer list into values
//! and operations: every op names its operand value ids, which turns the
//! positional skip conventions ([`Layer::ResidualAdd`]'s `span`,
//! [`Layer::ConcatChw`]'s shape-matched source) into first-class dataflow
//! edges. On top of that sit:
//!
//! - [`Graph::verify`] — well-formedness (def-before-use, single
//!   assignment, acyclicity, operand arity, exactly one output) plus a
//!   full shape re-inference of every op, with typed [`IrError`]s that
//!   name the offending op position.
//! - [`PassManager`] — runs transform [`Pass`]es and re-verifies the
//!   graph (including shapes) after every one, so a buggy pass is caught
//!   at the pass boundary instead of in the mapper.
//! - [`DeadValueElimination`] — drops ops whose results can never reach
//!   the output, compacting value ids.
//! - [`fusion_groups`] — the legality analysis behind `OptFlags::fuse`:
//!   proves an MVM-headed chain (conv → norm → activation → skip-add /
//!   skip-concat) is single-consumer and side-effect-free so the mapper
//!   ([`crate::sim::mapper`]) may collapse it into one fused MVM+ECU
//!   `LayerJob`.
//!
//! The IR is the mapper's source of truth: `sim/mapper.rs` lowers from a
//! verified graph, so every simulated model has passed these checks.

use super::graph::Model;
use super::layer::{Layer, Shape, ShapeError};

/// An SSA value with its inferred shape. A value is defined exactly once —
/// by one op's `out`, or by appearing in [`Graph::inputs`].
#[derive(Debug, Clone, PartialEq)]
pub struct Value {
    pub shape: Shape,
}

/// One operation: a [`Layer`] applied to operand values.
#[derive(Debug, Clone, PartialEq)]
pub struct Op {
    /// Flat layer index in the source [`Model`] — the stable diagnostic
    /// handle (kept even after passes drop ops, so messages still point
    /// into the model definition).
    pub index: usize,
    pub layer: Layer,
    /// Operand value ids; `[0]` is the primary dataflow input. Skip
    /// layers ([`Layer::ResidualAdd`], [`Layer::ConcatChw`]) carry their
    /// skip source as an explicit second operand.
    pub operands: Vec<usize>,
    /// The value this op defines (single assignment).
    pub out: usize,
    /// Dense-equivalent workload MACs at batch 1.
    pub dense_macs: usize,
}

impl Op {
    /// Required operand count for this op's layer kind.
    pub fn arity(layer: &Layer) -> usize {
        match layer {
            Layer::ResidualAdd { .. } | Layer::ConcatChw(_) => 2,
            _ => 1,
        }
    }
}

/// A dataflow graph: ops in execution order over a value table.
#[derive(Debug, Clone, PartialEq)]
pub struct Graph {
    pub name: String,
    pub values: Vec<Value>,
    pub ops: Vec<Op>,
    /// Graph input value ids; `[0]` is the primary model input. Further
    /// entries are synthesized skip sources (a skip whose producer is not
    /// in the linear prefix).
    pub inputs: Vec<usize>,
    /// The single graph output value id.
    pub output: usize,
}

/// Typed verifier diagnostic. Every op-scoped variant names the position
/// of the offending op in [`Graph::ops`].
#[derive(Debug, Clone, PartialEq)]
pub enum IrError {
    /// The source layer list does not shape-propagate, so it cannot be
    /// lifted into a graph at all.
    Shape(ShapeError),
    /// A declared graph input id is outside the value table.
    BadInput { value: usize },
    /// An op references a value id outside the value table.
    DanglingValue { op: usize, value: usize },
    /// An operand is never defined by any op (and is not an input).
    UseBeforeDef { op: usize, value: usize },
    /// An operand is defined by this op or a later one — the dependence
    /// edges are not acyclic.
    Cycle { op: usize, value: usize },
    /// A value is assigned more than once (or shadows an input).
    Redefined { op: usize, value: usize },
    /// Wrong operand count for the op's layer kind.
    MissingOperand { op: usize, expected: usize, got: usize },
    /// Re-inference disagrees with a recorded shape.
    ShapeMismatch { op: usize, expected: String, got: String },
    /// Shape inference itself fails on the operand shapes.
    InferenceFailed { op: usize, reason: String },
    /// The graph output value does not exist or is never defined.
    BadOutput { value: usize, reason: String },
}

impl std::fmt::Display for IrError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IrError::Shape(e) => write!(f, "{e}"),
            IrError::BadInput { value } => {
                write!(f, "graph input references missing value v{value}")
            }
            IrError::DanglingValue { op, value } => {
                write!(f, "op {op}: references dangling value v{value}")
            }
            IrError::UseBeforeDef { op, value } => {
                write!(f, "op {op}: value v{value} is used but never defined")
            }
            IrError::Cycle { op, value } => {
                write!(f, "op {op}: operand v{value} is defined by a later op (cycle)")
            }
            IrError::Redefined { op, value } => {
                write!(f, "op {op}: value v{value} assigned more than once")
            }
            IrError::MissingOperand { op, expected, got } => {
                write!(f, "op {op}: expects {expected} operand(s), got {got}")
            }
            IrError::ShapeMismatch { op, expected, got } => {
                write!(f, "op {op}: shape mismatch (expected {expected}, got {got})")
            }
            IrError::InferenceFailed { op, reason } => {
                write!(f, "op {op}: shape inference failed: {reason}")
            }
            IrError::BadOutput { value, reason } => {
                write!(f, "graph output v{value}: {reason}")
            }
        }
    }
}

impl std::error::Error for IrError {}

impl From<ShapeError> for IrError {
    fn from(e: ShapeError) -> Self {
        IrError::Shape(e)
    }
}

impl Graph {
    /// Lift a flat layer list into dataflow form.
    ///
    /// Ops are created 1:1 with layers (op `i` keeps layer index `i`);
    /// value 0 is the primary input. Skip operands become explicit:
    /// `ResidualAdd { span }` names the value that entered layer
    /// `i − span` (the residual body's input), and `ConcatChw(extra)`
    /// names the **earliest** value of shape `Chw(extra, h, w)` — the
    /// encoder-side feature a U-Net decoder stage concatenates. A skip
    /// with no in-graph producer (degenerate span, no shape match)
    /// synthesizes an auxiliary graph input instead of failing, so the
    /// verifier — not the lifter — owns rejection.
    pub fn from_model(model: &Model) -> Result<Graph, IrError> {
        let infos = model.infos()?;
        let mut values = vec![Value { shape: model.input().clone() }];
        let mut inputs = vec![0usize];
        let mut ops: Vec<Op> = Vec::with_capacity(infos.len());
        // primary-input value id of each op, for span-addressed skips
        let mut op_in: Vec<usize> = Vec::with_capacity(infos.len());
        let mut cur = 0usize;
        for info in infos {
            let mut operands = vec![cur];
            match &info.layer {
                Layer::ResidualAdd { span } => {
                    let skip = if *span >= 1 && *span <= info.index {
                        op_in[info.index - span]
                    } else {
                        let id = values.len();
                        values.push(Value { shape: info.in_shape.clone() });
                        inputs.push(id);
                        id
                    };
                    operands.push(skip);
                }
                Layer::ConcatChw(extra) => {
                    let want = match info.in_shape {
                        Shape::Chw(_, h, w) => Shape::Chw(*extra, h, w),
                        // a Vec input is ill-formed; verify reports it
                        Shape::Vec(_) => Shape::Vec(*extra),
                    };
                    let skip = match values.iter().position(|v| v.shape == want) {
                        Some(id) => id,
                        None => {
                            let id = values.len();
                            values.push(Value { shape: want });
                            inputs.push(id);
                            id
                        }
                    };
                    operands.push(skip);
                }
                _ => {}
            }
            let out = values.len();
            values.push(Value { shape: info.out_shape.clone() });
            op_in.push(cur);
            ops.push(Op {
                index: info.index,
                layer: info.layer.clone(),
                operands,
                out,
                dense_macs: info.macs,
            });
            cur = out;
        }
        Ok(Graph { name: model.name.clone(), values, ops, inputs, output: cur })
    }

    /// Static verification: well-formedness plus full shape re-inference.
    ///
    /// Checks, in order: inputs exist; single assignment (no op redefines
    /// a value or shadows an input); operand arity per layer kind; every
    /// operand exists and is defined by an **earlier** op or an input
    /// (def-before-use ⇒ the dependence edges are acyclic); every op's
    /// recorded output shape equals what [`Layer::out_shape`] re-infers
    /// from the operand shapes (skip operands are shape-checked too); the
    /// single graph output exists and is defined.
    pub fn verify(&self) -> Result<(), IrError> {
        let n = self.values.len();
        let mut is_input = vec![false; n];
        for &id in &self.inputs {
            if id >= n {
                return Err(IrError::BadInput { value: id });
            }
            is_input[id] = true;
        }
        // single assignment, with the full def map built up front so a
        // use of a later def is reported as a cycle, not a missing def
        let mut def: Vec<Option<usize>> = vec![None; n];
        for (pos, op) in self.ops.iter().enumerate() {
            if op.out >= n {
                return Err(IrError::DanglingValue { op: pos, value: op.out });
            }
            if is_input[op.out] || def[op.out].is_some() {
                return Err(IrError::Redefined { op: pos, value: op.out });
            }
            def[op.out] = Some(pos);
        }
        for (pos, op) in self.ops.iter().enumerate() {
            let expected = Op::arity(&op.layer);
            if op.operands.len() != expected {
                return Err(IrError::MissingOperand {
                    op: pos,
                    expected,
                    got: op.operands.len(),
                });
            }
            for &v in &op.operands {
                if v >= n {
                    return Err(IrError::DanglingValue { op: pos, value: v });
                }
                if is_input[v] {
                    continue;
                }
                match def[v] {
                    None => return Err(IrError::UseBeforeDef { op: pos, value: v }),
                    Some(d) if d >= pos => {
                        return Err(IrError::Cycle { op: pos, value: v })
                    }
                    _ => {}
                }
            }
            // ---- shape re-inference --------------------------------
            let in_shape = &self.values[op.operands[0]].shape;
            let inferred = op
                .layer
                .out_shape(in_shape, op.index)
                .map_err(|e| IrError::InferenceFailed { op: pos, reason: e.to_string() })?;
            match &op.layer {
                Layer::ResidualAdd { .. } => {
                    let skip = &self.values[op.operands[1]].shape;
                    if skip != in_shape {
                        return Err(IrError::ShapeMismatch {
                            op: pos,
                            expected: format!("{in_shape:?}"),
                            got: format!("{skip:?}"),
                        });
                    }
                }
                Layer::ConcatChw(extra) => {
                    if let Shape::Chw(_, h, w) = *in_shape {
                        let want = Shape::Chw(*extra, h, w);
                        let skip = &self.values[op.operands[1]].shape;
                        if *skip != want {
                            return Err(IrError::ShapeMismatch {
                                op: pos,
                                expected: format!("{want:?}"),
                                got: format!("{skip:?}"),
                            });
                        }
                    }
                }
                _ => {}
            }
            let recorded = &self.values[op.out].shape;
            if *recorded != inferred {
                return Err(IrError::ShapeMismatch {
                    op: pos,
                    expected: format!("{inferred:?}"),
                    got: format!("{recorded:?}"),
                });
            }
        }
        if self.output >= n {
            return Err(IrError::BadOutput {
                value: self.output,
                reason: "output value does not exist".into(),
            });
        }
        if !is_input[self.output] && def[self.output].is_none() {
            return Err(IrError::BadOutput {
                value: self.output,
                reason: "output value is never defined".into(),
            });
        }
        Ok(())
    }
}

// ------------------------------------------------------------------------
// Pass framework.
// ------------------------------------------------------------------------

/// A graph-to-graph transform. Passes may assume the graph verifies on
/// entry ([`PassManager`] guarantees it) and must leave it verifiable.
pub trait Pass {
    fn name(&self) -> &'static str;
    /// Transform the graph in place; return whether anything changed.
    fn run(&self, g: &mut Graph) -> bool;
}

/// Runs passes in order, re-verifying the graph — well-formedness *and*
/// shape consistency — after every one, so a pass that breaks an
/// invariant is caught at its own boundary.
#[derive(Default)]
pub struct PassManager {
    passes: Vec<Box<dyn Pass>>,
}

impl PassManager {
    pub fn new() -> Self {
        PassManager { passes: Vec::new() }
    }

    /// The standard cleanup pipeline: dead-value elimination.
    pub fn standard() -> Self {
        PassManager::new().with(Box::new(DeadValueElimination))
    }

    pub fn with(mut self, pass: Box<dyn Pass>) -> Self {
        self.passes.push(pass);
        self
    }

    /// Verify, run every pass (re-verifying after each), and report which
    /// passes changed the graph.
    pub fn run(&self, g: &mut Graph) -> Result<Vec<&'static str>, IrError> {
        g.verify()?;
        let mut applied = Vec::new();
        for pass in &self.passes {
            if pass.run(g) {
                applied.push(pass.name());
            }
            g.verify()?;
        }
        Ok(applied)
    }
}

/// Removes ops whose results can never reach the graph output, then
/// compacts the value table. Declared graph inputs are always kept (they
/// are the graph's interface), as is the output.
pub struct DeadValueElimination;

impl Pass for DeadValueElimination {
    fn name(&self) -> &'static str {
        "dead-value-elimination"
    }

    fn run(&self, g: &mut Graph) -> bool {
        let n = g.values.len();
        let mut live = vec![false; n];
        live[g.output] = true;
        let mut keep = vec![false; g.ops.len()];
        for (pos, op) in g.ops.iter().enumerate().rev() {
            if live[op.out] {
                keep[pos] = true;
                for &v in &op.operands {
                    live[v] = true;
                }
            }
        }
        for &id in &g.inputs {
            live[id] = true;
        }
        if keep.iter().all(|&k| k) && live.iter().all(|&l| l) {
            return false;
        }
        let mut remap = vec![usize::MAX; n];
        let mut values = Vec::new();
        for (id, v) in g.values.iter().enumerate() {
            if live[id] {
                remap[id] = values.len();
                values.push(v.clone());
            }
        }
        g.ops = g
            .ops
            .iter()
            .zip(&keep)
            .filter(|(_, &k)| k)
            .map(|(op, _)| Op {
                index: op.index,
                layer: op.layer.clone(),
                operands: op.operands.iter().map(|&v| remap[v]).collect(),
                out: remap[op.out],
                dense_macs: op.dense_macs,
            })
            .collect();
        for id in &mut g.inputs {
            *id = remap[*id];
        }
        g.output = remap[g.output];
        g.values = values;
        true
    }
}

// ------------------------------------------------------------------------
// Fusion-legality analysis.
// ------------------------------------------------------------------------

/// A maximal fusable chain: an MVM-headed op (`Dense`/`Conv2d`/`ConvT2d`)
/// plus the consecutive elementwise tail proven safe to collapse into it.
#[derive(Debug, Clone, PartialEq)]
pub struct FusionGroup {
    /// Position in [`Graph::ops`] of the MVM head.
    pub head: usize,
    /// Consecutive tail op positions (norm / activation / skip-add /
    /// skip-concat) legal to fold into the head.
    pub tail: Vec<usize>,
}

/// Prove which chains may fuse. A tail op is admitted only when:
///
/// - its kind is side-effect-free elementwise or data movement
///   (`Norm`, `Act`, `ResidualAdd`, `ConcatChw`);
/// - its primary operand is the chain's current result and that value has
///   **exactly one consumer** (this op) and is not the graph output — so
///   collapsing it is invisible to the rest of the graph;
/// - every skip operand is defined **before the head** (or is a graph
///   input), so folding cannot reorder a definition past its use.
///
/// Every MVM-headed op yields a group (possibly with an empty tail);
/// groups never overlap.
pub fn fusion_groups(g: &Graph) -> Vec<FusionGroup> {
    let n = g.values.len();
    let mut is_input = vec![false; n];
    for &id in &g.inputs {
        if id < n {
            is_input[id] = true;
        }
    }
    let mut def = vec![None; n];
    let mut consumers = vec![0usize; n];
    for (pos, op) in g.ops.iter().enumerate() {
        if op.out < n {
            def[op.out] = Some(pos);
        }
        for &v in &op.operands {
            if v < n {
                consumers[v] += 1;
            }
        }
    }
    if g.output < n {
        consumers[g.output] += 1;
    }

    let mut groups = Vec::new();
    let mut pos = 0usize;
    while pos < g.ops.len() {
        let headed = matches!(
            g.ops[pos].layer,
            Layer::Dense { .. } | Layer::Conv2d { .. } | Layer::ConvT2d { .. }
        );
        if !headed {
            pos += 1;
            continue;
        }
        let head = pos;
        let mut tail = Vec::new();
        let mut cur = g.ops[head].out;
        let mut j = head + 1;
        while j < g.ops.len() {
            let op = &g.ops[j];
            let fusable = matches!(
                op.layer,
                Layer::Norm(_) | Layer::Act(_) | Layer::ResidualAdd { .. } | Layer::ConcatChw(_)
            );
            if !fusable
                || op.operands.first() != Some(&cur)
                || cur >= n
                || consumers[cur] != 1
            {
                break;
            }
            let side_ok = op.operands[1..].iter().all(|&v| {
                v < n
                    && match def[v] {
                        Some(d) => d < head,
                        None => is_input[v],
                    }
            });
            if !side_ok {
                break;
            }
            tail.push(j);
            cur = op.out;
            j += 1;
        }
        groups.push(FusionGroup { head, tail });
        pos = j.max(head + 1);
    }
    groups
}

/// Op positions whose result has no consumer and is not the graph output
/// — the first wave [`DeadValueElimination`] would drop. Exposed for
/// `photogan lint` diagnostics.
pub fn dead_ops(g: &Graph) -> Vec<usize> {
    let n = g.values.len();
    let mut consumers = vec![0usize; n];
    for op in &g.ops {
        for &v in &op.operands {
            if v < n {
                consumers[v] += 1;
            }
        }
    }
    g.ops
        .iter()
        .enumerate()
        .filter(|(_, op)| op.out < n && op.out != g.output && consumers[op.out] == 0)
        .map(|(pos, _)| pos)
        .collect()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::arch::activation::ActKind;
    use crate::arch::norm::NormKind;
    use crate::models::zoo;

    fn residual_toy() -> Model {
        Model::new(
            "res-toy",
            Shape::Chw(4, 8, 8),
            vec![
                Layer::Conv2d { in_ch: 4, out_ch: 4, k: 3, s: 1, p: 1, bias: false },
                Layer::Norm(NormKind::Batch),
                Layer::Act(ActKind::Relu),
                Layer::Conv2d { in_ch: 4, out_ch: 4, k: 3, s: 1, p: 1, bias: false },
                Layer::Norm(NormKind::Batch),
                Layer::ResidualAdd { span: 5 },
            ],
        )
    }

    #[test]
    fn from_model_verifies_for_the_whole_zoo() {
        for m in zoo::extended_generators() {
            let g = Graph::from_model(&m).unwrap();
            assert_eq!(g.ops.len(), m.layers().len(), "{}: ops are 1:1 with layers", m.name);
            g.verify().unwrap_or_else(|e| panic!("{}: {e}", m.name));
            // the graph output is the last op's value
            assert_eq!(g.output, g.ops.last().unwrap().out);
            // no dead ops in a linear model lift
            assert!(dead_ops(&g).is_empty(), "{}: unexpected dead ops", m.name);
        }
    }

    #[test]
    fn residual_skip_is_the_block_input() {
        let g = Graph::from_model(&residual_toy()).unwrap();
        let res = g.ops.last().unwrap();
        assert!(matches!(res.layer, Layer::ResidualAdd { .. }));
        assert_eq!(res.operands.len(), 2);
        // span 5 from layer 5 → the value entering layer 0: the graph input
        assert_eq!(res.operands[1], 0);
    }

    #[test]
    fn concat_skip_picks_the_earliest_shape_match() {
        let g = Graph::from_model(&zoo::pix2pix()).unwrap();
        g.verify().unwrap();
        for op in g.ops.iter().filter(|o| matches!(o.layer, Layer::ConcatChw(_))) {
            assert_eq!(op.operands.len(), 2, "concat must carry its skip operand");
            let skip = op.operands[1];
            let primary = op.operands[0];
            // the skip is a real in-graph value produced earlier, not a
            // synthesized auxiliary input
            assert!(!g.inputs.contains(&skip) || skip == 0);
            if let (Shape::Chw(_, h, w), Shape::Chw(_, sh, sw)) =
                (&g.values[primary].shape, &g.values[skip].shape)
            {
                assert_eq!((h, w), (sh, sw), "skip resolution must match the trunk");
            } else {
                panic!("concat operands must be Chw");
            }
        }
    }

    #[test]
    fn verifier_rejects_use_before_def() {
        let mut g = Graph::from_model(&residual_toy()).unwrap();
        // a value that exists but nothing defines
        let ghost = g.values.len();
        g.values.push(Value { shape: Shape::Chw(4, 8, 8) });
        g.ops[3].operands[0] = ghost;
        assert_eq!(g.verify(), Err(IrError::UseBeforeDef { op: 3, value: ghost }));
        assert!(format!("{}", g.verify().unwrap_err()).contains("op 3"));
    }

    #[test]
    fn verifier_rejects_cycles() {
        let mut g = Graph::from_model(&residual_toy()).unwrap();
        // op 1 consuming op 3's result is a forward (cyclic) edge
        let later = g.ops[3].out;
        g.ops[1].operands[0] = later;
        assert_eq!(g.verify(), Err(IrError::Cycle { op: 1, value: later }));
    }

    #[test]
    fn verifier_rejects_dangling_values() {
        let mut g = Graph::from_model(&residual_toy()).unwrap();
        g.ops[2].operands[0] = 999;
        assert_eq!(g.verify(), Err(IrError::DanglingValue { op: 2, value: 999 }));
    }

    #[test]
    fn verifier_rejects_double_assignment() {
        let mut g = Graph::from_model(&residual_toy()).unwrap();
        let prior = g.ops[0].out;
        g.ops[4].out = prior;
        assert_eq!(g.verify(), Err(IrError::Redefined { op: 4, value: prior }));
    }

    #[test]
    fn verifier_rejects_shape_mismatches() {
        let mut g = Graph::from_model(&residual_toy()).unwrap();
        let out = g.ops[3].out;
        g.values[out].shape = Shape::Chw(4, 9, 9);
        assert!(matches!(g.verify(), Err(IrError::ShapeMismatch { op: 3, .. })));
        // and a skip operand with the wrong shape is caught too
        let mut g2 = Graph::from_model(&residual_toy()).unwrap();
        let ghost = g2.values.len();
        g2.values.push(Value { shape: Shape::Chw(2, 8, 8) });
        g2.inputs.push(ghost);
        let last = g2.ops.len() - 1;
        g2.ops[last].operands[1] = ghost;
        assert!(matches!(g2.verify(), Err(IrError::ShapeMismatch { op, .. }) if op == last));
    }

    #[test]
    fn verifier_rejects_missing_operands_and_bad_output() {
        let mut g = Graph::from_model(&residual_toy()).unwrap();
        let last = g.ops.len() - 1;
        g.ops[last].operands.pop();
        assert_eq!(
            g.verify(),
            Err(IrError::MissingOperand { op: last, expected: 2, got: 1 })
        );
        let mut g2 = Graph::from_model(&residual_toy()).unwrap();
        g2.output = 999;
        assert!(matches!(g2.verify(), Err(IrError::BadOutput { value: 999, .. })));
    }

    #[test]
    fn dead_value_elimination_drops_unreachable_ops() {
        let mut g = Graph::from_model(&residual_toy()).unwrap();
        // graft a dead branch: an act on the stem that nothing consumes
        let dead_out = g.values.len();
        g.values.push(Value { shape: Shape::Chw(4, 8, 8) });
        g.ops.push(Op {
            index: 6,
            layer: Layer::Act(ActKind::Tanh),
            operands: vec![g.ops[0].out],
            out: dead_out,
            dense_macs: 0,
        });
        // keep the original output: the grafted op is dead by construction
        g.output = g.ops[g.ops.len() - 2].out;
        g.verify().unwrap();
        assert_eq!(dead_ops(&g), vec![g.ops.len() - 1]);
        let before = (g.ops.len(), g.values.len());
        let applied = PassManager::standard().run(&mut g).unwrap();
        assert_eq!(applied, vec!["dead-value-elimination"]);
        assert_eq!(g.ops.len(), before.0 - 1);
        assert!(g.values.len() < before.1);
        g.verify().unwrap();
        assert!(dead_ops(&g).is_empty());
        // a second run is a no-op
        assert!(PassManager::standard().run(&mut g).unwrap().is_empty());
    }

    #[test]
    fn pass_manager_rechecks_after_every_pass() {
        struct Breaker;
        impl Pass for Breaker {
            fn name(&self) -> &'static str {
                "breaker"
            }
            fn run(&self, g: &mut Graph) -> bool {
                g.values[g.output].shape = Shape::Vec(1);
                true
            }
        }
        let mut g = Graph::from_model(&residual_toy()).unwrap();
        let err = PassManager::new().with(Box::new(Breaker)).run(&mut g).unwrap_err();
        assert!(matches!(err, IrError::ShapeMismatch { .. }));
    }

    #[test]
    fn fusion_groups_prove_residual_blocks_fusable() {
        let g = Graph::from_model(&residual_toy()).unwrap();
        let groups = fusion_groups(&g);
        // head conv 0 absorbs norm+act; head conv 3 absorbs norm+residual
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0], FusionGroup { head: 0, tail: vec![1, 2] });
        assert_eq!(groups[1], FusionGroup { head: 3, tail: vec![4, 5] });
    }

    #[test]
    fn fusion_stops_at_multi_consumer_values() {
        // cyclegan residual bodies are fusable; the block *inputs* have two
        // consumers (next conv + the skip) and must never appear in a tail
        let g = Graph::from_model(&zoo::cyclegan()).unwrap();
        let groups = fusion_groups(&g);
        let fused_residuals = groups
            .iter()
            .flat_map(|grp| &grp.tail)
            .filter(|&&p| matches!(g.ops[p].layer, Layer::ResidualAdd { .. }))
            .count();
        assert_eq!(fused_residuals, 9, "all nine residual adds must prove fusable");
        // no op position appears in two groups
        let mut seen = std::collections::HashSet::new();
        for grp in &groups {
            assert!(seen.insert(grp.head));
            for &t in &grp.tail {
                assert!(seen.insert(t));
            }
        }
    }

    #[test]
    fn fusion_requires_skips_defined_before_the_head() {
        let g = Graph::from_model(&zoo::pix2pix()).unwrap();
        let groups = fusion_groups(&g);
        let fused_concats: Vec<usize> = groups
            .iter()
            .flat_map(|grp| grp.tail.iter().copied())
            .filter(|&p| matches!(g.ops[p].layer, Layer::ConcatChw(_)))
            .collect();
        assert_eq!(fused_concats.len(), 7, "all seven U-Net concats must prove fusable");
        for p in fused_concats {
            let skip = g.ops[p].operands[1];
            // the skip producer sits strictly before the chain head
            let def = g.ops.iter().position(|o| o.out == skip);
            let head = groups
                .iter()
                .find(|grp| grp.tail.contains(&p))
                .map(|grp| grp.head)
                .unwrap();
            match def {
                Some(d) => assert!(d < head),
                None => assert!(g.inputs.contains(&skip)),
            }
        }
    }
}
