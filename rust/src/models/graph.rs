//! Model graph: an ordered layer list with validated, memoized shape
//! propagation.

use super::layer::{Layer, Shape, ShapeError, UpsampleMode};
use crate::arch::norm::NormKind;
use std::sync::OnceLock;

/// A GAN model (generator or discriminator) as a validated layer sequence.
///
/// `PartialEq` compares the full layer structure — the
/// [`crate::api::Session`] mapping cache uses it to distinguish a
/// registered model from a same-named modified clone.
///
/// `input` and `layers` are construction-immutable (read them through
/// [`Model::input`] / [`Model::layers`]), which is what lets
/// [`Model::infos`] memoize shape propagation without any invalidation
/// story.
#[derive(Debug, Clone)]
pub struct Model {
    pub name: String,
    input: Shape,
    layers: Vec<Layer>,
    /// Memoized shape propagation. `OnceLock` so `&self` callers share
    /// one walk; cloning a model clones the cached result too.
    memo: OnceLock<Result<Vec<LayerInfo>, ShapeError>>,
}

impl PartialEq for Model {
    fn eq(&self, other: &Self) -> bool {
        // the memo is derived state — identity is name + structure
        self.name == other.name && self.input == other.input && self.layers == other.layers
    }
}

/// Per-layer record from shape propagation.
#[derive(Debug, Clone)]
pub struct LayerInfo {
    pub index: usize,
    pub layer: Layer,
    pub in_shape: Shape,
    pub out_shape: Shape,
    /// Dense-equivalent MACs (workload op count).
    pub macs: usize,
}

impl Model {
    pub fn new(name: &str, input: Shape, layers: Vec<Layer>) -> Self {
        Model { name: name.to_string(), input, layers, memo: OnceLock::new() }
    }

    /// The model's input shape.
    pub fn input(&self) -> &Shape {
        &self.input
    }

    /// The ordered layer list.
    pub fn layers(&self) -> &[Layer] {
        &self.layers
    }

    /// Propagate shapes through all layers; errors pinpoint the bad layer.
    ///
    /// Memoized: the first call walks the layers, every later call on the
    /// same model returns the cached slice. `Model::output`/`params` and
    /// the mapper loop used to re-run the full propagation per call,
    /// making multi-model sweeps accidentally quadratic.
    pub fn infos(&self) -> Result<&[LayerInfo], ShapeError> {
        match self.memo.get_or_init(|| self.propagate()) {
            Ok(infos) => Ok(infos),
            Err(e) => Err(e.clone()),
        }
    }

    fn propagate(&self) -> Result<Vec<LayerInfo>, ShapeError> {
        let mut shape = self.input.clone();
        let mut out = Vec::with_capacity(self.layers.len());
        for (i, l) in self.layers.iter().enumerate() {
            let next = l.out_shape(&shape, i)?;
            let macs = l.macs(&shape, i)?;
            out.push(LayerInfo {
                index: i,
                layer: l.clone(),
                in_shape: shape.clone(),
                out_shape: next.clone(),
                macs,
            });
            shape = next;
        }
        Ok(out)
    }

    /// Output shape of the whole model.
    pub fn output(&self) -> Result<Shape, ShapeError> {
        Ok(self
            .infos()?
            .last()
            .map(|i| i.out_shape.clone())
            .unwrap_or_else(|| self.input.clone()))
    }

    /// Total trainable parameters, including 2·C per normalization layer
    /// (γ and β) resolved from the propagated shapes.
    pub fn params(&self) -> Result<usize, ShapeError> {
        let mut total = 0usize;
        for info in self.infos()? {
            total += info.layer.params();
            if let Layer::Norm(kind) = &info.layer {
                if *kind != NormKind::None {
                    if let Shape::Chw(c, _, _) = info.in_shape {
                        total += 2 * c;
                    } else {
                        total += 2 * info.in_shape.elements();
                    }
                }
            }
        }
        Ok(total)
    }

    /// Total dense-equivalent MACs for one inference.
    pub fn total_macs(&self) -> Result<usize, ShapeError> {
        Ok(self.infos()?.iter().map(|i| i.macs).sum())
    }

    /// Fraction of MACs in transposed-convolution layers — drives how much
    /// the sparse dataflow can help a model (paper Fig. 12 discussion).
    pub fn tconv_mac_fraction(&self) -> Result<f64, ShapeError> {
        let infos = self.infos()?;
        let total: usize = infos.iter().map(|i| i.macs).sum();
        if total == 0 {
            return Ok(0.0);
        }
        let tconv: usize = infos
            .iter()
            .filter(|i| matches!(i.layer, Layer::ConvT2d { .. }))
            .map(|i| i.macs)
            .sum();
        Ok(tconv as f64 / total as f64)
    }

    /// Fraction of MACs in stride-1 convolutions that immediately follow a
    /// nearest-neighbor upsample — the second structured-redundancy class
    /// the sparse dataflow can fold (see [`crate::sparse::UpconvSpec`]),
    /// mirroring [`Model::tconv_mac_fraction`] for the extended zoo's
    /// upsample+conv generators.
    pub fn upsample_conv_mac_fraction(&self) -> Result<f64, ShapeError> {
        let infos = self.infos()?;
        let total: usize = infos.iter().map(|i| i.macs).sum();
        if total == 0 {
            return Ok(0.0);
        }
        let mut up = 0usize;
        for pair in infos.windows(2) {
            let upsampled = matches!(
                pair[0].layer,
                Layer::Upsample2d { mode: UpsampleMode::Nearest, scale } if scale > 1
            );
            if upsampled && matches!(pair[1].layer, Layer::Conv2d { s: 1, .. }) {
                up += pair[1].macs;
            }
        }
        Ok(up as f64 / total as f64)
    }

    /// Bytes of weights at the given precision.
    pub fn weight_bytes(&self, bits: u32) -> Result<usize, ShapeError> {
        Ok(self.params()? * bits as usize / 8)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::arch::activation::ActKind;

    fn toy() -> Model {
        Model::new(
            "toy",
            Shape::Vec(8),
            vec![
                Layer::Dense { in_f: 8, out_f: 16, bias: true },
                Layer::Act(ActKind::Relu),
                Layer::Reshape(4, 2, 2),
                Layer::ConvT2d { in_ch: 4, out_ch: 2, k: 4, s: 2, p: 1, bias: false },
                Layer::Norm(NormKind::Batch),
                Layer::Act(ActKind::Tanh),
            ],
        )
    }

    #[test]
    fn shapes_chain() {
        let m = toy();
        assert_eq!(m.output().unwrap(), Shape::Chw(2, 4, 4));
        let infos = m.infos().unwrap();
        assert_eq!(infos.len(), 6);
        assert_eq!(infos[3].out_shape, Shape::Chw(2, 4, 4));
    }

    #[test]
    fn infos_are_memoized() {
        let m = toy();
        let first = m.infos().unwrap().as_ptr();
        let second = m.infos().unwrap().as_ptr();
        assert_eq!(first, second, "repeat calls must return the cached propagation");
        // errors are memoized too
        let bad = Model::new(
            "bad",
            Shape::Vec(8),
            vec![Layer::Dense { in_f: 9, out_f: 4, bias: false }],
        );
        assert_eq!(bad.infos().unwrap_err(), bad.infos().unwrap_err());
    }

    #[test]
    fn equality_ignores_the_memo() {
        let a = toy();
        let b = toy();
        let _ = a.infos().unwrap(); // a is memoized, b is not
        assert_eq!(a, b);
        // a clone carries the cache but stays equal
        assert_eq!(a.clone(), b);
    }

    #[test]
    fn params_include_norm() {
        let m = toy();
        // dense 8·16+16 + tconv 4·2·16 + norm 2·2
        assert_eq!(m.params().unwrap(), 144 + 128 + 4);
    }

    #[test]
    fn macs_aggregate() {
        let m = toy();
        // dense 128 + relu 16 + tconv 2·4·4·4·16 + norm 2·32 + tanh 32
        assert_eq!(m.total_macs().unwrap(), 128 + 16 + 2048 + 64 + 32);
    }

    #[test]
    fn tconv_fraction_sensible() {
        let f = toy().tconv_mac_fraction().unwrap();
        assert!((f - 2048.0 / 2288.0).abs() < 1e-12);
    }

    #[test]
    fn upsample_conv_fraction_counts_only_foldable_convs() {
        let m = Model::new(
            "up-toy",
            Shape::Chw(4, 4, 4),
            vec![
                // foldable: nearest 2x followed by a stride-1 conv
                Layer::Upsample2d { mode: UpsampleMode::Nearest, scale: 2 },
                Layer::Conv2d { in_ch: 4, out_ch: 8, k: 3, s: 1, p: 1, bias: false },
                // not foldable: a plain conv with no preceding upsample
                Layer::Conv2d { in_ch: 8, out_ch: 8, k: 3, s: 1, p: 1, bias: false },
            ],
        );
        let infos = m.infos().unwrap();
        // conv over the 8x8 upsampled input: 8·8·8·4·9; second conv: 8·8·8·8·9
        assert_eq!(infos[1].macs, 8 * 8 * 8 * 4 * 9);
        let expect = infos[1].macs as f64 / (infos[1].macs + infos[2].macs) as f64;
        assert!((m.upsample_conv_mac_fraction().unwrap() - expect).abs() < 1e-12);
        // models without nearest upsampling report zero
        assert_eq!(toy().upsample_conv_mac_fraction().unwrap(), 0.0);
    }

    #[test]
    fn bad_chain_reports_layer_index() {
        let m = Model::new(
            "bad",
            Shape::Vec(8),
            vec![Layer::Dense { in_f: 9, out_f: 4, bias: false }],
        );
        let err = m.infos().unwrap_err();
        assert!(format!("{err}").contains("layer 0"));
    }
}
