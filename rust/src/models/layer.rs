//! Layer types, shape inference, and op/parameter counting.

use crate::arch::activation::ActKind;
use crate::arch::norm::NormKind;

/// Tensor shape flowing between layers (batch handled at the sim level).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Shape {
    /// Flat feature vector of length `n`.
    Vec(usize),
    /// Channel-major image tensor `[c, h, w]`.
    Chw(usize, usize, usize),
}

impl Shape {
    pub fn elements(&self) -> usize {
        match *self {
            Shape::Vec(n) => n,
            Shape::Chw(c, h, w) => c * h * w,
        }
    }
}

/// How an [`Layer::Upsample2d`] layer produces its `scale×` larger output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpsampleMode {
    /// Nearest-neighbor replication: every input element fills an
    /// `scale×scale` output block (ProGAN / StyleGAN2-style generators).
    /// A stride-1 conv that follows reads each input element up to `k²`
    /// times — the structured redundancy [`crate::sparse::UpconvSpec`]
    /// folds away.
    Nearest,
    /// Pixel shuffle (depth-to-space): `c·scale²` channels rearrange into
    /// `c` channels at `scale×` resolution (SRGAN-style). Pure data
    /// movement — the compute already happened in the conv that fattened
    /// the channels, so there is no redundancy left to eliminate.
    PixelShuffle,
}

/// One layer of a GAN model.
#[derive(Debug, Clone, PartialEq)]
pub enum Layer {
    /// Fully-connected: `out = W·in + b`.
    Dense { in_f: usize, out_f: usize, bias: bool },
    /// 2-D convolution (discriminator path), square kernel.
    Conv2d { in_ch: usize, out_ch: usize, k: usize, s: usize, p: usize, bias: bool },
    /// 2-D transposed convolution (generator path), square kernel.
    ConvT2d { in_ch: usize, out_ch: usize, k: usize, s: usize, p: usize, bias: bool },
    /// Batch/instance normalization (or explicit bypass `NormKind::None`).
    Norm(NormKind),
    /// Optical activation.
    Act(ActKind),
    /// Reshape a flat vector into `[c, h, w]` (ECU bookkeeping, zero ops).
    Reshape(usize, usize, usize),
    /// Flatten `[c, h, w]` into a vector.
    Flatten,
    /// Concatenate a conditioning vector of length `n` (CondGAN labels).
    ConcatVec(usize),
    /// Residual skip-add around the previous `span` layers (CycleGAN /
    /// SRGAN ResNet blocks): `out = in + f(in)`; one add per element.
    ResidualAdd { span: usize },
    /// Spatial upsampling (generator path): zero MACs — the layer moves
    /// data; the *following* conv carries the compute.
    Upsample2d { mode: UpsampleMode, scale: usize },
    /// Channel-wise concatenation of a skip tensor with `extra_ch`
    /// channels at the same resolution (U-Net decoder stages):
    /// `[c, h, w] → [c + extra_ch, h, w]`. The IR carries the channel
    /// arithmetic; the skip buffer traffic is charged by the mapper.
    ConcatChw(usize),
}

/// Error from shape inference.
#[derive(Debug, Clone, PartialEq)]
pub enum ShapeError {
    Mismatch { index: usize, layer: String, expected: String, got: String },
    BadReshape { index: usize, target: usize, input: usize },
    BadConv { index: usize, k: usize, s: usize, p: usize, h: usize, w: usize },
    BadUpsample { index: usize, scale: usize, channels: usize },
}

impl std::fmt::Display for ShapeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShapeError::Mismatch { index, layer, expected, got } => {
                write!(f, "layer {index} ({layer}) expects {expected}, got {got}")
            }
            ShapeError::BadReshape { index, target, input } => {
                write!(f, "layer {index}: reshape target {target} elements != input {input}")
            }
            ShapeError::BadConv { index, k, s, p, h, w } => {
                write!(f, "layer {index}: conv arithmetic invalid (k={k}, s={s}, p={p} on {h}x{w})")
            }
            ShapeError::BadUpsample { index, scale, channels } => {
                write!(
                    f,
                    "layer {index}: upsample scale {scale} invalid for {channels} channels \
                     (scale must be ≥ 1; pixel shuffle needs channels divisible by scale²)"
                )
            }
        }
    }
}

impl std::error::Error for ShapeError {}

impl Layer {
    /// Output shape for a given input shape.
    pub fn out_shape(&self, input: &Shape, index: usize) -> Result<Shape, ShapeError> {
        let mismatch = |expected: &str| ShapeError::Mismatch {
            index,
            layer: format!("{self:?}"),
            expected: expected.to_string(),
            got: format!("{input:?}"),
        };
        match self {
            Layer::Dense { in_f, out_f, .. } => match input {
                Shape::Vec(n) if n == in_f => Ok(Shape::Vec(*out_f)),
                _ => Err(mismatch(&format!("Vec({in_f})"))),
            },
            Layer::Conv2d { in_ch, out_ch, k, s, p, .. } => match *input {
                Shape::Chw(c, h, w) if c == *in_ch => {
                    if h + 2 * p < *k || w + 2 * p < *k || *s == 0 {
                        return Err(ShapeError::BadConv { index, k: *k, s: *s, p: *p, h, w });
                    }
                    let ho = (h + 2 * p - k) / s + 1;
                    let wo = (w + 2 * p - k) / s + 1;
                    Ok(Shape::Chw(*out_ch, ho, wo))
                }
                _ => Err(mismatch(&format!("Chw({in_ch}, _, _)"))),
            },
            Layer::ConvT2d { in_ch, out_ch, k, s, p, .. } => match *input {
                Shape::Chw(c, h, w) if c == *in_ch => {
                    if *s == 0 || (h - 1) * s + k < 2 * p {
                        return Err(ShapeError::BadConv { index, k: *k, s: *s, p: *p, h, w });
                    }
                    let ho = (h - 1) * s + k - 2 * p;
                    let wo = (w - 1) * s + k - 2 * p;
                    Ok(Shape::Chw(*out_ch, ho, wo))
                }
                _ => Err(mismatch(&format!("Chw({in_ch}, _, _)"))),
            },
            Layer::Norm(_) | Layer::Act(_) | Layer::ResidualAdd { .. } => Ok(input.clone()),
            Layer::Reshape(c, h, w) => {
                let target = c * h * w;
                if target == input.elements() {
                    Ok(Shape::Chw(*c, *h, *w))
                } else {
                    Err(ShapeError::BadReshape { index, target, input: input.elements() })
                }
            }
            Layer::Flatten => Ok(Shape::Vec(input.elements())),
            Layer::ConcatVec(n) => match input {
                Shape::Vec(m) => Ok(Shape::Vec(m + n)),
                _ => Err(mismatch("Vec(_)")),
            },
            Layer::Upsample2d { mode, scale } => match *input {
                Shape::Chw(c, h, w) => {
                    if *scale == 0 {
                        return Err(ShapeError::BadUpsample { index, scale: *scale, channels: c });
                    }
                    match mode {
                        UpsampleMode::Nearest => Ok(Shape::Chw(c, h * scale, w * scale)),
                        UpsampleMode::PixelShuffle => {
                            let s2 = scale * scale;
                            if c % s2 != 0 {
                                Err(ShapeError::BadUpsample {
                                    index,
                                    scale: *scale,
                                    channels: c,
                                })
                            } else {
                                Ok(Shape::Chw(c / s2, h * scale, w * scale))
                            }
                        }
                    }
                }
                _ => Err(mismatch("Chw(_, _, _)")),
            },
            Layer::ConcatChw(extra) => match *input {
                Shape::Chw(c, h, w) => Ok(Shape::Chw(c + extra, h, w)),
                _ => Err(mismatch("Chw(_, _, _)")),
            },
        }
    }

    /// Trainable parameter count.
    pub fn params(&self) -> usize {
        match self {
            Layer::Dense { in_f, out_f, bias } => in_f * out_f + if *bias { *out_f } else { 0 },
            Layer::Conv2d { in_ch, out_ch, k, bias, .. }
            | Layer::ConvT2d { in_ch, out_ch, k, bias, .. } => {
                in_ch * out_ch * k * k + if *bias { *out_ch } else { 0 }
            }
            // γ, β per channel — counted against the *input* channels, which
            // the caller resolves; we charge 0 here and let `Model::params`
            // add 2·C from the propagated shape.
            Layer::Norm(_) => 0,
            _ => 0,
        }
    }

    /// MAC count for this layer given its input shape (dense/standard
    /// counting — the workload-level op count every platform is scored
    /// against; the *sparse* execution count for ConvT2d comes from
    /// [`crate::sparse`]).
    pub fn macs(&self, input: &Shape, index: usize) -> Result<usize, ShapeError> {
        let out = self.out_shape(input, index)?;
        Ok(match self {
            Layer::Dense { in_f, out_f, .. } => in_f * out_f,
            Layer::Conv2d { in_ch, k, .. } => match out {
                Shape::Chw(oc, ho, wo) => oc * ho * wo * in_ch * k * k,
                _ => unreachable!(),
            },
            // dense-equivalent count: every output tap over the
            // zero-inserted input
            Layer::ConvT2d { in_ch, k, .. } => match out {
                Shape::Chw(oc, ho, wo) => oc * ho * wo * in_ch * k * k,
                _ => unreachable!(),
            },
            // ~2 MAC-equivalents per element (scale+shift)
            Layer::Norm(NormKind::None) => 0,
            Layer::Norm(_) => 2 * input.elements(),
            Layer::Act(ActKind::None) => 0,
            Layer::Act(_) => input.elements(),
            Layer::ResidualAdd { .. } => input.elements(),
            // pure data movement: replication/rearrangement/concat carry no
            // MACs — the adjacent convs own the compute (and, for nearest
            // upsampling, the redundancy the sparse dataflow folds away)
            Layer::Reshape(..)
            | Layer::Flatten
            | Layer::ConcatVec(_)
            | Layer::Upsample2d { .. }
            | Layer::ConcatChw(_) => 0,
        })
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn dense_shapes_and_params() {
        let l = Layer::Dense { in_f: 110, out_f: 6272, bias: true };
        assert_eq!(l.out_shape(&Shape::Vec(110), 0), Ok(Shape::Vec(6272)));
        assert_eq!(l.params(), 110 * 6272 + 6272);
        assert!(l.out_shape(&Shape::Vec(100), 0).is_err());
    }

    #[test]
    fn conv_shape_arithmetic() {
        // 64x64, k4 s2 p1 -> 32x32
        let l = Layer::Conv2d { in_ch: 3, out_ch: 64, k: 4, s: 2, p: 1, bias: false };
        assert_eq!(
            l.out_shape(&Shape::Chw(3, 64, 64), 0),
            Ok(Shape::Chw(64, 32, 32))
        );
    }

    #[test]
    fn tconv_shape_arithmetic() {
        // DCGAN stem: 1x1, k4 s1 p0 -> 4x4
        let l = Layer::ConvT2d { in_ch: 100, out_ch: 512, k: 4, s: 1, p: 0, bias: false };
        assert_eq!(
            l.out_shape(&Shape::Chw(100, 1, 1), 0),
            Ok(Shape::Chw(512, 4, 4))
        );
        // upsample: 8x8, k4 s2 p1 -> 16x16
        let l2 = Layer::ConvT2d { in_ch: 256, out_ch: 128, k: 4, s: 2, p: 1, bias: false };
        assert_eq!(
            l2.out_shape(&Shape::Chw(256, 8, 8), 0),
            Ok(Shape::Chw(128, 16, 16))
        );
    }

    #[test]
    fn conv_tconv_inverse_shapes() {
        // ConvT2d(k,s,p) inverts Conv2d(k,s,p) shape-wise
        let conv = Layer::Conv2d { in_ch: 8, out_ch: 16, k: 4, s: 2, p: 1, bias: false };
        let tconv = Layer::ConvT2d { in_ch: 16, out_ch: 8, k: 4, s: 2, p: 1, bias: false };
        let x = Shape::Chw(8, 32, 32);
        let y = conv.out_shape(&x, 0).unwrap();
        assert_eq!(tconv.out_shape(&y, 1).unwrap(), x);
    }

    #[test]
    fn mac_counts() {
        let l = Layer::Conv2d { in_ch: 3, out_ch: 64, k: 4, s: 2, p: 1, bias: false };
        // 64·32·32·3·16
        assert_eq!(l.macs(&Shape::Chw(3, 64, 64), 0).unwrap(), 64 * 32 * 32 * 3 * 16);
        let d = Layer::Dense { in_f: 100, out_f: 200, bias: true };
        assert_eq!(d.macs(&Shape::Vec(100), 0).unwrap(), 20_000);
    }

    #[test]
    fn reshape_checks_element_count() {
        let l = Layer::Reshape(128, 7, 7);
        assert_eq!(
            l.out_shape(&Shape::Vec(6272), 0),
            Ok(Shape::Chw(128, 7, 7))
        );
        assert!(matches!(
            l.out_shape(&Shape::Vec(100), 0),
            Err(ShapeError::BadReshape { .. })
        ));
    }

    #[test]
    fn concat_extends_vec() {
        let l = Layer::ConcatVec(10);
        assert_eq!(l.out_shape(&Shape::Vec(100), 0), Ok(Shape::Vec(110)));
    }

    #[test]
    fn nearest_upsample_scales_spatial_dims_only() {
        let l = Layer::Upsample2d { mode: UpsampleMode::Nearest, scale: 2 };
        assert_eq!(
            l.out_shape(&Shape::Chw(64, 8, 8), 0),
            Ok(Shape::Chw(64, 16, 16))
        );
        // data movement only: zero params, zero MACs
        assert_eq!(l.params(), 0);
        assert_eq!(l.macs(&Shape::Chw(64, 8, 8), 0), Ok(0));
        // a vector input is a shape mismatch
        assert!(l.out_shape(&Shape::Vec(64), 0).is_err());
    }

    #[test]
    fn pixel_shuffle_trades_channels_for_resolution() {
        let l = Layer::Upsample2d { mode: UpsampleMode::PixelShuffle, scale: 2 };
        assert_eq!(
            l.out_shape(&Shape::Chw(256, 24, 24), 0),
            Ok(Shape::Chw(64, 48, 48))
        );
        // element count is preserved — it is a pure rearrangement
        assert_eq!(
            l.out_shape(&Shape::Chw(256, 24, 24), 0).unwrap().elements(),
            256 * 24 * 24
        );
        // channels not divisible by scale² is a typed shape error
        assert!(matches!(
            l.out_shape(&Shape::Chw(10, 4, 4), 3),
            Err(ShapeError::BadUpsample { index: 3, scale: 2, channels: 10 })
        ));
    }

    #[test]
    fn concat_chw_extends_channels() {
        let l = Layer::ConcatChw(512);
        assert_eq!(
            l.out_shape(&Shape::Chw(512, 2, 2), 0),
            Ok(Shape::Chw(1024, 2, 2))
        );
        assert_eq!(l.params(), 0);
        assert_eq!(l.macs(&Shape::Chw(512, 2, 2), 0), Ok(0));
        assert!(l.out_shape(&Shape::Vec(512), 0).is_err());
    }

    #[test]
    fn zero_scale_upsample_is_rejected() {
        for mode in [UpsampleMode::Nearest, UpsampleMode::PixelShuffle] {
            let l = Layer::Upsample2d { mode, scale: 0 };
            assert!(matches!(
                l.out_shape(&Shape::Chw(8, 4, 4), 0),
                Err(ShapeError::BadUpsample { scale: 0, .. })
            ));
        }
    }
}
