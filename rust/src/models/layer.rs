//! Layer types, shape inference, and op/parameter counting.

use crate::arch::activation::ActKind;
use crate::arch::norm::NormKind;

/// Tensor shape flowing between layers (batch handled at the sim level).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Shape {
    /// Flat feature vector of length `n`.
    Vec(usize),
    /// Channel-major image tensor `[c, h, w]`.
    Chw(usize, usize, usize),
}

impl Shape {
    pub fn elements(&self) -> usize {
        match *self {
            Shape::Vec(n) => n,
            Shape::Chw(c, h, w) => c * h * w,
        }
    }
}

/// One layer of a GAN model.
#[derive(Debug, Clone, PartialEq)]
pub enum Layer {
    /// Fully-connected: `out = W·in + b`.
    Dense { in_f: usize, out_f: usize, bias: bool },
    /// 2-D convolution (discriminator path), square kernel.
    Conv2d { in_ch: usize, out_ch: usize, k: usize, s: usize, p: usize, bias: bool },
    /// 2-D transposed convolution (generator path), square kernel.
    ConvT2d { in_ch: usize, out_ch: usize, k: usize, s: usize, p: usize, bias: bool },
    /// Batch/instance normalization (or explicit bypass `NormKind::None`).
    Norm(NormKind),
    /// Optical activation.
    Act(ActKind),
    /// Reshape a flat vector into `[c, h, w]` (ECU bookkeeping, zero ops).
    Reshape(usize, usize, usize),
    /// Flatten `[c, h, w]` into a vector.
    Flatten,
    /// Concatenate a conditioning vector of length `n` (CondGAN labels).
    ConcatVec(usize),
    /// Residual skip-add around the previous `span` layers (CycleGAN
    /// ResNet blocks): `out = in + f(in)`; one add per element.
    ResidualAdd { span: usize },
}

/// Error from shape inference.
#[derive(Debug, Clone, PartialEq)]
pub enum ShapeError {
    Mismatch { index: usize, layer: String, expected: String, got: String },
    BadReshape { index: usize, target: usize, input: usize },
    BadConv { index: usize, k: usize, s: usize, p: usize, h: usize, w: usize },
}

impl std::fmt::Display for ShapeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShapeError::Mismatch { index, layer, expected, got } => {
                write!(f, "layer {index} ({layer}) expects {expected}, got {got}")
            }
            ShapeError::BadReshape { index, target, input } => {
                write!(f, "layer {index}: reshape target {target} elements != input {input}")
            }
            ShapeError::BadConv { index, k, s, p, h, w } => {
                write!(f, "layer {index}: conv arithmetic invalid (k={k}, s={s}, p={p} on {h}x{w})")
            }
        }
    }
}

impl std::error::Error for ShapeError {}

impl Layer {
    /// Output shape for a given input shape.
    pub fn out_shape(&self, input: &Shape, index: usize) -> Result<Shape, ShapeError> {
        let mismatch = |expected: &str| ShapeError::Mismatch {
            index,
            layer: format!("{self:?}"),
            expected: expected.to_string(),
            got: format!("{input:?}"),
        };
        match self {
            Layer::Dense { in_f, out_f, .. } => match input {
                Shape::Vec(n) if n == in_f => Ok(Shape::Vec(*out_f)),
                _ => Err(mismatch(&format!("Vec({in_f})"))),
            },
            Layer::Conv2d { in_ch, out_ch, k, s, p, .. } => match *input {
                Shape::Chw(c, h, w) if c == *in_ch => {
                    if h + 2 * p < *k || w + 2 * p < *k || *s == 0 {
                        return Err(ShapeError::BadConv { index, k: *k, s: *s, p: *p, h, w });
                    }
                    let ho = (h + 2 * p - k) / s + 1;
                    let wo = (w + 2 * p - k) / s + 1;
                    Ok(Shape::Chw(*out_ch, ho, wo))
                }
                _ => Err(mismatch(&format!("Chw({in_ch}, _, _)"))),
            },
            Layer::ConvT2d { in_ch, out_ch, k, s, p, .. } => match *input {
                Shape::Chw(c, h, w) if c == *in_ch => {
                    if *s == 0 || (h - 1) * s + k < 2 * p {
                        return Err(ShapeError::BadConv { index, k: *k, s: *s, p: *p, h, w });
                    }
                    let ho = (h - 1) * s + k - 2 * p;
                    let wo = (w - 1) * s + k - 2 * p;
                    Ok(Shape::Chw(*out_ch, ho, wo))
                }
                _ => Err(mismatch(&format!("Chw({in_ch}, _, _)"))),
            },
            Layer::Norm(_) | Layer::Act(_) | Layer::ResidualAdd { .. } => Ok(input.clone()),
            Layer::Reshape(c, h, w) => {
                let target = c * h * w;
                if target == input.elements() {
                    Ok(Shape::Chw(*c, *h, *w))
                } else {
                    Err(ShapeError::BadReshape { index, target, input: input.elements() })
                }
            }
            Layer::Flatten => Ok(Shape::Vec(input.elements())),
            Layer::ConcatVec(n) => match input {
                Shape::Vec(m) => Ok(Shape::Vec(m + n)),
                _ => Err(mismatch("Vec(_)")),
            },
        }
    }

    /// Trainable parameter count.
    pub fn params(&self) -> usize {
        match self {
            Layer::Dense { in_f, out_f, bias } => in_f * out_f + if *bias { *out_f } else { 0 },
            Layer::Conv2d { in_ch, out_ch, k, bias, .. }
            | Layer::ConvT2d { in_ch, out_ch, k, bias, .. } => {
                in_ch * out_ch * k * k + if *bias { *out_ch } else { 0 }
            }
            // γ, β per channel — counted against the *input* channels, which
            // the caller resolves; we charge 0 here and let `Model::params`
            // add 2·C from the propagated shape.
            Layer::Norm(_) => 0,
            _ => 0,
        }
    }

    /// MAC count for this layer given its input shape (dense/standard
    /// counting — the workload-level op count every platform is scored
    /// against; the *sparse* execution count for ConvT2d comes from
    /// [`crate::sparse`]).
    pub fn macs(&self, input: &Shape, index: usize) -> Result<usize, ShapeError> {
        let out = self.out_shape(input, index)?;
        Ok(match self {
            Layer::Dense { in_f, out_f, .. } => in_f * out_f,
            Layer::Conv2d { in_ch, k, .. } => match out {
                Shape::Chw(oc, ho, wo) => oc * ho * wo * in_ch * k * k,
                _ => unreachable!(),
            },
            // dense-equivalent count: every output tap over the
            // zero-inserted input
            Layer::ConvT2d { in_ch, k, .. } => match out {
                Shape::Chw(oc, ho, wo) => oc * ho * wo * in_ch * k * k,
                _ => unreachable!(),
            },
            // ~2 MAC-equivalents per element (scale+shift)
            Layer::Norm(NormKind::None) => 0,
            Layer::Norm(_) => 2 * input.elements(),
            Layer::Act(ActKind::None) => 0,
            Layer::Act(_) => input.elements(),
            Layer::ResidualAdd { .. } => input.elements(),
            Layer::Reshape(..) | Layer::Flatten | Layer::ConcatVec(_) => 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_shapes_and_params() {
        let l = Layer::Dense { in_f: 110, out_f: 6272, bias: true };
        assert_eq!(l.out_shape(&Shape::Vec(110), 0), Ok(Shape::Vec(6272)));
        assert_eq!(l.params(), 110 * 6272 + 6272);
        assert!(l.out_shape(&Shape::Vec(100), 0).is_err());
    }

    #[test]
    fn conv_shape_arithmetic() {
        // 64x64, k4 s2 p1 -> 32x32
        let l = Layer::Conv2d { in_ch: 3, out_ch: 64, k: 4, s: 2, p: 1, bias: false };
        assert_eq!(
            l.out_shape(&Shape::Chw(3, 64, 64), 0),
            Ok(Shape::Chw(64, 32, 32))
        );
    }

    #[test]
    fn tconv_shape_arithmetic() {
        // DCGAN stem: 1x1, k4 s1 p0 -> 4x4
        let l = Layer::ConvT2d { in_ch: 100, out_ch: 512, k: 4, s: 1, p: 0, bias: false };
        assert_eq!(
            l.out_shape(&Shape::Chw(100, 1, 1), 0),
            Ok(Shape::Chw(512, 4, 4))
        );
        // upsample: 8x8, k4 s2 p1 -> 16x16
        let l2 = Layer::ConvT2d { in_ch: 256, out_ch: 128, k: 4, s: 2, p: 1, bias: false };
        assert_eq!(
            l2.out_shape(&Shape::Chw(256, 8, 8), 0),
            Ok(Shape::Chw(128, 16, 16))
        );
    }

    #[test]
    fn conv_tconv_inverse_shapes() {
        // ConvT2d(k,s,p) inverts Conv2d(k,s,p) shape-wise
        let conv = Layer::Conv2d { in_ch: 8, out_ch: 16, k: 4, s: 2, p: 1, bias: false };
        let tconv = Layer::ConvT2d { in_ch: 16, out_ch: 8, k: 4, s: 2, p: 1, bias: false };
        let x = Shape::Chw(8, 32, 32);
        let y = conv.out_shape(&x, 0).unwrap();
        assert_eq!(tconv.out_shape(&y, 1).unwrap(), x);
    }

    #[test]
    fn mac_counts() {
        let l = Layer::Conv2d { in_ch: 3, out_ch: 64, k: 4, s: 2, p: 1, bias: false };
        // 64·32·32·3·16
        assert_eq!(l.macs(&Shape::Chw(3, 64, 64), 0).unwrap(), 64 * 32 * 32 * 3 * 16);
        let d = Layer::Dense { in_f: 100, out_f: 200, bias: true };
        assert_eq!(d.macs(&Shape::Vec(100), 0).unwrap(), 20_000);
    }

    #[test]
    fn reshape_checks_element_count() {
        let l = Layer::Reshape(128, 7, 7);
        assert_eq!(
            l.out_shape(&Shape::Vec(6272), 0),
            Ok(Shape::Chw(128, 7, 7))
        );
        assert!(matches!(
            l.out_shape(&Shape::Vec(100), 0),
            Err(ShapeError::BadReshape { .. })
        ));
    }

    #[test]
    fn concat_extends_vec() {
        let l = Layer::ConcatVec(10);
        assert_eq!(l.out_shape(&Shape::Vec(100), 0), Ok(Shape::Vec(110)));
    }
}
