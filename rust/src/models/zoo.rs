//! The evaluated GAN model zoo: the paper's four Table 1 models (plus
//! their discriminators) and four paper-adjacent generators that broaden
//! the layer vocabulary the accelerator study exercises.
//!
//! Paper Table 1 (served by [`all_generators`]):
//!
//! | Model      | Dataset       | Params (paper) |
//! |------------|---------------|----------------|
//! | DCGAN      | celebA        | 3.98 M         |
//! | Cond. GAN  | F-MNIST       | 1.17 M         |
//! | ArtGAN     | Art Portraits | 1.27 M         |
//! | CycleGAN   | horse2zebra   | 11.38 M        |
//!
//! Extended zoo (served by [`extended_generators`] — what the
//! [`crate::api::Session`] registers, turning every downstream consumer
//! into an 8-model study). GANAX (arXiv:1806.01107) motivates the
//! breadth: GAN families differ structurally, and each of these exercises
//! a distinct generator idiom:
//!
//! | Model     | Idiom                                    | Params (ref) |
//! |-----------|------------------------------------------|--------------|
//! | SRGAN     | residual stack + pixel-shuffle upsampling | ~1.55 M     |
//! | Pix2Pix   | U-Net: tconv decoder + skip concatenation | ~54.4 M     |
//! | StyleGAN2 | nearest-upsample + conv synthesis stack   | ~14.0 M     |
//! | ProGAN    | nearest-upsample + conv, progressive schedule | ~13.6 M |
//!
//! Architectures follow the models' reference implementations at the image
//! sizes the datasets imply; each builder's parameter count is asserted
//! (±10%) in the tests below.

use super::graph::Model;
use super::layer::{Layer, Shape, UpsampleMode};
use crate::arch::activation::ActKind;
use crate::arch::norm::NormKind;

const LRELU: ActKind = ActKind::LeakyRelu(0.2);
/// PReLU (SRGAN) modeled as a fixed-slope leaky ReLU — the optical
/// comparator + dual-SOA unit realizes any fixed slope (§III.B.4).
const PRELU: ActKind = ActKind::LeakyRelu(0.25);

fn tconv(in_ch: usize, out_ch: usize, k: usize, s: usize, p: usize) -> Layer {
    Layer::ConvT2d { in_ch, out_ch, k, s, p, bias: false }
}

fn conv(in_ch: usize, out_ch: usize, k: usize, s: usize, p: usize) -> Layer {
    Layer::Conv2d { in_ch, out_ch, k, s, p, bias: false }
}

/// DCGAN generator [28] for 64×64 celebA: z(100) → 4×4×512 stem, four
/// stride-2 transposed convs, BN + ReLU, tanh output.
pub fn dcgan() -> Model {
    Model::new(
        "DCGAN",
        Shape::Chw(100, 1, 1),
        vec![
            tconv(100, 512, 4, 1, 0), // 4x4
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            tconv(512, 256, 4, 2, 1), // 8x8
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            tconv(256, 128, 4, 2, 1), // 16x16
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            tconv(128, 64, 4, 2, 1), // 32x32
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            // output stage: 3x3 refinement + to-RGB, per the celebA variant
            conv(64, 64, 3, 1, 1),
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            tconv(64, 3, 4, 2, 1), // 64x64
            Layer::Act(ActKind::Tanh),
        ],
    )
}

/// DCGAN discriminator: mirrored stride-2 convs with LeakyReLU.
pub fn dcgan_discriminator() -> Model {
    Model::new(
        "DCGAN-D",
        Shape::Chw(3, 64, 64),
        vec![
            conv(3, 64, 4, 2, 1), // 32
            Layer::Act(LRELU),
            conv(64, 128, 4, 2, 1), // 16
            Layer::Norm(NormKind::Batch),
            Layer::Act(LRELU),
            conv(128, 256, 4, 2, 1), // 8
            Layer::Norm(NormKind::Batch),
            Layer::Act(LRELU),
            conv(256, 512, 4, 2, 1), // 4
            Layer::Norm(NormKind::Batch),
            Layer::Act(LRELU),
            conv(512, 1, 4, 1, 0), // 1x1 logit
            Layer::Act(ActKind::Sigmoid),
        ],
    )
}

/// Conditional GAN generator [29] for 28×28 F-MNIST: z(100) ⊕ label(10) →
/// dense to 7×7×128, two stride-2 transposed convs, BN + ReLU, 3×3 to-gray,
/// tanh.
pub fn condgan() -> Model {
    Model::new(
        "CondGAN",
        Shape::Vec(100),
        vec![
            Layer::ConcatVec(10), // one-hot label conditioning
            Layer::Dense { in_f: 110, out_f: 128 * 7 * 7, bias: true },
            Layer::Act(ActKind::Relu),
            Layer::Reshape(128, 7, 7),
            Layer::Norm(NormKind::Batch),
            tconv(128, 128, 4, 2, 1), // 14x14
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            tconv(128, 64, 4, 2, 1), // 28x28
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            conv(64, 1, 3, 1, 1),
            Layer::Act(ActKind::Tanh),
        ],
    )
}

/// CondGAN discriminator (label-conditioned PatchGAN-lite on 28×28).
pub fn condgan_discriminator() -> Model {
    Model::new(
        "CondGAN-D",
        Shape::Chw(11, 28, 28), // image + broadcast one-hot label planes
        vec![
            conv(11, 64, 4, 2, 1), // 14
            Layer::Act(LRELU),
            conv(64, 128, 4, 2, 1), // 7
            Layer::Norm(NormKind::Batch),
            Layer::Act(LRELU),
            Layer::Flatten,
            Layer::Dense { in_f: 128 * 7 * 7, out_f: 1, bias: true },
            Layer::Act(ActKind::Sigmoid),
        ],
    )
}

/// ArtGAN generator [30] for 64×64 art portraits: z(100) ⊕ genre(10) →
/// dense to 4×4×288, four stride-2 transposed convs, BN + ReLU, tanh.
pub fn artgan() -> Model {
    Model::new(
        "ArtGAN",
        Shape::Vec(100),
        vec![
            Layer::ConcatVec(10),
            Layer::Dense { in_f: 110, out_f: 288 * 4 * 4, bias: true },
            Layer::Act(ActKind::Relu),
            Layer::Reshape(288, 4, 4),
            Layer::Norm(NormKind::Batch),
            tconv(288, 128, 4, 2, 1), // 8x8
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            tconv(128, 64, 4, 2, 1), // 16x16
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            tconv(64, 32, 4, 2, 1), // 32x32
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            tconv(32, 3, 4, 2, 1), // 64x64
            Layer::Act(ActKind::Tanh),
        ],
    )
}

/// CycleGAN generator [31] for 256×256 horse2zebra: c7s1-64, d128, d256,
/// nine 256-channel ResNet blocks with InstanceNorm, u128, u64, c7s1-3.
/// This is the reference 11.38 M-parameter configuration.
pub fn cyclegan() -> Model {
    let mut layers = vec![
        conv(3, 64, 7, 1, 3), // c7s1-64
        Layer::Norm(NormKind::Instance),
        Layer::Act(ActKind::Relu),
        conv(64, 128, 3, 2, 1), // d128 -> 128x128
        Layer::Norm(NormKind::Instance),
        Layer::Act(ActKind::Relu),
        conv(128, 256, 3, 2, 1), // d256 -> 64x64
        Layer::Norm(NormKind::Instance),
        Layer::Act(ActKind::Relu),
    ];
    for _ in 0..9 {
        // ResNet block: conv-IN-ReLU-conv-IN + skip
        layers.extend([
            conv(256, 256, 3, 1, 1),
            Layer::Norm(NormKind::Instance),
            Layer::Act(ActKind::Relu),
            conv(256, 256, 3, 1, 1),
            Layer::Norm(NormKind::Instance),
            Layer::ResidualAdd { span: 5 },
        ]);
    }
    layers.extend([
        // u128/u64: the reference uses k3 s2 with output_padding=1; our IR
        // expresses the same exact 2x upsample as k4 s2 p1 (identical
        // output shape, +2% params — within the Table 1 tolerance).
        tconv(256, 128, 4, 2, 1), // u128 -> 128x128
        Layer::Norm(NormKind::Instance),
        Layer::Act(ActKind::Relu),
        tconv(128, 64, 4, 2, 1), // u64 -> 256x256
        Layer::Norm(NormKind::Instance),
        Layer::Act(ActKind::Relu),
        conv(64, 3, 7, 1, 3), // c7s1-3
        Layer::Act(ActKind::Tanh),
    ]);
    Model::new("CycleGAN", Shape::Chw(3, 256, 256), layers)
}

/// CycleGAN 70×70 PatchGAN discriminator.
pub fn cyclegan_discriminator() -> Model {
    Model::new(
        "CycleGAN-D",
        Shape::Chw(3, 256, 256),
        vec![
            conv(3, 64, 4, 2, 1),
            Layer::Act(LRELU),
            conv(64, 128, 4, 2, 1),
            Layer::Norm(NormKind::Instance),
            Layer::Act(LRELU),
            conv(128, 256, 4, 2, 1),
            Layer::Norm(NormKind::Instance),
            Layer::Act(LRELU),
            conv(256, 512, 4, 1, 1),
            Layer::Norm(NormKind::Instance),
            Layer::Act(LRELU),
            conv(512, 1, 4, 1, 1),
        ],
    )
}

/// SRGAN generator (Ledig et al.) for ×4 super-resolution of 24×24 inputs:
/// k9 stem, 16 residual blocks (conv-BN-PReLU-conv-BN + skip), a global
/// skip, two pixel-shuffle ×2 upsample stages, k9 to-RGB.
///
/// The interesting property for PhotoGAN: upsampling happens by **pixel
/// shuffle**, so the convs always run at the *low* resolution with fat
/// channels — there is no structured redundancy for the sparse dataflow to
/// fold (contrast [`stylegan2`]/[`progan`]), making SRGAN the zoo's
/// sparse-neutral control.
pub fn srgan() -> Model {
    let mut layers = vec![
        conv(3, 64, 9, 1, 4), // k9 stem at 24x24
        Layer::Act(PRELU),
    ];
    for _ in 0..16 {
        // residual block: conv-BN-PReLU-conv-BN + skip
        layers.extend([
            conv(64, 64, 3, 1, 1),
            Layer::Norm(NormKind::Batch),
            Layer::Act(PRELU),
            conv(64, 64, 3, 1, 1),
            Layer::Norm(NormKind::Batch),
            Layer::ResidualAdd { span: 5 },
        ]);
    }
    layers.extend([
        // post-residual conv + the global skip over the whole trunk
        conv(64, 64, 3, 1, 1),
        Layer::Norm(NormKind::Batch),
        Layer::ResidualAdd { span: 98 },
        // two ×2 pixel-shuffle stages: conv to 4·64 channels, rearrange
        conv(64, 256, 3, 1, 1),
        Layer::Upsample2d { mode: UpsampleMode::PixelShuffle, scale: 2 }, // 48x48
        Layer::Act(PRELU),
        conv(64, 256, 3, 1, 1),
        Layer::Upsample2d { mode: UpsampleMode::PixelShuffle, scale: 2 }, // 96x96
        Layer::Act(PRELU),
        conv(64, 3, 9, 1, 4),
        Layer::Act(ActKind::Tanh),
    ]);
    Model::new("SRGAN", Shape::Chw(3, 24, 24), layers)
}

/// Pix2Pix U-Net generator (Isola et al.) for 256×256 image translation:
/// eight stride-2 encoder convs (C64…C512), eight transposed-conv decoder
/// stages, each decoder stage concatenating the same-resolution encoder
/// activation ([`Layer::ConcatChw`]) — the reference 54.4 M-parameter
/// configuration.
pub fn pix2pix() -> Model {
    let mut layers = vec![
        conv(3, 64, 4, 2, 1), // 128x128
        Layer::Act(LRELU),
    ];
    // encoder C128..C512 with BN (the innermost stage skips BN)
    for (i, o) in [(64, 128), (128, 256), (256, 512), (512, 512), (512, 512), (512, 512)] {
        layers.extend([
            conv(i, o, 4, 2, 1),
            Layer::Norm(NormKind::Batch),
            Layer::Act(LRELU),
        ]);
    }
    layers.extend([conv(512, 512, 4, 2, 1), Layer::Act(ActKind::Relu)]); // 1x1 bottleneck
    // decoder: tconv, BN, ReLU, then concat the mirrored encoder skip
    for (i, o, skip) in [
        (512, 512, 512),
        (1024, 512, 512),
        (1024, 512, 512),
        (1024, 512, 512),
        (1024, 256, 256),
        (512, 128, 128),
        (256, 64, 64),
    ] {
        layers.extend([
            tconv(i, o, 4, 2, 1),
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            Layer::ConcatChw(skip),
        ]);
    }
    layers.extend([tconv(128, 3, 4, 2, 1), Layer::Act(ActKind::Tanh)]); // 256x256
    Model::new("Pix2Pix", Shape::Chw(3, 256, 256), layers)
}

/// A StyleGAN2-style synthesis stack (Karras et al.) for 64×64: a learned
/// 4×4×512 constant, then per-resolution blocks of nearest-neighbor ×2
/// upsampling followed by two 3×3 convs. Weight demodulation is modeled as
/// per-instance normalization (per-instance statistics + broadband-MR
/// re-tune — the same cost class), and the mapped style network is elided
/// (it is negligible next to synthesis compute).
pub fn stylegan2() -> Model {
    let mut layers = vec![
        conv(512, 512, 3, 1, 1), // stem conv at 4x4
        Layer::Norm(NormKind::Instance),
        Layer::Act(LRELU),
    ];
    let mut cin = 512;
    for cout in [512usize, 512, 256, 128] {
        // one resolution block: 8, 16, 32, 64
        layers.extend([
            Layer::Upsample2d { mode: UpsampleMode::Nearest, scale: 2 },
            conv(cin, cout, 3, 1, 1),
            Layer::Norm(NormKind::Instance),
            Layer::Act(LRELU),
            conv(cout, cout, 3, 1, 1),
            Layer::Norm(NormKind::Instance),
            Layer::Act(LRELU),
        ]);
        cin = cout;
    }
    layers.extend([conv(128, 3, 1, 1, 0), Layer::Act(ActKind::Tanh)]); // toRGB
    Model::new("StyleGAN2", Shape::Chw(512, 4, 4), layers)
}

/// ProGAN generator (Karras et al.) for 64×64: latent→4×4 stem transposed
/// conv, then progressive nearest-upsample + double-conv blocks with
/// pixelnorm (modeled as per-instance normalization) — the second
/// upsample+conv workload, on a different channel schedule than
/// [`stylegan2`].
pub fn progan() -> Model {
    let mut layers = vec![
        tconv(512, 512, 4, 1, 0), // latent 1x1 -> 4x4 stem
        Layer::Norm(NormKind::Instance),
        Layer::Act(LRELU),
        conv(512, 512, 3, 1, 1),
        Layer::Norm(NormKind::Instance),
        Layer::Act(LRELU),
    ];
    let mut cin = 512;
    for cout in [512usize, 256, 128, 64] {
        // 8, 16, 32, 64
        layers.extend([
            Layer::Upsample2d { mode: UpsampleMode::Nearest, scale: 2 },
            conv(cin, cout, 3, 1, 1),
            Layer::Norm(NormKind::Instance),
            Layer::Act(LRELU),
            conv(cout, cout, 3, 1, 1),
            Layer::Norm(NormKind::Instance),
            Layer::Act(LRELU),
        ]);
        cin = cout;
    }
    layers.extend([conv(64, 3, 1, 1, 0), Layer::Act(ActKind::Tanh)]); // toRGB
    Model::new("ProGAN", Shape::Chw(512, 1, 1), layers)
}

/// The four generators the paper evaluates, in Table 1 order. Paper
/// exhibits that reproduce published numbers (Table 1 parity, the
/// Figs. 13/14 calibration) stay scoped to this set.
pub fn all_generators() -> Vec<Model> {
    vec![dcgan(), condgan(), artgan(), cyclegan()]
}

/// The full extended zoo: Table 1 plus the four paper-adjacent
/// architectures — what [`crate::api::Session`] registers, so `simulate`,
/// `dse`, `compare`, and `serve` all run the 8-model study.
pub fn extended_generators() -> Vec<Model> {
    let mut models = all_generators();
    models.extend([srgan(), pix2pix(), stylegan2(), progan()]);
    models
}

/// Table 1 parameter counts (paper), in the same order.
pub const PAPER_PARAMS: [(&str, f64); 4] = [
    ("DCGAN", 3.98e6),
    ("CondGAN", 1.17e6),
    ("ArtGAN", 1.27e6),
    ("CycleGAN", 11.38e6),
];

/// Reference parameter counts for the extended zoo (from the models'
/// published configurations), in [`extended_generators`] order after the
/// Table 1 four.
pub const EXTENDED_PARAMS: [(&str, f64); 4] = [
    ("SRGAN", 1.55e6),
    ("Pix2Pix", 54.41e6),
    ("StyleGAN2", 14.02e6),
    ("ProGAN", 13.60e6),
];

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn output_shapes_match_datasets() {
        assert_eq!(dcgan().output().unwrap(), Shape::Chw(3, 64, 64));
        assert_eq!(condgan().output().unwrap(), Shape::Chw(1, 28, 28));
        assert_eq!(artgan().output().unwrap(), Shape::Chw(3, 64, 64));
        assert_eq!(cyclegan().output().unwrap(), Shape::Chw(3, 256, 256));
    }

    #[test]
    fn parameter_counts_match_table1_within_10pct() {
        for (model, (name, expect)) in all_generators().iter().zip(PAPER_PARAMS) {
            assert_eq!(model.name, name);
            let p = model.params().unwrap() as f64;
            let err = (p - expect).abs() / expect;
            assert!(
                err < 0.10,
                "{name}: {p:.0} params vs paper {expect:.0} ({:.1}% off)",
                err * 100.0
            );
        }
    }

    #[test]
    fn discriminators_validate() {
        for d in [dcgan_discriminator(), condgan_discriminator(), cyclegan_discriminator()] {
            assert!(d.infos().is_ok(), "{} failed shape check", d.name);
            assert!(d.params().unwrap() > 0);
        }
    }

    #[test]
    fn extended_output_shapes_match_datasets() {
        assert_eq!(srgan().output().unwrap(), Shape::Chw(3, 96, 96));
        assert_eq!(pix2pix().output().unwrap(), Shape::Chw(3, 256, 256));
        assert_eq!(stylegan2().output().unwrap(), Shape::Chw(3, 64, 64));
        assert_eq!(progan().output().unwrap(), Shape::Chw(3, 64, 64));
    }

    #[test]
    fn extended_parameter_counts_match_references_within_10pct() {
        let models = extended_generators();
        for ((name, expect), model) in EXTENDED_PARAMS.into_iter().zip(&models[4..]) {
            assert_eq!(model.name, name);
            let p = model.params().unwrap() as f64;
            let err = (p - expect).abs() / expect;
            assert!(
                err < 0.10,
                "{name}: {p:.0} params vs reference {expect:.0} ({:.1}% off)",
                err * 100.0
            );
        }
    }

    #[test]
    fn extended_zoo_has_eight_distinct_shape_valid_models() {
        let models = extended_generators();
        assert_eq!(models.len(), 8);
        for m in &models {
            assert!(m.infos().is_ok(), "{} failed shape check", m.name);
            assert!(m.params().unwrap() > 0);
            assert!(m.total_macs().unwrap() > 0);
        }
        let mut names: Vec<&str> = models.iter().map(|m| m.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 8, "model names must be distinct");
    }

    #[test]
    fn extended_zoo_covers_every_upsampling_idiom() {
        // tconv decoder (Pix2Pix), pixel shuffle (SRGAN), nearest
        // upsample + conv (StyleGAN2/ProGAN) — the workload breadth the
        // GANAX-style generalization is about
        assert!(pix2pix().tconv_mac_fraction().unwrap() > 0.25);
        assert!(pix2pix().layers().iter().any(|l| matches!(l, Layer::ConcatChw(_))));
        assert!(srgan()
            .layers()
            .iter()
            .any(|l| matches!(l, Layer::Upsample2d { mode: UpsampleMode::PixelShuffle, .. })));
        // pixel shuffle leaves nothing for either sparse census
        assert_eq!(srgan().tconv_mac_fraction().unwrap(), 0.0);
        assert_eq!(srgan().upsample_conv_mac_fraction().unwrap(), 0.0);
        // the synthesis stacks put most of their MACs behind nearest
        // upsampling — the new fold census has real work to do
        assert!(stylegan2().upsample_conv_mac_fraction().unwrap() > 0.5);
        assert!(progan().upsample_conv_mac_fraction().unwrap() > 0.5);
    }

    #[test]
    fn cyclegan_has_lowest_tconv_fraction() {
        // The paper's Fig. 12 explanation: CycleGAN has proportionally fewer
        // transposed-conv MACs than the other generators.
        let fractions: Vec<(String, f64)> = all_generators()
            .iter()
            .map(|m| (m.name.clone(), m.tconv_mac_fraction().unwrap()))
            .collect();
        let cycle = fractions.iter().find(|(n, _)| n == "CycleGAN").unwrap().1;
        for (name, f) in &fractions {
            if name != "CycleGAN" {
                assert!(
                    cycle < *f,
                    "CycleGAN tconv fraction {cycle:.3} should be lowest, {name}={f:.3}"
                );
            }
        }
    }

    #[test]
    fn generator_ops_are_dominated_by_convs() {
        for m in all_generators() {
            let infos = m.infos().unwrap();
            let conv_macs: usize = infos
                .iter()
                .filter(|i| {
                    matches!(i.layer, Layer::Conv2d { .. } | Layer::ConvT2d { .. } | Layer::Dense { .. })
                })
                .map(|i| i.macs)
                .sum();
            let total = m.total_macs().unwrap();
            assert!(
                conv_macs as f64 / total as f64 > 0.95,
                "{}: compute layers are {:.1}% of MACs",
                m.name,
                100.0 * conv_macs as f64 / total as f64
            );
        }
    }
}
