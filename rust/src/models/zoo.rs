//! The four evaluated GAN models (paper Table 1) and their discriminators.
//!
//! | Model      | Dataset       | Params (paper) |
//! |------------|---------------|----------------|
//! | DCGAN      | celebA        | 3.98 M         |
//! | Cond. GAN  | F-MNIST       | 1.17 M         |
//! | ArtGAN     | Art Portraits | 1.27 M         |
//! | CycleGAN   | horse2zebra   | 11.38 M        |
//!
//! Architectures follow the models' reference implementations ([28]–[31])
//! at the image sizes the datasets imply; each builder's parameter count is
//! asserted against Table 1 (±10%) in the tests below.

use super::graph::Model;
use super::layer::{Layer, Shape};
use crate::arch::activation::ActKind;
use crate::arch::norm::NormKind;

const LRELU: ActKind = ActKind::LeakyRelu(0.2);

fn tconv(in_ch: usize, out_ch: usize, k: usize, s: usize, p: usize) -> Layer {
    Layer::ConvT2d { in_ch, out_ch, k, s, p, bias: false }
}

fn conv(in_ch: usize, out_ch: usize, k: usize, s: usize, p: usize) -> Layer {
    Layer::Conv2d { in_ch, out_ch, k, s, p, bias: false }
}

/// DCGAN generator [28] for 64×64 celebA: z(100) → 4×4×512 stem, four
/// stride-2 transposed convs, BN + ReLU, tanh output.
pub fn dcgan() -> Model {
    Model::new(
        "DCGAN",
        Shape::Chw(100, 1, 1),
        vec![
            tconv(100, 512, 4, 1, 0), // 4x4
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            tconv(512, 256, 4, 2, 1), // 8x8
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            tconv(256, 128, 4, 2, 1), // 16x16
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            tconv(128, 64, 4, 2, 1), // 32x32
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            // output stage: 3x3 refinement + to-RGB, per the celebA variant
            conv(64, 64, 3, 1, 1),
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            tconv(64, 3, 4, 2, 1), // 64x64
            Layer::Act(ActKind::Tanh),
        ],
    )
}

/// DCGAN discriminator: mirrored stride-2 convs with LeakyReLU.
pub fn dcgan_discriminator() -> Model {
    Model::new(
        "DCGAN-D",
        Shape::Chw(3, 64, 64),
        vec![
            conv(3, 64, 4, 2, 1), // 32
            Layer::Act(LRELU),
            conv(64, 128, 4, 2, 1), // 16
            Layer::Norm(NormKind::Batch),
            Layer::Act(LRELU),
            conv(128, 256, 4, 2, 1), // 8
            Layer::Norm(NormKind::Batch),
            Layer::Act(LRELU),
            conv(256, 512, 4, 2, 1), // 4
            Layer::Norm(NormKind::Batch),
            Layer::Act(LRELU),
            conv(512, 1, 4, 1, 0), // 1x1 logit
            Layer::Act(ActKind::Sigmoid),
        ],
    )
}

/// Conditional GAN generator [29] for 28×28 F-MNIST: z(100) ⊕ label(10) →
/// dense to 7×7×128, two stride-2 transposed convs, BN + ReLU, 3×3 to-gray,
/// tanh.
pub fn condgan() -> Model {
    Model::new(
        "CondGAN",
        Shape::Vec(100),
        vec![
            Layer::ConcatVec(10), // one-hot label conditioning
            Layer::Dense { in_f: 110, out_f: 128 * 7 * 7, bias: true },
            Layer::Act(ActKind::Relu),
            Layer::Reshape(128, 7, 7),
            Layer::Norm(NormKind::Batch),
            tconv(128, 128, 4, 2, 1), // 14x14
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            tconv(128, 64, 4, 2, 1), // 28x28
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            conv(64, 1, 3, 1, 1),
            Layer::Act(ActKind::Tanh),
        ],
    )
}

/// CondGAN discriminator (label-conditioned PatchGAN-lite on 28×28).
pub fn condgan_discriminator() -> Model {
    Model::new(
        "CondGAN-D",
        Shape::Chw(11, 28, 28), // image + broadcast one-hot label planes
        vec![
            conv(11, 64, 4, 2, 1), // 14
            Layer::Act(LRELU),
            conv(64, 128, 4, 2, 1), // 7
            Layer::Norm(NormKind::Batch),
            Layer::Act(LRELU),
            Layer::Flatten,
            Layer::Dense { in_f: 128 * 7 * 7, out_f: 1, bias: true },
            Layer::Act(ActKind::Sigmoid),
        ],
    )
}

/// ArtGAN generator [30] for 64×64 art portraits: z(100) ⊕ genre(10) →
/// dense to 4×4×288, four stride-2 transposed convs, BN + ReLU, tanh.
pub fn artgan() -> Model {
    Model::new(
        "ArtGAN",
        Shape::Vec(100),
        vec![
            Layer::ConcatVec(10),
            Layer::Dense { in_f: 110, out_f: 288 * 4 * 4, bias: true },
            Layer::Act(ActKind::Relu),
            Layer::Reshape(288, 4, 4),
            Layer::Norm(NormKind::Batch),
            tconv(288, 128, 4, 2, 1), // 8x8
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            tconv(128, 64, 4, 2, 1), // 16x16
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            tconv(64, 32, 4, 2, 1), // 32x32
            Layer::Norm(NormKind::Batch),
            Layer::Act(ActKind::Relu),
            tconv(32, 3, 4, 2, 1), // 64x64
            Layer::Act(ActKind::Tanh),
        ],
    )
}

/// CycleGAN generator [31] for 256×256 horse2zebra: c7s1-64, d128, d256,
/// nine 256-channel ResNet blocks with InstanceNorm, u128, u64, c7s1-3.
/// This is the reference 11.38 M-parameter configuration.
pub fn cyclegan() -> Model {
    let mut layers = vec![
        conv(3, 64, 7, 1, 3), // c7s1-64
        Layer::Norm(NormKind::Instance),
        Layer::Act(ActKind::Relu),
        conv(64, 128, 3, 2, 1), // d128 -> 128x128
        Layer::Norm(NormKind::Instance),
        Layer::Act(ActKind::Relu),
        conv(128, 256, 3, 2, 1), // d256 -> 64x64
        Layer::Norm(NormKind::Instance),
        Layer::Act(ActKind::Relu),
    ];
    for _ in 0..9 {
        // ResNet block: conv-IN-ReLU-conv-IN + skip
        layers.extend([
            conv(256, 256, 3, 1, 1),
            Layer::Norm(NormKind::Instance),
            Layer::Act(ActKind::Relu),
            conv(256, 256, 3, 1, 1),
            Layer::Norm(NormKind::Instance),
            Layer::ResidualAdd { span: 5 },
        ]);
    }
    layers.extend([
        // u128/u64: the reference uses k3 s2 with output_padding=1; our IR
        // expresses the same exact 2x upsample as k4 s2 p1 (identical
        // output shape, +2% params — within the Table 1 tolerance).
        tconv(256, 128, 4, 2, 1), // u128 -> 128x128
        Layer::Norm(NormKind::Instance),
        Layer::Act(ActKind::Relu),
        tconv(128, 64, 4, 2, 1), // u64 -> 256x256
        Layer::Norm(NormKind::Instance),
        Layer::Act(ActKind::Relu),
        conv(64, 3, 7, 1, 3), // c7s1-3
        Layer::Act(ActKind::Tanh),
    ]);
    Model::new("CycleGAN", Shape::Chw(3, 256, 256), layers)
}

/// CycleGAN 70×70 PatchGAN discriminator.
pub fn cyclegan_discriminator() -> Model {
    Model::new(
        "CycleGAN-D",
        Shape::Chw(3, 256, 256),
        vec![
            conv(3, 64, 4, 2, 1),
            Layer::Act(LRELU),
            conv(64, 128, 4, 2, 1),
            Layer::Norm(NormKind::Instance),
            Layer::Act(LRELU),
            conv(128, 256, 4, 2, 1),
            Layer::Norm(NormKind::Instance),
            Layer::Act(LRELU),
            conv(256, 512, 4, 1, 1),
            Layer::Norm(NormKind::Instance),
            Layer::Act(LRELU),
            conv(512, 1, 4, 1, 1),
        ],
    )
}

/// The four generators the paper evaluates, in Table 1 order.
pub fn all_generators() -> Vec<Model> {
    vec![dcgan(), condgan(), artgan(), cyclegan()]
}

/// Table 1 parameter counts (paper), in the same order.
pub const PAPER_PARAMS: [(&str, f64); 4] = [
    ("DCGAN", 3.98e6),
    ("CondGAN", 1.17e6),
    ("ArtGAN", 1.27e6),
    ("CycleGAN", 11.38e6),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_shapes_match_datasets() {
        assert_eq!(dcgan().output().unwrap(), Shape::Chw(3, 64, 64));
        assert_eq!(condgan().output().unwrap(), Shape::Chw(1, 28, 28));
        assert_eq!(artgan().output().unwrap(), Shape::Chw(3, 64, 64));
        assert_eq!(cyclegan().output().unwrap(), Shape::Chw(3, 256, 256));
    }

    #[test]
    fn parameter_counts_match_table1_within_10pct() {
        for (model, (name, expect)) in all_generators().iter().zip(PAPER_PARAMS) {
            assert_eq!(model.name, name);
            let p = model.params().unwrap() as f64;
            let err = (p - expect).abs() / expect;
            assert!(
                err < 0.10,
                "{name}: {p:.0} params vs paper {expect:.0} ({:.1}% off)",
                err * 100.0
            );
        }
    }

    #[test]
    fn discriminators_validate() {
        for d in [dcgan_discriminator(), condgan_discriminator(), cyclegan_discriminator()] {
            assert!(d.infos().is_ok(), "{} failed shape check", d.name);
            assert!(d.params().unwrap() > 0);
        }
    }

    #[test]
    fn cyclegan_has_lowest_tconv_fraction() {
        // The paper's Fig. 12 explanation: CycleGAN has proportionally fewer
        // transposed-conv MACs than the other generators.
        let fractions: Vec<(String, f64)> = all_generators()
            .iter()
            .map(|m| (m.name.clone(), m.tconv_mac_fraction().unwrap()))
            .collect();
        let cycle = fractions.iter().find(|(n, _)| n == "CycleGAN").unwrap().1;
        for (name, f) in &fractions {
            if name != "CycleGAN" {
                assert!(
                    cycle < *f,
                    "CycleGAN tconv fraction {cycle:.3} should be lowest, {name}={f:.3}"
                );
            }
        }
    }

    #[test]
    fn generator_ops_are_dominated_by_convs() {
        for m in all_generators() {
            let infos = m.infos().unwrap();
            let conv_macs: usize = infos
                .iter()
                .filter(|i| {
                    matches!(i.layer, Layer::Conv2d { .. } | Layer::ConvT2d { .. } | Layer::Dense { .. })
                })
                .map(|i| i.macs)
                .sum();
            let total = m.total_macs().unwrap();
            assert!(
                conv_macs as f64 / total as f64 > 0.95,
                "{}: compute layers are {:.1}% of MACs",
                m.name,
                100.0 * conv_macs as f64 / total as f64
            );
        }
    }
}
