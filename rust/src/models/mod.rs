//! GAN workload IR: layer types, shape propagation, op/param counting, and
//! the model zoo — the four evaluated models of paper Table 1 (DCGAN,
//! Conditional GAN, ArtGAN, CycleGAN) plus their discriminators, and the
//! extended paper-adjacent generators (SRGAN, Pix2Pix, StyleGAN2, ProGAN)
//! that broaden layer-type coverage (upsample+conv, pixel shuffle, U-Net
//! skip concatenation).
//!
//! The IR is deliberately *architectural*: it carries shapes and layer
//! semantics (enough for exact op counts and the sparse-dataflow censuses),
//! not weights. The functional path — actual inference with weights — lives
//! in the JAX layer (`python/compile/models/`) and is executed through
//! `crate::runtime` (present only with the `pjrt` feature).
//!
//! [`ir`] lifts the flat layer list into an SSA-style dataflow graph
//! (explicit skip-connection operands, static verifier, pass framework,
//! fusion-legality analysis) — the form `sim/mapper.rs` lowers from.

// Same error-handling contract as `api/`/`coordinator/`/`workload/`: no
// unwraps or expects in production paths; invariants that genuinely cannot
// fail are documented `panic!`s. Tests opt back in via `#[allow]`.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod graph;
pub mod ir;
pub mod layer;
pub mod zoo;

pub use graph::{LayerInfo, Model};
pub use ir::{
    dead_ops, fusion_groups, DeadValueElimination, FusionGroup, Graph, IrError, Op, Pass,
    PassManager, Value,
};
pub use layer::{Layer, Shape, UpsampleMode};
pub use zoo::{
    all_generators, artgan, condgan, cyclegan, dcgan, extended_generators, pix2pix, progan,
    srgan, stylegan2,
};
