//! Normalization block (paper §III.B.3, Fig. 7).
//!
//! M normalization units built from **broadband MRs** [25] that imprint the
//! per-channel scale directly onto the optical stream arriving from the
//! convolution units. Supports BatchNorm (parameters frozen after training)
//! and InstanceNorm (parameters recomputed at inference — CycleGAN-style
//! image translation), plus a **bypass** path for conv layers with no
//! normalization.
//!
//! IN statistics (µ, σ per channel per instance) are computed in the ECU
//! from the ADC-sampled stream of the *previous* pass; the optical unit then
//! applies `γ·(x−µ)/σ + β` as a broadband scale + coherent offset. The ECU
//! statistics cost is charged by the simulator as digital ops; this module
//! models the optical apply path.

use super::config::ArchConfig;

/// Normalization flavor of a layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NormKind {
    Batch,
    Instance,
    /// Bypass the broadband MRs entirely (no normalization).
    None,
}

/// One normalization unit.
#[derive(Debug, Clone)]
pub struct NormUnit {
    pub cfg: ArchConfig,
}

impl NormUnit {
    pub fn new(cfg: &ArchConfig) -> Self {
        NormUnit { cfg: cfg.clone() }
    }

    /// Per-element latency added to a stream passing through (s).
    pub fn latency(&self, kind: NormKind) -> f64 {
        let d = &self.cfg.params.device;
        match kind {
            // broadband MR response is EO-modulator-class; the scale value
            // is held, so per-element cost is just the modulation transit.
            NormKind::Batch => d.vcsel_latency, // offset add via coherent sum
            // IN also re-tunes the broadband MR per instance; amortized per
            // element this is negligible, but the per-instance retune is
            // charged by `retune_latency`.
            NormKind::Instance => d.vcsel_latency,
            NormKind::None => 0.0, // bypass waveguide
        }
    }

    /// Per-instance broadband-MR retune cost for IN (s) — EO tuning.
    pub fn retune_latency(&self, kind: NormKind) -> f64 {
        match kind {
            NormKind::Instance => self.cfg.params.device.eo_tuning_latency,
            _ => 0.0,
        }
    }

    /// Unit power while streaming (W): K broadband MR holds + offset VCSEL.
    pub fn power(&self, kind: NormKind) -> f64 {
        let d = &self.cfg.params.device;
        match kind {
            NormKind::None => 0.0,
            _ => self.cfg.k as f64 * d.eo_tuning_power + d.vcsel_power,
        }
    }

    /// Functional apply: `γ·(x−µ)/σ + β`, with the scale quantized to the
    /// broadband MR's precision.
    pub fn apply(&self, x: f64, mu: f64, sigma: f64, gamma: f64, beta: f64, kind: NormKind) -> f64 {
        match kind {
            NormKind::None => x,
            _ => {
                let bits = self.cfg.params.system.precision_bits;
                let levels = ((1u64 << bits) - 1) as f64;
                let scale = gamma / sigma.max(1e-6);
                // broadband MR imprints |scale| ≤ 1 after pre-normalization;
                // model the quantization of the imprinted coefficient.
                let norm = scale.abs().max(1e-12);
                let pre = scale / norm; // ±1
                let q = (norm.min(1.0) * levels).round() / levels * pre
                    + (norm - norm.min(1.0)) * pre; // overflow handled in ECU
                (x - mu) * q + beta
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn unit() -> NormUnit {
        NormUnit::new(&ArchConfig::paper_optimum())
    }

    #[test]
    fn bypass_is_free_and_identity() {
        let u = unit();
        assert_eq!(u.latency(NormKind::None), 0.0);
        assert_eq!(u.power(NormKind::None), 0.0);
        assert_eq!(u.apply(1.234, 9.9, 2.0, 3.0, 4.0, NormKind::None), 1.234);
    }

    #[test]
    fn instance_norm_retunes_batch_does_not() {
        let u = unit();
        assert_eq!(u.retune_latency(NormKind::Instance), 20e-9);
        assert_eq!(u.retune_latency(NormKind::Batch), 0.0);
    }

    #[test]
    fn apply_matches_reference_within_quantization() {
        let u = unit();
        check("norm apply", 256, move |g| {
            let x = g.f64_in(-2.0, 2.0);
            let mu = g.f64_in(-1.0, 1.0);
            let sigma = g.f64_in(0.1, 2.0);
            let gamma = g.f64_in(-1.0, 1.0);
            let beta = g.f64_in(-1.0, 1.0);
            let expect = gamma * (x - mu) / sigma + beta;
            let got = u.apply(x, mu, sigma, gamma, beta, NormKind::Instance);
            // quantization of the scale coefficient bounds the error
            let bound = (x - mu).abs() * (1.0 / 255.0) + 1e-9;
            assert!((got - expect).abs() <= bound, "got={got} expect={expect}");
        });
    }

    #[test]
    fn power_scales_with_rows() {
        let small = NormUnit::new(&ArchConfig::new(16, 2, 11, 3)).power(NormKind::Batch);
        let big = NormUnit::new(&ArchConfig::new(16, 8, 11, 3)).power(NormKind::Batch);
        assert!(big > small);
    }
}
