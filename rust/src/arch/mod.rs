//! The PhotoGAN accelerator architecture (paper §III, Fig. 4).
//!
//! A chip is `[N, K, L, M]`:
//! - **N** — wavelengths per waveguide = the *reduction* length of one
//!   optical dot product (columns of each MR bank array; bounded by the
//!   36-MR crosstalk rule),
//! - **K** — parallel waveguides per unit = output rows produced per symbol
//!   (each row terminates in its own BPD),
//! - **L** — dense units (dense block),
//! - **M** — convolution units (convolution block) and, matching the paper,
//!   also the number of normalization units.
//!
//! Each dense/conv unit is two K×N MR bank arrays (activations, weights) in
//! series (Figs. 5/6); normalization units are broadband-MR columns
//! (Fig. 7); activation units are the SOA Leaky-ReLU path (Fig. 8). PCMCs
//! route block-to-block optically; an ECU handles memory, buffering and
//! matrix mapping; one VCSEL array per block is shared across its units and
//! one DAC array is shared between the dense and conv blocks (§III.C.3).

pub mod accelerator;
pub mod activation;
pub mod config;
pub mod conv;
pub mod dense;
pub mod norm;
pub mod power;
pub mod unit;

pub use accelerator::Accelerator;
pub use config::ArchConfig;
pub use unit::{UnitPower, UnitTiming};
