//! Whole-chip assembly (paper Fig. 4): dense + conv + norm + activation
//! blocks, the shared DAC array, the PCMC routing fabric, and the ECU.

use super::activation::{ActKind, ActivationUnit};
use super::config::{ArchConfig, ConfigError};
use super::conv::ConvBlock;
use super::dense::DenseBlock;
use super::norm::{NormKind, NormUnit};
use super::power::{PowerBreakdown, ECU_BASE_W, ECU_PER_UNIT_W};
use super::unit::BlockKind;
use crate::photonics::constants::DeviceParams;
use crate::photonics::converter::{Dac, SharedDacArray};
use crate::photonics::pcmc::{PcmState, PcmcFabric};

/// Which MVM block is currently powered (power gating, §III.C.3: "when the
/// dense block is active, the convolution block is deactivated, and vice
/// versa").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActiveBlock {
    Dense,
    Conv,
    /// Both lit — only the *ungated* baseline configuration allows this.
    Both,
}

/// The assembled PhotoGAN chip.
#[derive(Debug, Clone)]
pub struct Accelerator {
    pub cfg: ArchConfig,
    pub dense: DenseBlock,
    pub conv: ConvBlock,
    pub norm: NormUnit,
    pub act: ActivationUnit,
    pub shared_dac: SharedDacArray,
    pub fabric: PcmcFabric,
    /// Route ids in `fabric`.
    pub route_dense_to_act: usize,
    pub route_conv_to_norm: usize,
    pub route_norm_to_act: usize,
}

impl Accelerator {
    /// Assemble a chip from a configuration. Fails if the configuration is
    /// structurally invalid (crosstalk bound / degenerate).
    pub fn new(cfg: ArchConfig) -> Result<Self, ConfigError> {
        cfg.validate()?;
        let device: DeviceParams = cfg.params.device.clone();
        // The shared DAC array is sized for the widest consumer: N lanes per
        // unit of the larger block (dense L vs conv M).
        let dac_lanes = cfg.n * cfg.l.max(cfg.m);
        let mut fabric = PcmcFabric::new(&device, 3);
        let route_dense_to_act = fabric.add_route(vec![(0, PcmState::Amorphous)]);
        let route_conv_to_norm = fabric.add_route(vec![(1, PcmState::Crystalline)]);
        let route_norm_to_act = fabric.add_route(vec![(2, PcmState::Crystalline)]);
        Ok(Accelerator {
            dense: DenseBlock::new(&cfg),
            conv: ConvBlock::new(&cfg),
            norm: NormUnit::new(&cfg),
            act: ActivationUnit::new(&cfg),
            shared_dac: SharedDacArray::new(Dac::new(device, cfg.params.system.precision_bits), dac_lanes),
            fabric,
            route_dense_to_act,
            route_conv_to_norm,
            route_norm_to_act,
            cfg,
        })
    }

    /// Total units across MVM blocks.
    pub fn total_units(&self) -> usize {
        self.cfg.l + self.cfg.m
    }

    /// ECU power (W).
    pub fn ecu_power(&self) -> f64 {
        ECU_BASE_W + ECU_PER_UNIT_W * self.total_units() as f64
    }

    /// Itemized chip power with the given active block and gating policy.
    ///
    /// `gated = true` applies the paper's power gating: the inactive MVM
    /// block is fully de-powered and the DAC array is owned by the active
    /// block only. `gated = false` (baseline) leaves the inactive block
    /// idling (lasers + holds + bias) and duplicates DAC drive.
    pub fn power(&self, active: ActiveBlock, gated: bool) -> PowerBreakdown {
        let d = self.dense.power();
        let c = self.conv.power();
        let dac_w = self.shared_dac.dac.power();
        let n = self.cfg.n as f64;
        // one norm unit per conv unit (paper: M normalization units); each
        // NormUnit::power already covers its K broadband-MR lanes
        let norm_w = self.norm.power(NormKind::Instance) * self.cfg.m as f64;
        let act_lanes = (self.cfg.l.max(self.cfg.m) * self.cfg.k) as f64;
        let act_w = self.act.power(ActKind::LeakyRelu(0.2)) * act_lanes;
        // `MvmUnit::power().active` includes N DAC lanes per unit; the chip
        // charges DACs through the *shared array* instead, so subtract the
        // per-unit DAC share from whichever block is active and add the
        // array term explicitly (this is what makes DAC sharing visible).
        let dense_dac = n * self.cfg.l as f64 * dac_w;
        let conv_dac = n * self.cfg.m as f64 * dac_w;
        let (dense_w, conv_w, dac_total) = match (active, gated) {
            (ActiveBlock::Dense, true) => (d.active - dense_dac, c.gated, dense_dac),
            (ActiveBlock::Conv, true) => (d.gated, c.active - conv_dac, conv_dac),
            // Ungated baseline: no sharing — each block owns (and keeps
            // powered) a full DAC array; move the DAC share of `idle`
            // (= active) into the DAC column for reporting.
            (ActiveBlock::Dense, false) => {
                (d.active - dense_dac, c.idle - conv_dac, dense_dac + conv_dac)
            }
            (ActiveBlock::Conv, false) => {
                (d.idle - dense_dac, c.active - conv_dac, conv_dac + dense_dac)
            }
            (ActiveBlock::Both, _) => {
                (d.active - dense_dac, c.active - conv_dac, dense_dac + conv_dac)
            }
        };
        PowerBreakdown {
            dense_block: dense_w.max(0.0),
            conv_block: conv_w.max(0.0),
            norm_block: if matches!(active, ActiveBlock::Conv | ActiveBlock::Both) { norm_w } else { 0.0 },
            act_block: act_w,
            shared_dac: dac_total,
            ecu: self.ecu_power(),
        }
    }

    /// Worst-case operational power (W) under the given gating policy —
    /// the quantity checked against the paper's 100 W DSE cap.
    pub fn peak_power(&self, gated: bool) -> f64 {
        if gated {
            self.power(ActiveBlock::Dense, true)
                .total()
                .max(self.power(ActiveBlock::Conv, true).total())
        } else {
            self.power(ActiveBlock::Both, false).total()
        }
    }

    /// Validate the full configuration including the power cap.
    pub fn validate(&self, gated: bool) -> Result<(), ConfigError> {
        self.cfg.validate()?;
        let peak = self.peak_power(gated);
        let cap = self.cfg.params.system.power_cap_w;
        if peak > cap {
            return Err(ConfigError::PowerCap(peak, cap));
        }
        Ok(())
    }

    /// Peak MACs/s with gating (one MVM block at a time) or without.
    pub fn peak_macs_per_sec(&self, gated: bool) -> f64 {
        if gated {
            self.dense.peak_macs_per_sec().max(self.conv.peak_macs_per_sec())
        } else {
            self.dense.peak_macs_per_sec() + self.conv.peak_macs_per_sec()
        }
    }

    /// Cost model of the MVM unit for a block kind.
    pub fn mvm_unit(&self, kind: BlockKind) -> &super::unit::MvmUnit {
        match kind {
            BlockKind::Dense => self.dense.unit(),
            BlockKind::Conv => self.conv.unit(),
            _ => panic!("no MVM unit for {kind:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chip() -> Accelerator {
        Accelerator::new(ArchConfig::paper_optimum()).unwrap()
    }

    #[test]
    fn paper_optimum_fits_power_cap() {
        let a = chip();
        assert!(a.validate(true).is_ok());
        let peak = a.peak_power(true);
        assert!(peak < 100.0, "peak={peak}");
    }

    #[test]
    fn gating_reduces_peak_power() {
        let a = chip();
        assert!(a.peak_power(true) < a.peak_power(false));
    }

    #[test]
    fn gated_inactive_block_draws_nothing() {
        let a = chip();
        let p = a.power(ActiveBlock::Dense, true);
        assert_eq!(p.conv_block, 0.0);
        let q = a.power(ActiveBlock::Conv, true);
        assert_eq!(q.dense_block, 0.0);
        assert!(q.norm_block > 0.0, "norm follows the conv chain");
    }

    #[test]
    fn ungated_inactive_block_idles() {
        let a = chip();
        let p = a.power(ActiveBlock::Dense, false);
        assert!(p.conv_block > 0.0, "no gating: conv idles but draws power");
    }

    #[test]
    fn dac_not_double_counted() {
        // Total with gating must be strictly less than naive sum of block
        // active powers + dac array (which would double count lanes).
        let a = chip();
        let naive = a.dense.power().active + a.conv.power().active;
        let gated = a.power(ActiveBlock::Dense, true).total();
        assert!(gated < naive + a.ecu_power() + 1.0);
    }

    #[test]
    fn invalid_config_rejected_at_assembly() {
        assert!(Accelerator::new(ArchConfig::new(37, 2, 11, 3)).is_err());
    }

    #[test]
    fn peak_macs_additive_without_gating() {
        let a = chip();
        let g = a.peak_macs_per_sec(true);
        let ug = a.peak_macs_per_sec(false);
        assert!(ug > g);
    }
}
