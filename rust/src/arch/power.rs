//! Chip-level power accounting.
//!
//! Aggregates block power into the operating-state views the simulator and
//! the DSE need: *peak* (both MVM blocks lit — only possible without power
//! gating), *gated peak* (one MVM block at a time, §III.C.3), and the
//! itemized breakdown used in reports.

/// Electronic control unit (ECU) power model: interfaces main memory,
/// buffers intermediates, maps matrices (paper Fig. 4). Base controller +
/// per-unit sequencing overhead.
pub const ECU_BASE_W: f64 = 0.1;
pub const ECU_PER_UNIT_W: f64 = 0.01;

/// Main-memory (DRAM) access energy per byte (J/B) — DDR4-class interface;
/// charged by the simulator for weight/activation traffic that crosses the
/// chip boundary.
pub const DRAM_ENERGY_PER_BYTE: f64 = 20e-12;

/// Sustained main-memory bandwidth (B/s) — DDR4-class single channel; the
/// event-driven scheduler places weight-prefetch segments on the DRAM
/// timeline at this rate (occupancy/utilization reporting only — prefetch
/// never stalls compute, matching the energy-only closed-form reference).
pub const DRAM_BYTES_PER_S: f64 = 25e9;

/// Digital ECU op energy (J/op) for the sparse-dataflow bookkeeping
/// (column reintroduction, §III.C.1) and IN statistics.
pub const ECU_ENERGY_PER_OP: f64 = 1e-12;

/// Sustained ECU digital op rate (ops/s) — a GHz-class controller with a
/// wide SIMD datapath. Used only for ECU busy-time attribution in
/// [`crate::sim::SimReport`] resource tables; ECU ops are latency-free in
/// the cost model (they hide behind streaming), so this never adds time.
pub const ECU_OPS_PER_S: f64 = 1e12;

/// Digital ECU **data-movement** energy (J/element) — the new op class the
/// extended zoo introduces: nearest-neighbor replication, pixel-shuffle
/// rearrangement, and U-Net skip-concat copies are address-generation +
/// SRAM-to-SRAM moves, cheaper than the MAC-class bookkeeping op above
/// (no arithmetic datapath engaged).
pub const ECU_ENERGY_PER_COPY: f64 = 0.4e-12;

/// Itemized chip power (W) in a given operating condition.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PowerBreakdown {
    pub dense_block: f64,
    pub conv_block: f64,
    pub norm_block: f64,
    pub act_block: f64,
    pub shared_dac: f64,
    pub ecu: f64,
}

impl PowerBreakdown {
    pub fn total(&self) -> f64 {
        self.dense_block + self.conv_block + self.norm_block + self.act_block
            + self.shared_dac
            + self.ecu
    }

    /// Render an itemized report line set.
    pub fn report(&self) -> String {
        use crate::util::units::fmt_power;
        format!(
            "dense={} conv={} norm={} act={} dac={} ecu={} total={}",
            fmt_power(self.dense_block),
            fmt_power(self.conv_block),
            fmt_power(self.norm_block),
            fmt_power(self.act_block),
            fmt_power(self.shared_dac),
            fmt_power(self.ecu),
            fmt_power(self.total()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_is_sum() {
        let p = PowerBreakdown {
            dense_block: 1.0,
            conv_block: 2.0,
            norm_block: 0.5,
            act_block: 0.25,
            shared_dac: 0.125,
            ecu: 1.0,
        };
        assert!((p.total() - 4.875).abs() < 1e-12);
        assert!(p.report().contains("total=4.88 W"));
    }

    #[test]
    fn constants_sane() {
        assert!(DRAM_ENERGY_PER_BYTE > 1e-12 && DRAM_ENERGY_PER_BYTE < 1e-10);
        assert!(ECU_ENERGY_PER_OP < DRAM_ENERGY_PER_BYTE);
        // a pure data move must cost less than a MAC-class bookkeeping op,
        // and far less than going out to DRAM
        assert!(ECU_ENERGY_PER_COPY > 0.0 && ECU_ENERGY_PER_COPY < ECU_ENERGY_PER_OP);
        assert!(ECU_ENERGY_PER_COPY < DRAM_ENERGY_PER_BYTE);
    }
}
