//! Architectural configuration `[N, K, L, M]` and its validity rules.

use crate::photonics::constants::PhotonicParams;
use crate::photonics::crosstalk;
use crate::photonics::mr::Microring;
use std::fmt;
use std::str::FromStr;

/// PhotoGAN architectural parameters (paper §IV.A).
#[derive(Debug, Clone, PartialEq)]
pub struct ArchConfig {
    /// Columns per MR bank array — wavelengths per waveguide, i.e. the
    /// optical dot-product (reduction) length. Bounded by the 36-MR rule.
    pub n: usize,
    /// Rows per MR bank array — parallel output rows per unit (one BPD per
    /// row).
    pub k: usize,
    /// Dense units.
    pub l: usize,
    /// Convolution units (and normalization units).
    pub m: usize,
    /// Physical parameter bundle.
    pub params: PhotonicParams,
}

/// Why a configuration is invalid.
#[derive(Debug, Clone, PartialEq)]
pub enum ConfigError {
    TooManyWavelengths(usize, usize),
    Crosstalk(String),
    Degenerate { n: usize, k: usize, l: usize, m: usize },
    PowerCap(f64, f64),
    /// An `N,K,L,M` string did not parse (see [`ArchConfig::from_str`]).
    BadQuad(String),
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::TooManyWavelengths(n, max) => write!(
                f,
                "N={n} exceeds the {max}-MR/waveguide crosstalk bound (paper §IV)"
            ),
            ConfigError::Crosstalk(msg) => write!(f, "crosstalk check failed: {msg}"),
            ConfigError::Degenerate { n, k, l, m } => write!(
                f,
                "all of N, K, L, M must be ≥ 1 (got N={n} K={k} L={l} M={m})"
            ),
            ConfigError::PowerCap(peak, cap) => {
                write!(f, "peak power {peak:.1} W exceeds the cap {cap:.1} W")
            }
            ConfigError::BadQuad(s) => {
                write!(f, "'{s}' is not an N,K,L,M quadruple (expected e.g. 16,2,11,3)")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

impl FromStr for ArchConfig {
    type Err = ConfigError;

    /// Parse `"N,K,L,M"` (whitespace around commas allowed) into a config
    /// with default device parameters. Structural validity is *not* checked
    /// here — call [`ArchConfig::validate`] or assemble an
    /// [`super::Accelerator`].
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        crate::util::cli::parse_quad(s)
            .map(|(n, k, l, m)| ArchConfig::new(n, k, l, m))
            .ok_or_else(|| ConfigError::BadQuad(s.to_string()))
    }
}

impl ArchConfig {
    /// The paper's DSE optimum: `[N, K, L, M] = [16, 2, 11, 3]`.
    pub fn paper_optimum() -> Self {
        ArchConfig { n: 16, k: 2, l: 11, m: 3, params: PhotonicParams::default() }
    }

    /// Arbitrary configuration with default device parameters.
    pub fn new(n: usize, k: usize, l: usize, m: usize) -> Self {
        ArchConfig { n, k, l, m, params: PhotonicParams::default() }
    }

    /// Structural validation: non-degenerate and within the crosstalk bound.
    /// (The power-cap check needs the assembled [`super::Accelerator`].)
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.n == 0 || self.k == 0 || self.l == 0 || self.m == 0 {
            return Err(ConfigError::Degenerate {
                n: self.n,
                k: self.k,
                l: self.l,
                m: self.m,
            });
        }
        let max = self.params.system.max_mrs_per_waveguide;
        if self.n > max {
            return Err(ConfigError::TooManyWavelengths(self.n, max));
        }
        crosstalk::validate_channel_count(&self.params.system, &Microring::default(), self.n)
            .map_err(ConfigError::Crosstalk)?;
        Ok(())
    }

    /// MACs retired per symbol by one dense/conv unit.
    pub fn macs_per_symbol_per_unit(&self) -> usize {
        self.n * self.k
    }

    /// Total MRs in one unit (two K×N banks).
    pub fn mrs_per_unit(&self) -> usize {
        2 * self.n * self.k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn paper_optimum_is_valid() {
        assert_eq!(ArchConfig::paper_optimum().validate(), Ok(()));
    }

    #[test]
    fn n_bound_enforced() {
        let c = ArchConfig::new(37, 2, 1, 1);
        assert_eq!(
            c.validate(),
            Err(ConfigError::TooManyWavelengths(37, 36))
        );
    }

    #[test]
    fn degenerate_rejected() {
        assert!(matches!(
            ArchConfig::new(0, 1, 1, 1).validate(),
            Err(ConfigError::Degenerate { .. })
        ));
        assert!(matches!(
            ArchConfig::new(16, 2, 0, 3).validate(),
            Err(ConfigError::Degenerate { .. })
        ));
    }

    #[test]
    fn from_str_parses_quads() {
        let c: ArchConfig = "16,2,11,3".parse().unwrap();
        assert_eq!((c.n, c.k, c.l, c.m), (16, 2, 11, 3));
        assert_eq!(
            " 4, 1, 1, 1 ".parse::<ArchConfig>().map(|c| (c.n, c.k, c.l, c.m)),
            Ok((4, 1, 1, 1))
        );
        assert_eq!(
            "16,2,11".parse::<ArchConfig>(),
            Err(ConfigError::BadQuad("16,2,11".into()))
        );
        // parsing is syntactic; validation is separate
        let wide: ArchConfig = "99,1,1,1".parse().unwrap();
        assert!(wide.validate().is_err());
    }

    #[test]
    fn error_messages_render() {
        let e = ConfigError::PowerCap(123.456, 100.0);
        assert_eq!(e.to_string(), "peak power 123.5 W exceeds the cap 100.0 W");
        assert!(ConfigError::Degenerate { n: 0, k: 1, l: 1, m: 1 }
            .to_string()
            .contains("N=0"));
    }

    #[test]
    fn mac_counts() {
        let c = ArchConfig::paper_optimum();
        assert_eq!(c.macs_per_symbol_per_unit(), 32);
        assert_eq!(c.mrs_per_unit(), 64);
    }

    #[test]
    fn all_in_bound_configs_validate() {
        check("valid configs", 128, |g| {
            let c = ArchConfig::new(
                g.usize_in(1, 36),
                g.usize_in(1, 8),
                g.usize_in(1, 16),
                g.usize_in(1, 16),
            );
            assert_eq!(c.validate(), Ok(()));
        });
    }
}
