//! Dense block (paper §III.B.1, Fig. 5).
//!
//! L dense units, each an [`MvmUnit`] of two K×N MR banks with a BPD per
//! row and a coherent-summation bias stage: the bank output drives a VCSEL
//! at λ₀ whose field interferes constructively with a second, bias-carrying
//! VCSEL at the same λ₀ — adding the bias entirely optically (§II.D). The
//! block owns one shared VCSEL comb array and is pipelined with the
//! activation block (Fig. 10a).

use super::config::ArchConfig;
use super::unit::{BlockKind, MvmUnit, UnitPower, UnitTiming};

/// The dense block: `cfg.l` identical units.
#[derive(Debug, Clone)]
pub struct DenseBlock {
    pub cfg: ArchConfig,
    unit: MvmUnit,
}

impl DenseBlock {
    pub fn new(cfg: &ArchConfig) -> Self {
        DenseBlock { cfg: cfg.clone(), unit: MvmUnit::new(BlockKind::Dense, cfg) }
    }

    /// Number of units in the block.
    pub fn units(&self) -> usize {
        self.cfg.l
    }

    /// The unit cost model (all units are identical).
    pub fn unit(&self) -> &MvmUnit {
        &self.unit
    }

    pub fn timing(&self) -> UnitTiming {
        self.unit.timing()
    }

    /// Whole-block power in each state (all units together).
    pub fn power(&self) -> UnitPower {
        let u = self.unit.power();
        UnitPower {
            active: u.active * self.cfg.l as f64,
            idle: u.idle * self.cfg.l as f64,
            gated: u.gated * self.cfg.l as f64,
            laser: u.laser * self.cfg.l as f64,
        }
    }

    /// Peak MACs/s of the block with stage pipelining.
    pub fn peak_macs_per_sec(&self) -> f64 {
        let symbol = self.timing().symbol_time(true);
        (self.cfg.macs_per_symbol_per_unit() * self.cfg.l) as f64 / symbol
    }
}

/// Functional micro-model of one dense-unit dot product with bias — the
/// analog path the hardware realises (quantized activations/weights ×
/// BPD accumulation × coherent bias add). Used by tests to pin the
/// *numerics* the architecture claims, independent of JAX.
pub fn dense_unit_dot(activations: &[f64], weights: &[f64], bias: f64, bits: u32) -> f64 {
    assert_eq!(activations.len(), weights.len());
    let levels = ((1u64 << bits) - 1) as f64;
    let q = |x: f64| (x.clamp(-1.0, 1.0) * levels).round() / levels;
    let acc: f64 = activations
        .iter()
        .zip(weights)
        .map(|(&a, &w)| q(a) * q(w))
        .sum();
    acc + bias
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn block_power_scales_with_l() {
        let a = DenseBlock::new(&ArchConfig::new(16, 2, 1, 3)).power();
        let b = DenseBlock::new(&ArchConfig::new(16, 2, 11, 3)).power();
        assert!((b.active / a.active - 11.0).abs() < 1e-9);
    }

    #[test]
    fn peak_macs_paper_optimum() {
        let blk = DenseBlock::new(&ArchConfig::paper_optimum());
        // 32 MACs/symbol/unit × 11 units at ~2.6 GHz ≈ 0.9 T MACs/s
        let peak = blk.peak_macs_per_sec();
        assert!(peak > 1e11 && peak < 1e13, "peak={peak}");
    }

    #[test]
    fn functional_dot_matches_fp_within_quant_error() {
        check("dense unit dot ≈ fp dot", 256, |g| {
            let n = g.usize_in(1, 36);
            let a: Vec<f64> = (0..n).map(|_| g.f64_in(-1.0, 1.0)).collect();
            let w: Vec<f64> = (0..n).map(|_| g.f64_in(-1.0, 1.0)).collect();
            let bias = g.f64_in(-0.5, 0.5);
            let exact: f64 = a.iter().zip(&w).map(|(x, y)| x * y).sum::<f64>() + bias;
            let got = dense_unit_dot(&a, &w, bias, 8);
            // worst-case 8-bit error per product ≈ 2·(1/510) + (1/510)^2
            let bound = n as f64 * (2.0 / 510.0 + 1.0 / (510.0 * 510.0)) + 1e-12;
            assert!((got - exact).abs() <= bound, "err={} bound={bound}", (got - exact).abs());
        });
    }

    #[test]
    fn dot_is_exact_at_full_precision() {
        // with very high "bits" the quantizer is effectively identity
        let a = [0.25, -0.5, 0.75];
        let w = [0.1, 0.2, -0.3];
        let exact: f64 = a.iter().zip(&w).map(|(x, y)| x * y).sum::<f64>() + 0.05;
        assert!((dense_unit_dot(&a, &w, 0.05, 30) - exact).abs() < 1e-6);
    }
}
