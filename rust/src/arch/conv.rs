//! Convolution block (paper §III.B.2, Fig. 6).
//!
//! M convolution units, each the same two-bank K×N [`MvmUnit`] as the dense
//! block, but with its output routed *optically* (via PCMC) to the
//! normalization block instead of being converted back — eliminating
//! intermediate O/E conversions (and hence ADC energy) between conv, norm
//! and activation (Fig. 10b). Convolutions (and transposed convolutions)
//! are lowered to MVM streams by im2col, per [24]; the transposed-conv
//! sparse dataflow (§III.C.1) is applied upstream by
//! [`crate::sparse`] before the stream reaches this block.

use super::config::ArchConfig;
use super::unit::{BlockKind, MvmUnit, UnitPower, UnitTiming};

/// The convolution block: `cfg.m` identical units.
#[derive(Debug, Clone)]
pub struct ConvBlock {
    pub cfg: ArchConfig,
    unit: MvmUnit,
}

impl ConvBlock {
    pub fn new(cfg: &ArchConfig) -> Self {
        ConvBlock { cfg: cfg.clone(), unit: MvmUnit::new(BlockKind::Conv, cfg) }
    }

    pub fn units(&self) -> usize {
        self.cfg.m
    }

    pub fn unit(&self) -> &MvmUnit {
        &self.unit
    }

    pub fn timing(&self) -> UnitTiming {
        self.unit.timing()
    }

    /// Whole-block power. Unlike the dense block, the per-symbol egress ADC
    /// is *not* charged while chained optically into norm/act — the chain
    /// boundary charges it once at the end (handled by the simulator).
    pub fn power(&self) -> UnitPower {
        let u = self.unit.power();
        UnitPower {
            active: u.active * self.cfg.m as f64,
            idle: u.idle * self.cfg.m as f64,
            gated: u.gated * self.cfg.m as f64,
            laser: u.laser * self.cfg.m as f64,
        }
    }

    pub fn peak_macs_per_sec(&self) -> f64 {
        let symbol = self.timing().symbol_time(true);
        (self.cfg.macs_per_symbol_per_unit() * self.cfg.m) as f64 / symbol
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_and_dense_units_share_cost_model() {
        let cfg = ArchConfig::paper_optimum();
        let c = ConvBlock::new(&cfg);
        let d = super::super::dense::DenseBlock::new(&cfg);
        // identical per-unit physics
        assert_eq!(c.timing(), d.timing());
        let (cu, du) = (c.unit().power(), d.unit().power());
        assert!((cu.active - du.active).abs() < 1e-15);
    }

    #[test]
    fn block_sizes_follow_m() {
        let cfg = ArchConfig::new(16, 2, 11, 3);
        assert_eq!(ConvBlock::new(&cfg).units(), 3);
        let p1 = ConvBlock::new(&ArchConfig::new(16, 2, 11, 1)).power();
        let p3 = ConvBlock::new(&cfg).power();
        assert!((p3.active / p1.active - 3.0).abs() < 1e-9);
    }
}
