//! Activation block (paper §III.B.4, Fig. 8).
//!
//! SOA-based optical non-linearities: Leaky-ReLU via the comparator + PCMC
//! + dual-SOA route of Fig. 8 (see [`crate::photonics::soa::LeakyReluUnit`]),
//! ReLU as the α→0 special case, and Tanh/Sigmoid via saturating SOA gain
//! [26]. One activation unit serves one streaming row; the block is sized
//! by the simulator to match whichever MVM block feeds it (max(L, M) · K
//! lanes — the activation units are cheap relative to MVM units).

use super::config::ArchConfig;
use crate::photonics::soa::{LeakyReluUnit, Soa};

/// Supported optical activations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ActKind {
    LeakyRelu(f64),
    Relu,
    Tanh,
    Sigmoid,
    /// Pass-through (no activation after this layer).
    None,
}

/// One activation lane.
#[derive(Debug, Clone)]
pub struct ActivationUnit {
    pub cfg: ArchConfig,
    lrelu: LeakyReluUnit,
    tanh_soa: Soa,
}

impl ActivationUnit {
    pub fn new(cfg: &ArchConfig) -> Self {
        ActivationUnit {
            lrelu: LeakyReluUnit::new(cfg.params.device.clone(), 0.2),
            tanh_soa: Soa::new(cfg.params.device.clone(), 1.0).with_saturation(1.0),
            cfg: cfg.clone(),
        }
    }

    /// Per-element latency (s).
    pub fn latency(&self, kind: ActKind) -> f64 {
        let d = &self.cfg.params.device;
        match kind {
            ActKind::None => 0.0,
            ActKind::LeakyRelu(_) | ActKind::Relu => self.lrelu.latency(),
            // saturating single-SOA path: PD not needed, just the SOA
            ActKind::Tanh | ActKind::Sigmoid => d.soa_latency,
        }
    }

    /// Per-lane power while streaming (W).
    pub fn power(&self, kind: ActKind) -> f64 {
        let d = &self.cfg.params.device;
        match kind {
            ActKind::None => 0.0,
            ActKind::LeakyRelu(_) | ActKind::Relu => self.lrelu.power(),
            ActKind::Tanh | ActKind::Sigmoid => d.soa_power,
        }
    }

    /// Functional response (normalized analog domain).
    pub fn apply(&self, x: f64, kind: ActKind) -> f64 {
        match kind {
            ActKind::None => x,
            ActKind::Relu => {
                if x > 0.0 {
                    x
                } else {
                    0.0
                }
            }
            ActKind::LeakyRelu(a) => {
                if x > 0.0 {
                    x
                } else {
                    a * x
                }
            }
            ActKind::Tanh => self.tanh_soa.amplify(x),
            ActKind::Sigmoid => 0.5 * (self.tanh_soa.amplify(x / 2.0) + 1.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn unit() -> ActivationUnit {
        ActivationUnit::new(&ArchConfig::paper_optimum())
    }

    #[test]
    fn relu_and_leaky_relu() {
        let u = unit();
        assert_eq!(u.apply(2.0, ActKind::Relu), 2.0);
        assert_eq!(u.apply(-2.0, ActKind::Relu), 0.0);
        assert_eq!(u.apply(-2.0, ActKind::LeakyRelu(0.1)), -0.2);
    }

    #[test]
    fn tanh_bounded_sigmoid_in_unit_interval() {
        let u = unit();
        check("tanh/sigmoid ranges", 256, move |g| {
            let x = g.f64_in(-5.0, 5.0);
            assert!(u.apply(x, ActKind::Tanh).abs() <= 1.0 + 1e-12);
            let s = u.apply(x, ActKind::Sigmoid);
            assert!((0.0..=1.0).contains(&s), "sigmoid out of range: {s}");
        });
    }

    #[test]
    fn sigmoid_midpoint_is_half() {
        let u = unit();
        assert!((u.apply(0.0, ActKind::Sigmoid) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn none_is_free_identity() {
        let u = unit();
        assert_eq!(u.latency(ActKind::None), 0.0);
        assert_eq!(u.power(ActKind::None), 0.0);
        assert_eq!(u.apply(0.7, ActKind::None), 0.7);
    }

    #[test]
    fn tanh_path_is_faster_than_lrelu_path() {
        // Leaky-ReLU needs PD + comparator + PCMC routing; Tanh is one SOA.
        let u = unit();
        assert!(u.latency(ActKind::Tanh) < u.latency(ActKind::LeakyRelu(0.2)));
    }
}
