//! Core MVM unit model shared by the dense and convolution blocks
//! (Figs. 5/6): two K×N MR bank arrays in series, one BPD per row, a
//! coherent-summation bias stage, DAC lanes in, ADC lanes out.
//!
//! The unit exposes the two quantities the simulator composes:
//! [`UnitTiming`] (weight-reload and per-symbol stage latencies) and
//! [`UnitPower`] (active / idle / gated power). The paper's stage-level
//! pipelining (§III.C.2) corresponds to `symbol_time(pipelined=true) =
//! max(stage1, stage2)` instead of their sum.

use super::config::ArchConfig;
use crate::photonics::laser;
use crate::photonics::mr::Microring;
use crate::photonics::waveguide::LossBudget;
use crate::util::units::ratio_to_db;

/// Which block a unit belongs to (affects only routing/bias details today,
/// but keeps traces and power reports attributable).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BlockKind {
    Dense,
    Conv,
    Norm,
    Activation,
}

/// Per-tile / per-symbol latency decomposition of an MVM unit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UnitTiming {
    /// Reprogramming the weight MR bank for a new tile (s): DAC settle +
    /// EO tuning, all MRs in parallel.
    pub weight_load: f64,
    /// Stage 1 — drive path: DAC convert + VCSEL modulation + time of
    /// flight through the banks (s).
    pub stage1: f64,
    /// Stage 2 — detect path: BPD + bias coherent summation (VCSEL) (s).
    pub stage2: f64,
    /// ADC conversion appended when the result leaves the optical domain
    /// at the end of a block chain (s).
    pub adc: f64,
}

impl UnitTiming {
    /// Per-symbol period with / without stage-level pipelining.
    pub fn symbol_time(&self, pipelined: bool) -> f64 {
        if pipelined {
            self.stage1.max(self.stage2)
        } else {
            self.stage1 + self.stage2
        }
    }

    /// Symbol period including the egress ADC (used at chain boundaries).
    pub fn symbol_time_with_adc(&self, pipelined: bool) -> f64 {
        if pipelined {
            // ADC overlaps the next symbol's stage 1 in the pipelined design
            self.symbol_time(true).max(self.adc)
        } else {
            self.symbol_time(false) + self.adc
        }
    }
}

/// Power draw of one MVM unit in each operating state (W).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UnitPower {
    /// Streaming symbols: lasers + converters + detectors + tuning holds.
    pub active: f64,
    /// Powered but stalled (no power gating): bias currents + laser +
    /// tuning holds keep burning.
    pub idle: f64,
    /// Power-gated: lasers off, EO holds released, PCMC routes hold free.
    pub gated: f64,
    /// Laser (wall-plug) component of `active` — reported separately
    /// because Eq. 2 makes it the only superlinear term in [N, K].
    pub laser: f64,
}

/// The MVM unit cost model.
#[derive(Debug, Clone)]
pub struct MvmUnit {
    pub kind: BlockKind,
    pub cfg: ArchConfig,
}

impl MvmUnit {
    pub fn new(kind: BlockKind, cfg: &ArchConfig) -> Self {
        assert!(matches!(kind, BlockKind::Dense | BlockKind::Conv));
        MvmUnit { kind, cfg: cfg.clone() }
    }

    /// Optical link loss through this unit (dB): both banks, the unit
    /// waveguide, one PCMC hop toward the next block.
    pub fn link_loss_db(&self) -> f64 {
        let p = &self.cfg.params;
        LossBudget::unit_link(
            &p.loss,
            p.system.unit_waveguide_length_cm,
            self.cfg.n.saturating_sub(1), // pass-by MRs per bank per λ
            1,                            // PCMC hop to the next block
            0.5,
            0.1, // cm of EO-tuned section
        )
        .total_db()
    }

    /// Wall-plug laser power for this unit's K rows (W). The block's shared
    /// VCSEL comb is split across the K row-waveguides, which adds a
    /// 10·log10(K) split term on top of Eq. 2's wavelength term.
    pub fn laser_power_w(&self) -> f64 {
        // Drive electronics floor: N comb lanes must be powered regardless.
        let drive_floor = self.cfg.n as f64 * self.cfg.params.device.vcsel_power;
        self.laser_eq2_w().max(drive_floor)
    }

    /// The Eq. 2 wall-plug component alone (W) — exponential in link loss
    /// (dB), hence superlinear in N; the DSE pressure against very wide
    /// banks comes from here.
    pub fn laser_eq2_w(&self) -> f64 {
        let p = &self.cfg.params;
        let split_db = ratio_to_db(self.cfg.k as f64)
            + p.loss.splitter_db * (self.cfg.k as f64).log2().ceil();
        let loss = self.link_loss_db() + split_db;
        laser::laser_wall_plug_watts(&p.system, loss, self.cfg.n)
    }

    /// Timing decomposition (see [`UnitTiming`]).
    pub fn timing(&self) -> UnitTiming {
        let d = &self.cfg.params.device;
        // time of flight: ~0.3 cm of waveguide at c/n_g
        let group_v = 299_792_458.0 / Microring::default().n_group;
        let tof = self.cfg.params.system.unit_waveguide_length_cm * 1e-2 / group_v;
        UnitTiming {
            weight_load: d.dac_latency + d.eo_tuning_latency,
            stage1: d.dac_latency + d.vcsel_latency + tof,
            stage2: d.pd_latency + d.vcsel_latency, // BPD + bias coherent sum
            adc: d.adc_latency,
        }
    }

    /// Power decomposition (see [`UnitPower`]).
    pub fn power(&self) -> UnitPower {
        let d = &self.cfg.params.device;
        let n = self.cfg.n as f64;
        let k = self.cfg.k as f64;
        let laser = self.laser_power_w();
        let dacs = n * d.dac_power; // N activation lanes (weights static)
        let adcs = k * d.adc_power; // one egress lane per row
        let bpds = k * 2.0 * d.pd_power; // balanced pair per row
        let bias = 2.0 * d.vcsel_power; // bias coherent-sum VCSEL pair
        let tuning_hold = 2.0 * n * k * d.eo_tuning_power; // both banks
        let active = laser + dacs + adcs + bpds + bias + tuning_hold;
        // Idle (no power gating): nothing is managed — lasers, tuning
        // holds, converter and detector rails all stay up. This is the
        // whole premium the paper's gating optimization recovers.
        let idle = active;
        UnitPower { active, idle, gated: 0.0, laser }
    }

    /// MACs retired per symbol.
    pub fn macs_per_symbol(&self) -> usize {
        self.cfg.macs_per_symbol_per_unit()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn unit() -> MvmUnit {
        MvmUnit::new(BlockKind::Dense, &ArchConfig::paper_optimum())
    }

    #[test]
    fn stage_pipelining_takes_max_not_sum() {
        let t = unit().timing();
        assert!(t.symbol_time(true) < t.symbol_time(false));
        assert_eq!(t.symbol_time(true), t.stage1.max(t.stage2));
        assert_eq!(t.symbol_time(false), t.stage1 + t.stage2);
    }

    #[test]
    fn symbol_rate_is_dac_limited() {
        // With Table 2 numbers, stage 1 (DAC 0.29 ns + VCSEL 0.07 ns + ToF)
        // dominates stage 2 (PD 5.8 ps + VCSEL 0.07 ns).
        let t = unit().timing();
        assert!(t.stage1 > t.stage2);
        // symbol rate in the GHz class
        let rate = 1.0 / t.symbol_time(true);
        assert!(rate > 1e9 && rate < 1e10, "rate={rate}");
    }

    #[test]
    fn weight_load_dominated_by_eo_tuning() {
        let t = unit().timing();
        assert!((t.weight_load - (20e-9 + 0.29e-9)).abs() < 1e-15);
    }

    #[test]
    fn power_ordering_gated_idle_active() {
        let p = unit().power();
        // ungated idle keeps every rail up (== active); gating drops all
        assert!(p.gated < p.idle);
        assert_eq!(p.idle, p.active);
        assert!(p.laser > 0.0 && p.laser < p.active);
    }

    #[test]
    fn laser_power_superlinear_in_n() {
        // The Eq. 2 wall-plug component grows faster than linearly with N
        // (+dB per pass-by MR and +10log10 N are exponential in linear
        // watts). The total may sit on the linear N·VCSEL drive floor.
        let at = |n: usize| {
            MvmUnit::new(BlockKind::Dense, &ArchConfig::new(n, 2, 1, 1)).laser_eq2_w()
        };
        let (p9, p18, p36) = (at(9), at(18), at(36));
        assert!(p18 > p9 && p36 > p18);
        assert!(
            (p36 / p18) > (p18 / p9),
            "growth must accelerate: {p9} {p18} {p36}"
        );
    }

    #[test]
    fn power_scales_with_rows_and_cols() {
        check("unit power monotone in K and N", 64, |g| {
            let n = g.usize_in(2, 35);
            let k = g.usize_in(1, 7);
            let base = MvmUnit::new(BlockKind::Conv, &ArchConfig::new(n, k, 1, 1)).power();
            let more_n =
                MvmUnit::new(BlockKind::Conv, &ArchConfig::new(n + 1, k, 1, 1)).power();
            let more_k =
                MvmUnit::new(BlockKind::Conv, &ArchConfig::new(n, k + 1, 1, 1)).power();
            assert!(more_n.active > base.active);
            assert!(more_k.active > base.active);
        });
    }

    #[test]
    #[should_panic]
    fn norm_kind_is_not_an_mvm_unit() {
        MvmUnit::new(BlockKind::Norm, &ArchConfig::paper_optimum());
    }
}
