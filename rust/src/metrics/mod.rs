//! Metric definitions shared by the simulator, baselines and reports.
//!
//! The paper's two headline metrics:
//! - **GOPS** — achieved giga-operations/second, counting the *workload's*
//!   dense-equivalent ops (2 per MAC). Fixed numerator per model, so
//!   platforms that skip structural zeros (PhotoGAN's sparse dataflow)
//!   or waste work on them (zero-inserted execution) are scored on the
//!   same yardstick.
//! - **EPB** — energy-per-bit: total inference energy / bits processed,
//!   with bits = ops × precision (8). Any consistent denominator gives the
//!   same *ratios*, which is what the paper reports.

/// Ops (not MACs) per multiply-accumulate.
pub const OPS_PER_MAC: f64 = 2.0;

/// Workload bits for an op count at a precision.
pub fn bits_for_ops(ops: f64, precision_bits: u32) -> f64 {
    ops * precision_bits as f64
}

/// GOPS from ops and latency.
pub fn gops(ops: f64, latency_s: f64) -> f64 {
    assert!(latency_s > 0.0);
    ops / latency_s / 1e9
}

/// EPB from energy and bits.
pub fn epb(energy_j: f64, bits: f64) -> f64 {
    assert!(bits > 0.0);
    energy_j / bits
}

/// Geometric-mean speedup of `a` over `b` across paired samples.
pub fn geomean_ratio(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    assert!(!a.is_empty());
    let log_sum: f64 = a.iter().zip(b).map(|(x, y)| (x / y).ln()).sum();
    (log_sum / a.len() as f64).exp()
}

/// Arithmetic-mean ratio (the paper's "on average X×" convention).
pub fn mean_ratio(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    assert!(!a.is_empty());
    a.iter().zip(b).map(|(x, y)| x / y).sum::<f64>() / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_conversions() {
        assert_eq!(gops(2e9, 1.0), 2.0);
        assert_eq!(epb(1.0, 8e9), 1.25e-10);
        assert_eq!(bits_for_ops(1e9, 8), 8e9);
    }

    #[test]
    fn ratios() {
        let a = [4.0, 9.0];
        let b = [1.0, 1.0];
        assert!((geomean_ratio(&a, &b) - 6.0).abs() < 1e-12);
        assert!((mean_ratio(&a, &b) - 6.5).abs() < 1e-12);
    }
}
