//! Design-space exploration over `[N, K, L, M]` (paper §IV.A, Fig. 11).
//!
//! Exhaustively sweeps the architectural grid under the 100 W power cap,
//! scoring each valid configuration by the paper's objective —
//! **GOPS/EPB** averaged over the four evaluated GAN models — and returns
//! the Pareto-ish cloud plus the optimum. Multi-threaded with
//! `std::thread::scope` (the per-model job mapping is computed once and
//! shared read-only across workers).

use crate::arch::accelerator::Accelerator;
use crate::arch::config::ArchConfig;
use crate::models::Model;
use crate::sim::engine::simulate_mapped;
use crate::sim::mapper::{map_model, LayerJob};
use crate::sim::options::OptFlags;
use std::sync::Arc;

/// A model's name plus its (configuration-independent) mapped jobs —
/// the unit of work the sweep re-costs per configuration. `Arc` so the
/// [`crate::api::Session`] mapping cache can hand out shared mappings
/// without cloning the job lists.
pub type MappedModel = (String, Arc<Vec<LayerJob>>);

/// One evaluated configuration.
#[derive(Debug, Clone)]
pub struct DsePoint {
    pub n: usize,
    pub k: usize,
    pub l: usize,
    pub m: usize,
    /// Gated peak power (W) — must be under the cap.
    pub peak_power_w: f64,
    /// Average GOPS across models.
    pub gops: f64,
    /// Average EPB across models (J/bit).
    pub epb: f64,
    /// The objective: GOPS / EPB.
    pub objective: f64,
}

/// Sweep grid specification.
#[derive(Debug, Clone)]
pub struct Grid {
    pub n: Vec<usize>,
    pub k: Vec<usize>,
    pub l: Vec<usize>,
    pub m: Vec<usize>,
}

impl Grid {
    /// The paper-scale grid (N ≤ 36 by the crosstalk rule).
    pub fn paper() -> Self {
        Grid {
            n: vec![4, 8, 12, 16, 20, 24, 28, 32, 36],
            k: vec![1, 2, 4, 8],
            l: vec![1, 3, 5, 7, 9, 11, 13],
            m: vec![1, 2, 3, 4, 5],
        }
    }

    /// A small smoke grid for tests.
    pub fn smoke() -> Self {
        Grid { n: vec![8, 16, 32], k: vec![1, 2, 4], l: vec![3, 11], m: vec![1, 3] }
    }

    pub fn len(&self) -> usize {
        self.n.len() * self.k.len() * self.l.len() * self.m.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Structural validation: every axis non-empty, every value ≥ 1.
    /// Oversized values (e.g. N beyond the crosstalk bound) are *not* an
    /// error — those configurations are individually rejected during the
    /// sweep, exactly like over-cap ones — but zeros are malformed input
    /// and surface as a typed `ApiError::InvalidGrid` at the Session
    /// boundary instead of silently evaluating nothing.
    pub fn validate(&self) -> Result<(), String> {
        for (axis, vals) in
            [("n", &self.n), ("k", &self.k), ("l", &self.l), ("m", &self.m)]
        {
            if vals.is_empty() {
                return Err(format!("axis {axis} is empty"));
            }
            if vals.iter().any(|&v| v == 0) {
                return Err(format!("axis {axis} contains 0"));
            }
        }
        Ok(())
    }

    fn configs(&self) -> Vec<(usize, usize, usize, usize)> {
        let mut out = Vec::with_capacity(self.len());
        for &n in &self.n {
            for &k in &self.k {
                for &l in &self.l {
                    for &m in &self.m {
                        out.push((n, k, l, m));
                    }
                }
            }
        }
        out
    }
}

/// Evaluate one configuration against pre-mapped model jobs. Returns `None`
/// if the configuration is invalid or over the power cap.
fn evaluate(
    n: usize,
    k: usize,
    l: usize,
    m: usize,
    mapped: &[MappedModel],
    opts: OptFlags,
) -> Option<DsePoint> {
    let cfg = ArchConfig::new(n, k, l, m);
    let acc = Accelerator::new(cfg).ok()?;
    acc.validate(opts.power_gated).ok()?;
    let peak = acc.peak_power(opts.power_gated);
    let mut gops = 0.0;
    let mut epb = 0.0;
    for (name, jobs) in mapped {
        let r = simulate_mapped(name, jobs, &acc, 1, opts);
        gops += r.gops();
        epb += r.epb();
    }
    let n_models = mapped.len() as f64;
    gops /= n_models;
    epb /= n_models;
    Some(DsePoint { n, k, l, m, peak_power_w: peak, gops, epb, objective: gops / epb })
}

/// Run the sweep over pre-mapped models (the [`crate::api::Session`] path:
/// mappings come from its memoized cache, so repeated sweeps never re-map).
/// Returns all valid points sorted by descending objective (so `[0]` is
/// the optimum). `threads` is clamped to ≥ 1.
pub fn explore_mapped(
    grid: &Grid,
    mapped: &[MappedModel],
    opts: OptFlags,
    threads: usize,
) -> Vec<DsePoint> {
    let threads = threads.max(1);
    let configs = grid.configs();
    let chunk = configs.len().div_ceil(threads);
    let mut points: Vec<DsePoint> = std::thread::scope(|scope| {
        let handles: Vec<_> = configs
            .chunks(chunk.max(1))
            .map(|slice| {
                let mapped = &mapped;
                scope.spawn(move || {
                    slice
                        .iter()
                        .filter_map(|&(n, k, l, m)| evaluate(n, k, l, m, mapped, opts))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| match h.join() {
                Ok(points) => points,
                Err(panic) => std::panic::resume_unwind(panic),
            })
            .collect()
    });
    points.sort_by(|a, b| b.objective.total_cmp(&a.objective));
    points
}

/// Run the sweep, mapping each model once up front. Thin wrapper over
/// [`explore_mapped`] for callers without a [`crate::api::Session`].
pub fn explore(grid: &Grid, models: &[Model], opts: OptFlags, threads: usize) -> Vec<DsePoint> {
    let mapped: Vec<MappedModel> = models
        .iter()
        .map(|m| (m.name.clone(), Arc::new(map_model(m, 1, &opts))))
        .collect();
    explore_mapped(grid, &mapped, opts, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn smoke_grid_finds_an_optimum() {
        // keep the test fast: two small models
        let models = vec![zoo::condgan(), zoo::artgan()];
        let pts = explore(&Grid::smoke(), &models, OptFlags::all(), 4);
        assert!(!pts.is_empty());
        // sorted descending by objective
        for w in pts.windows(2) {
            assert!(w[0].objective >= w[1].objective);
        }
        // every surviving point respects the cap and the crosstalk rule
        for p in &pts {
            assert!(p.peak_power_w <= 100.0);
            assert!(p.n <= 36);
        }
    }

    #[test]
    fn objective_consistency() {
        let models = vec![zoo::condgan()];
        let pts = explore(&Grid::smoke(), &models, OptFlags::all(), 2);
        for p in &pts {
            assert!((p.objective - p.gops / p.epb).abs() < 1e-6 * p.objective.abs());
        }
    }

    #[test]
    fn single_thread_matches_multi_thread() {
        let models = vec![zoo::condgan()];
        let a = explore(&Grid::smoke(), &models, OptFlags::all(), 1);
        let b = explore(&Grid::smoke(), &models, OptFlags::all(), 8);
        assert_eq!(a.len(), b.len());
        assert_eq!(
            (a[0].n, a[0].k, a[0].l, a[0].m),
            (b[0].n, b[0].k, b[0].l, b[0].m)
        );
    }

    #[test]
    fn grid_validate_catches_empty_axes_and_zeros() {
        assert!(Grid::paper().validate().is_ok());
        assert!(Grid::smoke().validate().is_ok());
        let empty = Grid { n: vec![], k: vec![1], l: vec![1], m: vec![1] };
        assert_eq!(empty.validate().unwrap_err(), "axis n is empty");
        let zeroed = Grid { n: vec![8], k: vec![2], l: vec![0, 3], m: vec![1] };
        assert_eq!(zeroed.validate().unwrap_err(), "axis l contains 0");
        // oversized values are dropped per-config, not rejected wholesale
        let oversized = Grid { n: vec![400], k: vec![1], l: vec![1], m: vec![1] };
        assert!(oversized.validate().is_ok());
        assert!(explore(&oversized, &[zoo::condgan()], OptFlags::all(), 1).is_empty());
    }

    #[test]
    fn every_point_respects_the_power_cap_for_random_grids() {
        use crate::util::prop::check;
        let models = vec![zoo::condgan()];
        check("dse points under the 100 W cap", 10, |g| {
            let grid = Grid {
                n: vec![g.usize_in(1, 36), g.usize_in(1, 36)],
                k: vec![g.usize_in(1, 8)],
                l: vec![g.usize_in(1, 13)],
                m: vec![g.usize_in(1, 5)],
            };
            for opts in [OptFlags::all(), OptFlags::overlapped()] {
                for p in explore(&grid, &models, opts, 2) {
                    assert!(
                        p.peak_power_w <= 100.0,
                        "[{},{},{},{}] peak {} W over cap",
                        p.n,
                        p.k,
                        p.l,
                        p.m,
                        p.peak_power_w
                    );
                    assert!(p.objective.is_finite() && p.objective > 0.0);
                }
            }
        });
    }

    #[test]
    fn optimum_invariant_under_grid_axis_permutation() {
        let models = vec![zoo::condgan(), zoo::artgan()];
        let grid = Grid::smoke();
        let mut permuted = grid.clone();
        permuted.n.reverse();
        permuted.k.reverse();
        permuted.l.reverse();
        permuted.m.reverse();
        for opts in [OptFlags::all(), OptFlags::overlapped()] {
            let a = explore(&grid, &models, opts, 3);
            let b = explore(&permuted, &models, opts, 3);
            assert_eq!(a.len(), b.len(), "permutation must not change the valid set");
            assert_eq!(
                (a[0].n, a[0].k, a[0].l, a[0].m),
                (b[0].n, b[0].k, b[0].l, b[0].m),
                "optimum must be axis-order invariant"
            );
            assert_eq!(a[0].objective, b[0].objective, "objective is order-independent");
        }
    }

    #[test]
    fn mapped_recosting_equals_fresh_simulation() {
        use crate::sim::simulate;
        use crate::util::prop::check;
        let models = [zoo::condgan(), zoo::dcgan()];
        check("simulate_mapped re-cost == fresh simulate", 12, |g| {
            let cfg = ArchConfig::new(
                g.usize_in(2, 36),
                g.usize_in(1, 8),
                g.usize_in(1, 13),
                g.usize_in(1, 5),
            );
            let Ok(acc) = Accelerator::new(cfg) else { return };
            for m in &models {
                for opts in [OptFlags::all(), OptFlags::overlapped()] {
                    let jobs = map_model(m, 1, &opts);
                    let recost = simulate_mapped(&m.name, &jobs, &acc, 1, opts);
                    let fresh = simulate(m, &acc, 1, opts);
                    assert_eq!(recost.latency, fresh.latency, "{} {opts:?}", m.name);
                    assert_eq!(
                        recost.energy.total(),
                        fresh.energy.total(),
                        "{} {opts:?}",
                        m.name
                    );
                }
            }
        });
    }
}
