//! Builder-style request types for the [`crate::api::Session`] facade.
//!
//! Builders collect parameters fluently; `build()` performs the
//! *request-local* validation (batch ≥ 1, structurally valid config,
//! non-empty grid…) and returns a typed [`ApiError`]. Validation that
//! needs session state (model-name resolution, power-cap vs. the
//! assembled chip) happens when the request is executed.
//!
//! Request fields are public for ergonomic consumption (the CLI reads
//! them back for progress output), which means a request can also be
//! constructed field-by-field, bypassing `build()` — so
//! [`crate::api::Session`] re-checks the cheap invariants defensively at
//! execution time. Keep the two in sync when adding invariants.

use super::error::ApiError;
use crate::arch::config::ArchConfig;
use crate::dse::Grid;
use crate::sim::OptFlags;

/// Which models a simulation request covers.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum ModelSelect {
    /// Every model in the session registry (paper Table 1 order).
    #[default]
    All,
    /// One model by (case-insensitive) name.
    Named(String),
    /// An explicit ordered subset by (case-insensitive) name — what a
    /// scenario's `models` list compiles to.
    Subset(Vec<String>),
}

/// A validated simulation request (construct via [`SimRequest::builder`]).
#[derive(Debug, Clone)]
pub struct SimRequest {
    pub models: ModelSelect,
    pub batch: usize,
    /// `None` = the session's own accelerator configuration.
    pub config: Option<ArchConfig>,
    pub opts: OptFlags,
    /// When set, the request fails with [`ApiError::PowerCapExceeded`] if
    /// the (possibly ungated) chip exceeds the system power cap instead of
    /// simulating anyway.
    pub strict_power: bool,
}

impl SimRequest {
    pub fn builder() -> SimRequestBuilder {
        SimRequestBuilder::default()
    }
}

/// Fluent builder for [`SimRequest`].
#[derive(Debug, Clone)]
pub struct SimRequestBuilder {
    models: ModelSelect,
    batch: usize,
    config: Option<ArchConfig>,
    opts: OptFlags,
    strict_power: bool,
}

impl Default for SimRequestBuilder {
    fn default() -> Self {
        SimRequestBuilder {
            models: ModelSelect::All,
            batch: 1,
            config: None,
            opts: OptFlags::all(),
            strict_power: false,
        }
    }
}

impl SimRequestBuilder {
    /// Restrict to one model by name (resolved against the session
    /// registry at execution time; unknown names yield
    /// [`ApiError::UnknownModel`]).
    pub fn model(mut self, name: impl Into<String>) -> Self {
        self.models = ModelSelect::Named(name.into());
        self
    }

    /// Simulate every registered model (the default).
    pub fn all_models(mut self) -> Self {
        self.models = ModelSelect::All;
        self
    }

    /// Restrict to an ordered subset of models by name (each resolved
    /// against the session registry at execution time; an empty list means
    /// every registered model, matching [`ModelSelect::All`]).
    pub fn models<S: Into<String>>(mut self, names: Vec<S>) -> Self {
        let names: Vec<String> = names.into_iter().map(Into::into).collect();
        self.models = if names.is_empty() {
            ModelSelect::All
        } else {
            ModelSelect::Subset(names)
        };
        self
    }

    /// Inference instances streamed back-to-back (default 1).
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Override the session's accelerator configuration for this request.
    /// The mapping cache is still shared — layer mappings are
    /// configuration-independent.
    pub fn config(mut self, cfg: ArchConfig) -> Self {
        self.config = Some(cfg);
        self
    }

    /// Optimization toggles (default: all three enabled).
    pub fn opts(mut self, opts: OptFlags) -> Self {
        self.opts = opts;
        self
    }

    /// Fail with [`ApiError::PowerCapExceeded`] when the chip's peak power
    /// (under this request's gating policy) exceeds the cap.
    pub fn strict_power(mut self, strict: bool) -> Self {
        self.strict_power = strict;
        self
    }

    /// Validate and freeze the request.
    pub fn build(self) -> Result<SimRequest, ApiError> {
        if self.batch == 0 {
            return Err(ApiError::InvalidBatch(0));
        }
        if let Some(cfg) = &self.config {
            cfg.validate().map_err(ApiError::from)?;
        }
        Ok(SimRequest {
            models: self.models,
            batch: self.batch,
            config: self.config,
            opts: self.opts,
            strict_power: self.strict_power,
        })
    }
}

/// A validated design-space-exploration request (construct via
/// [`SweepRequest::builder`]).
///
/// Default optimization flags are [`OptFlags::overlapped`]: the Fig. 11
/// optimum is searched under the event-driven overlap scheduler (the
/// timing the serving layer actually experiences). Pass
/// `.opts(OptFlags::all())` for the paper's analytical calibration sweep.
#[derive(Debug, Clone)]
pub struct SweepRequest {
    pub grid: Grid,
    pub opts: OptFlags,
    pub threads: usize,
}

impl SweepRequest {
    pub fn builder() -> SweepRequestBuilder {
        SweepRequestBuilder::default()
    }
}

/// Fluent builder for [`SweepRequest`].
#[derive(Debug, Clone)]
pub struct SweepRequestBuilder {
    grid: Grid,
    opts: OptFlags,
    threads: usize,
}

impl Default for SweepRequestBuilder {
    fn default() -> Self {
        SweepRequestBuilder {
            grid: Grid::paper(),
            opts: OptFlags::overlapped(),
            threads: default_threads(),
        }
    }
}

/// Available parallelism, falling back to 4 (same default as the seed CLI).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

impl SweepRequestBuilder {
    /// The `[N,K,L,M]` grid to sweep (default: the paper grid).
    pub fn grid(mut self, grid: Grid) -> Self {
        self.grid = grid;
        self
    }

    /// Optimization toggles applied at every point (default: every paper
    /// optimization plus the overlap scheduler — [`OptFlags::overlapped`]).
    pub fn opts(mut self, opts: OptFlags) -> Self {
        self.opts = opts;
        self
    }

    /// Worker threads (default: available parallelism).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Validate and freeze the request.
    pub fn build(self) -> Result<SweepRequest, ApiError> {
        if self.grid.is_empty() {
            return Err(ApiError::EmptyGrid);
        }
        self.grid
            .validate()
            .map_err(|reason| ApiError::InvalidGrid { reason })?;
        if self.threads == 0 {
            return Err(ApiError::InvalidThreads(0));
        }
        Ok(SweepRequest { grid: self.grid, opts: self.opts, threads: self.threads })
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::arch::config::ConfigError;

    #[test]
    fn sim_builder_defaults() {
        let r = SimRequest::builder().build().unwrap();
        assert_eq!(r.models, ModelSelect::All);
        assert_eq!(r.batch, 1);
        assert!(r.config.is_none());
        assert_eq!(r.opts, OptFlags::all());
        assert!(!r.strict_power);
    }

    #[test]
    fn sim_builder_rejects_zero_batch() {
        assert_eq!(
            SimRequest::builder().batch(0).build().unwrap_err(),
            ApiError::InvalidBatch(0)
        );
    }

    #[test]
    fn sim_builder_rejects_invalid_config() {
        let err = SimRequest::builder()
            .config(ArchConfig::new(37, 2, 11, 3))
            .build()
            .unwrap_err();
        assert_eq!(
            err,
            ApiError::InvalidConfig(ConfigError::TooManyWavelengths(37, 36))
        );
    }

    #[test]
    fn sweep_builder_rejects_empty_grid_and_zero_threads() {
        let empty = Grid { n: vec![], k: vec![1], l: vec![1], m: vec![1] };
        assert_eq!(
            SweepRequest::builder().grid(empty).build().unwrap_err(),
            ApiError::EmptyGrid
        );
        assert_eq!(
            SweepRequest::builder().threads(0).build().unwrap_err(),
            ApiError::InvalidThreads(0)
        );
    }

    #[test]
    fn sweep_builder_rejects_zeroed_axes_with_a_typed_error() {
        let zeroed = Grid { n: vec![8, 0], k: vec![2], l: vec![11], m: vec![3] };
        assert_eq!(
            SweepRequest::builder().grid(zeroed).build().unwrap_err(),
            ApiError::InvalidGrid { reason: "axis n contains 0".into() }
        );
    }

    #[test]
    fn sweep_defaults_to_the_overlap_scheduler() {
        let r = SweepRequest::builder().build().unwrap();
        assert_eq!(r.opts, OptFlags::overlapped());
        // the analytical calibration sweep stays one call away
        let analytic = SweepRequest::builder().opts(OptFlags::all()).build().unwrap();
        assert!(!analytic.opts.overlap);
    }

    #[test]
    fn builders_are_fluent() {
        let r = SimRequest::builder()
            .model("dcgan")
            .batch(8)
            .opts(OptFlags::baseline())
            .strict_power(true)
            .build()
            .unwrap();
        assert_eq!(r.models, ModelSelect::Named("dcgan".into()));
        assert_eq!(r.batch, 8);
        assert!(r.strict_power);
    }
}
