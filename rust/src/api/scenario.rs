//! The declarative Scenario layer: JSON workload descriptions compiled
//! into validated plans and executed into one outcome envelope.
//!
//! A [`Scenario`] is a typed IR for an experiment: a name, a seed, and a
//! stage list drawing on the session's five capabilities (simulate / dse /
//! compare / serve / report). Serve stages carry a weighted model
//! [`TrafficMix`], an [`ArrivalProcess`] (closed-loop, open-loop Poisson,
//! bursty on/off, or recorded-trace replay), a fleet shape, and optional
//! SLO targets.
//!
//! The pipeline is `parse → plan → run`:
//!
//! 1. [`Scenario::from_json`] parses the document into the IR (shape
//!    errors are per-field [`ApiError::ScenarioParse`]).
//! 2. [`Session::plan`] validates the IR against the session — model
//!    names resolve against the registry ([`ApiError::UnknownModel`]),
//!    mix weights must be positive ([`ApiError::InvalidMixWeight`]),
//!    rates and durations must be finite and positive
//!    ([`ApiError::InvalidRate`] / [`ApiError::InvalidDuration`]) — and
//!    compiles each stage into an executable [`PlannedStage`].
//! 3. [`Session::run`] executes the [`Plan`] into a [`ScenarioOutcome`]:
//!    one envelope holding every stage's [`Outcome`] plus a per-stage
//!    [`SloVerdict`], rendering as tables or JSON.
//!
//! Serve stages default to the **virtual** engine
//! ([`crate::workload::vserve`]): a deterministic virtual-time simulation
//! whose results are byte-identical for a fixed seed. `engine:
//! "threaded"` instead drives the real multi-shard coordinator through
//! [`Session::serve`] (wall-clock timing — what `photogan serve`
//! compiles to).
//!
//! The five legacy CLI subcommands are thin presets over this layer (see
//! [`Scenario::single`] and the `*Stage::default` impls): `photogan
//! simulate --model dcgan` builds a one-stage scenario and runs it through
//! the same `plan → run` path as `photogan run scenario.json`.
//!
//! ```
//! use photogan::api::{Scenario, Session};
//! use std::sync::Arc;
//!
//! let text = r#"{
//!   "name": "demo", "seed": 3,
//!   "stages": [
//!     { "kind": "simulate", "name": "sim", "models": ["dcgan"], "batch": 2 },
//!     { "kind": "serve", "name": "fleet",
//!       "mix": [ { "model": "dcgan", "weight": 1.0 } ],
//!       "arrival": { "process": "closed-loop", "clients": 2, "per_client": 8 },
//!       "shards": 2, "slo": { "p99_ms": 1000.0 } }
//!   ]
//! }"#;
//! let scenario = Scenario::from_json(text)?;
//! let session = Arc::new(Session::new()?);
//! let plan = session.plan(&scenario)?;
//! let outcome = session.run(&plan)?;
//! assert_eq!(outcome.stages.len(), 2);
//! assert!(outcome.to_json().contains("\"slo\""));
//! // the IR round-trips: parse(to_json(s)) == s
//! assert_eq!(Scenario::from_json(&scenario.to_json())?, scenario);
//! # Ok::<(), photogan::api::ApiError>(())
//! ```

use super::error::ApiError;
use super::outcome::{Outcome, ReportOutcome, SimOutcome, SweepOutcome, WorkloadOutcome};
use super::request::{SimRequest, SweepRequest};
use super::serve::{ServeBackend, ServeCore, ServeRequest};
use super::session::Session;
use crate::arch::config::ArchConfig;
use crate::baselines::{all_platforms, platform_named, Platform};
use crate::coordinator::RoutingPolicy;
use crate::dse::Grid;
use crate::report;
use crate::sim::OptFlags;
use crate::util::json::{obj, JsonValue};
use crate::util::rng::Pcg32;
use crate::util::table::Table;
use crate::workload::vserve::{
    simulate_fleet, AutoscaleConfig, AutoscalePolicy, CalibrationConfig, FailureConfig,
    FleetConfig, FleetCost, QueueKind, ShardClass, VirtualServeConfig,
};
use crate::workload::{ArrivalProcess, MixError, TrafficMix};
use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::str::FromStr;
use std::sync::Arc;
use std::time::Duration;

// ---------------------------------------------------------------- SLOs

/// Optional per-stage service-level objectives. Which members apply
/// depends on the stage kind: serve stages check `p99_ms` /
/// `min_throughput_rps` / `max_reject_frac`, simulate stages check
/// `max_latency_ms` / `min_gops`, dse stages check `min_gops` (of the
/// sweep optimum). Setting an inapplicable member is a typed plan error.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SloSpec {
    /// Serve: p99 end-to-end latency must be ≤ this many milliseconds.
    pub p99_ms: Option<f64>,
    /// Serve: goodput must be ≥ this many requests per second.
    pub min_throughput_rps: Option<f64>,
    /// Serve: rejected fraction of submissions must be ≤ this.
    pub max_reject_frac: Option<f64>,
    /// Simulate: worst per-model latency must be ≤ this many ms.
    pub max_latency_ms: Option<f64>,
    /// Simulate / dse: worst per-model (or optimum) GOPS must be ≥ this.
    pub min_gops: Option<f64>,
    /// Serve (virtual): shard availability — the fraction of shard-time
    /// not lost to re-calibration outages — must be ≥ this.
    pub min_availability: Option<f64>,
}

impl SloSpec {
    /// True when no objective is set.
    pub fn is_empty(&self) -> bool {
        self.p99_ms.is_none()
            && self.min_throughput_rps.is_none()
            && self.max_reject_frac.is_none()
            && self.max_latency_ms.is_none()
            && self.min_gops.is_none()
            && self.min_availability.is_none()
    }
}

/// One evaluated SLO check.
#[derive(Debug, Clone, PartialEq)]
pub struct SloCheck {
    /// Metric name (an [`SloSpec`] member name).
    pub metric: String,
    pub target: f64,
    pub actual: f64,
    pub pass: bool,
}

/// The per-stage SLO verdict: every check evaluated, and the conjunction.
#[derive(Debug, Clone, PartialEq)]
pub struct SloVerdict {
    pub pass: bool,
    pub checks: Vec<SloCheck>,
}

impl SloVerdict {
    /// A verdict with no checks (stages without SLOs pass vacuously).
    pub fn empty() -> SloVerdict {
        SloVerdict { pass: true, checks: Vec::new() }
    }

    fn from_checks(checks: Vec<SloCheck>) -> SloVerdict {
        SloVerdict { pass: checks.iter().all(|c| c.pass), checks }
    }

    /// `"pass"`, `"FAIL"`, or `"-"` (no checks) — the table cell.
    pub fn label(&self) -> &'static str {
        if self.checks.is_empty() {
            "-"
        } else if self.pass {
            "pass"
        } else {
            "FAIL"
        }
    }

    pub fn json(&self) -> JsonValue {
        obj(vec![
            ("pass", JsonValue::Bool(self.pass)),
            (
                "checks",
                JsonValue::Arr(
                    self.checks
                        .iter()
                        .map(|c| {
                            obj(vec![
                                ("metric", JsonValue::Str(c.metric.clone())),
                                ("target", JsonValue::Num(c.target)),
                                ("actual", JsonValue::Num(c.actual)),
                                ("pass", JsonValue::Bool(c.pass)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

// ------------------------------------------------------------ stage IR

/// Which engine a serve stage runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ServeEngine {
    /// Deterministic virtual-time simulation ([`crate::workload::vserve`]):
    /// byte-identical results for a fixed seed.
    #[default]
    Virtual,
    /// The real threaded coordinator via [`Session::serve`] (wall-clock
    /// timing; what `photogan serve` compiles to).
    Threaded,
    /// The real async continuous-batching coordinator
    /// ([`crate::coordinator::AsyncServer`]) via the same driver —
    /// wall-clock timing, and the only engine that honors `deadline_ms`.
    Async,
}

impl ServeEngine {
    pub fn name(self) -> &'static str {
        match self {
            ServeEngine::Virtual => "virtual",
            ServeEngine::Threaded => "threaded",
            ServeEngine::Async => "async",
        }
    }
}

impl fmt::Display for ServeEngine {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for ServeEngine {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "virtual" => Ok(ServeEngine::Virtual),
            "threaded" => Ok(ServeEngine::Threaded),
            "async" => Ok(ServeEngine::Async),
            other => {
                Err(format!("unknown engine '{other}' (expected virtual, threaded, or async)"))
            }
        }
    }
}

/// A simulate stage: per-model latency/energy/GOPS/EPB rows.
#[derive(Debug, Clone, PartialEq)]
pub struct SimStage {
    pub name: String,
    /// Model subset (empty = every registered model).
    pub models: Vec<String>,
    pub batch: usize,
    pub opts: OptFlags,
    /// Optional `"N,K,L,M"` chip override.
    pub config: Option<String>,
    pub strict_power: bool,
    pub slo: SloSpec,
}

impl Default for SimStage {
    fn default() -> Self {
        SimStage {
            name: "simulate".into(),
            models: Vec::new(),
            batch: 1,
            opts: OptFlags::all(),
            config: None,
            strict_power: false,
            slo: SloSpec::default(),
        }
    }
}

/// A design-space-exploration stage (paper Fig. 11).
#[derive(Debug, Clone, PartialEq)]
pub struct DseStage {
    pub name: String,
    /// `"paper"` or `"smoke"`.
    pub grid: String,
    /// Worker threads (`None` = available parallelism).
    pub threads: Option<usize>,
    pub opts: OptFlags,
    pub slo: SloSpec,
}

impl Default for DseStage {
    fn default() -> Self {
        DseStage {
            name: "dse".into(),
            grid: "paper".into(),
            threads: None,
            opts: OptFlags::overlapped(),
            slo: SloSpec::default(),
        }
    }
}

/// A platform-comparison stage (paper Figs. 13/14).
#[derive(Debug, Clone, PartialEq)]
pub struct CompareStage {
    pub name: String,
    pub opts: OptFlags,
}

impl Default for CompareStage {
    fn default() -> Self {
        CompareStage { name: "compare".into(), opts: OptFlags::all() }
    }
}

/// Re-calibration dynamics for a virtual serve stage: every
/// `interval_ms` of virtual time a shard goes down for `outage_ms` while
/// its MR banks re-lock ([`crate::workload::vserve::CalibrationConfig`]).
/// The physics-grounded defaults come from
/// [`crate::fidelity::CalibrationModel`]; scenarios set the knob in
/// milliseconds directly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CalibrationSpec {
    /// Virtual milliseconds between the start of consecutive outages.
    pub interval_ms: f64,
    /// Length of each outage in virtual milliseconds.
    pub outage_ms: f64,
}

/// One group of identical shards in a heterogeneous virtual fleet
/// (virtual engine only). `platform` is `"photonic"` (the session's
/// photonic cost model) or a baseline key resolved against
/// [`crate::baselines::all_platforms`] — `"gpu"`, `"cpu"`, `"tpu"`,
/// `"fpga"`, `"reram"`, or a full platform name.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetGroup {
    /// Hardware class key (see above).
    pub platform: String,
    /// Number of shards in this group (default 1).
    pub count: usize,
    /// Workers per shard; `None` inherits the stage-level `workers`.
    pub workers: Option<usize>,
    /// Idle power draw in watts (default 0).
    pub idle_w: f64,
    /// Billing rate in $/hour of active shard time (default 0).
    pub cost_per_hour: f64,
}

/// Shard failure/recovery injection for a virtual serve stage
/// ([`crate::workload::vserve::FailureConfig`] in milliseconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureSpec {
    /// Mean virtual milliseconds between failures.
    pub mtbf_ms: f64,
    /// Mean virtual milliseconds to repair.
    pub mttr_ms: f64,
}

/// Autoscaling of a virtual fleet's active set
/// ([`crate::workload::vserve::AutoscaleConfig`] in milliseconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscaleSpec {
    /// `"target-utilization"` or `"queue-depth"`.
    pub policy: AutoscalePolicyKind,
    /// Smallest active set (default 1).
    pub min_shards: usize,
    /// Largest active set (required; capped by the fleet size at plan
    /// time).
    pub max_shards: usize,
    /// Active set at time zero; `None` starts at `max_shards`.
    pub initial: Option<usize>,
    /// Virtual milliseconds between decisions.
    pub interval_ms: f64,
}

/// The autoscale policy discriminator with its parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AutoscalePolicyKind {
    /// Scale on mean worker occupancy vs `target`.
    TargetUtilization { target: f64 },
    /// Scale on mean outstanding samples per active shard vs the
    /// `high`/`low` watermarks.
    QueueDepth { high: usize, low: usize },
}

/// A serve stage: a traffic mix under an arrival process on a fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeStage {
    pub name: String,
    pub engine: ServeEngine,
    /// Threaded engine only: `"sim"` or `"pjrt"`.
    pub backend: String,
    /// Threaded pjrt backend only: artifact directory.
    pub artifacts: Option<String>,
    /// Threaded engine only: the single served model (`None` = first).
    pub model: Option<String>,
    /// Threaded engine only: closed request count.
    pub requests: usize,
    /// Virtual engine: weighted `(model, weight)` traffic mix.
    pub mix: Vec<(String, f64)>,
    /// Virtual engine: when requests arrive.
    pub arrival: Option<ArrivalProcess>,
    pub shards: usize,
    pub workers: usize,
    pub max_batch: usize,
    pub max_wait_ms: f64,
    pub queue_depth: usize,
    pub routing: String,
    pub opts: OptFlags,
    /// Threaded sim backend: wall seconds per simulated second.
    pub time_scale: f64,
    /// Virtual engine: periodic re-calibration outages.
    pub calibration: Option<CalibrationSpec>,
    /// Virtual engine: heterogeneous shard groups. Empty = a homogeneous
    /// photonic fleet of `shards` shards (the pre-fleet behavior). When
    /// non-empty, the group counts replace `shards`.
    pub fleet: Vec<FleetGroup>,
    /// Virtual engine: shard failure/recovery injection.
    pub failures: Option<FailureSpec>,
    /// Virtual engine: autoscaling of the active routing set.
    pub autoscale: Option<AutoscaleSpec>,
    /// SLO admission-control deadline in milliseconds: the async engine
    /// sheds submissions whose predicted queueing delay exceeds it, and
    /// the virtual engine mirrors the same heuristic deterministically.
    /// The threaded engine has no shed path and rejects this member.
    pub deadline_ms: Option<f64>,
    pub slo: SloSpec,
}

impl Default for ServeStage {
    fn default() -> Self {
        ServeStage {
            name: "serve".into(),
            engine: ServeEngine::Virtual,
            backend: "sim".into(),
            artifacts: None,
            model: None,
            requests: 64,
            mix: Vec::new(),
            arrival: None,
            shards: 1,
            workers: 2,
            max_batch: 8,
            max_wait_ms: 5.0,
            queue_depth: 1024,
            routing: "round-robin".into(),
            opts: OptFlags::overlapped(),
            time_scale: 1.0,
            calibration: None,
            fleet: Vec::new(),
            failures: None,
            autoscale: None,
            deadline_ms: None,
            slo: SloSpec::default(),
        }
    }
}

/// A report stage: every paper table/figure in one pass.
#[derive(Debug, Clone, PartialEq)]
pub struct ReportStage {
    pub name: String,
    pub threads: Option<usize>,
}

impl Default for ReportStage {
    fn default() -> Self {
        ReportStage { name: "report".into(), threads: None }
    }
}

/// One stage of a scenario.
#[derive(Debug, Clone, PartialEq)]
pub enum StageSpec {
    Simulate(SimStage),
    Dse(DseStage),
    Compare(CompareStage),
    Serve(ServeStage),
    Report(ReportStage),
}

impl StageSpec {
    /// The stage kind (the JSON `kind` discriminator).
    pub fn kind(&self) -> &'static str {
        match self {
            StageSpec::Simulate(_) => "simulate",
            StageSpec::Dse(_) => "dse",
            StageSpec::Compare(_) => "compare",
            StageSpec::Serve(_) => "serve",
            StageSpec::Report(_) => "report",
        }
    }

    /// The stage's display name.
    pub fn name(&self) -> &str {
        match self {
            StageSpec::Simulate(s) => &s.name,
            StageSpec::Dse(s) => &s.name,
            StageSpec::Compare(s) => &s.name,
            StageSpec::Serve(s) => &s.name,
            StageSpec::Report(s) => &s.name,
        }
    }
}

/// A declarative experiment: name, seed, and stage list.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub name: String,
    /// Root seed; stage `i` derives its streams via
    /// [`Pcg32::fork`]`(i)`, so stages are independently reproducible.
    ///
    /// JSON numbers are `f64`, so seeds round-trip exactly only up to
    /// 2^53 − 1; larger values lose low bits through `to_json`/`from_json`
    /// (documents in the wild use small seeds, and the parser reads any
    /// non-negative integer the document can express).
    pub seed: u64,
    pub stages: Vec<StageSpec>,
}

impl Scenario {
    /// A one-stage scenario (what the legacy CLI subcommands compile to).
    pub fn single(name: impl Into<String>, stage: StageSpec) -> Scenario {
        Scenario { name: name.into(), seed: 0, stages: vec![stage] }
    }
}

// ----------------------------------------------------- JSON: helpers

fn parse_err(field: impl Into<String>, reason: impl Into<String>) -> ApiError {
    ApiError::ScenarioParse { field: field.into(), reason: reason.into() }
}

fn req_member<'a>(v: &'a JsonValue, path: &str, key: &str) -> Result<&'a JsonValue, ApiError> {
    v.get(key)
        .ok_or_else(|| parse_err(format!("{path}.{key}"), "missing required member"))
}

fn str_member(v: &JsonValue, path: &str, key: &str) -> Result<String, ApiError> {
    req_member(v, path, key)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| parse_err(format!("{path}.{key}"), "expected a string"))
}

fn opt_str_member(v: &JsonValue, path: &str, key: &str) -> Result<Option<String>, ApiError> {
    match v.get(key) {
        None => Ok(None),
        Some(m) => m
            .as_str()
            .map(|s| Some(s.to_string()))
            .ok_or_else(|| parse_err(format!("{path}.{key}"), "expected a string")),
    }
}

fn num_member(v: &JsonValue, path: &str, key: &str) -> Result<f64, ApiError> {
    req_member(v, path, key)?
        .as_f64()
        .ok_or_else(|| parse_err(format!("{path}.{key}"), "expected a number"))
}

fn opt_num_member(
    v: &JsonValue,
    path: &str,
    key: &str,
    default: f64,
) -> Result<f64, ApiError> {
    match v.get(key) {
        None => Ok(default),
        Some(m) => m
            .as_f64()
            .ok_or_else(|| parse_err(format!("{path}.{key}"), "expected a number")),
    }
}

fn opt_usize_member(
    v: &JsonValue,
    path: &str,
    key: &str,
    default: usize,
) -> Result<usize, ApiError> {
    match v.get(key) {
        None => Ok(default),
        Some(m) => m
            .as_usize()
            .ok_or_else(|| parse_err(format!("{path}.{key}"), "expected a non-negative integer")),
    }
}

fn opt_bool_member(
    v: &JsonValue,
    path: &str,
    key: &str,
    default: bool,
) -> Result<bool, ApiError> {
    match v.get(key) {
        None => Ok(default),
        Some(m) => m
            .as_bool()
            .ok_or_else(|| parse_err(format!("{path}.{key}"), "expected a boolean")),
    }
}

/// Parse an `opts` member: a preset name (`"baseline"`, `"sw"`,
/// `"pipelined"`, `"gating"`, `"all"`, `"overlapped"`) or an object of
/// booleans (absent members default to the `all` preset's values).
fn parse_opts(v: &JsonValue, path: &str, default: OptFlags) -> Result<OptFlags, ApiError> {
    let Some(m) = v.get("opts") else { return Ok(default) };
    let path = format!("{path}.opts");
    match m {
        JsonValue::Str(s) => match s.to_ascii_lowercase().as_str() {
            "baseline" => Ok(OptFlags::baseline()),
            "sw" | "sw-optimized" | "sparse" => Ok(OptFlags::sw_optimized()),
            "pipelined" | "pipeline" => Ok(OptFlags::pipelined_only()),
            "gating" | "power-gating" => Ok(OptFlags::power_gating_only()),
            "all" => Ok(OptFlags::all()),
            "overlapped" | "overlap" => Ok(OptFlags::overlapped()),
            "fused" | "fuse" => Ok(OptFlags::fused()),
            other => Err(parse_err(
                path,
                format!(
                    "unknown opts preset '{other}' (expected baseline, sw, pipelined, \
                     gating, all, overlapped, or fused — or an object of booleans)"
                ),
            )),
        },
        JsonValue::Obj(_) => {
            let base = OptFlags::all();
            Ok(OptFlags {
                sparse: opt_bool_member(m, &path, "sparse", base.sparse)?,
                pipelined: opt_bool_member(m, &path, "pipelined", base.pipelined)?,
                power_gated: opt_bool_member(m, &path, "power_gated", base.power_gated)?,
                overlap: opt_bool_member(m, &path, "overlap", base.overlap)?,
                fuse: opt_bool_member(m, &path, "fuse", base.fuse)?,
            })
        }
        _ => Err(parse_err(path, "expected a preset name or an object of booleans")),
    }
}

fn opts_json(opts: OptFlags) -> JsonValue {
    obj(vec![
        ("sparse", JsonValue::Bool(opts.sparse)),
        ("pipelined", JsonValue::Bool(opts.pipelined)),
        ("power_gated", JsonValue::Bool(opts.power_gated)),
        ("overlap", JsonValue::Bool(opts.overlap)),
        ("fuse", JsonValue::Bool(opts.fuse)),
    ])
}

fn parse_slo(v: &JsonValue, path: &str) -> Result<SloSpec, ApiError> {
    let Some(m) = v.get("slo") else { return Ok(SloSpec::default()) };
    let path = format!("{path}.slo");
    let JsonValue::Obj(members) = m else {
        return Err(parse_err(path, "expected an object of SLO targets"));
    };
    let mut slo = SloSpec::default();
    for (key, val) in members {
        let num = match val.as_f64() {
            Some(n) => n,
            None => return Err(parse_err(format!("{path}.{key}"), "expected a number")),
        };
        match key.as_str() {
            "p99_ms" => slo.p99_ms = Some(num),
            "min_throughput_rps" => slo.min_throughput_rps = Some(num),
            "max_reject_frac" => slo.max_reject_frac = Some(num),
            "max_latency_ms" => slo.max_latency_ms = Some(num),
            "min_gops" => slo.min_gops = Some(num),
            "min_availability" => slo.min_availability = Some(num),
            other => {
                return Err(parse_err(
                    path,
                    format!(
                        "unknown SLO metric '{other}' (expected p99_ms, \
                         min_throughput_rps, max_reject_frac, max_latency_ms, min_gops, \
                         min_availability)"
                    ),
                ))
            }
        }
    }
    Ok(slo)
}

fn slo_json(slo: &SloSpec) -> Option<JsonValue> {
    if slo.is_empty() {
        return None;
    }
    let mut members = Vec::new();
    for (key, val) in [
        ("p99_ms", slo.p99_ms),
        ("min_throughput_rps", slo.min_throughput_rps),
        ("max_reject_frac", slo.max_reject_frac),
        ("max_latency_ms", slo.max_latency_ms),
        ("min_gops", slo.min_gops),
        ("min_availability", slo.min_availability),
    ] {
        if let Some(v) = val {
            members.push((key, JsonValue::Num(v)));
        }
    }
    Some(obj(members))
}

fn parse_calibration(v: &JsonValue, path: &str) -> Result<Option<CalibrationSpec>, ApiError> {
    let Some(m) = v.get("calibration") else { return Ok(None) };
    let path = format!("{path}.calibration");
    if !matches!(m, JsonValue::Obj(_)) {
        return Err(parse_err(path, "expected an object with interval_ms and outage_ms"));
    }
    Ok(Some(CalibrationSpec {
        interval_ms: num_member(m, &path, "interval_ms")?,
        outage_ms: num_member(m, &path, "outage_ms")?,
    }))
}

fn calibration_json(c: &CalibrationSpec) -> JsonValue {
    obj(vec![
        ("interval_ms", JsonValue::Num(c.interval_ms)),
        ("outage_ms", JsonValue::Num(c.outage_ms)),
    ])
}

fn parse_fleet(v: &JsonValue, path: &str) -> Result<Vec<FleetGroup>, ApiError> {
    let Some(m) = v.get("fleet") else { return Ok(Vec::new()) };
    let path = format!("{path}.fleet");
    let Some(arr) = m.as_array() else {
        return Err(parse_err(path, "expected an array of shard groups"));
    };
    let mut groups = Vec::with_capacity(arr.len());
    for (i, g) in arr.iter().enumerate() {
        let gpath = format!("{path}[{i}]");
        if !matches!(g, JsonValue::Obj(_)) {
            return Err(parse_err(gpath, "expected an object with a 'platform' member"));
        }
        groups.push(FleetGroup {
            platform: str_member(g, &gpath, "platform")?,
            count: opt_usize_member(g, &gpath, "count", 1)?,
            workers: match g.get("workers") {
                None => None,
                Some(_) => Some(opt_usize_member(g, &gpath, "workers", 0)?),
            },
            idle_w: opt_num_member(g, &gpath, "idle_w", 0.0)?,
            cost_per_hour: opt_num_member(g, &gpath, "cost_per_hour", 0.0)?,
        });
    }
    Ok(groups)
}

fn fleet_json(groups: &[FleetGroup]) -> JsonValue {
    JsonValue::Arr(
        groups
            .iter()
            .map(|g| {
                let mut members = vec![
                    ("platform", JsonValue::Str(g.platform.clone())),
                    ("count", JsonValue::Num(g.count as f64)),
                ];
                if let Some(w) = g.workers {
                    members.push(("workers", JsonValue::Num(w as f64)));
                }
                members.push(("idle_w", JsonValue::Num(g.idle_w)));
                members.push(("cost_per_hour", JsonValue::Num(g.cost_per_hour)));
                obj(members)
            })
            .collect(),
    )
}

fn parse_failures(v: &JsonValue, path: &str) -> Result<Option<FailureSpec>, ApiError> {
    let Some(m) = v.get("failures") else { return Ok(None) };
    let path = format!("{path}.failures");
    if !matches!(m, JsonValue::Obj(_)) {
        return Err(parse_err(path, "expected an object with mtbf_ms and mttr_ms"));
    }
    Ok(Some(FailureSpec {
        mtbf_ms: num_member(m, &path, "mtbf_ms")?,
        mttr_ms: num_member(m, &path, "mttr_ms")?,
    }))
}

fn failures_json(f: &FailureSpec) -> JsonValue {
    obj(vec![
        ("mtbf_ms", JsonValue::Num(f.mtbf_ms)),
        ("mttr_ms", JsonValue::Num(f.mttr_ms)),
    ])
}

fn parse_autoscale(v: &JsonValue, path: &str) -> Result<Option<AutoscaleSpec>, ApiError> {
    let Some(m) = v.get("autoscale") else { return Ok(None) };
    let path = format!("{path}.autoscale");
    if !matches!(m, JsonValue::Obj(_)) {
        return Err(parse_err(path, "expected an object with a 'policy' member"));
    }
    let policy = match str_member(m, &path, "policy")?.as_str() {
        "target-utilization" => AutoscalePolicyKind::TargetUtilization {
            target: num_member(m, &path, "target")?,
        },
        "queue-depth" => AutoscalePolicyKind::QueueDepth {
            high: req_member(m, &path, "high")?
                .as_usize()
                .ok_or_else(|| parse_err(format!("{path}.high"), "expected an integer"))?,
            low: req_member(m, &path, "low")?
                .as_usize()
                .ok_or_else(|| parse_err(format!("{path}.low"), "expected an integer"))?,
        },
        other => {
            return Err(parse_err(
                format!("{path}.policy"),
                format!(
                    "unknown autoscale policy '{other}' (expected target-utilization \
                     or queue-depth)"
                ),
            ))
        }
    };
    Ok(Some(AutoscaleSpec {
        policy,
        min_shards: opt_usize_member(m, &path, "min_shards", 1)?,
        max_shards: req_member(m, &path, "max_shards")?
            .as_usize()
            .ok_or_else(|| parse_err(format!("{path}.max_shards"), "expected an integer"))?,
        initial: match m.get("initial") {
            None => None,
            Some(_) => Some(opt_usize_member(m, &path, "initial", 0)?),
        },
        interval_ms: num_member(m, &path, "interval_ms")?,
    }))
}

fn autoscale_json(a: &AutoscaleSpec) -> JsonValue {
    let mut members = Vec::new();
    match a.policy {
        AutoscalePolicyKind::TargetUtilization { target } => {
            members.push(("policy", JsonValue::Str("target-utilization".into())));
            members.push(("target", JsonValue::Num(target)));
        }
        AutoscalePolicyKind::QueueDepth { high, low } => {
            members.push(("policy", JsonValue::Str("queue-depth".into())));
            members.push(("high", JsonValue::Num(high as f64)));
            members.push(("low", JsonValue::Num(low as f64)));
        }
    }
    members.push(("min_shards", JsonValue::Num(a.min_shards as f64)));
    members.push(("max_shards", JsonValue::Num(a.max_shards as f64)));
    if let Some(i) = a.initial {
        members.push(("initial", JsonValue::Num(i as f64)));
    }
    members.push(("interval_ms", JsonValue::Num(a.interval_ms)));
    obj(members)
}

fn parse_arrival(v: &JsonValue, path: &str) -> Result<Option<ArrivalProcess>, ApiError> {
    let Some(m) = v.get("arrival") else { return Ok(None) };
    let path = format!("{path}.arrival");
    if !matches!(m, JsonValue::Obj(_)) {
        return Err(parse_err(path, "expected an object with a 'process' member"));
    }
    let process = str_member(m, &path, "process")?;
    let arrival = match process.as_str() {
        "closed-loop" => ArrivalProcess::ClosedLoop {
            clients: req_member(m, &path, "clients")?
                .as_usize()
                .ok_or_else(|| parse_err(format!("{path}.clients"), "expected an integer"))?,
            per_client: req_member(m, &path, "per_client")?
                .as_usize()
                .ok_or_else(|| parse_err(format!("{path}.per_client"), "expected an integer"))?,
        },
        "poisson" => ArrivalProcess::Poisson {
            rate_hz: num_member(m, &path, "rate_hz")?,
            duration_s: num_member(m, &path, "duration_s")?,
        },
        "bursty" => ArrivalProcess::Bursty {
            rate_hz: num_member(m, &path, "rate_hz")?,
            on_s: num_member(m, &path, "on_s")?,
            off_s: opt_num_member(m, &path, "off_s", 0.0)?,
            duration_s: num_member(m, &path, "duration_s")?,
        },
        "diurnal" => ArrivalProcess::Diurnal {
            base_hz: num_member(m, &path, "base_hz")?,
            peak_hz: num_member(m, &path, "peak_hz")?,
            period_s: num_member(m, &path, "period_s")?,
            duration_s: num_member(m, &path, "duration_s")?,
        },
        "flash-crowd" => ArrivalProcess::FlashCrowd {
            base_hz: num_member(m, &path, "base_hz")?,
            spike_hz: num_member(m, &path, "spike_hz")?,
            spike_at_s: num_member(m, &path, "spike_at_s")?,
            spike_s: num_member(m, &path, "spike_s")?,
            duration_s: num_member(m, &path, "duration_s")?,
        },
        "trace" => {
            let arr = req_member(m, &path, "arrivals_s")?
                .as_array()
                .ok_or_else(|| {
                    parse_err(format!("{path}.arrivals_s"), "expected an array of numbers")
                })?;
            let mut arrivals_s = Vec::with_capacity(arr.len());
            for (i, t) in arr.iter().enumerate() {
                arrivals_s.push(t.as_f64().ok_or_else(|| {
                    parse_err(format!("{path}.arrivals_s[{i}]"), "expected a number")
                })?);
            }
            ArrivalProcess::Trace { arrivals_s }
        }
        other => {
            return Err(parse_err(
                format!("{path}.process"),
                format!(
                    "unknown arrival process '{other}' (expected closed-loop, poisson, \
                     bursty, diurnal, flash-crowd, or trace)"
                ),
            ))
        }
    };
    Ok(Some(arrival))
}

fn arrival_json(a: &ArrivalProcess) -> JsonValue {
    match a {
        ArrivalProcess::ClosedLoop { clients, per_client } => obj(vec![
            ("process", JsonValue::Str("closed-loop".into())),
            ("clients", JsonValue::Num(*clients as f64)),
            ("per_client", JsonValue::Num(*per_client as f64)),
        ]),
        ArrivalProcess::Poisson { rate_hz, duration_s } => obj(vec![
            ("process", JsonValue::Str("poisson".into())),
            ("rate_hz", JsonValue::Num(*rate_hz)),
            ("duration_s", JsonValue::Num(*duration_s)),
        ]),
        ArrivalProcess::Bursty { rate_hz, on_s, off_s, duration_s } => obj(vec![
            ("process", JsonValue::Str("bursty".into())),
            ("rate_hz", JsonValue::Num(*rate_hz)),
            ("on_s", JsonValue::Num(*on_s)),
            ("off_s", JsonValue::Num(*off_s)),
            ("duration_s", JsonValue::Num(*duration_s)),
        ]),
        ArrivalProcess::Diurnal { base_hz, peak_hz, period_s, duration_s } => obj(vec![
            ("process", JsonValue::Str("diurnal".into())),
            ("base_hz", JsonValue::Num(*base_hz)),
            ("peak_hz", JsonValue::Num(*peak_hz)),
            ("period_s", JsonValue::Num(*period_s)),
            ("duration_s", JsonValue::Num(*duration_s)),
        ]),
        ArrivalProcess::FlashCrowd { base_hz, spike_hz, spike_at_s, spike_s, duration_s } => {
            obj(vec![
                ("process", JsonValue::Str("flash-crowd".into())),
                ("base_hz", JsonValue::Num(*base_hz)),
                ("spike_hz", JsonValue::Num(*spike_hz)),
                ("spike_at_s", JsonValue::Num(*spike_at_s)),
                ("spike_s", JsonValue::Num(*spike_s)),
                ("duration_s", JsonValue::Num(*duration_s)),
            ])
        }
        ArrivalProcess::Trace { arrivals_s } => obj(vec![
            ("process", JsonValue::Str("trace".into())),
            (
                "arrivals_s",
                JsonValue::Arr(arrivals_s.iter().map(|&t| JsonValue::Num(t)).collect()),
            ),
        ]),
    }
}

// ------------------------------------------------- JSON: parse stages

impl Scenario {
    /// Parse a scenario document. Shape problems are per-field
    /// [`ApiError::ScenarioParse`]; semantic validation happens in
    /// [`Session::plan`].
    pub fn from_json(text: &str) -> Result<Scenario, ApiError> {
        let doc = crate::util::json::parse(text).map_err(|e| parse_err("$", e.to_string()))?;
        Scenario::from_value(&doc)
    }

    /// Parse an already-parsed JSON document.
    pub fn from_value(doc: &JsonValue) -> Result<Scenario, ApiError> {
        if !matches!(doc, JsonValue::Obj(_)) {
            return Err(parse_err("$", "expected a JSON object"));
        }
        let name = str_member(doc, "$", "name")?;
        let seed = opt_usize_member(doc, "$", "seed", 0)? as u64;
        let stages_val = req_member(doc, "$", "stages")?
            .as_array()
            .ok_or_else(|| parse_err("$.stages", "expected an array of stage objects"))?;
        if stages_val.is_empty() {
            return Err(parse_err("$.stages", "a scenario needs at least one stage"));
        }
        let mut stages = Vec::with_capacity(stages_val.len());
        for (i, sv) in stages_val.iter().enumerate() {
            stages.push(parse_stage(sv, i)?);
        }
        Ok(Scenario { name, seed, stages })
    }

    /// Canonical JSON rendering — every field materialized, member order
    /// fixed, so `from_json(to_json(s)) == s` (the round-trip fixpoint).
    pub fn to_json(&self) -> String {
        self.json().render()
    }

    /// Structured form of [`Scenario::to_json`].
    pub fn json(&self) -> JsonValue {
        obj(vec![
            ("name", JsonValue::Str(self.name.clone())),
            ("seed", JsonValue::Num(self.seed as f64)),
            (
                "stages",
                JsonValue::Arr(self.stages.iter().map(stage_json).collect()),
            ),
        ])
    }
}

fn parse_stage(v: &JsonValue, index: usize) -> Result<StageSpec, ApiError> {
    let path = format!("stages[{index}]");
    if !matches!(v, JsonValue::Obj(_)) {
        return Err(parse_err(path, "expected a stage object"));
    }
    let kind = str_member(v, &path, "kind")?;
    let name = opt_str_member(v, &path, "name")?.unwrap_or_else(|| format!("{kind}-{index}"));
    match kind.as_str() {
        "simulate" => {
            let models = match v.get("models") {
                None => Vec::new(),
                Some(arr) => {
                    let items = arr.as_array().ok_or_else(|| {
                        parse_err(format!("{path}.models"), "expected an array of model names")
                    })?;
                    let mut out = Vec::with_capacity(items.len());
                    for (i, it) in items.iter().enumerate() {
                        out.push(
                            it.as_str()
                                .map(str::to_string)
                                .ok_or_else(|| {
                                    parse_err(
                                        format!("{path}.models[{i}]"),
                                        "expected a model name string",
                                    )
                                })?,
                        );
                    }
                    out
                }
            };
            Ok(StageSpec::Simulate(SimStage {
                name,
                models,
                batch: opt_usize_member(v, &path, "batch", 1)?,
                opts: parse_opts(v, &path, OptFlags::all())?,
                config: opt_str_member(v, &path, "config")?,
                strict_power: opt_bool_member(v, &path, "strict_power", false)?,
                slo: parse_slo(v, &path)?,
            }))
        }
        "dse" => Ok(StageSpec::Dse(DseStage {
            name,
            grid: opt_str_member(v, &path, "grid")?.unwrap_or_else(|| "paper".into()),
            threads: match v.get("threads") {
                None => None,
                Some(_) => Some(opt_usize_member(v, &path, "threads", 0)?),
            },
            opts: parse_opts(v, &path, OptFlags::overlapped())?,
            slo: parse_slo(v, &path)?,
        })),
        "compare" => Ok(StageSpec::Compare(CompareStage {
            name,
            opts: parse_opts(v, &path, OptFlags::all())?,
        })),
        "serve" => {
            let engine = match opt_str_member(v, &path, "engine")? {
                None => ServeEngine::Virtual,
                Some(s) => s
                    .parse()
                    .map_err(|reason| parse_err(format!("{path}.engine"), reason))?,
            };
            let mix = match v.get("mix") {
                None => Vec::new(),
                Some(arr) => {
                    let items = arr.as_array().ok_or_else(|| {
                        parse_err(format!("{path}.mix"), "expected an array of mix entries")
                    })?;
                    let mut out = Vec::with_capacity(items.len());
                    for (i, it) in items.iter().enumerate() {
                        let epath = format!("{path}.mix[{i}]");
                        if !matches!(it, JsonValue::Obj(_)) {
                            return Err(parse_err(epath, "expected a {model, weight} object"));
                        }
                        let model = str_member(it, &epath, "model")?;
                        let weight = opt_num_member(it, &epath, "weight", 1.0)?;
                        out.push((model, weight));
                    }
                    out
                }
            };
            Ok(StageSpec::Serve(ServeStage {
                name,
                engine,
                backend: opt_str_member(v, &path, "backend")?.unwrap_or_else(|| "sim".into()),
                artifacts: opt_str_member(v, &path, "artifacts")?,
                model: opt_str_member(v, &path, "model")?,
                requests: opt_usize_member(v, &path, "requests", 64)?,
                mix,
                arrival: parse_arrival(v, &path)?,
                shards: opt_usize_member(v, &path, "shards", 1)?,
                workers: opt_usize_member(v, &path, "workers", 2)?,
                max_batch: opt_usize_member(v, &path, "max_batch", 8)?,
                max_wait_ms: opt_num_member(v, &path, "max_wait_ms", 5.0)?,
                queue_depth: opt_usize_member(v, &path, "queue_depth", 1024)?,
                routing: opt_str_member(v, &path, "routing")?
                    .unwrap_or_else(|| "round-robin".into()),
                opts: parse_opts(v, &path, OptFlags::overlapped())?,
                time_scale: opt_num_member(v, &path, "time_scale", 1.0)?,
                calibration: parse_calibration(v, &path)?,
                fleet: parse_fleet(v, &path)?,
                failures: parse_failures(v, &path)?,
                autoscale: parse_autoscale(v, &path)?,
                deadline_ms: match v.get("deadline_ms") {
                    None => None,
                    Some(_) => {
                        let ms = opt_num_member(v, &path, "deadline_ms", 0.0)?;
                        if !ms.is_finite() || ms <= 0.0 {
                            return Err(parse_err(
                                format!("{path}.deadline_ms"),
                                format!("SLO deadline must be finite and > 0 (got {ms})"),
                            ));
                        }
                        Some(ms)
                    }
                },
                slo: parse_slo(v, &path)?,
            }))
        }
        "report" => Ok(StageSpec::Report(ReportStage {
            name,
            threads: match v.get("threads") {
                None => None,
                Some(_) => Some(opt_usize_member(v, &path, "threads", 0)?),
            },
        })),
        other => Err(parse_err(
            format!("{path}.kind"),
            format!(
                "unknown stage kind '{other}' (expected simulate, dse, compare, serve, \
                 or report)"
            ),
        )),
    }
}

fn stage_json(stage: &StageSpec) -> JsonValue {
    match stage {
        StageSpec::Simulate(s) => {
            let mut members = vec![
                ("kind", JsonValue::Str("simulate".into())),
                ("name", JsonValue::Str(s.name.clone())),
                (
                    "models",
                    JsonValue::Arr(
                        s.models.iter().map(|m| JsonValue::Str(m.clone())).collect(),
                    ),
                ),
                ("batch", JsonValue::Num(s.batch as f64)),
                ("opts", opts_json(s.opts)),
            ];
            if let Some(cfg) = &s.config {
                members.push(("config", JsonValue::Str(cfg.clone())));
            }
            members.push(("strict_power", JsonValue::Bool(s.strict_power)));
            if let Some(slo) = slo_json(&s.slo) {
                members.push(("slo", slo));
            }
            obj(members)
        }
        StageSpec::Dse(s) => {
            let mut members = vec![
                ("kind", JsonValue::Str("dse".into())),
                ("name", JsonValue::Str(s.name.clone())),
                ("grid", JsonValue::Str(s.grid.clone())),
            ];
            if let Some(t) = s.threads {
                members.push(("threads", JsonValue::Num(t as f64)));
            }
            members.push(("opts", opts_json(s.opts)));
            if let Some(slo) = slo_json(&s.slo) {
                members.push(("slo", slo));
            }
            obj(members)
        }
        StageSpec::Compare(s) => obj(vec![
            ("kind", JsonValue::Str("compare".into())),
            ("name", JsonValue::Str(s.name.clone())),
            ("opts", opts_json(s.opts)),
        ]),
        StageSpec::Serve(s) => {
            let mut members = vec![
                ("kind", JsonValue::Str("serve".into())),
                ("name", JsonValue::Str(s.name.clone())),
                ("engine", JsonValue::Str(s.engine.name().into())),
                ("backend", JsonValue::Str(s.backend.clone())),
            ];
            if let Some(a) = &s.artifacts {
                members.push(("artifacts", JsonValue::Str(a.clone())));
            }
            if let Some(m) = &s.model {
                members.push(("model", JsonValue::Str(m.clone())));
            }
            members.push(("requests", JsonValue::Num(s.requests as f64)));
            members.push((
                "mix",
                JsonValue::Arr(
                    s.mix
                        .iter()
                        .map(|(m, w)| {
                            obj(vec![
                                ("model", JsonValue::Str(m.clone())),
                                ("weight", JsonValue::Num(*w)),
                            ])
                        })
                        .collect(),
                ),
            ));
            if let Some(a) = &s.arrival {
                members.push(("arrival", arrival_json(a)));
            }
            members.push(("shards", JsonValue::Num(s.shards as f64)));
            members.push(("workers", JsonValue::Num(s.workers as f64)));
            members.push(("max_batch", JsonValue::Num(s.max_batch as f64)));
            members.push(("max_wait_ms", JsonValue::Num(s.max_wait_ms)));
            members.push(("queue_depth", JsonValue::Num(s.queue_depth as f64)));
            members.push(("routing", JsonValue::Str(s.routing.clone())));
            members.push(("opts", opts_json(s.opts)));
            members.push(("time_scale", JsonValue::Num(s.time_scale)));
            if let Some(c) = &s.calibration {
                members.push(("calibration", calibration_json(c)));
            }
            if !s.fleet.is_empty() {
                members.push(("fleet", fleet_json(&s.fleet)));
            }
            if let Some(f) = &s.failures {
                members.push(("failures", failures_json(f)));
            }
            if let Some(a) = &s.autoscale {
                members.push(("autoscale", autoscale_json(a)));
            }
            if let Some(ms) = s.deadline_ms {
                members.push(("deadline_ms", JsonValue::Num(ms)));
            }
            if let Some(slo) = slo_json(&s.slo) {
                members.push(("slo", slo));
            }
            obj(members)
        }
        StageSpec::Report(s) => {
            let mut members = vec![
                ("kind", JsonValue::Str("report".into())),
                ("name", JsonValue::Str(s.name.clone())),
            ];
            if let Some(t) = s.threads {
                members.push(("threads", JsonValue::Num(t as f64)));
            }
            obj(members)
        }
    }
}

// --------------------------------------------------------------- plan

/// How a planned fleet class resolves its batch service times: the
/// session's photonic simulator or a calibrated baseline platform.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClassBinding {
    /// The session's photonic cost model (shared mapping cache).
    Photonic,
    /// Index into [`crate::baselines::all_platforms`].
    Platform(usize),
}

/// An executable stage, compiled and validated by [`Session::plan`].
#[derive(Debug, Clone)]
pub enum PlannedStage {
    Simulate { name: String, req: SimRequest, slo: SloSpec },
    Dse { name: String, req: SweepRequest, slo: SloSpec },
    Compare { name: String, opts: OptFlags },
    /// Deterministic virtual-time fleet serving over the session cost
    /// model (and, for heterogeneous fleets, the baseline platforms).
    ServeVirtual {
        name: String,
        fleet: FleetConfig,
        /// Service-model binding of each fleet class (parallel to
        /// `fleet.classes`).
        bindings: Vec<ClassBinding>,
        mix: TrafficMix,
        arrival: ArrivalProcess,
        opts: OptFlags,
        slo: SloSpec,
    },
    /// The real threaded coordinator via [`Session::serve`].
    ServeThreaded { name: String, req: ServeRequest, slo: SloSpec },
    Report { name: String, threads: usize },
}

/// A validated, executable scenario.
#[derive(Debug, Clone)]
pub struct Plan {
    pub scenario: String,
    pub seed: u64,
    pub stages: Vec<PlannedStage>,
}

/// SLO members each stage kind may set.
fn check_slo_applies(slo: &SloSpec, allowed: &[&str], path: &str) -> Result<(), ApiError> {
    for (name, present) in [
        ("p99_ms", slo.p99_ms.is_some()),
        ("min_throughput_rps", slo.min_throughput_rps.is_some()),
        ("max_reject_frac", slo.max_reject_frac.is_some()),
        ("max_latency_ms", slo.max_latency_ms.is_some()),
        ("min_gops", slo.min_gops.is_some()),
        ("min_availability", slo.min_availability.is_some()),
    ] {
        if present && !allowed.contains(&name) {
            return Err(parse_err(
                format!("{path}.slo.{name}"),
                format!("not applicable to this stage kind (allowed: {})", allowed.join(", ")),
            ));
        }
    }
    for (name, value, allow_zero, hi) in [
        ("p99_ms", slo.p99_ms, false, f64::INFINITY),
        ("min_throughput_rps", slo.min_throughput_rps, false, f64::INFINITY),
        // a zero rejection budget is a legitimate (strict) target
        ("max_reject_frac", slo.max_reject_frac, true, 1.0),
        ("max_latency_ms", slo.max_latency_ms, false, f64::INFINITY),
        ("min_gops", slo.min_gops, false, f64::INFINITY),
        ("min_availability", slo.min_availability, false, 1.0),
    ] {
        if let Some(v) = value {
            let positive_ok = if allow_zero { v >= 0.0 } else { v > 0.0 };
            if !v.is_finite() || !positive_ok || v > hi {
                return Err(parse_err(
                    format!("{path}.slo.{name}"),
                    format!("target must be a finite value in a sane range (got {v})"),
                ));
            }
        }
    }
    Ok(())
}

/// Validate an arrival process with exact per-field error attribution.
///
/// This deliberately re-states the predicates of
/// [`ArrivalProcess::validate`] (whose `ArrivalError` cannot name which
/// JSON member of a bursty/trace process failed): keep the two in sync
/// when the workload-level rules change.
fn check_arrival(a: &ArrivalProcess, path: &str) -> Result<(), ApiError> {
    let apath = format!("{path}.arrival");
    match a {
        ArrivalProcess::ClosedLoop { clients, per_client } => {
            if *clients == 0 {
                return Err(parse_err(format!("{apath}.clients"), "must be >= 1"));
            }
            if *per_client == 0 {
                return Err(parse_err(format!("{apath}.per_client"), "must be >= 1"));
            }
        }
        ArrivalProcess::Poisson { rate_hz, duration_s } => {
            if !rate_hz.is_finite() || *rate_hz <= 0.0 {
                return Err(ApiError::InvalidRate {
                    field: format!("{apath}.rate_hz"),
                    rate: *rate_hz,
                });
            }
            if !duration_s.is_finite() || *duration_s <= 0.0 {
                return Err(ApiError::InvalidDuration {
                    field: format!("{apath}.duration_s"),
                    seconds: *duration_s,
                });
            }
        }
        ArrivalProcess::Bursty { rate_hz, on_s, off_s, duration_s } => {
            if !rate_hz.is_finite() || *rate_hz <= 0.0 {
                return Err(ApiError::InvalidRate {
                    field: format!("{apath}.rate_hz"),
                    rate: *rate_hz,
                });
            }
            if !on_s.is_finite() || *on_s <= 0.0 {
                return Err(ApiError::InvalidDuration {
                    field: format!("{apath}.on_s"),
                    seconds: *on_s,
                });
            }
            if !off_s.is_finite() || *off_s < 0.0 {
                return Err(ApiError::InvalidDuration {
                    field: format!("{apath}.off_s"),
                    seconds: *off_s,
                });
            }
            if !duration_s.is_finite() || *duration_s <= 0.0 {
                return Err(ApiError::InvalidDuration {
                    field: format!("{apath}.duration_s"),
                    seconds: *duration_s,
                });
            }
        }
        ArrivalProcess::Diurnal { base_hz, peak_hz, period_s, duration_s } => {
            if !base_hz.is_finite() || *base_hz <= 0.0 {
                return Err(ApiError::InvalidRate {
                    field: format!("{apath}.base_hz"),
                    rate: *base_hz,
                });
            }
            // the thinning envelope needs peak >= base
            if !peak_hz.is_finite() || *peak_hz < *base_hz {
                return Err(ApiError::InvalidRate {
                    field: format!("{apath}.peak_hz"),
                    rate: *peak_hz,
                });
            }
            if !period_s.is_finite() || *period_s <= 0.0 {
                return Err(ApiError::InvalidDuration {
                    field: format!("{apath}.period_s"),
                    seconds: *period_s,
                });
            }
            if !duration_s.is_finite() || *duration_s <= 0.0 {
                return Err(ApiError::InvalidDuration {
                    field: format!("{apath}.duration_s"),
                    seconds: *duration_s,
                });
            }
        }
        ArrivalProcess::FlashCrowd { base_hz, spike_hz, spike_at_s, spike_s, duration_s } => {
            for (name, r) in [("base_hz", base_hz), ("spike_hz", spike_hz)] {
                if !r.is_finite() || *r <= 0.0 {
                    return Err(ApiError::InvalidRate {
                        field: format!("{apath}.{name}"),
                        rate: *r,
                    });
                }
            }
            if !spike_at_s.is_finite() || *spike_at_s < 0.0 {
                return Err(parse_err(
                    format!("{apath}.spike_at_s"),
                    format!("spike offset must be finite and >= 0 (got {spike_at_s})"),
                ));
            }
            for (name, d) in [("spike_s", spike_s), ("duration_s", duration_s)] {
                if !d.is_finite() || *d <= 0.0 {
                    return Err(ApiError::InvalidDuration {
                        field: format!("{apath}.{name}"),
                        seconds: *d,
                    });
                }
            }
        }
        ArrivalProcess::Trace { arrivals_s } => {
            if arrivals_s.is_empty() {
                return Err(parse_err(
                    format!("{apath}.arrivals_s"),
                    "must contain at least one arrival",
                ));
            }
            let mut prev = 0.0f64;
            for (i, &t) in arrivals_s.iter().enumerate() {
                if !t.is_finite() || t < 0.0 || t < prev {
                    return Err(parse_err(
                        format!("{apath}.arrivals_s[{i}]"),
                        format!("offsets must be finite, >= 0, and non-decreasing (got {t})"),
                    ));
                }
                prev = t;
            }
        }
    }
    Ok(())
}

impl Session {
    /// Validate a [`Scenario`] against this session and compile it into an
    /// executable [`Plan`]. All semantic failures are typed: unknown
    /// models, non-positive mix weights, malformed rates/durations,
    /// degenerate fleet shapes, inapplicable SLO targets.
    pub fn plan(&self, scenario: &Scenario) -> Result<Plan, ApiError> {
        let mut stages = Vec::with_capacity(scenario.stages.len());
        for (i, stage) in scenario.stages.iter().enumerate() {
            let path = format!("stages[{i}]");
            stages.push(self.plan_stage(stage, &path)?);
        }
        Ok(Plan {
            scenario: scenario.name.clone(),
            seed: scenario.seed,
            stages,
        })
    }

    fn plan_stage(&self, stage: &StageSpec, path: &str) -> Result<PlannedStage, ApiError> {
        match stage {
            StageSpec::Simulate(s) => {
                check_slo_applies(&s.slo, &["max_latency_ms", "min_gops"], path)?;
                // resolve names against the registry now (canonical casing)
                // and verify each referenced model's dataflow IR — an empty
                // list means every registered model runs, so check them all
                let mut resolved = Vec::with_capacity(s.models.len());
                for name in &s.models {
                    let model = self.model(name)?;
                    self.verify_model_ir(model)?;
                    resolved.push(model.name.clone());
                }
                if s.models.is_empty() {
                    for name in self.model_names() {
                        self.verify_model_ir(self.model(&name)?)?;
                    }
                }
                let mut builder = SimRequest::builder().batch(s.batch).opts(s.opts);
                builder = match resolved.len() {
                    0 => builder.all_models(),
                    1 => builder.model(resolved.remove(0)),
                    _ => builder.models(resolved),
                };
                if let Some(cfg) = &s.config {
                    let parsed: ArchConfig = cfg.parse().map_err(ApiError::from)?;
                    builder = builder.config(parsed);
                }
                builder = builder.strict_power(s.strict_power);
                Ok(PlannedStage::Simulate {
                    name: s.name.clone(),
                    req: builder.build()?,
                    slo: s.slo.clone(),
                })
            }
            StageSpec::Dse(s) => {
                check_slo_applies(&s.slo, &["min_gops"], path)?;
                let grid = match s.grid.as_str() {
                    "paper" => Grid::paper(),
                    "smoke" => Grid::smoke(),
                    other => {
                        return Err(parse_err(
                            format!("{path}.grid"),
                            format!("expected 'paper' or 'smoke', got '{other}'"),
                        ))
                    }
                };
                let threads = s.threads.unwrap_or_else(super::request::default_threads);
                let req = SweepRequest::builder()
                    .grid(grid)
                    .threads(threads)
                    .opts(s.opts)
                    .build()?;
                Ok(PlannedStage::Dse { name: s.name.clone(), req, slo: s.slo.clone() })
            }
            StageSpec::Compare(s) => Ok(PlannedStage::Compare {
                name: s.name.clone(),
                opts: s.opts,
            }),
            StageSpec::Serve(s) => self.plan_serve(s, path),
            StageSpec::Report(s) => {
                if s.threads == Some(0) {
                    return Err(ApiError::InvalidThreads(0));
                }
                Ok(PlannedStage::Report {
                    name: s.name.clone(),
                    threads: s.threads.unwrap_or_else(super::request::default_threads),
                })
            }
        }
    }

    fn plan_serve(&self, s: &ServeStage, path: &str) -> Result<PlannedStage, ApiError> {
        check_slo_applies(
            &s.slo,
            &["p99_ms", "min_throughput_rps", "max_reject_frac", "min_availability"],
            path,
        )?;
        if !s.max_wait_ms.is_finite() || s.max_wait_ms < 0.0 {
            return Err(parse_err(
                format!("{path}.max_wait_ms"),
                format!("must be finite and >= 0 (got {})", s.max_wait_ms),
            ));
        }
        match s.engine {
            ServeEngine::Virtual => {
                if s.mix.is_empty() {
                    return Err(parse_err(
                        format!("{path}.mix"),
                        "a virtual serve stage needs at least one mix entry",
                    ));
                }
                let mut resolved = Vec::with_capacity(s.mix.len());
                for (model, weight) in &s.mix {
                    let m = self.model(model)?;
                    self.verify_model_ir(m)?;
                    resolved.push((m.name.clone(), *weight));
                }
                // weight validation lives in TrafficMix::new (one rule
                // set); its typed MixError maps onto the per-field ApiError
                let mix = TrafficMix::new(resolved).map_err(|e| match e {
                    MixError::BadWeight { index, weight, .. } => ApiError::InvalidMixWeight {
                        field: format!("{path}.mix[{index}].weight"),
                        // report the name the document used, not the
                        // canonical registry casing
                        model: s.mix[index].0.clone(),
                        weight,
                    },
                    MixError::Empty => parse_err(format!("{path}.mix"), e.to_string()),
                })?;
                let arrival = s.arrival.clone().ok_or_else(|| {
                    parse_err(
                        format!("{path}.arrival"),
                        "a virtual serve stage needs an arrival process",
                    )
                })?;
                check_arrival(&arrival, path)?;
                if s.shards == 0 {
                    return Err(ApiError::InvalidShards(0));
                }
                if s.workers == 0 {
                    return Err(ApiError::InvalidWorkers(0));
                }
                if s.max_batch == 0 {
                    return Err(ApiError::InvalidBatch(0));
                }
                if s.queue_depth == 0 {
                    return Err(parse_err(format!("{path}.queue_depth"), "must be >= 1"));
                }
                let routing: RoutingPolicy = s
                    .routing
                    .parse()
                    .map_err(|reason| parse_err(format!("{path}.routing"), reason))?;
                let calibration = match &s.calibration {
                    None => None,
                    Some(c) => {
                        if !c.interval_ms.is_finite() || c.interval_ms <= 0.0 {
                            return Err(ApiError::InvalidDuration {
                                field: format!("{path}.calibration.interval_ms"),
                                seconds: c.interval_ms * 1e-3,
                            });
                        }
                        if !c.outage_ms.is_finite() || c.outage_ms < 0.0 {
                            return Err(ApiError::InvalidDuration {
                                field: format!("{path}.calibration.outage_ms"),
                                seconds: c.outage_ms * 1e-3,
                            });
                        }
                        Some(CalibrationConfig {
                            interval_s: c.interval_ms * 1e-3,
                            outage_s: c.outage_ms * 1e-3,
                        })
                    }
                };
                // fleet groups expand into shard classes; no groups means
                // a uniform photonic fleet of the stage-level shape
                let mut classes = Vec::new();
                let mut bindings = Vec::new();
                let mut shard_class = Vec::new();
                if s.fleet.is_empty() {
                    classes.push(ShardClass {
                        name: "photonic".to_string(),
                        workers: s.workers,
                        idle_w: 0.0,
                        cost_per_hour: 0.0,
                    });
                    bindings.push(ClassBinding::Photonic);
                    shard_class = vec![0; s.shards];
                } else {
                    for (i, g) in s.fleet.iter().enumerate() {
                        let gpath = format!("{path}.fleet[{i}]");
                        if g.count == 0 {
                            return Err(parse_err(format!("{gpath}.count"), "must be >= 1"));
                        }
                        if g.workers == Some(0) {
                            return Err(ApiError::InvalidWorkers(0));
                        }
                        if !g.idle_w.is_finite() || g.idle_w < 0.0 {
                            return Err(parse_err(
                                format!("{gpath}.idle_w"),
                                format!("must be finite and >= 0 (got {})", g.idle_w),
                            ));
                        }
                        if !g.cost_per_hour.is_finite() || g.cost_per_hour < 0.0 {
                            return Err(parse_err(
                                format!("{gpath}.cost_per_hour"),
                                format!("must be finite and >= 0 (got {})", g.cost_per_hour),
                            ));
                        }
                        let (name, binding) = if g.platform.eq_ignore_ascii_case("photonic") {
                            ("photonic".to_string(), ClassBinding::Photonic)
                        } else {
                            match platform_named(&g.platform) {
                                Some(idx) => (
                                    all_platforms()[idx].name.to_string(),
                                    ClassBinding::Platform(idx),
                                ),
                                None => {
                                    return Err(ApiError::UnknownPlatform {
                                        field: format!("{gpath}.platform"),
                                        name: g.platform.clone(),
                                    })
                                }
                            }
                        };
                        classes.push(ShardClass {
                            name,
                            workers: g.workers.unwrap_or(s.workers),
                            idle_w: g.idle_w,
                            cost_per_hour: g.cost_per_hour,
                        });
                        bindings.push(binding);
                        shard_class
                            .extend(std::iter::repeat(classes.len() - 1).take(g.count));
                    }
                }
                let total_shards = shard_class.len();
                let failures = match &s.failures {
                    None => None,
                    Some(fsp) => {
                        if !fsp.mtbf_ms.is_finite() || fsp.mtbf_ms <= 0.0 {
                            return Err(ApiError::InvalidDuration {
                                field: format!("{path}.failures.mtbf_ms"),
                                seconds: fsp.mtbf_ms * 1e-3,
                            });
                        }
                        if !fsp.mttr_ms.is_finite() || fsp.mttr_ms < 0.0 {
                            return Err(ApiError::InvalidDuration {
                                field: format!("{path}.failures.mttr_ms"),
                                seconds: fsp.mttr_ms * 1e-3,
                            });
                        }
                        Some(FailureConfig {
                            mtbf_s: fsp.mtbf_ms * 1e-3,
                            mttr_s: fsp.mttr_ms * 1e-3,
                        })
                    }
                };
                let autoscale = match &s.autoscale {
                    None => None,
                    Some(a) => {
                        let apath = format!("{path}.autoscale");
                        if a.min_shards == 0 {
                            return Err(parse_err(format!("{apath}.min_shards"), "must be >= 1"));
                        }
                        if a.max_shards < a.min_shards || a.max_shards > total_shards {
                            return Err(parse_err(
                                format!("{apath}.max_shards"),
                                format!(
                                    "must lie in [min_shards, fleet size] = \
                                     [{}, {total_shards}] (got {})",
                                    a.min_shards, a.max_shards
                                ),
                            ));
                        }
                        let initial = a.initial.unwrap_or(a.max_shards);
                        if initial < a.min_shards || initial > a.max_shards {
                            return Err(parse_err(
                                format!("{apath}.initial"),
                                format!(
                                    "must lie in [{}, {}] (got {initial})",
                                    a.min_shards, a.max_shards
                                ),
                            ));
                        }
                        if !a.interval_ms.is_finite() || a.interval_ms <= 0.0 {
                            return Err(ApiError::InvalidDuration {
                                field: format!("{apath}.interval_ms"),
                                seconds: a.interval_ms * 1e-3,
                            });
                        }
                        let policy = match a.policy {
                            AutoscalePolicyKind::TargetUtilization { target } => {
                                if !target.is_finite() || target <= 0.0 || target > 1.0 {
                                    return Err(parse_err(
                                        format!("{apath}.target"),
                                        format!(
                                            "must be a finite fraction in (0, 1] (got {target})"
                                        ),
                                    ));
                                }
                                AutoscalePolicy::TargetUtilization { target }
                            }
                            AutoscalePolicyKind::QueueDepth { high, low } => {
                                if high == 0 {
                                    return Err(parse_err(
                                        format!("{apath}.high"),
                                        "must be >= 1",
                                    ));
                                }
                                if low >= high {
                                    return Err(parse_err(
                                        format!("{apath}.low"),
                                        format!("must be < high = {high} (got {low})"),
                                    ));
                                }
                                AutoscalePolicy::QueueDepth { high, low }
                            }
                        };
                        Some(AutoscaleConfig {
                            policy,
                            min_shards: a.min_shards,
                            max_shards: a.max_shards,
                            initial,
                            interval_s: a.interval_ms * 1e-3,
                        })
                    }
                };
                Ok(PlannedStage::ServeVirtual {
                    name: s.name.clone(),
                    fleet: FleetConfig {
                        base: VirtualServeConfig {
                            shards: total_shards,
                            workers: s.workers,
                            max_batch: s.max_batch,
                            max_wait_s: s.max_wait_ms * 1e-3,
                            queue_depth: s.queue_depth,
                            routing,
                            calibration,
                            deadline_s: s.deadline_ms.map(|ms| ms * 1e-3),
                        },
                        classes,
                        shard_class,
                        failures,
                        autoscale,
                        queue: QueueKind::Wheel,
                    },
                    bindings,
                    mix,
                    arrival,
                    opts: s.opts,
                    slo: s.slo.clone(),
                })
            }
            ServeEngine::Threaded | ServeEngine::Async => {
                if !s.mix.is_empty() {
                    return Err(parse_err(
                        format!("{path}.mix"),
                        "a wall-clock engine serves one model — use 'model', not 'mix'",
                    ));
                }
                if s.arrival.is_some() {
                    return Err(parse_err(
                        format!("{path}.arrival"),
                        "a wall-clock engine drives a fixed request count ('requests'); \
                         arrival processes apply to the virtual engine",
                    ));
                }
                if s.calibration.is_some() {
                    return Err(parse_err(
                        format!("{path}.calibration"),
                        "re-calibration outages are a virtual-engine model; the wall-clock \
                         engines have no calibration knob",
                    ));
                }
                if !s.fleet.is_empty() {
                    return Err(parse_err(
                        format!("{path}.fleet"),
                        "heterogeneous fleets are a virtual-engine model; the wall-clock \
                         engines serve one hardware class",
                    ));
                }
                if s.failures.is_some() {
                    return Err(parse_err(
                        format!("{path}.failures"),
                        "failure injection is a virtual-engine model; the wall-clock \
                         engines have no failure knob",
                    ));
                }
                if s.autoscale.is_some() {
                    return Err(parse_err(
                        format!("{path}.autoscale"),
                        "autoscaling is a virtual-engine model; the wall-clock engines \
                         run a fixed shard set",
                    ));
                }
                if s.engine == ServeEngine::Threaded && s.deadline_ms.is_some() {
                    return Err(parse_err(
                        format!("{path}.deadline_ms"),
                        "the threaded engine has no shed path — SLO admission control \
                         needs the async or virtual engine",
                    ));
                }
                let backend: ServeBackend = s
                    .backend
                    .parse()
                    .map_err(|reason| parse_err(format!("{path}.backend"), reason))?;
                let routing: RoutingPolicy = s
                    .routing
                    .parse()
                    .map_err(|reason| parse_err(format!("{path}.routing"), reason))?;
                let core = match s.engine {
                    ServeEngine::Async => ServeCore::Async,
                    _ => ServeCore::Threaded,
                };
                let mut builder = ServeRequest::builder()
                    .backend(backend)
                    .core(core)
                    .requests(s.requests)
                    .max_batch(s.max_batch)
                    .workers(s.workers)
                    .shards(s.shards)
                    .routing(routing)
                    .queue_depth(s.queue_depth)
                    .max_wait(Duration::from_secs_f64(s.max_wait_ms * 1e-3))
                    .opts(s.opts)
                    .time_scale(s.time_scale);
                if let Some(dir) = &s.artifacts {
                    builder = builder.artifacts(dir.clone());
                }
                if let Some(model) = &s.model {
                    builder = builder.model(model.clone());
                }
                if let Some(ms) = s.deadline_ms {
                    builder = builder.deadline(Duration::from_secs_f64(ms * 1e-3));
                }
                Ok(PlannedStage::ServeThreaded {
                    name: s.name.clone(),
                    req: builder.build()?,
                    slo: s.slo.clone(),
                })
            }
        }
    }
}

// ---------------------------------------------------------------- run

/// [`FleetCost`] over the session: photonic classes take batch service
/// times and energy from the photonic simulator through the shared
/// mapping cache; platform classes from the calibrated baseline models
/// ([`crate::baselines::all_platforms`]). Memoized per
/// `(class, model, batch)` — the DES asks for the same few points
/// millions of times.
struct ScenarioCost<'a> {
    session: &'a Session,
    opts: OptFlags,
    bindings: &'a [ClassBinding],
    platforms: Vec<Platform>,
    memo: RefCell<HashMap<(usize, String, usize), (f64, f64)>>,
}

impl<'a> ScenarioCost<'a> {
    fn new(session: &'a Session, opts: OptFlags, bindings: &'a [ClassBinding]) -> Self {
        ScenarioCost {
            session,
            opts,
            bindings,
            platforms: all_platforms(),
            memo: RefCell::new(HashMap::new()),
        }
    }

    /// `(latency_s, energy_j)` of one batch on one class.
    fn point(&self, class: usize, model: &str, batch: usize) -> (f64, f64) {
        let key = (class, model.to_string(), batch);
        if let Some(&v) = self.memo.borrow().get(&key) {
            return v;
        }
        // a missing model is unreachable: plan() resolved every mix entry
        let v = match (self.bindings.get(class), self.session.model(model)) {
            (Some(ClassBinding::Platform(idx)), Ok(m)) => {
                let r = self.platforms[*idx].evaluate(m, batch.max(1));
                (r.latency, r.energy)
            }
            (_, Ok(m)) => {
                let r = self.session.sim_report(m, batch.max(1), self.opts);
                (r.latency, r.energy.total())
            }
            (_, Err(_)) => (0.0, 0.0),
        };
        self.memo.borrow_mut().insert(key, v);
        v
    }
}

impl FleetCost for ScenarioCost<'_> {
    fn batch_latency_s(&self, class: usize, model: &str, batch: usize) -> f64 {
        self.point(class, model, batch).0
    }

    fn batch_energy_j(&self, class: usize, model: &str, batch: usize) -> f64 {
        self.point(class, model, batch).1
    }
}

fn slo_for_sim(slo: &SloSpec, out: &SimOutcome) -> SloVerdict {
    let mut checks = Vec::new();
    if let Some(target) = slo.max_latency_ms {
        let actual = out.rows.iter().map(|r| r.latency_s * 1e3).fold(0.0, f64::max);
        checks.push(SloCheck {
            metric: "max_latency_ms".into(),
            target,
            actual,
            pass: actual <= target,
        });
    }
    if let Some(target) = slo.min_gops {
        let worst = out.rows.iter().map(|r| r.gops).fold(f64::INFINITY, f64::min);
        let actual = if worst.is_finite() { worst } else { 0.0 };
        checks.push(SloCheck { metric: "min_gops".into(), target, actual, pass: actual >= target });
    }
    SloVerdict::from_checks(checks)
}

fn slo_for_dse(slo: &SloSpec, out: &SweepOutcome) -> SloVerdict {
    let mut checks = Vec::new();
    if let Some(target) = slo.min_gops {
        let actual = out.optimum().map(|p| p.gops).unwrap_or(0.0);
        checks.push(SloCheck { metric: "min_gops".into(), target, actual, pass: actual >= target });
    }
    SloVerdict::from_checks(checks)
}

fn slo_for_serve(
    slo: &SloSpec,
    p99_ms: f64,
    throughput_rps: f64,
    reject_frac: f64,
    availability: f64,
) -> SloVerdict {
    let mut checks = Vec::new();
    if let Some(target) = slo.p99_ms {
        checks.push(SloCheck {
            metric: "p99_ms".into(),
            target,
            actual: p99_ms,
            pass: p99_ms <= target,
        });
    }
    if let Some(target) = slo.min_throughput_rps {
        checks.push(SloCheck {
            metric: "min_throughput_rps".into(),
            target,
            actual: throughput_rps,
            pass: throughput_rps >= target,
        });
    }
    if let Some(target) = slo.max_reject_frac {
        checks.push(SloCheck {
            metric: "max_reject_frac".into(),
            target,
            actual: reject_frac,
            pass: reject_frac <= target,
        });
    }
    if let Some(target) = slo.min_availability {
        checks.push(SloCheck {
            metric: "min_availability".into(),
            target,
            actual: availability,
            pass: availability >= target,
        });
    }
    SloVerdict::from_checks(checks)
}

/// One executed stage: its outcome plus its SLO verdict.
#[derive(Debug, Clone)]
pub struct StageOutcome {
    pub name: String,
    /// Stage kind (`"simulate"`, `"dse"`, `"compare"`, `"serve"`,
    /// `"report"`).
    pub kind: String,
    pub outcome: Outcome,
    pub slo: SloVerdict,
}

/// The single envelope a scenario run produces: every stage outcome and
/// verdict, rendering as tables or one JSON document. With virtual serve
/// stages the JSON is a pure function of `(scenario, seed)` — running the
/// same scenario twice yields byte-identical output.
#[derive(Debug, Clone)]
pub struct ScenarioOutcome {
    pub scenario: String,
    pub seed: u64,
    pub stages: Vec<StageOutcome>,
}

impl ScenarioOutcome {
    /// Conjunction of every stage verdict.
    pub fn slo_pass(&self) -> bool {
        self.stages.iter().all(|s| s.slo.pass)
    }

    /// The per-stage SLO verdict summary table.
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(vec!["stage", "kind", "slo checks", "verdict"]).with_title(
            format!(
                "scenario '{}' (seed {}) — {} stage(s), SLO {}",
                self.scenario,
                self.seed,
                self.stages.len(),
                if self.slo_pass() { "PASS" } else { "FAIL" },
            ),
        );
        for s in &self.stages {
            let checks = if s.slo.checks.is_empty() {
                "-".to_string()
            } else {
                s.slo
                    .checks
                    .iter()
                    .map(|c| format!("{} {:.4} (target {:.4})", c.metric, c.actual, c.target))
                    .collect::<Vec<_>>()
                    .join("; ")
            };
            t.row(vec![
                s.name.clone(),
                s.kind.clone(),
                checks,
                s.slo.label().to_string(),
            ]);
        }
        t
    }

    /// Every stage's tables, then the verdict summary.
    pub fn to_tables(&self) -> Vec<Table> {
        let mut tables = Vec::new();
        for s in &self.stages {
            tables.extend(s.outcome.to_tables());
        }
        tables.push(self.to_table());
        tables
    }

    pub fn json(&self) -> JsonValue {
        obj(vec![
            ("command", JsonValue::Str("run".into())),
            ("scenario", JsonValue::Str(self.scenario.clone())),
            ("seed", JsonValue::Num(self.seed as f64)),
            ("slo_pass", JsonValue::Bool(self.slo_pass())),
            (
                "stages",
                JsonValue::Arr(
                    self.stages
                        .iter()
                        .map(|s| {
                            obj(vec![
                                ("name", JsonValue::Str(s.name.clone())),
                                ("kind", JsonValue::Str(s.kind.clone())),
                                ("slo", s.slo.json()),
                                ("outcome", s.outcome.json()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn to_json(&self) -> String {
        self.json().render()
    }
}

impl Session {
    /// Execute a compiled [`Plan`], stage by stage, into one
    /// [`ScenarioOutcome`]. Takes an `Arc` receiver (like
    /// [`Session::serve`]) because threaded serve stages hand the
    /// session's mapping cache to shard workers; clone the `Arc` first if
    /// you need the session afterwards.
    pub fn run(self: Arc<Self>, plan: &Plan) -> Result<ScenarioOutcome, ApiError> {
        let mut stages = Vec::with_capacity(plan.stages.len());
        for (i, stage) in plan.stages.iter().enumerate() {
            stages.push(run_stage(&self, plan, i, stage)?);
        }
        Ok(ScenarioOutcome {
            scenario: plan.scenario.clone(),
            seed: plan.seed,
            stages,
        })
    }
}

fn run_stage(
    session: &Arc<Session>,
    plan: &Plan,
    index: usize,
    stage: &PlannedStage,
) -> Result<StageOutcome, ApiError> {
    Ok(match stage {
        PlannedStage::Simulate { name, req, slo } => {
            let out = session.simulate(req)?;
            let verdict = slo_for_sim(slo, &out);
            StageOutcome {
                name: name.clone(),
                kind: "simulate".into(),
                outcome: Outcome::Sim(out),
                slo: verdict,
            }
        }
        PlannedStage::Dse { name, req, slo } => {
            let out = session.sweep(req)?;
            let verdict = slo_for_dse(slo, &out);
            StageOutcome {
                name: name.clone(),
                kind: "dse".into(),
                outcome: Outcome::Sweep(out),
                slo: verdict,
            }
        }
        PlannedStage::Compare { name, opts } => StageOutcome {
            name: name.clone(),
            kind: "compare".into(),
            outcome: Outcome::Compare(session.compare_opts(*opts)),
            slo: SloVerdict::empty(),
        },
        PlannedStage::ServeVirtual { name, fleet, bindings, mix, arrival, opts, slo } => {
            // stage i owns fork(i) of the scenario seed, so editing one
            // stage never perturbs another's traffic
            let mut stage_rng = Pcg32::new(plan.seed).fork(index as u64);
            let stage_seed = stage_rng.next_u64();
            let cost = ScenarioCost::new(session.as_ref(), *opts, bindings);
            let v = simulate_fleet(fleet, mix, arrival, &cost, stage_seed);
            let cfg = &fleet.base;
            let out = WorkloadOutcome {
                mix: mix.normalized(),
                arrival_kind: arrival.kind().into(),
                arrival: arrival.describe(),
                shards: cfg.shards,
                workers: cfg.workers,
                max_batch: cfg.max_batch,
                max_wait_ms: cfg.max_wait_s * 1e3,
                queue_depth: cfg.queue_depth,
                routing: cfg.routing.name().into(),
                offered: v.offered,
                admitted: v.admitted,
                rejected: v.rejected,
                shed: v.shed,
                makespan_s: v.makespan_s,
                throughput_rps: v.throughput_rps(),
                mean_ms: v.mean_latency_ms(),
                p50_ms: v.latency_percentile_ms(50.0),
                p95_ms: v.latency_percentile_ms(95.0),
                p99_ms: v.latency_percentile_ms(99.0),
                batches: v.batches,
                mean_batch: v.mean_batch,
                outages: v.outages,
                failures: v.failures,
                downtime_s: v.downtime_s,
                availability: v.availability,
                energy_j: v.energy_j,
                cost: v.cost,
                scale_ups: v.scale_ups,
                scale_downs: v.scale_downs,
                avg_active_shards: v.avg_active_shards,
                classes: fleet.classes.iter().map(|c| c.name.clone()).collect(),
                per_model: v.per_model.clone(),
                per_shard: v.per_shard.clone(),
            };
            let verdict = slo_for_serve(
                slo,
                out.p99_ms,
                out.throughput_rps,
                v.reject_fraction(),
                v.availability,
            );
            StageOutcome {
                name: name.clone(),
                kind: "serve".into(),
                outcome: Outcome::Workload(out),
                slo: verdict,
            }
        }
        PlannedStage::ServeThreaded { name, req, slo } => {
            let out = Arc::clone(session).serve(req)?;
            let attempts = out.requests as f64 + out.rejections as f64;
            let refused = out.rejections as f64 + out.sheds as f64;
            let reject_frac = if attempts > 0.0 { refused / attempts } else { 0.0 };
            // the wall-clock coordinators have no calibration model: always up
            let verdict = slo_for_serve(slo, out.p99_ms, out.throughput_img_s, reject_frac, 1.0);
            StageOutcome {
                name: name.clone(),
                kind: "serve".into(),
                outcome: Outcome::Serve(out),
                slo: verdict,
            }
        }
        PlannedStage::Report { name, threads } => {
            let session: &Session = session.as_ref();
            let mut tables = Vec::new();
            let (t1, _) = report::table1();
            tables.push(t1);
            tables.push(report::table2());
            let (t12, _) = report::fig12(session);
            tables.push(t12);
            let (t_ovl, _) = report::overlap_ablation(session);
            tables.push(t_ovl);
            let (t_fid, _) = report::fidelity_pareto(session);
            tables.push(t_fid);
            tables.extend(session.compare().to_tables());
            let (t11, _) = report::fig11(session, &Grid::paper(), *threads);
            tables.push(t11);
            StageOutcome {
                name: name.clone(),
                kind: "report".into(),
                outcome: Outcome::Report(ReportOutcome { threads: *threads, tables }),
                slo: SloVerdict::empty(),
            }
        }
    })
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn opts_presets_and_objects_parse() {
        let doc =
            crate::util::json::parse(r#"{"opts":"overlapped"}"#).unwrap();
        assert_eq!(parse_opts(&doc, "x", OptFlags::all()).unwrap(), OptFlags::overlapped());
        let doc = crate::util::json::parse(r#"{"opts":{"sparse":false}}"#).unwrap();
        let flags = parse_opts(&doc, "x", OptFlags::all()).unwrap();
        assert!(!flags.sparse && flags.pipelined && flags.power_gated && !flags.overlap);
        let doc = crate::util::json::parse(r#"{"opts":"warp-speed"}"#).unwrap();
        let err = parse_opts(&doc, "x", OptFlags::all()).unwrap_err();
        assert!(matches!(err, ApiError::ScenarioParse { ref field, .. } if field == "x.opts"));
        // absent → the caller's default
        let doc = crate::util::json::parse("{}").unwrap();
        assert_eq!(parse_opts(&doc, "x", OptFlags::baseline()).unwrap(), OptFlags::baseline());
    }

    #[test]
    fn unknown_slo_metric_is_a_parse_error() {
        let doc = crate::util::json::parse(r#"{"slo":{"p42_ms":1.0}}"#).unwrap();
        let err = parse_slo(&doc, "stages[0]").unwrap_err();
        assert!(
            matches!(err, ApiError::ScenarioParse { ref field, ref reason }
                if field == "stages[0].slo" && reason.contains("p42_ms")),
            "{err:?}"
        );
    }

    #[test]
    fn stage_names_default_to_kind_and_index() {
        let sc = Scenario::from_json(
            r#"{"name":"n","stages":[{"kind":"compare"},{"kind":"report"}]}"#,
        )
        .unwrap();
        assert_eq!(sc.stages[0].name(), "compare-0");
        assert_eq!(sc.stages[1].name(), "report-1");
        assert_eq!(sc.seed, 0, "seed defaults to 0");
    }

    #[test]
    fn unknown_stage_kind_names_the_field() {
        let err = Scenario::from_json(r#"{"name":"n","stages":[{"kind":"mine"}]}"#).unwrap_err();
        assert!(
            matches!(err, ApiError::ScenarioParse { ref field, .. }
                if field == "stages[0].kind"),
            "{err:?}"
        );
    }

    #[test]
    fn empty_scenarios_and_bad_json_are_typed() {
        assert!(matches!(
            Scenario::from_json(r#"{"name":"n","stages":[]}"#).unwrap_err(),
            ApiError::ScenarioParse { ref field, .. } if field == "$.stages"
        ));
        assert!(matches!(
            Scenario::from_json("{nope").unwrap_err(),
            ApiError::ScenarioParse { ref field, .. } if field == "$"
        ));
        assert!(matches!(
            Scenario::from_json(r#"{"stages":[]}"#).unwrap_err(),
            ApiError::ScenarioParse { ref field, .. } if field == "$.name"
        ));
    }

    #[test]
    fn arrival_shapes_parse_and_round_trip() {
        for (text, kind) in [
            (r#"{"arrival":{"process":"closed-loop","clients":2,"per_client":4}}"#, "closed-loop"),
            (r#"{"arrival":{"process":"poisson","rate_hz":100.0,"duration_s":1.0}}"#, "poisson"),
            (
                r#"{"arrival":{"process":"bursty","rate_hz":50.0,"on_s":0.1,"off_s":0.2,"duration_s":1.0}}"#,
                "bursty",
            ),
            (r#"{"arrival":{"process":"trace","arrivals_s":[0.0,0.5]}}"#, "trace"),
        ] {
            let doc = crate::util::json::parse(text).unwrap();
            let a = parse_arrival(&doc, "x").unwrap().expect(kind);
            assert_eq!(a.kind(), kind);
            // serialize → reparse → equal
            let rendered = obj(vec![("arrival", arrival_json(&a))]).render();
            let doc2 = crate::util::json::parse(&rendered).unwrap();
            assert_eq!(parse_arrival(&doc2, "x").unwrap().unwrap(), a, "{kind}");
        }
        let doc = crate::util::json::parse(r#"{"arrival":{"process":"psychic"}}"#).unwrap();
        assert!(matches!(
            parse_arrival(&doc, "x").unwrap_err(),
            ApiError::ScenarioParse { ref field, .. } if field == "x.arrival.process"
        ));
    }

    #[test]
    fn slo_applicability_is_enforced() {
        let slo = SloSpec { p99_ms: Some(5.0), ..SloSpec::default() };
        let err = check_slo_applies(&slo, &["min_gops"], "stages[0]").unwrap_err();
        assert!(matches!(err, ApiError::ScenarioParse { ref field, .. }
            if field == "stages[0].slo.p99_ms"));
        assert!(check_slo_applies(&slo, &["p99_ms"], "stages[0]").is_ok());
        let bad = SloSpec { p99_ms: Some(f64::NAN), ..SloSpec::default() };
        assert!(check_slo_applies(&bad, &["p99_ms"], "s").is_err());
        let frac = SloSpec { max_reject_frac: Some(1.5), ..SloSpec::default() };
        assert!(check_slo_applies(&frac, &["max_reject_frac"], "s").is_err());
        let zero_frac = SloSpec { max_reject_frac: Some(0.0), ..SloSpec::default() };
        assert!(check_slo_applies(&zero_frac, &["max_reject_frac"], "s").is_ok());
    }

    #[test]
    fn calibration_parses_validates_and_round_trips() {
        let text = r#"{"name":"n","stages":[{
            "kind":"serve",
            "mix":[{"model":"dcgan","weight":1.0}],
            "arrival":{"process":"poisson","rate_hz":100.0,"duration_s":0.1},
            "calibration":{"interval_ms":40.0,"outage_ms":6.0}
        }]}"#;
        let sc = Scenario::from_json(text).unwrap();
        let StageSpec::Serve(s) = &sc.stages[0] else { panic!("not a serve stage") };
        assert_eq!(
            s.calibration,
            Some(CalibrationSpec { interval_ms: 40.0, outage_ms: 6.0 })
        );
        // serialize → reparse → equal (the fixpoint covers the new member)
        assert_eq!(Scenario::from_json(&sc.to_json()).unwrap(), sc);
        // member must be an object with both durations
        let err = Scenario::from_json(
            r#"{"name":"n","stages":[{"kind":"serve","calibration":true}]}"#,
        )
        .unwrap_err();
        assert!(matches!(err, ApiError::ScenarioParse { ref field, .. }
            if field == "stages[0].calibration"));
        let err = Scenario::from_json(
            r#"{"name":"n","stages":[{"kind":"serve","calibration":{"interval_ms":1.0}}]}"#,
        )
        .unwrap_err();
        assert!(matches!(err, ApiError::ScenarioParse { ref field, .. }
            if field == "stages[0].calibration.outage_ms"));
    }

    #[test]
    fn min_availability_is_a_serve_slo_in_the_unit_interval() {
        let doc = crate::util::json::parse(r#"{"slo":{"min_availability":0.95}}"#).unwrap();
        let slo = parse_slo(&doc, "stages[0]").unwrap();
        assert_eq!(slo.min_availability, Some(0.95));
        assert!(!slo.is_empty());
        assert!(check_slo_applies(&slo, &["min_availability"], "s").is_ok());
        // not applicable outside serve stages
        assert!(check_slo_applies(&slo, &["min_gops"], "s").is_err());
        // must land in (0, 1]
        let hi = SloSpec { min_availability: Some(1.5), ..SloSpec::default() };
        assert!(check_slo_applies(&hi, &["min_availability"], "s").is_err());
        let zero = SloSpec { min_availability: Some(0.0), ..SloSpec::default() };
        assert!(check_slo_applies(&zero, &["min_availability"], "s").is_err());
        // the verdict compares availability against the floor
        let v = slo_for_serve(&slo, 1.0, 10.0, 0.0, 0.9);
        assert!(!v.pass && v.checks[0].metric == "min_availability");
        let v = slo_for_serve(&slo, 1.0, 10.0, 0.0, 0.99);
        assert!(v.pass);
    }

    #[test]
    fn diurnal_and_flash_crowd_arrivals_round_trip() {
        for (text, kind) in [
            (
                r#"{"arrival":{"process":"diurnal","base_hz":100.0,"peak_hz":900.0,"period_s":0.5,"duration_s":1.0}}"#,
                "diurnal",
            ),
            (
                r#"{"arrival":{"process":"flash-crowd","base_hz":200.0,"spike_hz":4000.0,"spike_at_s":0.2,"spike_s":0.1,"duration_s":0.5}}"#,
                "flash-crowd",
            ),
        ] {
            let doc = crate::util::json::parse(text).unwrap();
            let a = parse_arrival(&doc, "x").unwrap().expect(kind);
            assert_eq!(a.kind(), kind);
            let rendered = obj(vec![("arrival", arrival_json(&a))]).render();
            let doc2 = crate::util::json::parse(&rendered).unwrap();
            assert_eq!(parse_arrival(&doc2, "x").unwrap().unwrap(), a, "{kind}");
        }
        // plan-time checks attribute each field: a trough above the crest
        let bad = ArrivalProcess::Diurnal {
            base_hz: 900.0,
            peak_hz: 100.0,
            period_s: 0.5,
            duration_s: 1.0,
        };
        let err = check_arrival(&bad, "stages[0]").unwrap_err();
        assert!(
            matches!(err, ApiError::InvalidRate { ref field, .. }
                if field == "stages[0].arrival.peak_hz"),
            "{err:?}"
        );
        let bad = ArrivalProcess::FlashCrowd {
            base_hz: 200.0,
            spike_hz: 4000.0,
            spike_at_s: -1.0,
            spike_s: 0.1,
            duration_s: 0.5,
        };
        let err = check_arrival(&bad, "stages[0]").unwrap_err();
        assert!(
            matches!(err, ApiError::ScenarioParse { ref field, .. }
                if field == "stages[0].arrival.spike_at_s"),
            "{err:?}"
        );
    }

    #[test]
    fn fleet_failures_and_autoscale_parse_and_round_trip() {
        let text = r#"{"name":"n","stages":[{
            "kind":"serve",
            "mix":[{"model":"dcgan","weight":1.0}],
            "arrival":{"process":"poisson","rate_hz":100.0,"duration_s":0.1},
            "fleet":[
                {"platform":"photonic","count":2,"cost_per_hour":3.0},
                {"platform":"gpu","count":1,"workers":4,"idle_w":80.0,"cost_per_hour":4.0}
            ],
            "failures":{"mtbf_ms":150.0,"mttr_ms":10.0},
            "autoscale":{"policy":"queue-depth","high":64,"low":4,
                         "min_shards":1,"max_shards":3,"interval_ms":20.0}
        }]}"#;
        let sc = Scenario::from_json(text).unwrap();
        let StageSpec::Serve(s) = &sc.stages[0] else { panic!("not a serve stage") };
        assert_eq!(s.fleet.len(), 2);
        assert_eq!(s.fleet[0].platform, "photonic");
        assert_eq!(s.fleet[0].count, 2);
        assert_eq!(s.fleet[0].workers, None);
        assert_eq!(s.fleet[1].workers, Some(4));
        assert_eq!(s.failures, Some(FailureSpec { mtbf_ms: 150.0, mttr_ms: 10.0 }));
        assert_eq!(
            s.autoscale,
            Some(AutoscaleSpec {
                policy: AutoscalePolicyKind::QueueDepth { high: 64, low: 4 },
                min_shards: 1,
                max_shards: 3,
                initial: None,
                interval_ms: 20.0,
            })
        );
        // serialize → reparse → equal (the fixpoint covers the new members)
        assert_eq!(Scenario::from_json(&sc.to_json()).unwrap(), sc);
        // the target-utilization policy round-trips its own members
        let text = text.replace(
            r#""policy":"queue-depth","high":64,"low":4,"#,
            r#""policy":"target-utilization","target":0.7,"#,
        );
        let sc = Scenario::from_json(&text).unwrap();
        assert_eq!(Scenario::from_json(&sc.to_json()).unwrap(), sc);
        // unknown policies and malformed members are attributed
        let err = Scenario::from_json(
            r#"{"name":"n","stages":[{"kind":"serve",
                "autoscale":{"policy":"vibes","max_shards":2,"interval_ms":1.0}}]}"#,
        )
        .unwrap_err();
        assert!(matches!(err, ApiError::ScenarioParse { ref field, .. }
            if field == "stages[0].autoscale.policy"));
        let err = Scenario::from_json(
            r#"{"name":"n","stages":[{"kind":"serve","fleet":[{"count":1}]}]}"#,
        )
        .unwrap_err();
        assert!(matches!(err, ApiError::ScenarioParse { ref field, .. }
            if field == "stages[0].fleet[0].platform"));
        let err = Scenario::from_json(
            r#"{"name":"n","stages":[{"kind":"serve","failures":{"mtbf_ms":1.0}}]}"#,
        )
        .unwrap_err();
        assert!(matches!(err, ApiError::ScenarioParse { ref field, .. }
            if field == "stages[0].failures.mttr_ms"));
    }

    #[test]
    fn verdicts_aggregate() {
        let v = SloVerdict::from_checks(vec![
            SloCheck { metric: "a".into(), target: 1.0, actual: 0.5, pass: true },
            SloCheck { metric: "b".into(), target: 1.0, actual: 2.0, pass: false },
        ]);
        assert!(!v.pass);
        assert_eq!(v.label(), "FAIL");
        assert_eq!(SloVerdict::empty().label(), "-");
        assert!(SloVerdict::empty().pass);
        let json = v.json().render();
        assert!(json.contains("\"pass\":false") && json.contains("\"metric\":\"a\""));
    }
}
