//! Serving through the Session API: pick a [`ServeBackend`], start an
//! N-shard [`Server`], validate the request against the loaded model set
//! **before** submitting anything, drive the request stream with bounded
//! in-flight pacing, and return a typed [`ServeOutcome`].
//!
//! Two backends share one driver:
//!
//! - [`ServeBackend::Sim`] (default) — a [`SimExecutor`] costed by the L2
//!   photonic simulator through the session mapping cache. Needs **no
//!   PJRT artifacts**; this is the scenario engine for "what does a fleet
//!   of N PhotoGAN chips do under load?".
//! - [`ServeBackend::Pjrt`] — the real AOT-HLO inference engine (requires
//!   the `pjrt` feature and `make artifacts`); selecting it without the
//!   feature is a typed [`ApiError`], not a compile hole.
//!
//! ```
//! use photogan::api::{ServeBackend, ServeRequest, Session};
//! use photogan::coordinator::RoutingPolicy;
//! use std::sync::Arc;
//!
//! let request = ServeRequest::builder()
//!     .backend(ServeBackend::Sim)
//!     .model("condgan")
//!     .shards(2)
//!     .routing(RoutingPolicy::LeastOutstanding)
//!     .requests(8)
//!     .time_scale(0.0) // cost model only — don't sleep simulated latencies
//!     .build()?;
//! let outcome = Arc::new(Session::new()?).serve(&request)?;
//! assert_eq!(outcome.total_requests, 8);
//! assert_eq!(outcome.shards, 2);
//! assert!(outcome.to_json().contains("\"backend\":\"sim\""));
//! # Ok::<(), photogan::api::ApiError>(())
//! ```

use super::error::ApiError;
use super::executor::SimExecutor;
use super::outcome::ServeOutcome;
use super::session::Session;
use crate::coordinator::server::{BatchExecutor, Server, ServerConfig, ServerStats, SubmitError};
use crate::coordinator::{
    AsyncServer, AsyncServerConfig, BatchPolicy, PendingReply, RoutingPolicy, TrafficSink,
};
use crate::sim::OptFlags;
use crate::util::stats::percentile_sorted;
use std::collections::VecDeque;
use std::fmt;
use std::path::PathBuf;
use std::str::FromStr;
use std::sync::Arc;
use std::time::Duration;

/// Which executor a [`ServeRequest`] runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ServeBackend {
    /// Photonic-simulator timing via [`SimExecutor`]; no artifacts needed.
    #[default]
    Sim,
    /// Real PJRT inference over AOT HLO artifacts (`pjrt` feature).
    Pjrt,
}

impl ServeBackend {
    /// The canonical CLI spelling (`--backend <name>`).
    pub fn name(self) -> &'static str {
        match self {
            ServeBackend::Sim => "sim",
            ServeBackend::Pjrt => "pjrt",
        }
    }
}

impl fmt::Display for ServeBackend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for ServeBackend {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "sim" => Ok(ServeBackend::Sim),
            "pjrt" => Ok(ServeBackend::Pjrt),
            other => Err(format!("unknown backend '{other}' (expected sim or pjrt)")),
        }
    }
}

/// Which serving core a [`ServeRequest`] runs on (orthogonal to the
/// backend: both cores drive the same [`BatchExecutor`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ServeCore {
    /// The leader-thread dispatch-and-wait coordinator
    /// ([`crate::coordinator::Server`]).
    #[default]
    Threaded,
    /// The continuous-batching submit-queue/completion core
    /// ([`crate::coordinator::AsyncServer`]) — required for SLO
    /// admission control ([`ServeRequestBuilder::deadline`]).
    Async,
}

impl ServeCore {
    /// The canonical CLI spelling (`--core <name>`).
    pub fn name(self) -> &'static str {
        match self {
            ServeCore::Threaded => "threaded",
            ServeCore::Async => "async",
        }
    }
}

impl fmt::Display for ServeCore {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for ServeCore {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "threaded" => Ok(ServeCore::Threaded),
            "async" => Ok(ServeCore::Async),
            other => Err(format!("unknown core '{other}' (expected threaded or async)")),
        }
    }
}

/// A validated serving request (construct via [`ServeRequest::builder`]).
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub backend: ServeBackend,
    /// Serving core: threaded dispatch-and-wait or async continuous
    /// batching.
    pub core: ServeCore,
    /// PJRT artifact directory (ignored by the sim backend).
    pub artifacts: PathBuf,
    /// `None` = the executor's first served model.
    pub model: Option<String>,
    pub requests: usize,
    pub max_batch: usize,
    /// Worker threads per shard.
    pub workers: usize,
    pub max_wait: Duration,
    /// Serving shards (each modeling one chip).
    pub shards: usize,
    pub routing: RoutingPolicy,
    /// Bounded in-flight samples per shard (typed backpressure beyond).
    pub queue_depth: usize,
    /// Optimization flags for the sim backend's cost model.
    pub opts: OptFlags,
    /// Sim pacing: wall seconds per simulated second (`0` = cost only).
    pub time_scale: f64,
    /// SLO deadline for admission control (async core only): a submission
    /// whose predicted queueing delay exceeds it is shed with a typed
    /// [`crate::coordinator::SubmitError::Shed`]. `None` disarms shedding.
    pub deadline: Option<Duration>,
}

impl ServeRequest {
    pub fn builder() -> ServeRequestBuilder {
        ServeRequestBuilder::default()
    }
}

/// Fluent builder for [`ServeRequest`].
///
/// Defaults: sim backend, 64 requests, batch 8, 2 workers and 1024
/// in-flight samples per shard, 1 shard, round-robin routing, 5 ms
/// batching window, all sim optimizations plus the event-driven overlap
/// scheduler ([`OptFlags::overlapped`] — dispatched batches pace at
/// pipelined inter-layer timing), real-time pacing.
///
/// ```
/// use photogan::api::{ApiError, ServeRequest};
///
/// let req = ServeRequest::builder().shards(4).queue_depth(64).build()?;
/// assert_eq!(req.shards, 4);
/// assert_eq!(req.routing.name(), "round-robin");
///
/// // invalid shapes are typed errors, not panics
/// assert!(matches!(
///     ServeRequest::builder().shards(0).build(),
///     Err(ApiError::InvalidShards(0))
/// ));
/// # Ok::<(), ApiError>(())
/// ```
#[derive(Debug, Clone)]
pub struct ServeRequestBuilder {
    backend: ServeBackend,
    core: ServeCore,
    artifacts: PathBuf,
    model: Option<String>,
    requests: usize,
    max_batch: usize,
    workers: usize,
    max_wait: Duration,
    shards: usize,
    routing: RoutingPolicy,
    queue_depth: usize,
    opts: OptFlags,
    time_scale: f64,
    deadline: Option<Duration>,
}

impl Default for ServeRequestBuilder {
    fn default() -> Self {
        ServeRequestBuilder {
            backend: ServeBackend::Sim,
            core: ServeCore::Threaded,
            artifacts: PathBuf::from("artifacts"),
            model: None,
            requests: 64,
            max_batch: 8,
            workers: 2,
            max_wait: Duration::from_millis(5),
            shards: 1,
            routing: RoutingPolicy::RoundRobin,
            queue_depth: 1024,
            opts: OptFlags::overlapped(),
            time_scale: 1.0,
            deadline: None,
        }
    }
}

impl ServeRequestBuilder {
    pub fn backend(mut self, backend: ServeBackend) -> Self {
        self.backend = backend;
        self
    }

    pub fn core(mut self, core: ServeCore) -> Self {
        self.core = core;
        self
    }

    /// SLO deadline for admission control — requires [`ServeCore::Async`]
    /// (the threaded core has no shed path; `build` rejects the combo).
    pub fn deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts = dir.into();
        self
    }

    pub fn model(mut self, name: impl Into<String>) -> Self {
        self.model = Some(name.into());
        self
    }

    pub fn requests(mut self, n: usize) -> Self {
        self.requests = n;
        self
    }

    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = n;
        self
    }

    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n;
        self
    }

    pub fn max_wait(mut self, d: Duration) -> Self {
        self.max_wait = d;
        self
    }

    pub fn shards(mut self, n: usize) -> Self {
        self.shards = n;
        self
    }

    pub fn routing(mut self, policy: RoutingPolicy) -> Self {
        self.routing = policy;
        self
    }

    pub fn queue_depth(mut self, n: usize) -> Self {
        self.queue_depth = n;
        self
    }

    pub fn opts(mut self, opts: OptFlags) -> Self {
        self.opts = opts;
        self
    }

    pub fn time_scale(mut self, scale: f64) -> Self {
        self.time_scale = scale;
        self
    }

    /// Validate and freeze the request.
    pub fn build(self) -> Result<ServeRequest, ApiError> {
        if self.max_batch == 0 {
            return Err(ApiError::InvalidBatch(0));
        }
        if self.workers == 0 {
            return Err(ApiError::InvalidWorkers(0));
        }
        if self.shards == 0 {
            return Err(ApiError::InvalidShards(0));
        }
        if self.queue_depth == 0 {
            return Err(ApiError::InvalidFlag {
                flag: "queue-depth".into(),
                reason: "must admit at least one in-flight sample (got 0)".into(),
            });
        }
        if !self.time_scale.is_finite() || self.time_scale < 0.0 {
            return Err(ApiError::InvalidTimeScale(self.time_scale));
        }
        match self.deadline {
            Some(d) if d.is_zero() => {
                return Err(ApiError::InvalidFlag {
                    flag: "deadline-ms".into(),
                    reason: "SLO deadline must be > 0 (a zero deadline sheds everything)"
                        .into(),
                });
            }
            Some(_) if self.core == ServeCore::Threaded => {
                return Err(ApiError::InvalidFlag {
                    flag: "deadline-ms".into(),
                    reason: "SLO admission control needs the async core (--core async)"
                        .into(),
                });
            }
            _ => {}
        }
        Ok(ServeRequest {
            backend: self.backend,
            core: self.core,
            artifacts: self.artifacts,
            model: self.model,
            requests: self.requests,
            max_batch: self.max_batch,
            workers: self.workers,
            max_wait: self.max_wait,
            shards: self.shards,
            routing: self.routing,
            queue_depth: self.queue_depth,
            opts: self.opts,
            time_scale: self.time_scale,
            deadline: self.deadline,
        })
    }
}

impl Session {
    /// Serve `req.requests` generation requests on the requested backend.
    ///
    /// Takes an `Arc` receiver because the sim backend's executor keeps
    /// hitting this session's mapping cache from shard worker threads for
    /// the lifetime of the serving loop (clone the `Arc` first if you need
    /// the session afterwards — see the module example).
    pub fn serve(self: Arc<Self>, req: &ServeRequest) -> Result<ServeOutcome, ApiError> {
        match req.backend {
            ServeBackend::Sim => {
                let exec = Arc::new(SimExecutor::with_options(
                    Arc::clone(&self),
                    req.opts,
                    req.time_scale,
                )?);
                self.serve_executor(exec, req)
            }
            ServeBackend::Pjrt => self.serve_pjrt(req),
        }
    }

    #[cfg(feature = "pjrt")]
    fn serve_pjrt(&self, req: &ServeRequest) -> Result<ServeOutcome, ApiError> {
        let engine = crate::runtime::Engine::load(&req.artifacts)
            .map_err(|e| ApiError::ArtifactError(format!("{e:#}")))?;
        self.serve_executor(Arc::new(engine), req)
    }

    #[cfg(not(feature = "pjrt"))]
    fn serve_pjrt(&self, _req: &ServeRequest) -> Result<ServeOutcome, ApiError> {
        Err(ApiError::ArtifactError(
            "the pjrt backend needs the PJRT runtime — rebuild with `--features pjrt`, \
             or use `--backend sim` (no artifacts required)"
                .into(),
        ))
    }

    /// Serving loop over an already-loaded PJRT engine (lets tests and
    /// warm callers skip the artifact compile).
    #[cfg(feature = "pjrt")]
    pub fn serve_with(
        &self,
        engine: Arc<crate::runtime::Engine>,
        req: &ServeRequest,
    ) -> Result<ServeOutcome, ApiError> {
        self.serve_executor(engine, req)
    }

    /// The backend-agnostic serving driver: start the requested serving
    /// core ([`ServeCore`]), resolve the model name against the server's
    /// routing set *before* any submission (unknown models are a typed
    /// [`ApiError::UnknownModel`], never a leader-loop zero-fill), then
    /// drive a closed request stream with at most `queue_depth` samples in
    /// flight. A shard-queue rejection with nothing left to drain
    /// surfaces as typed [`ApiError::Backpressure`]; an SLO shed on the
    /// async core consumes its request (retrying a shed would livelock
    /// against the same deadline heuristic) and is counted in
    /// [`ServeOutcome::sheds`].
    pub fn serve_executor<E: BatchExecutor>(
        &self,
        executor: Arc<E>,
        req: &ServeRequest,
    ) -> Result<ServeOutcome, ApiError> {
        let policy = BatchPolicy { max_batch: req.max_batch, max_wait: req.max_wait };
        match req.core {
            ServeCore::Threaded => {
                let server = Server::start(
                    executor,
                    ServerConfig {
                        policy,
                        workers: req.workers,
                        shards: req.shards,
                        routing: req.routing,
                        queue_depth: req.queue_depth,
                    },
                );
                let model = match resolve_model(server.models(), req.model.as_deref()) {
                    Ok(m) => m,
                    Err(e) => {
                        server.shutdown();
                        return Err(e);
                    }
                };
                let start = std::time::Instant::now();
                let driven = drive(&server.handle(), &model, req.requests);
                let wall = start.elapsed().as_secs_f64();
                let stats = server.shutdown();
                Ok(finish(req, model, driven?, wall, stats))
            }
            ServeCore::Async => {
                let server = AsyncServer::start(
                    executor,
                    AsyncServerConfig {
                        policy,
                        workers: req.workers,
                        shards: req.shards,
                        routing: req.routing,
                        queue_depth: req.queue_depth,
                        deadline: req.deadline,
                    },
                );
                let model = match resolve_model(server.models(), req.model.as_deref()) {
                    Ok(m) => m,
                    Err(e) => {
                        server.shutdown();
                        return Err(e);
                    }
                };
                let start = std::time::Instant::now();
                let driven = drive(&server.handle(), &model, req.requests);
                let wall = start.elapsed().as_secs_f64();
                let stats = server.shutdown();
                Ok(finish(req, model, driven?, wall, stats))
            }
        }
    }
}

/// Resolve the requested model name against the serving core's routed set
/// (case-insensitive); `None` picks the executor's first served model.
fn resolve_model(models: &[String], wanted: Option<&str>) -> Result<String, ApiError> {
    match wanted {
        Some(w) => models
            .iter()
            .find(|n| n.eq_ignore_ascii_case(w))
            .cloned()
            .ok_or_else(|| ApiError::UnknownModel {
                name: w.to_string(),
                available: models.to_vec(),
            }),
        None => models
            .first()
            .cloned()
            .ok_or_else(|| ApiError::ArtifactError("no models loaded".into())),
    }
}

/// What one driver pass observed: per-completion client latencies (ms),
/// queue-full rejections absorbed by draining, and SLO sheds.
struct Driven {
    lat_ms: Vec<f64>,
    rejections: u64,
    sheds: u64,
}

/// The closed-stream driver, generic over the serving core's
/// [`TrafficSink`]: a `QueueFull` is relieved by completing the oldest
/// in-flight request (typed [`ApiError::Backpressure`] when nothing is in
/// flight), a `Shed` consumes its request, and every admitted request is
/// awaited before returning.
fn drive<S: TrafficSink>(sink: &S, model: &str, requests: usize) -> Result<Driven, ApiError> {
    fn settle<P: PendingReply>(pending: P, lat_ms: &mut Vec<f64>) -> Result<(), ApiError> {
        let resp = pending
            .wait()
            .ok_or_else(|| ApiError::Internal("response channel closed".into()))?;
        lat_ms.push(resp.total_time * 1e3);
        Ok(())
    }

    let mut pending: VecDeque<S::Pending> = VecDeque::new();
    let mut lat_ms: Vec<f64> = Vec::with_capacity(requests);
    let mut rejections = 0u64;
    let mut sheds = 0u64;
    for i in 0..requests {
        loop {
            match sink.submit(model, i as u64, Some((i % 10) as u32), 1) {
                Ok(p) => {
                    pending.push_back(p);
                    break;
                }
                Err(SubmitError::QueueFull { shard, outstanding, limit }) => {
                    rejections += 1;
                    // relieve pressure by completing the oldest in-flight
                    // request; if nothing is in flight the configuration
                    // can never admit this request
                    match pending.pop_front() {
                        Some(p) => settle(p, &mut lat_ms)?,
                        None => {
                            return Err(ApiError::Backpressure { shard, outstanding, limit })
                        }
                    }
                }
                Err(SubmitError::Shed { .. }) => {
                    // admission control refused the request outright:
                    // count it and move to the next one
                    sheds += 1;
                    break;
                }
                Err(e) => return Err(ApiError::from(e)),
            }
        }
    }
    for p in pending {
        settle(p, &mut lat_ms)?;
    }
    Ok(Driven { lat_ms, rejections, sheds })
}

/// Assemble the outcome from driver observations and coordinator stats.
fn finish(
    req: &ServeRequest,
    model: String,
    driven: Driven,
    wall: f64,
    stats: ServerStats,
) -> ServeOutcome {
    let Driven { mut lat_ms, rejections, sheds } = driven;
    // one sort serves all three quantiles (latencies are finite)
    lat_ms.sort_by(f64::total_cmp);
    let mut per_model: Vec<(String, String)> = stats.per_model.into_iter().collect();
    per_model.sort();
    let per_shard: Vec<(String, String)> = stats
        .per_shard
        .iter()
        .map(|s| (format!("shard {}", s.shard), s.summary.clone()))
        .collect();
    let completed = lat_ms.len();
    ServeOutcome {
        backend: req.backend.name().to_string(),
        core: req.core.name().to_string(),
        model,
        shards: req.shards,
        routing: req.routing.name().to_string(),
        requests: req.requests,
        rejections,
        sheds,
        wall_s: wall,
        throughput_img_s: if wall > 0.0 { completed as f64 / wall } else { 0.0 },
        p50_ms: percentile_sorted(&lat_ms, 50.0),
        p95_ms: percentile_sorted(&lat_ms, 95.0),
        p99_ms: percentile_sorted(&lat_ms, 99.0),
        total_requests: stats.total_requests,
        total_samples: stats.total_samples,
        dropped_samples: stats.dropped_samples,
        per_model,
        per_shard,
    }
}
