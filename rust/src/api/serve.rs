//! Serving through the Session API: pick a [`ServeBackend`], start an
//! N-shard [`Server`], validate the request against the loaded model set
//! **before** submitting anything, drive the request stream with bounded
//! in-flight pacing, and return a typed [`ServeOutcome`].
//!
//! Two backends share one driver:
//!
//! - [`ServeBackend::Sim`] (default) — a [`SimExecutor`] costed by the L2
//!   photonic simulator through the session mapping cache. Needs **no
//!   PJRT artifacts**; this is the scenario engine for "what does a fleet
//!   of N PhotoGAN chips do under load?".
//! - [`ServeBackend::Pjrt`] — the real AOT-HLO inference engine (requires
//!   the `pjrt` feature and `make artifacts`); selecting it without the
//!   feature is a typed [`ApiError`], not a compile hole.
//!
//! ```
//! use photogan::api::{ServeBackend, ServeRequest, Session};
//! use photogan::coordinator::RoutingPolicy;
//! use std::sync::Arc;
//!
//! let request = ServeRequest::builder()
//!     .backend(ServeBackend::Sim)
//!     .model("condgan")
//!     .shards(2)
//!     .routing(RoutingPolicy::LeastOutstanding)
//!     .requests(8)
//!     .time_scale(0.0) // cost model only — don't sleep simulated latencies
//!     .build()?;
//! let outcome = Arc::new(Session::new()?).serve(&request)?;
//! assert_eq!(outcome.total_requests, 8);
//! assert_eq!(outcome.shards, 2);
//! assert!(outcome.to_json().contains("\"backend\":\"sim\""));
//! # Ok::<(), photogan::api::ApiError>(())
//! ```

use super::error::ApiError;
use super::executor::SimExecutor;
use super::outcome::ServeOutcome;
use super::session::Session;
use crate::coordinator::server::{BatchExecutor, Server, ServerConfig, SubmitError};
use crate::coordinator::{BatchPolicy, RoutingPolicy};
use crate::sim::OptFlags;
use crate::util::stats::percentile_sorted;
use std::collections::VecDeque;
use std::fmt;
use std::path::PathBuf;
use std::str::FromStr;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

/// Which executor a [`ServeRequest`] runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ServeBackend {
    /// Photonic-simulator timing via [`SimExecutor`]; no artifacts needed.
    #[default]
    Sim,
    /// Real PJRT inference over AOT HLO artifacts (`pjrt` feature).
    Pjrt,
}

impl ServeBackend {
    /// The canonical CLI spelling (`--backend <name>`).
    pub fn name(self) -> &'static str {
        match self {
            ServeBackend::Sim => "sim",
            ServeBackend::Pjrt => "pjrt",
        }
    }
}

impl fmt::Display for ServeBackend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for ServeBackend {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "sim" => Ok(ServeBackend::Sim),
            "pjrt" => Ok(ServeBackend::Pjrt),
            other => Err(format!("unknown backend '{other}' (expected sim or pjrt)")),
        }
    }
}

/// A validated serving request (construct via [`ServeRequest::builder`]).
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub backend: ServeBackend,
    /// PJRT artifact directory (ignored by the sim backend).
    pub artifacts: PathBuf,
    /// `None` = the executor's first served model.
    pub model: Option<String>,
    pub requests: usize,
    pub max_batch: usize,
    /// Worker threads per shard.
    pub workers: usize,
    pub max_wait: Duration,
    /// Serving shards (each modeling one chip).
    pub shards: usize,
    pub routing: RoutingPolicy,
    /// Bounded in-flight samples per shard (typed backpressure beyond).
    pub queue_depth: usize,
    /// Optimization flags for the sim backend's cost model.
    pub opts: OptFlags,
    /// Sim pacing: wall seconds per simulated second (`0` = cost only).
    pub time_scale: f64,
}

impl ServeRequest {
    pub fn builder() -> ServeRequestBuilder {
        ServeRequestBuilder::default()
    }
}

/// Fluent builder for [`ServeRequest`].
///
/// Defaults: sim backend, 64 requests, batch 8, 2 workers and 1024
/// in-flight samples per shard, 1 shard, round-robin routing, 5 ms
/// batching window, all sim optimizations plus the event-driven overlap
/// scheduler ([`OptFlags::overlapped`] — dispatched batches pace at
/// pipelined inter-layer timing), real-time pacing.
///
/// ```
/// use photogan::api::{ApiError, ServeRequest};
///
/// let req = ServeRequest::builder().shards(4).queue_depth(64).build()?;
/// assert_eq!(req.shards, 4);
/// assert_eq!(req.routing.name(), "round-robin");
///
/// // invalid shapes are typed errors, not panics
/// assert!(matches!(
///     ServeRequest::builder().shards(0).build(),
///     Err(ApiError::InvalidShards(0))
/// ));
/// # Ok::<(), ApiError>(())
/// ```
#[derive(Debug, Clone)]
pub struct ServeRequestBuilder {
    backend: ServeBackend,
    artifacts: PathBuf,
    model: Option<String>,
    requests: usize,
    max_batch: usize,
    workers: usize,
    max_wait: Duration,
    shards: usize,
    routing: RoutingPolicy,
    queue_depth: usize,
    opts: OptFlags,
    time_scale: f64,
}

impl Default for ServeRequestBuilder {
    fn default() -> Self {
        ServeRequestBuilder {
            backend: ServeBackend::Sim,
            artifacts: PathBuf::from("artifacts"),
            model: None,
            requests: 64,
            max_batch: 8,
            workers: 2,
            max_wait: Duration::from_millis(5),
            shards: 1,
            routing: RoutingPolicy::RoundRobin,
            queue_depth: 1024,
            opts: OptFlags::overlapped(),
            time_scale: 1.0,
        }
    }
}

impl ServeRequestBuilder {
    pub fn backend(mut self, backend: ServeBackend) -> Self {
        self.backend = backend;
        self
    }

    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts = dir.into();
        self
    }

    pub fn model(mut self, name: impl Into<String>) -> Self {
        self.model = Some(name.into());
        self
    }

    pub fn requests(mut self, n: usize) -> Self {
        self.requests = n;
        self
    }

    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = n;
        self
    }

    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n;
        self
    }

    pub fn max_wait(mut self, d: Duration) -> Self {
        self.max_wait = d;
        self
    }

    pub fn shards(mut self, n: usize) -> Self {
        self.shards = n;
        self
    }

    pub fn routing(mut self, policy: RoutingPolicy) -> Self {
        self.routing = policy;
        self
    }

    pub fn queue_depth(mut self, n: usize) -> Self {
        self.queue_depth = n;
        self
    }

    pub fn opts(mut self, opts: OptFlags) -> Self {
        self.opts = opts;
        self
    }

    pub fn time_scale(mut self, scale: f64) -> Self {
        self.time_scale = scale;
        self
    }

    /// Validate and freeze the request.
    pub fn build(self) -> Result<ServeRequest, ApiError> {
        if self.max_batch == 0 {
            return Err(ApiError::InvalidBatch(0));
        }
        if self.workers == 0 {
            return Err(ApiError::InvalidWorkers(0));
        }
        if self.shards == 0 {
            return Err(ApiError::InvalidShards(0));
        }
        if self.queue_depth == 0 {
            return Err(ApiError::InvalidFlag {
                flag: "queue-depth".into(),
                reason: "must admit at least one in-flight sample (got 0)".into(),
            });
        }
        if !self.time_scale.is_finite() || self.time_scale < 0.0 {
            return Err(ApiError::InvalidTimeScale(self.time_scale));
        }
        Ok(ServeRequest {
            backend: self.backend,
            artifacts: self.artifacts,
            model: self.model,
            requests: self.requests,
            max_batch: self.max_batch,
            workers: self.workers,
            max_wait: self.max_wait,
            shards: self.shards,
            routing: self.routing,
            queue_depth: self.queue_depth,
            opts: self.opts,
            time_scale: self.time_scale,
        })
    }
}

impl Session {
    /// Serve `req.requests` generation requests on the requested backend.
    ///
    /// Takes an `Arc` receiver because the sim backend's executor keeps
    /// hitting this session's mapping cache from shard worker threads for
    /// the lifetime of the serving loop (clone the `Arc` first if you need
    /// the session afterwards — see the module example).
    pub fn serve(self: Arc<Self>, req: &ServeRequest) -> Result<ServeOutcome, ApiError> {
        match req.backend {
            ServeBackend::Sim => {
                let exec = Arc::new(SimExecutor::with_options(
                    Arc::clone(&self),
                    req.opts,
                    req.time_scale,
                )?);
                self.serve_executor(exec, req)
            }
            ServeBackend::Pjrt => self.serve_pjrt(req),
        }
    }

    #[cfg(feature = "pjrt")]
    fn serve_pjrt(&self, req: &ServeRequest) -> Result<ServeOutcome, ApiError> {
        let engine = crate::runtime::Engine::load(&req.artifacts)
            .map_err(|e| ApiError::ArtifactError(format!("{e:#}")))?;
        self.serve_executor(Arc::new(engine), req)
    }

    #[cfg(not(feature = "pjrt"))]
    fn serve_pjrt(&self, _req: &ServeRequest) -> Result<ServeOutcome, ApiError> {
        Err(ApiError::ArtifactError(
            "the pjrt backend needs the PJRT runtime — rebuild with `--features pjrt`, \
             or use `--backend sim` (no artifacts required)"
                .into(),
        ))
    }

    /// Serving loop over an already-loaded PJRT engine (lets tests and
    /// warm callers skip the artifact compile).
    #[cfg(feature = "pjrt")]
    pub fn serve_with(
        &self,
        engine: Arc<crate::runtime::Engine>,
        req: &ServeRequest,
    ) -> Result<ServeOutcome, ApiError> {
        self.serve_executor(engine, req)
    }

    /// The backend-agnostic serving driver: start the sharded coordinator,
    /// resolve the model name against the server's routing set *before*
    /// any submission (unknown models are a typed
    /// [`ApiError::UnknownModel`], never a leader-loop zero-fill), then
    /// drive a closed request stream with at most `queue_depth` samples in
    /// flight. A shard-queue rejection with nothing left to drain
    /// surfaces as typed [`ApiError::Backpressure`].
    pub fn serve_executor<E: BatchExecutor>(
        &self,
        executor: Arc<E>,
        req: &ServeRequest,
    ) -> Result<ServeOutcome, ApiError> {
        let server = Server::start(
            executor,
            ServerConfig {
                policy: BatchPolicy { max_batch: req.max_batch, max_wait: req.max_wait },
                workers: req.workers,
                shards: req.shards,
                routing: req.routing,
                queue_depth: req.queue_depth,
            },
        );
        let resolved = match &req.model {
            Some(wanted) => server
                .models()
                .iter()
                .find(|n| n.eq_ignore_ascii_case(wanted))
                .cloned()
                .ok_or_else(|| ApiError::UnknownModel {
                    name: wanted.clone(),
                    available: server.models().to_vec(),
                }),
            None => server
                .models()
                .first()
                .cloned()
                .ok_or_else(|| ApiError::ArtifactError("no models loaded".into())),
        };
        let model = match resolved {
            Ok(m) => m,
            Err(e) => {
                server.shutdown();
                return Err(e);
            }
        };

        fn recv_one(
            rx: Receiver<crate::coordinator::GenResponse>,
            lat_ms: &mut Vec<f64>,
        ) -> Result<(), ApiError> {
            let resp = rx
                .recv()
                .map_err(|_| ApiError::Internal("response channel closed".into()))?;
            lat_ms.push(resp.total_time * 1e3);
            Ok(())
        }

        let start = std::time::Instant::now();
        let mut pending: VecDeque<Receiver<crate::coordinator::GenResponse>> = VecDeque::new();
        let mut lat_ms: Vec<f64> = Vec::with_capacity(req.requests);
        let mut rejections = 0u64;
        for i in 0..req.requests {
            loop {
                match server.submit(&model, i as u64, Some((i % 10) as u32), 1) {
                    Ok(rx) => {
                        pending.push_back(rx);
                        break;
                    }
                    Err(SubmitError::QueueFull { shard, outstanding, limit }) => {
                        rejections += 1;
                        // relieve pressure by completing the oldest
                        // in-flight request; if nothing is in flight the
                        // configuration can never admit this request
                        match pending.pop_front() {
                            Some(rx) => recv_one(rx, &mut lat_ms)?,
                            None => {
                                server.shutdown();
                                return Err(ApiError::Backpressure {
                                    shard,
                                    outstanding,
                                    limit,
                                });
                            }
                        }
                    }
                    Err(e) => {
                        server.shutdown();
                        return Err(ApiError::from(e));
                    }
                }
            }
        }
        for rx in pending {
            recv_one(rx, &mut lat_ms)?;
        }
        let wall = start.elapsed().as_secs_f64();
        let stats = server.shutdown();

        // one sort serves all three quantiles (latencies are finite)
        lat_ms.sort_by(f64::total_cmp);
        let mut per_model: Vec<(String, String)> = stats.per_model.into_iter().collect();
        per_model.sort();
        let per_shard: Vec<(String, String)> = stats
            .per_shard
            .iter()
            .map(|s| (format!("shard {}", s.shard), s.summary.clone()))
            .collect();
        Ok(ServeOutcome {
            backend: req.backend.name().to_string(),
            model,
            shards: req.shards,
            routing: req.routing.name().to_string(),
            requests: req.requests,
            rejections,
            wall_s: wall,
            throughput_img_s: if wall > 0.0 { req.requests as f64 / wall } else { 0.0 },
            p50_ms: percentile_sorted(&lat_ms, 50.0),
            p95_ms: percentile_sorted(&lat_ms, 95.0),
            p99_ms: percentile_sorted(&lat_ms, 99.0),
            total_requests: stats.total_requests,
            total_samples: stats.total_samples,
            dropped_samples: stats.dropped_samples,
            per_model,
            per_shard,
        })
    }
}
