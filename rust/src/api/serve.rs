//! Serving through the Session API: load PJRT artifacts, validate the
//! request against the loaded model set **before** submitting anything to
//! the coordinator (an unknown model used to hang or zero-fill inside the
//! leader loop), drive the request stream, and return a typed
//! [`ServeOutcome`].
//!
//! Only compiled with the `pjrt` feature (the `xla` crate is optional in
//! the offline crate set).

use super::error::ApiError;
use super::outcome::ServeOutcome;
use super::session::Session;
use crate::coordinator::server::{Server, ServerConfig};
use crate::coordinator::BatchPolicy;
use crate::runtime::Engine;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

/// A validated serving request (construct via [`ServeRequest::builder`]).
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub artifacts: PathBuf,
    /// `None` = first loaded model (sorted order).
    pub model: Option<String>,
    pub requests: usize,
    pub max_batch: usize,
    pub workers: usize,
    pub max_wait: Duration,
}

impl ServeRequest {
    pub fn builder() -> ServeRequestBuilder {
        ServeRequestBuilder::default()
    }
}

/// Fluent builder for [`ServeRequest`] (defaults mirror the seed CLI:
/// `artifacts/`, 64 requests, batch 8, 2 workers, 5 ms batching window).
#[derive(Debug, Clone)]
pub struct ServeRequestBuilder {
    artifacts: PathBuf,
    model: Option<String>,
    requests: usize,
    max_batch: usize,
    workers: usize,
    max_wait: Duration,
}

impl Default for ServeRequestBuilder {
    fn default() -> Self {
        ServeRequestBuilder {
            artifacts: PathBuf::from("artifacts"),
            model: None,
            requests: 64,
            max_batch: 8,
            workers: 2,
            max_wait: Duration::from_millis(5),
        }
    }
}

impl ServeRequestBuilder {
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts = dir.into();
        self
    }

    pub fn model(mut self, name: impl Into<String>) -> Self {
        self.model = Some(name.into());
        self
    }

    pub fn requests(mut self, n: usize) -> Self {
        self.requests = n;
        self
    }

    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = n;
        self
    }

    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n;
        self
    }

    pub fn max_wait(mut self, d: Duration) -> Self {
        self.max_wait = d;
        self
    }

    /// Validate and freeze the request.
    pub fn build(self) -> Result<ServeRequest, ApiError> {
        if self.max_batch == 0 {
            return Err(ApiError::InvalidBatch(0));
        }
        if self.workers == 0 {
            return Err(ApiError::InvalidWorkers(0));
        }
        Ok(ServeRequest {
            artifacts: self.artifacts,
            model: self.model,
            requests: self.requests,
            max_batch: self.max_batch,
            workers: self.workers,
            max_wait: self.max_wait,
        })
    }
}

impl Session {
    /// Load artifacts and drive `req.requests` generation requests through
    /// the coordinator. The model name is resolved against the server's
    /// routing set ([`Server::models`]) *before* any request is submitted,
    /// so an unknown model is a typed [`ApiError::UnknownModel`] instead
    /// of a leader-loop zero-fill.
    pub fn serve(&self, req: &ServeRequest) -> Result<ServeOutcome, ApiError> {
        let engine = Engine::load(&req.artifacts)
            .map_err(|e| ApiError::ArtifactError(format!("{e:#}")))?;
        let outcome = self.serve_with(Arc::new(engine), req)?;
        Ok(outcome)
    }

    /// Serving loop over an already-loaded engine (lets tests and warm
    /// callers skip the PJRT compile).
    pub fn serve_with(
        &self,
        engine: Arc<Engine>,
        req: &ServeRequest,
    ) -> Result<ServeOutcome, ApiError> {
        let server = Server::start(
            engine,
            ServerConfig {
                policy: BatchPolicy { max_batch: req.max_batch, max_wait: req.max_wait },
                workers: req.workers,
            },
        );
        // resolve against the server's actual routing set *before* any
        // submission — an unknown model must be a typed error, not a
        // leader-loop zero-fill
        let resolved = match &req.model {
            Some(wanted) => server
                .models()
                .iter()
                .find(|n| n.eq_ignore_ascii_case(wanted))
                .cloned()
                .ok_or_else(|| ApiError::UnknownModel {
                    name: wanted.clone(),
                    available: server.models().to_vec(),
                }),
            None => server
                .models()
                .first()
                .cloned()
                .ok_or_else(|| ApiError::ArtifactError("no models loaded".into())),
        };
        let model = match resolved {
            Ok(m) => m,
            Err(e) => {
                server.shutdown();
                return Err(e);
            }
        };
        let start = std::time::Instant::now();
        let rxs: Vec<_> = (0..req.requests)
            .map(|i| server.submit(&model, i as u64, Some((i % 10) as u32), 1))
            .collect();
        for rx in rxs {
            rx.recv()
                .map_err(|_| ApiError::Internal("response channel closed".into()))?;
        }
        let wall = start.elapsed().as_secs_f64();
        let stats = server.shutdown();
        let mut per_model: Vec<(String, String)> = stats.per_model.into_iter().collect();
        per_model.sort();
        Ok(ServeOutcome {
            model,
            requests: req.requests,
            wall_s: wall,
            throughput_img_s: if wall > 0.0 { req.requests as f64 / wall } else { 0.0 },
            total_requests: stats.total_requests,
            total_samples: stats.total_samples,
            per_model,
        })
    }
}
