//! Plan-time static analysis: `photogan lint`.
//!
//! Lint runs everything `Session::plan` checks — without executing a
//! single stage — and layers scenario-level diagnostics on top: IR
//! verification for every referenced model ([`crate::models::ir`]),
//! contradictory SLOs (a throughput floor above the offered arrival rate,
//! an availability floor above the calibration ceiling), vacuous SLOs
//! (`max_reject_frac >= 1`, an availability target with nothing that can
//! take a shard down), unreachable traffic (a flash-crowd spike after the
//! stage ends), shed-everything deadlines (below every mix model's
//! batch-1 service floor), and duplicate stage names.
//!
//! Every [`Diagnostic`] is typed: a severity, a stable `code`, a JSON
//! path (or `model:<name>` / IR op position) and a message. Errors make
//! `photogan lint` exit nonzero ([`ApiError::LintFailed`]); warnings
//! don't.

use super::error::ApiError;
use super::scenario::{Scenario, ServeStage, StageSpec};
use super::session::Session;
use crate::models::ir::{dead_ops, Graph};
use crate::models::Model;
use crate::util::json::{obj, JsonValue};
use crate::workload::ArrivalProcess;
use std::collections::HashSet;
use std::fmt;

/// Diagnostic severity: errors fail the lint, warnings don't.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One typed lint finding.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    pub severity: Severity,
    /// Stable machine-readable class, e.g. `ir-verify`,
    /// `contradictory-slo`, `vacuous-slo`, `shed-everything`.
    pub code: &'static str,
    /// Where: a JSON path (`stages[1].slo.min_throughput_rps`) or a model
    /// handle (`model:CycleGAN`). IR findings carry the op position inside
    /// the message (the [`crate::models::ir::IrError`] rendering).
    pub path: String,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}] {}: {}", self.severity, self.code, self.path, self.message)
    }
}

impl Diagnostic {
    fn error(code: &'static str, path: impl Into<String>, message: impl Into<String>) -> Self {
        Diagnostic { severity: Severity::Error, code, path: path.into(), message: message.into() }
    }

    fn warning(code: &'static str, path: impl Into<String>, message: impl Into<String>) -> Self {
        Diagnostic {
            severity: Severity::Warning,
            code,
            path: path.into(),
            message: message.into(),
        }
    }

    pub fn json(&self) -> JsonValue {
        obj(vec![
            ("severity", JsonValue::Str(self.severity.name().into())),
            ("code", JsonValue::Str(self.code.into())),
            ("path", JsonValue::Str(self.path.clone())),
            ("message", JsonValue::Str(self.message.clone())),
        ])
    }
}

/// The outcome of one lint run: every diagnostic, errors first.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct LintReport {
    /// What was linted: a scenario name or `model:<name>`.
    pub target: String,
    pub diagnostics: Vec<Diagnostic>,
}

impl LintReport {
    pub fn error_count(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn warning_count(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    pub fn has_errors(&self) -> bool {
        self.error_count() > 0
    }

    /// The CLI contract: `Ok(())` when clean (of errors), the typed
    /// [`ApiError::LintFailed`] otherwise — exit code 2.
    pub fn into_result(self) -> Result<LintReport, ApiError> {
        if self.has_errors() {
            Err(ApiError::LintFailed { errors: self.error_count() })
        } else {
            Ok(self)
        }
    }

    /// Human rendering: one line per diagnostic plus a summary line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&d.to_string());
            out.push('\n');
        }
        out.push_str(&format!(
            "{}: {} error(s), {} warning(s)\n",
            self.target,
            self.error_count(),
            self.warning_count()
        ));
        out
    }

    pub fn json(&self) -> JsonValue {
        obj(vec![
            ("target", JsonValue::Str(self.target.clone())),
            ("errors", JsonValue::Num(self.error_count() as f64)),
            ("warnings", JsonValue::Num(self.warning_count() as f64)),
            (
                "diagnostics",
                JsonValue::Arr(self.diagnostics.iter().map(Diagnostic::json).collect()),
            ),
        ])
    }

    fn sort(&mut self) {
        // errors first, stable within each severity
        self.diagnostics.sort_by_key(|d| match d.severity {
            Severity::Error => 0,
            Severity::Warning => 1,
        });
    }
}

/// The JSON path carried by a plan error, when it has one.
fn error_path(e: &ApiError) -> String {
    match e {
        ApiError::ScenarioParse { field, .. }
        | ApiError::InvalidMixWeight { field, .. }
        | ApiError::InvalidRate { field, .. }
        | ApiError::UnknownPlatform { field, .. }
        | ApiError::InvalidDuration { field, .. } => field.clone(),
        _ => "$".into(),
    }
}

/// Mean offered request rate of an arrival process, when it is
/// well-defined — the ceiling any throughput SLO must stay under.
fn offered_rate_hz(a: &ArrivalProcess) -> Option<f64> {
    match a {
        ArrivalProcess::Poisson { rate_hz, .. } => Some(*rate_hz),
        ArrivalProcess::Bursty { rate_hz, on_s, off_s, .. } => {
            let cycle = on_s + off_s;
            (cycle > 0.0).then(|| rate_hz * on_s / cycle)
        }
        // the envelope peak bounds everything the process can offer
        ArrivalProcess::Diurnal { peak_hz, .. } => Some(*peak_hz),
        ArrivalProcess::FlashCrowd { base_hz, spike_hz, .. } => Some(base_hz.max(*spike_hz)),
        ArrivalProcess::Trace { arrivals_s } => {
            let last = *arrivals_s.last()?;
            (last > 0.0).then(|| arrivals_s.len() as f64 / last)
        }
        ArrivalProcess::ClosedLoop { .. } => None,
    }
}

impl Session {
    /// Verify one model's dataflow IR; the typed rejection feeds both
    /// [`Session::plan`] and [`Session::lint_scenario`].
    pub(crate) fn verify_model_ir(&self, model: &Model) -> Result<(), ApiError> {
        let graph = Graph::from_model(model).map_err(|e| ApiError::InvalidModel {
            model: model.name.clone(),
            reason: e.to_string(),
        })?;
        graph.verify().map_err(|e| ApiError::InvalidModel {
            model: model.name.clone(),
            reason: e.to_string(),
        })
    }

    /// Lint one registered model: IR verification plus dead-op warnings.
    /// Unknown names are the usual typed [`ApiError::UnknownModel`].
    pub fn lint_model(&self, name: &str) -> Result<LintReport, ApiError> {
        let model = self.model(name)?;
        let mut report =
            LintReport { target: format!("model:{}", model.name), diagnostics: Vec::new() };
        lint_model_into(model, &format!("model:{}", model.name), &mut report);
        report.sort();
        Ok(report)
    }

    /// Lint a scenario: everything [`Session::plan`] rejects becomes an
    /// error diagnostic, plus the scenario-level analyses in the module
    /// docs. Never executes a stage.
    pub fn lint_scenario(&self, scenario: &Scenario) -> LintReport {
        let mut report =
            LintReport { target: scenario.name.clone(), diagnostics: Vec::new() };

        if let Err(e) = self.plan(scenario) {
            report
                .diagnostics
                .push(Diagnostic::error("plan", error_path(&e), e.to_string()));
        }

        let mut seen_names: HashSet<&str> = HashSet::new();
        let mut linted_models: HashSet<String> = HashSet::new();
        for (i, stage) in scenario.stages.iter().enumerate() {
            let path = format!("stages[{i}]");
            if !seen_names.insert(stage.name()) {
                report.diagnostics.push(Diagnostic::warning(
                    "duplicate-stage",
                    format!("{path}.name"),
                    format!(
                        "stage name '{}' is reused — outcome rows become ambiguous",
                        stage.name()
                    ),
                ));
            }
            let referenced: Vec<String> = match stage {
                StageSpec::Simulate(s) if s.models.is_empty() => self.model_names(),
                StageSpec::Simulate(s) => s.models.clone(),
                StageSpec::Serve(s) => s.mix.iter().map(|(m, _)| m.clone()).collect(),
                _ => Vec::new(),
            };
            for name in referenced {
                // unknown names were already reported by the plan pass
                let Ok(model) = self.model(&name) else { continue };
                if linted_models.insert(model.name.clone()) {
                    lint_model_into(model, &format!("model:{}", model.name), &mut report);
                }
            }
            if let StageSpec::Serve(s) = stage {
                self.lint_serve_stage(s, &path, &mut report);
            }
        }
        report.sort();
        report
    }

    fn lint_serve_stage(&self, s: &ServeStage, path: &str, report: &mut LintReport) {
        let slo = &s.slo;
        if let (Some(target), Some(arrival)) = (slo.min_throughput_rps, &s.arrival) {
            if let Some(offered) = offered_rate_hz(arrival) {
                if target > offered {
                    report.diagnostics.push(Diagnostic::error(
                        "contradictory-slo",
                        format!("{path}.slo.min_throughput_rps"),
                        format!(
                            "throughput floor {target} rps exceeds the offered arrival \
                             rate ({offered:.3} rps) — the SLO cannot pass"
                        ),
                    ));
                }
            }
        }
        if let Some(frac) = slo.max_reject_frac {
            if frac >= 1.0 {
                report.diagnostics.push(Diagnostic::warning(
                    "vacuous-slo",
                    format!("{path}.slo.max_reject_frac"),
                    format!("a rejection budget of {frac} can never fail"),
                ));
            }
        }
        if let Some(avail) = slo.min_availability {
            match &s.calibration {
                Some(c) if c.interval_ms > 0.0 => {
                    let ceiling = 1.0 - (c.outage_ms / c.interval_ms).min(1.0);
                    if avail > ceiling {
                        report.diagnostics.push(Diagnostic::error(
                            "contradictory-slo",
                            format!("{path}.slo.min_availability"),
                            format!(
                                "availability floor {avail} exceeds the calibration \
                                 ceiling {ceiling:.4} ({} ms outage every {} ms)",
                                c.outage_ms, c.interval_ms
                            ),
                        ));
                    }
                }
                Some(_) => {}
                None => {
                    if s.failures.is_none() {
                        report.diagnostics.push(Diagnostic::warning(
                            "vacuous-slo",
                            format!("{path}.slo.min_availability"),
                            "no calibration or failure injection configured — \
                             availability is identically 1",
                        ));
                    }
                }
            }
        }
        if let Some(ArrivalProcess::FlashCrowd { spike_at_s, duration_s, .. }) = &s.arrival {
            if spike_at_s >= duration_s {
                report.diagnostics.push(Diagnostic::warning(
                    "unreachable-traffic",
                    format!("{path}.arrival.spike_at_s"),
                    format!(
                        "the spike at {spike_at_s} s starts at or after the stage ends \
                         ({duration_s} s) — it never happens"
                    ),
                ));
            }
        }
        if let Some(deadline_ms) = s.deadline_ms {
            // the batch-1 service time is the floor any admission deadline
            // must clear; below every mix model's floor, everything sheds
            let floors: Vec<(String, f64)> = s
                .mix
                .iter()
                .filter_map(|(name, _)| self.model(name).ok())
                .map(|m| {
                    let r = self.sim_report(m, 1, s.opts);
                    (m.name.clone(), r.latency * 1e3)
                })
                .collect();
            if !floors.is_empty() && floors.iter().all(|(_, f)| deadline_ms < *f) {
                let min = floors.iter().map(|(_, f)| *f).fold(f64::INFINITY, f64::min);
                report.diagnostics.push(Diagnostic::error(
                    "shed-everything",
                    format!("{path}.deadline_ms"),
                    format!(
                        "deadline {deadline_ms} ms is below every mix model's batch-1 \
                         service floor (fastest: {min:.4} ms) — every request sheds"
                    ),
                ));
            }
        }
    }
}

/// IR-verify one model into the report: an error diagnostic on rejection,
/// dead-op warnings on a verifiable graph.
fn lint_model_into(model: &Model, path: &str, report: &mut LintReport) {
    let graph = match Graph::from_model(model) {
        Ok(g) => g,
        Err(e) => {
            report
                .diagnostics
                .push(Diagnostic::error("ir-verify", path.to_string(), e.to_string()));
            return;
        }
    };
    if let Err(e) = graph.verify() {
        report
            .diagnostics
            .push(Diagnostic::error("ir-verify", path.to_string(), e.to_string()));
        return;
    }
    for op in dead_ops(&graph) {
        report.diagnostics.push(Diagnostic::warning(
            "dead-op",
            path.to_string(),
            format!("op {op} (layer {}) computes a value nothing consumes", graph.ops[op].index),
        ));
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn scenario(text: &str) -> Scenario {
        Scenario::from_json(text).unwrap()
    }

    #[test]
    fn shipped_style_scenarios_lint_clean() {
        let s = Session::new().unwrap();
        let sc = scenario(
            r#"{ "name": "ok", "stages": [
                 { "kind": "simulate", "models": ["dcgan"], "batch": 2 },
                 { "kind": "serve",
                   "mix": [ { "model": "dcgan", "weight": 1.0 } ],
                   "arrival": { "process": "poisson", "rate_hz": 100.0,
                                "duration_s": 0.5 },
                   "slo": { "min_throughput_rps": 50.0 } }
               ] }"#,
        );
        let report = s.lint_scenario(&sc);
        assert!(!report.has_errors(), "{}", report.render());
        assert_eq!(report.warning_count(), 0, "{}", report.render());
        assert!(report.clone().into_result().is_ok());
    }

    #[test]
    fn plan_failures_become_error_diagnostics() {
        let s = Session::new().unwrap();
        let sc = scenario(
            r#"{ "name": "bad", "stages": [
                 { "kind": "simulate", "models": ["gan5"] } ] }"#,
        );
        let report = s.lint_scenario(&sc);
        assert!(report.has_errors());
        assert!(report.diagnostics.iter().any(|d| d.code == "plan"));
        assert!(matches!(
            report.into_result(),
            Err(ApiError::LintFailed { errors }) if errors >= 1
        ));
    }

    #[test]
    fn contradictory_throughput_slo_is_an_error_with_json_path() {
        let s = Session::new().unwrap();
        let sc = scenario(
            r#"{ "name": "slo", "stages": [
                 { "kind": "serve",
                   "mix": [ { "model": "dcgan", "weight": 1.0 } ],
                   "arrival": { "process": "poisson", "rate_hz": 10.0,
                                "duration_s": 0.5 },
                   "slo": { "min_throughput_rps": 100.0 } } ] }"#,
        );
        let report = s.lint_scenario(&sc);
        let d = report
            .diagnostics
            .iter()
            .find(|d| d.code == "contradictory-slo")
            .expect("must flag the impossible throughput floor");
        assert_eq!(d.severity, Severity::Error);
        assert_eq!(d.path, "stages[0].slo.min_throughput_rps");
    }

    #[test]
    fn availability_above_calibration_ceiling_is_contradictory() {
        let s = Session::new().unwrap();
        // 2 ms outage every 10 ms caps availability at 0.8
        let sc = scenario(
            r#"{ "name": "avail", "stages": [
                 { "kind": "serve",
                   "mix": [ { "model": "dcgan", "weight": 1.0 } ],
                   "arrival": { "process": "poisson", "rate_hz": 10.0,
                                "duration_s": 0.5 },
                   "calibration": { "interval_ms": 10.0, "outage_ms": 2.0 },
                   "slo": { "min_availability": 0.95 } } ] }"#,
        );
        let report = s.lint_scenario(&sc);
        assert!(report
            .diagnostics
            .iter()
            .any(|d| d.code == "contradictory-slo"
                && d.path == "stages[0].slo.min_availability"));
    }

    #[test]
    fn vacuous_slos_and_unreachable_spikes_warn() {
        let s = Session::new().unwrap();
        let sc = scenario(
            r#"{ "name": "warns", "stages": [
                 { "kind": "serve",
                   "mix": [ { "model": "dcgan", "weight": 1.0 } ],
                   "arrival": { "process": "flash-crowd", "base_hz": 10.0,
                                "spike_hz": 50.0, "spike_at_s": 2.0,
                                "spike_s": 0.1, "duration_s": 1.0 },
                   "slo": { "max_reject_frac": 1.0, "min_availability": 0.9 } } ] }"#,
        );
        let report = s.lint_scenario(&sc);
        assert!(!report.has_errors(), "{}", report.render());
        let codes: Vec<&str> = report.diagnostics.iter().map(|d| d.code).collect();
        assert!(codes.contains(&"vacuous-slo"), "{codes:?}");
        assert!(codes.contains(&"unreachable-traffic"), "{codes:?}");
    }

    #[test]
    fn duplicate_stage_names_warn() {
        let s = Session::new().unwrap();
        let sc = scenario(
            r#"{ "name": "dup", "stages": [
                 { "kind": "simulate", "name": "x", "models": ["dcgan"] },
                 { "kind": "compare", "name": "x" } ] }"#,
        );
        let report = s.lint_scenario(&sc);
        assert!(report.diagnostics.iter().any(|d| d.code == "duplicate-stage"));
    }

    #[test]
    fn shed_everything_deadline_is_an_error() {
        let s = Session::new().unwrap();
        // 1 ns deadline: far below any model's batch-1 service time
        let sc = scenario(
            r#"{ "name": "shed", "stages": [
                 { "kind": "serve",
                   "mix": [ { "model": "dcgan", "weight": 1.0 } ],
                   "arrival": { "process": "poisson", "rate_hz": 10.0,
                                "duration_s": 0.5 },
                   "deadline_ms": 0.000001 } ] }"#,
        );
        let report = s.lint_scenario(&sc);
        assert!(report
            .diagnostics
            .iter()
            .any(|d| d.code == "shed-everything" && d.path == "stages[0].deadline_ms"));
    }

    #[test]
    fn lint_model_verifies_registered_models() {
        let s = Session::new().unwrap();
        let report = s.lint_model("cyclegan").unwrap();
        assert!(!report.has_errors());
        assert!(matches!(s.lint_model("gan5"), Err(ApiError::UnknownModel { .. })));
    }

    #[test]
    fn invalid_registered_model_fails_ir_lint_and_plan() {
        use crate::models::layer::{Layer, Shape};
        let mut s = Session::new().unwrap();
        s.register_model(Model::new(
            "Broken",
            Shape::Vec(8),
            vec![Layer::Dense { in_f: 9, out_f: 4, bias: false }],
        ));
        let report = s.lint_model("broken").unwrap();
        assert!(report.has_errors());
        assert!(report.diagnostics.iter().any(|d| d.code == "ir-verify"));
        // the same rejection surfaces as a typed plan error
        let sc = scenario(
            r#"{ "name": "broken", "stages": [
                 { "kind": "simulate", "models": ["broken"] } ] }"#,
        );
        let err = s.plan(&sc).unwrap_err();
        assert!(matches!(err, ApiError::InvalidModel { ref model, .. } if model == "Broken"));
        assert_eq!(err.exit_code(), 2);
    }

    #[test]
    fn diagnostics_render_and_serialize() {
        let d = Diagnostic::error("ir-verify", "model:X", "op 3: bad");
        assert_eq!(d.to_string(), "error[ir-verify] model:X: op 3: bad");
        let report = LintReport { target: "t".into(), diagnostics: vec![d] };
        let json = report.json().render();
        assert!(json.contains("\"ir-verify\""));
        assert!(report.render().contains("1 error(s), 0 warning(s)"));
    }
}
