//! [`SimExecutor`] — a [`BatchExecutor`] backed by the photonic simulator.
//!
//! This is what makes `photogan serve --backend sim` work with **zero PJRT
//! artifacts**: every dispatched batch is costed by the L2 architectural
//! simulator through the shared [`Session`] mapping cache (one mapping per
//! `(model, batch, OptFlags)`, re-costed per call), the worker thread
//! "executes" for the predicted batch latency scaled by `time_scale`, and
//! deterministic seed-derived samples are emitted. The serving loop
//! therefore sees *photonic-timing-accurate* latencies: batching amortizes
//! weight reloads exactly as the simulator predicts, which is what the
//! multi-shard scaling benches measure.
//!
//! ```
//! use photogan::api::{Session, SimExecutor};
//! use photogan::coordinator::server::BatchExecutor;
//! use std::sync::Arc;
//!
//! let session = Arc::new(Session::new()?);
//! let exec = SimExecutor::new(Arc::clone(&session))?;
//! assert_eq!(exec.models().len(), 8); // Table 1 + the extended zoo
//!
//! // two samples of CondGAN (28×28 grayscale = 784 elements each)
//! let images = exec.generate("CondGAN", &[(7, Some(3)), (8, Some(3))]);
//! assert_eq!(images.len(), 2 * exec.elements_per_sample("CondGAN"));
//! // the sim mapping was pulled through the session's shared cache
//! assert!(session.mapping_cache_entries() >= 1);
//! # Ok::<(), photogan::api::ApiError>(())
//! ```

use super::error::ApiError;
use super::session::Session;
use crate::coordinator::server::BatchExecutor;
use crate::sim::OptFlags;
use crate::util::rng::{splitmix64, Pcg32};
use std::sync::Arc;
use std::time::Duration;

/// Sim-engine-backed batch executor (see the module docs).
pub struct SimExecutor {
    session: Arc<Session>,
    opts: OptFlags,
    /// Wall-clock seconds slept per simulated second: `1.0` = real time,
    /// `0.0` = cost model only (tests), `>1.0` = slow motion.
    time_scale: f64,
    /// `(model name, output elements per sample)`, precomputed so the hot
    /// path never re-walks layer shapes.
    elements: Vec<(String, usize)>,
}

impl SimExecutor {
    /// Executor over the session's registered models with all paper
    /// optimizations **plus the event-driven overlap scheduler**
    /// ([`OptFlags::overlapped`]) and real-time pacing
    /// (`time_scale = 1.0`): serving latencies reflect pipelined
    /// inter-layer timing, not the sequential analytical bound.
    pub fn new(session: Arc<Session>) -> Result<SimExecutor, ApiError> {
        SimExecutor::with_options(session, OptFlags::overlapped(), 1.0)
    }

    /// Executor with explicit optimization flags and time scaling.
    pub fn with_options(
        session: Arc<Session>,
        opts: OptFlags,
        time_scale: f64,
    ) -> Result<SimExecutor, ApiError> {
        if !time_scale.is_finite() || time_scale < 0.0 {
            return Err(ApiError::InvalidTimeScale(time_scale));
        }
        let mut elements = Vec::with_capacity(session.models().len());
        for m in session.models() {
            let out = m.output().map_err(|e| {
                ApiError::Internal(format!(
                    "model '{}' has no computable output shape: {e}",
                    m.name
                ))
            })?;
            elements.push((m.name.clone(), out.elements()));
        }
        Ok(SimExecutor { session, opts, time_scale, elements })
    }

    /// The configured pacing factor.
    pub fn time_scale(&self) -> f64 {
        self.time_scale
    }

    /// The simulator-predicted end-to-end latency (s) for one batch of
    /// `batch` samples — exactly what [`BatchExecutor::generate`] paces by.
    pub fn batch_latency(&self, model: &str, batch: usize) -> Result<f64, ApiError> {
        let m = self.session.model(model)?;
        Ok(self.session.sim_report(m, batch.max(1), self.opts).latency)
    }
}

impl BatchExecutor for SimExecutor {
    fn models(&self) -> Vec<String> {
        self.elements.iter().map(|(n, _)| n.clone()).collect()
    }

    fn elements_per_sample(&self, model: &str) -> usize {
        self.elements
            .iter()
            .find(|(n, _)| n.eq_ignore_ascii_case(model))
            .map(|(_, e)| *e)
            .unwrap_or(0)
    }

    fn generate(&self, model: &str, entries: &[(u64, Option<u32>)]) -> Vec<f32> {
        let elems = self.elements_per_sample(model);
        if elems == 0 || entries.is_empty() {
            // unknown model or empty batch: the worker's size check turns
            // this into a zero-filled degraded response
            return Vec::new();
        }
        // photonic-timing-accurate pacing: cost the whole batch through
        // the shared mapping cache, then hold the worker for the scaled
        // predicted latency
        if let Ok(m) = self.session.model(model) {
            let latency = self.session.sim_report(m, entries.len(), self.opts).latency;
            let wall = latency * self.time_scale;
            if wall > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(wall));
            }
        }
        // deterministic samples: each (seed, label) pair owns an
        // independent RNG stream, so a sample's pixels are identical no
        // matter which batch it was served in
        let mut out = Vec::with_capacity(entries.len() * elems);
        for &(seed, label) in entries {
            let mut state =
                seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(label.map_or(0, |l| u64::from(l) + 1));
            let mut rng = Pcg32::new(splitmix64(&mut state));
            out.extend((0..elems).map(|_| rng.f32() * 2.0 - 1.0));
        }
        out
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn exec() -> (Arc<Session>, SimExecutor) {
        let session = Arc::new(Session::new().unwrap());
        let e = SimExecutor::with_options(Arc::clone(&session), OptFlags::all(), 0.0).unwrap();
        (session, e)
    }

    #[test]
    fn serves_every_registered_model() {
        let (session, e) = exec();
        assert_eq!(e.models(), session.model_names());
        for name in e.models() {
            assert!(e.elements_per_sample(&name) > 0, "{name}");
        }
        // CondGAN emits 28×28 grayscale images
        assert_eq!(e.elements_per_sample("CondGAN"), 784);
    }

    #[test]
    fn samples_are_deterministic_and_batch_independent() {
        let (_s, e) = exec();
        let solo = e.generate("CondGAN", &[(7, Some(1))]);
        let pair = e.generate("CondGAN", &[(7, Some(1)), (8, Some(1))]);
        assert_eq!(solo.len(), 784);
        assert_eq!(pair.len(), 2 * 784);
        assert_eq!(solo, pair[..784], "sample must not depend on batch composition");
        assert_ne!(solo, pair[784..], "different seeds must differ");
        // a different label is a different stream
        let other_label = e.generate("CondGAN", &[(7, Some(2))]);
        assert_ne!(solo, other_label);
        // pixel range is the generator's tanh-style [-1, 1]
        assert!(solo.iter().all(|v| (-1.0..=1.0).contains(v)));
    }

    #[test]
    fn uses_the_shared_session_mapping_cache() {
        let (session, e) = exec();
        assert_eq!(session.mapping_cache_entries(), 0);
        e.generate("DCGAN", &[(0, None), (1, None)]);
        let after_first = session.mapping_cache_entries();
        assert!(after_first >= 1, "generate must populate the session cache");
        // same batch size again: pure cache hit, no new entries
        e.generate("DCGAN", &[(2, None), (3, None)]);
        assert_eq!(session.mapping_cache_entries(), after_first);
    }

    #[test]
    fn batching_amortizes_predicted_latency() {
        let (_s, e) = exec();
        let one = e.batch_latency("CondGAN", 1).unwrap();
        let eight = e.batch_latency("CondGAN", 8).unwrap();
        assert!(eight / 8.0 < one, "per-sample latency must drop with batching");
    }

    #[test]
    fn default_executor_paces_at_overlapped_timing() {
        let session = Arc::new(Session::new().unwrap());
        let overlapped = SimExecutor::new(Arc::clone(&session)).unwrap();
        let analytic =
            SimExecutor::with_options(Arc::clone(&session), OptFlags::all(), 1.0).unwrap();
        let a = overlapped.batch_latency("DCGAN", 4).unwrap();
        let b = analytic.batch_latency("DCGAN", 4).unwrap();
        assert!(a < b, "overlap pacing {a} must beat the analytical bound {b}");
    }

    #[test]
    fn invalid_time_scale_is_typed() {
        let session = Arc::new(Session::new().unwrap());
        for bad in [-1.0, f64::NAN, f64::INFINITY] {
            let err =
                SimExecutor::with_options(Arc::clone(&session), OptFlags::all(), bad).unwrap_err();
            assert!(matches!(err, ApiError::InvalidTimeScale(_)), "{bad}");
        }
    }

    #[test]
    fn unknown_model_degrades_to_empty() {
        let (_s, e) = exec();
        assert_eq!(e.elements_per_sample("nope"), 0);
        assert!(e.generate("nope", &[(0, None)]).is_empty());
    }
}
