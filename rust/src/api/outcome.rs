//! Unified result types for [`crate::api::Session`] requests.
//!
//! Every outcome renders two ways: `to_table()`/`to_tables()` for the
//! human-readable CLI path (identical formatting to the pre-Session CLI)
//! and `to_json()` for machine-readable `--json` output. The JSON is
//! written with [`crate::util::json`] and round-trips through its parser
//! (covered by the API integration tests).

use crate::dse::DsePoint;
use crate::sim::{OptFlags, SimReport};
use crate::util::json::{num_arr, obj, str_arr, JsonValue};
use crate::util::table::{f2, Table};
use crate::util::units::{fmt_energy, fmt_time};
use crate::workload::vserve::VirtualShardLoad;

/// One resource's busy/utilization/critical-path summary for a model
/// (the event-scheduler accounting surfaced through the API).
#[derive(Debug, Clone)]
pub struct ResourceRow {
    /// Stable kebab-case name from `sim::Resource::name`.
    pub resource: String,
    pub busy_s: f64,
    /// Busy fraction of the model's end-to-end latency.
    pub utilization: f64,
    /// Seconds on the end-to-end critical path (sums to the latency
    /// across all resources).
    pub critical_s: f64,
}

/// One model's simulation metrics (a row of `photogan simulate`).
#[derive(Debug, Clone)]
pub struct SimRow {
    pub model: String,
    pub latency_s: f64,
    /// The closed-form sequential latency (equals `latency_s` unless the
    /// overlap scheduler ran).
    pub serial_latency_s: f64,
    pub energy_j: f64,
    pub gops: f64,
    /// Energy per bit in femtojoules (the paper's Fig. 14 unit).
    pub epb_fj: f64,
    pub avg_power_w: f64,
    /// Per-resource busy/utilization/critical accounting, in
    /// `sim::Resource::ALL` order.
    pub resources: Vec<ResourceRow>,
}

impl SimRow {
    pub(crate) fn from_report(r: &SimReport) -> SimRow {
        SimRow {
            model: r.model.clone(),
            latency_s: r.latency,
            serial_latency_s: r.serial_latency,
            energy_j: r.energy.total(),
            gops: r.gops(),
            epb_fj: r.epb() * 1e15,
            avg_power_w: r.avg_power(),
            resources: r
                .resources
                .iter()
                .map(|u| ResourceRow {
                    resource: u.resource.name().to_string(),
                    busy_s: u.busy,
                    utilization: u.utilization(r.latency),
                    critical_s: u.critical,
                })
                .collect(),
        }
    }

    /// Overlap speedup vs. the sequential reference (1.0 when the
    /// scheduler did not run).
    pub fn overlap_speedup(&self) -> f64 {
        if self.latency_s > 0.0 {
            self.serial_latency_s / self.latency_s
        } else {
            1.0
        }
    }

    /// The resource carrying the largest critical-path share, if any.
    pub fn dominant_resource(&self) -> Option<&str> {
        self.resources
            .iter()
            .filter(|u| u.critical_s > 0.0)
            .max_by(|a, b| a.critical_s.total_cmp(&b.critical_s))
            .map(|u| u.resource.as_str())
    }
}

/// Outcome of [`crate::api::Session::simulate`].
#[derive(Debug, Clone)]
pub struct SimOutcome {
    /// The `[N,K,L,M]` the request ran on.
    pub config: (usize, usize, usize, usize),
    pub batch: usize,
    pub opts: OptFlags,
    pub rows: Vec<SimRow>,
}

fn opts_json(opts: &OptFlags) -> JsonValue {
    obj(vec![
        ("sparse", JsonValue::Bool(opts.sparse)),
        ("pipelined", JsonValue::Bool(opts.pipelined)),
        ("power_gated", JsonValue::Bool(opts.power_gated)),
        ("overlap", JsonValue::Bool(opts.overlap)),
        ("fuse", JsonValue::Bool(opts.fuse)),
    ])
}

fn config_json(c: (usize, usize, usize, usize)) -> JsonValue {
    obj(vec![
        ("n", JsonValue::Num(c.0 as f64)),
        ("k", JsonValue::Num(c.1 as f64)),
        ("l", JsonValue::Num(c.2 as f64)),
        ("m", JsonValue::Num(c.3 as f64)),
    ])
}

impl SimOutcome {
    /// The `photogan simulate` table (same columns/formatting as the
    /// pre-Session CLI).
    pub fn to_table(&self) -> Table {
        let (n, k, l, m) = self.config;
        let mut t = Table::new(vec!["model", "latency", "energy", "GOPS", "EPB (fJ/b)", "avg W"])
            .with_title(format!(
                "simulate [N,K,L,M]=[{},{},{},{}] batch={} opts={:?}",
                n, k, l, m, self.batch, self.opts
            ));
        for r in &self.rows {
            t.row(vec![
                r.model.clone(),
                fmt_time(r.latency_s),
                fmt_energy(r.energy_j),
                format!("{:.1}", r.gops),
                format!("{:.2}", r.epb_fj),
                format!("{:.2}", r.avg_power_w),
            ]);
        }
        t
    }

    /// Per-model × per-resource utilization / critical-path table (the
    /// event scheduler's headline observability output).
    pub fn resource_table(&self) -> Table {
        let mut t = Table::new(vec![
            "model", "speedup", "dominant", "resource", "busy", "util", "crit path",
        ])
        .with_title("per-resource busy / utilization / critical-path attribution".to_string());
        for r in &self.rows {
            for u in &r.resources {
                if u.busy_s == 0.0 && u.critical_s == 0.0 {
                    continue;
                }
                t.row(vec![
                    r.model.clone(),
                    format!("{:.3}x", r.overlap_speedup()),
                    r.dominant_resource().unwrap_or("-").to_string(),
                    u.resource.clone(),
                    fmt_time(u.busy_s),
                    format!("{:.1}%", 100.0 * u.utilization),
                    fmt_time(u.critical_s),
                ]);
            }
        }
        t
    }

    pub fn to_tables(&self) -> Vec<Table> {
        if self.opts.overlap {
            vec![self.to_table(), self.resource_table()]
        } else {
            vec![self.to_table()]
        }
    }

    pub fn json(&self) -> JsonValue {
        obj(vec![
            ("command", JsonValue::Str("simulate".into())),
            ("config", config_json(self.config)),
            ("batch", JsonValue::Num(self.batch as f64)),
            ("opts", opts_json(&self.opts)),
            (
                "results",
                JsonValue::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            obj(vec![
                                ("model", JsonValue::Str(r.model.clone())),
                                ("latency_s", JsonValue::Num(r.latency_s)),
                                ("serial_latency_s", JsonValue::Num(r.serial_latency_s)),
                                ("overlap_speedup", JsonValue::Num(r.overlap_speedup())),
                                ("energy_j", JsonValue::Num(r.energy_j)),
                                ("gops", JsonValue::Num(r.gops)),
                                ("epb_fj", JsonValue::Num(r.epb_fj)),
                                ("avg_power_w", JsonValue::Num(r.avg_power_w)),
                                (
                                    "resources",
                                    JsonValue::Arr(
                                        r.resources
                                            .iter()
                                            .map(|u| {
                                                obj(vec![
                                                    (
                                                        "resource",
                                                        JsonValue::Str(u.resource.clone()),
                                                    ),
                                                    ("busy_s", JsonValue::Num(u.busy_s)),
                                                    (
                                                        "utilization",
                                                        JsonValue::Num(u.utilization),
                                                    ),
                                                    (
                                                        "critical_s",
                                                        JsonValue::Num(u.critical_s),
                                                    ),
                                                ])
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn to_json(&self) -> String {
        self.json().render()
    }
}

/// Outcome of [`crate::api::Session::sweep`] (paper Fig. 11).
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    /// Total configurations in the requested grid (valid or not).
    pub grid_configs: usize,
    pub threads: usize,
    pub opts: OptFlags,
    /// Valid points, sorted by descending objective (`[0]` is the optimum).
    pub points: Vec<DsePoint>,
    /// The paper's published optimum, for the table caption.
    pub paper_optimum: (usize, usize, usize, usize),
}

impl SweepOutcome {
    /// The sweep optimum, if any configuration was valid.
    pub fn optimum(&self) -> Option<&DsePoint> {
        self.points.first()
    }

    /// The Fig. 11 top-10 table (same formatting as the pre-Session CLI).
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(vec![
            "rank", "N", "K", "L", "M", "peak W", "GOPS", "EPB (fJ/b)", "GOPS/EPB",
        ])
        .with_title(format!(
            "Fig. 11: DSE over [N,K,L,M] ({} configs, paper optimum {:?})",
            self.grid_configs, self.paper_optimum
        ));
        for (i, p) in self.points.iter().take(10).enumerate() {
            t.row(vec![
                format!("{}", i + 1),
                p.n.to_string(),
                p.k.to_string(),
                p.l.to_string(),
                p.m.to_string(),
                f2(p.peak_power_w),
                f2(p.gops),
                f2(p.epb * 1e15),
                format!("{:.3e}", p.objective),
            ]);
        }
        t
    }

    pub fn to_tables(&self) -> Vec<Table> {
        vec![self.to_table()]
    }

    pub fn json(&self) -> JsonValue {
        let point_json = |p: &DsePoint| {
            obj(vec![
                ("n", JsonValue::Num(p.n as f64)),
                ("k", JsonValue::Num(p.k as f64)),
                ("l", JsonValue::Num(p.l as f64)),
                ("m", JsonValue::Num(p.m as f64)),
                ("peak_w", JsonValue::Num(p.peak_power_w)),
                ("gops", JsonValue::Num(p.gops)),
                ("epb_fj", JsonValue::Num(p.epb * 1e15)),
                ("objective", JsonValue::Num(p.objective)),
            ])
        };
        obj(vec![
            ("command", JsonValue::Str("dse".into())),
            ("grid_configs", JsonValue::Num(self.grid_configs as f64)),
            ("threads", JsonValue::Num(self.threads as f64)),
            ("opts", opts_json(&self.opts)),
            ("valid_points", JsonValue::Num(self.points.len() as f64)),
            (
                "optimum",
                self.optimum().map(point_json).unwrap_or(JsonValue::Null),
            ),
            (
                "paper_optimum",
                config_json(self.paper_optimum),
            ),
            (
                "points",
                JsonValue::Arr(self.points.iter().map(point_json).collect()),
            ),
        ])
    }

    pub fn to_json(&self) -> String {
        self.json().render()
    }
}

/// One platform's per-model metric series (PhotoGAN first).
#[derive(Debug, Clone)]
pub struct PlatformSeries {
    pub platform: String,
    pub gops: Vec<f64>,
    /// Energy per bit (J/bit) per model.
    pub epb: Vec<f64>,
}

/// Outcome of [`crate::api::Session::compare`] (paper Figs. 13/14).
#[derive(Debug, Clone)]
pub struct CompareOutcome {
    pub model_names: Vec<String>,
    /// PhotoGAN first, then the baseline platforms.
    pub series: Vec<PlatformSeries>,
}

impl CompareOutcome {
    /// Average PhotoGAN-vs-platform GOPS ratio for series `i` (`None` for
    /// PhotoGAN itself).
    pub fn avg_gops_ratio(&self, i: usize) -> Option<f64> {
        if i == 0 || self.series.is_empty() {
            return None;
        }
        let pg = &self.series[0].gops;
        let other = &self.series.get(i)?.gops;
        let n = other.len().min(pg.len());
        if n == 0 {
            return None;
        }
        Some(pg.iter().zip(other).take(n).map(|(a, b)| a / b).sum::<f64>() / n as f64)
    }

    /// Average platform-vs-PhotoGAN EPB ratio for series `i` (`None` for
    /// PhotoGAN itself). Ratios > 1 mean PhotoGAN is more efficient.
    pub fn avg_epb_ratio(&self, i: usize) -> Option<f64> {
        if i == 0 || self.series.is_empty() {
            return None;
        }
        let pg = &self.series[0].epb;
        let other = &self.series.get(i)?.epb;
        let n = other.len().min(pg.len());
        if n == 0 {
            return None;
        }
        Some(other.iter().zip(pg).take(n).map(|(b, a)| b / a).sum::<f64>() / n as f64)
    }

    /// [`CompareOutcome::avg_gops_ratio`] restricted to the paper's
    /// Table 1 columns (the first four models in registration order) —
    /// the only window the published Fig. 13 ratios are calibrated
    /// against, so this is what the report exhibits print next to the
    /// paper numbers.
    pub fn table1_gops_ratio(&self, i: usize) -> Option<f64> {
        if i == 0 {
            return None;
        }
        let pg = &self.series.first()?.gops;
        let other = &self.series.get(i)?.gops;
        let n = pg.len().min(other.len()).min(4);
        if n == 0 {
            return None;
        }
        Some(pg.iter().zip(other).take(n).map(|(a, b)| a / b).sum::<f64>() / n as f64)
    }

    /// [`CompareOutcome::avg_epb_ratio`] restricted to the paper's
    /// Table 1 columns (see [`CompareOutcome::table1_gops_ratio`]).
    pub fn table1_epb_ratio(&self, i: usize) -> Option<f64> {
        if i == 0 {
            return None;
        }
        let pg = &self.series.first()?.epb;
        let other = &self.series.get(i)?.epb;
        let n = pg.len().min(other.len()).min(4);
        if n == 0 {
            return None;
        }
        Some(other.iter().zip(pg).take(n).map(|(b, a)| b / a).sum::<f64>() / n as f64)
    }

    /// The Fig. 13 (GOPS) and Fig. 14 (EPB) tables.
    pub fn to_tables(&self) -> Vec<Table> {
        vec![
            crate::report::figures::fig13(self),
            crate::report::figures::fig14(self),
        ]
    }

    /// Primary table (Fig. 13 GOPS).
    pub fn to_table(&self) -> Table {
        crate::report::figures::fig13(self)
    }

    pub fn json(&self) -> JsonValue {
        obj(vec![
            ("command", JsonValue::Str("compare".into())),
            ("models", str_arr(&self.model_names)),
            (
                "series",
                JsonValue::Arr(
                    self.series
                        .iter()
                        .enumerate()
                        .map(|(i, s)| {
                            obj(vec![
                                ("platform", JsonValue::Str(s.platform.clone())),
                                ("gops", num_arr(&s.gops)),
                                (
                                    "epb_fj",
                                    num_arr(
                                        &s.epb.iter().map(|e| e * 1e15).collect::<Vec<_>>(),
                                    ),
                                ),
                                (
                                    "avg_gops_ratio",
                                    self.avg_gops_ratio(i)
                                        .map(JsonValue::Num)
                                        .unwrap_or(JsonValue::Null),
                                ),
                                (
                                    "avg_epb_ratio",
                                    self.avg_epb_ratio(i)
                                        .map(JsonValue::Num)
                                        .unwrap_or(JsonValue::Null),
                                ),
                                // paper-calibration window (Table 1 columns
                                // only) — what the report exhibits print
                                // next to the published ratios
                                (
                                    "table1_gops_ratio",
                                    self.table1_gops_ratio(i)
                                        .map(JsonValue::Num)
                                        .unwrap_or(JsonValue::Null),
                                ),
                                (
                                    "table1_epb_ratio",
                                    self.table1_epb_ratio(i)
                                        .map(JsonValue::Num)
                                        .unwrap_or(JsonValue::Null),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn to_json(&self) -> String {
        self.json().render()
    }
}

/// Outcome of [`crate::api::Session::serve`] (the sharded coordinator
/// driver): end-to-end throughput, client-observed latency percentiles,
/// and per-shard / per-model metric summaries.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    /// Backend name (`"sim"` or `"pjrt"`).
    pub backend: String,
    /// Serving core name (`"threaded"` or `"async"`).
    pub core: String,
    pub model: String,
    pub shards: usize,
    /// Routing policy name (e.g. `"round-robin"`).
    pub routing: String,
    pub requests: usize,
    /// Shard-queue-full rejections the driver absorbed by draining.
    pub rejections: u64,
    /// Requests refused by SLO-aware admission control (async core only;
    /// the driver moves on instead of retrying a shed request).
    pub sheds: u64,
    pub wall_s: f64,
    pub throughput_img_s: f64,
    /// Client-observed end-to-end latency percentiles (ms).
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub total_requests: u64,
    pub total_samples: u64,
    /// Latency samples shed by the bounded reservoirs across all shards
    /// (0 means the percentiles above saw every completion).
    pub dropped_samples: u64,
    /// Per-model latency/throughput summary strings from the coordinator.
    pub per_model: Vec<(String, String)>,
    /// Per-shard summary strings (`"shard 0"` …), indexed by shard id.
    pub per_shard: Vec<(String, String)>,
}

impl ServeOutcome {
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(vec!["scope", "summary"]).with_title(format!(
            "serve[{}/{}] model={} shards={} routing={}: {} req in {:.2}s \
             ({:.1} img/s) p50={:.2}ms p95={:.2}ms p99={:.2}ms",
            self.backend,
            self.core,
            self.model,
            self.shards,
            self.routing,
            self.requests,
            self.wall_s,
            self.throughput_img_s,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
        ));
        if self.sheds > 0 {
            t.row(vec![
                "admission".into(),
                format!("{} requests shed by SLO admission control", self.sheds),
            ]);
        }
        if self.dropped_samples > 0 {
            t.row(vec![
                "histograms".into(),
                format!("{} latency samples shed by bounded reservoirs", self.dropped_samples),
            ]);
        }
        for (shard, s) in &self.per_shard {
            t.row(vec![shard.clone(), s.clone()]);
        }
        for (m, s) in &self.per_model {
            t.row(vec![format!("model {m}"), s.clone()]);
        }
        t
    }

    pub fn to_tables(&self) -> Vec<Table> {
        vec![self.to_table()]
    }

    pub fn json(&self) -> JsonValue {
        obj(vec![
            ("command", JsonValue::Str("serve".into())),
            ("backend", JsonValue::Str(self.backend.clone())),
            ("core", JsonValue::Str(self.core.clone())),
            ("model", JsonValue::Str(self.model.clone())),
            ("shards", JsonValue::Num(self.shards as f64)),
            ("routing", JsonValue::Str(self.routing.clone())),
            ("requests", JsonValue::Num(self.requests as f64)),
            ("rejections", JsonValue::Num(self.rejections as f64)),
            ("sheds", JsonValue::Num(self.sheds as f64)),
            ("wall_s", JsonValue::Num(self.wall_s)),
            ("throughput_img_s", JsonValue::Num(self.throughput_img_s)),
            ("p50_ms", JsonValue::Num(self.p50_ms)),
            ("p95_ms", JsonValue::Num(self.p95_ms)),
            ("p99_ms", JsonValue::Num(self.p99_ms)),
            ("total_requests", JsonValue::Num(self.total_requests as f64)),
            ("total_samples", JsonValue::Num(self.total_samples as f64)),
            ("dropped_samples", JsonValue::Num(self.dropped_samples as f64)),
            (
                "per_model",
                JsonValue::Obj(
                    self.per_model
                        .iter()
                        .map(|(m, s)| (m.clone(), JsonValue::Str(s.clone())))
                        .collect(),
                ),
            ),
            (
                "per_shard",
                JsonValue::Obj(
                    self.per_shard
                        .iter()
                        .map(|(m, s)| (m.clone(), JsonValue::Str(s.clone())))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn to_json(&self) -> String {
        self.json().render()
    }

    /// The run-to-run deterministic subset of [`ServeOutcome::json`]:
    /// counts and identity only, no wall-clock-derived quantity (latency
    /// percentiles, throughput, per-model summary strings). Two runs with
    /// the same seed, the same shape, and no SLO deadline render
    /// byte-identical `stable_json` — CI diffs it with `cmp` to catch
    /// nondeterminism in the submission path.
    pub fn stable_json(&self) -> String {
        obj(vec![
            ("command", JsonValue::Str("serve".into())),
            ("backend", JsonValue::Str(self.backend.clone())),
            ("core", JsonValue::Str(self.core.clone())),
            ("model", JsonValue::Str(self.model.clone())),
            ("shards", JsonValue::Num(self.shards as f64)),
            ("routing", JsonValue::Str(self.routing.clone())),
            ("requests", JsonValue::Num(self.requests as f64)),
            ("rejections", JsonValue::Num(self.rejections as f64)),
            ("sheds", JsonValue::Num(self.sheds as f64)),
            ("total_requests", JsonValue::Num(self.total_requests as f64)),
            ("total_samples", JsonValue::Num(self.total_samples as f64)),
            ("dropped_samples", JsonValue::Num(self.dropped_samples as f64)),
        ])
        .render()
    }
}

/// Outcome of a virtual-time serve stage (the deterministic scenario
/// engine — see [`crate::workload::vserve`]). Every field is a pure
/// function of `(scenario, seed)`: no wall-clock quantities appear, which
/// is what makes scenario JSON byte-identical across runs.
#[derive(Debug, Clone)]
pub struct WorkloadOutcome {
    /// Normalized `(model, weight)` mix, in declaration order.
    pub mix: Vec<(String, f64)>,
    /// Arrival-process kind (`"poisson"`, `"closed-loop"`, …).
    pub arrival_kind: String,
    /// One-line arrival description.
    pub arrival: String,
    pub shards: usize,
    /// Virtual workers per shard.
    pub workers: usize,
    pub max_batch: usize,
    pub max_wait_ms: f64,
    pub queue_depth: usize,
    /// Routing policy name.
    pub routing: String,
    /// Submission attempts / admissions / typed queue-full rejections.
    pub offered: usize,
    pub admitted: usize,
    pub rejected: usize,
    /// Requests refused by the deterministic SLO admission-control mirror
    /// (0 unless the stage sets a deadline).
    pub shed: usize,
    /// Virtual seconds from stream start to the last completion.
    pub makespan_s: f64,
    /// Admitted requests per virtual second.
    pub throughput_rps: f64,
    /// Virtual latency distribution (ms).
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Dispatched batches and their mean size.
    pub batches: u64,
    pub mean_batch: f64,
    /// Re-calibration outages taken across all shards (0 without a
    /// calibration model).
    pub outages: u64,
    /// Injected shard failures across the fleet (0 without a failure
    /// model).
    pub failures: u64,
    /// Total virtual shard-seconds lost to outages and failures (merged
    /// windows, overlaps counted once).
    pub downtime_s: f64,
    /// `1 − downtime / (shards × makespan)` — the availability the
    /// `min_availability` SLO checks.
    pub availability: f64,
    /// Total fleet energy (batch energy + idle draw), joules.
    pub energy_j: f64,
    /// Total fleet cost ($) from per-class billing rates.
    pub cost: f64,
    /// Autoscaler decisions taken (0 without an autoscale policy).
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// Time-weighted mean size of the active routing set (equals the
    /// shard count without autoscaling).
    pub avg_active_shards: f64,
    /// Fleet class names, indexed by [`VirtualShardLoad::class`]
    /// (`["uniform"]` for homogeneous stages).
    pub classes: Vec<String>,
    /// Admitted requests per mix model, declaration order.
    pub per_model: Vec<(String, u64)>,
    /// Per-shard load/downtime/energy accounting from the virtual engine.
    pub per_shard: Vec<VirtualShardLoad>,
}

impl WorkloadOutcome {
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(vec!["scope", "summary"]).with_title(format!(
            "serve[virtual] {}: shards={} workers={} routing={} — {} offered, \
             {} admitted, {} rejected in {:.4}s virtual ({:.0} req/s) \
             p50={:.3}ms p95={:.3}ms p99={:.3}ms mean batch={:.2}",
            self.arrival,
            self.shards,
            self.workers,
            self.routing,
            self.offered,
            self.admitted,
            self.rejected,
            self.makespan_s,
            self.throughput_rps,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.mean_batch,
        ));
        if self.shed > 0 {
            t.row(vec![
                "admission".into(),
                format!("{} requests shed by the SLO deadline model", self.shed),
            ]);
        }
        if self.outages > 0 || self.failures > 0 {
            t.row(vec![
                "downtime".into(),
                format!(
                    "{} outage(s), {} failure(s), {:.4}s downtime, {:.2}% availability",
                    self.outages,
                    self.failures,
                    self.downtime_s,
                    100.0 * self.availability
                ),
            ]);
        }
        if self.energy_j > 0.0 || self.cost > 0.0 {
            t.row(vec![
                "fleet".into(),
                format!("{:.4} J total energy, ${:.6} billed", self.energy_j, self.cost),
            ]);
        }
        if self.scale_ups > 0 || self.scale_downs > 0 {
            t.row(vec![
                "autoscale".into(),
                format!(
                    "{} up / {} down, {:.2} mean active shards",
                    self.scale_ups, self.scale_downs, self.avg_active_shards
                ),
            ]);
        }
        for s in &self.per_shard {
            let class = self
                .classes
                .get(s.class)
                .map(String::as_str)
                .unwrap_or("uniform");
            t.row(vec![
                format!("shard {}", s.shard),
                format!(
                    "[{class}] {} req, {:.1}% worker occupancy",
                    s.requests,
                    100.0 * s.utilization
                ),
            ]);
        }
        for (model, n) in &self.per_model {
            t.row(vec![format!("model {model}"), format!("{n} req")]);
        }
        t
    }

    pub fn to_tables(&self) -> Vec<Table> {
        vec![self.to_table()]
    }

    pub fn json(&self) -> JsonValue {
        obj(vec![
            ("command", JsonValue::Str("serve".into())),
            ("engine", JsonValue::Str("virtual".into())),
            (
                "mix",
                JsonValue::Arr(
                    self.mix
                        .iter()
                        .map(|(m, w)| {
                            obj(vec![
                                ("model", JsonValue::Str(m.clone())),
                                ("weight", JsonValue::Num(*w)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("arrival_kind", JsonValue::Str(self.arrival_kind.clone())),
            ("arrival", JsonValue::Str(self.arrival.clone())),
            ("shards", JsonValue::Num(self.shards as f64)),
            ("workers", JsonValue::Num(self.workers as f64)),
            ("max_batch", JsonValue::Num(self.max_batch as f64)),
            ("max_wait_ms", JsonValue::Num(self.max_wait_ms)),
            ("queue_depth", JsonValue::Num(self.queue_depth as f64)),
            ("routing", JsonValue::Str(self.routing.clone())),
            ("offered", JsonValue::Num(self.offered as f64)),
            ("admitted", JsonValue::Num(self.admitted as f64)),
            ("rejected", JsonValue::Num(self.rejected as f64)),
            ("shed", JsonValue::Num(self.shed as f64)),
            ("makespan_s", JsonValue::Num(self.makespan_s)),
            ("throughput_rps", JsonValue::Num(self.throughput_rps)),
            ("mean_ms", JsonValue::Num(self.mean_ms)),
            ("p50_ms", JsonValue::Num(self.p50_ms)),
            ("p95_ms", JsonValue::Num(self.p95_ms)),
            ("p99_ms", JsonValue::Num(self.p99_ms)),
            ("batches", JsonValue::Num(self.batches as f64)),
            ("mean_batch", JsonValue::Num(self.mean_batch)),
            ("outages", JsonValue::Num(self.outages as f64)),
            ("failures", JsonValue::Num(self.failures as f64)),
            ("downtime_s", JsonValue::Num(self.downtime_s)),
            ("availability", JsonValue::Num(self.availability)),
            ("energy_j", JsonValue::Num(self.energy_j)),
            ("cost", JsonValue::Num(self.cost)),
            ("scale_ups", JsonValue::Num(self.scale_ups as f64)),
            ("scale_downs", JsonValue::Num(self.scale_downs as f64)),
            ("avg_active_shards", JsonValue::Num(self.avg_active_shards)),
            ("classes", str_arr(&self.classes)),
            (
                "per_model",
                JsonValue::Obj(
                    self.per_model
                        .iter()
                        .map(|(m, n)| (m.clone(), JsonValue::Num(*n as f64)))
                        .collect(),
                ),
            ),
            (
                "per_shard",
                JsonValue::Arr(
                    self.per_shard
                        .iter()
                        .map(|s| {
                            let class = self
                                .classes
                                .get(s.class)
                                .map(String::as_str)
                                .unwrap_or("uniform");
                            obj(vec![
                                ("shard", JsonValue::Num(s.shard as f64)),
                                ("class", JsonValue::Str(class.into())),
                                ("requests", JsonValue::Num(s.requests as f64)),
                                ("busy_s", JsonValue::Num(s.busy_s)),
                                ("utilization", JsonValue::Num(s.utilization)),
                                ("outages", JsonValue::Num(s.outages as f64)),
                                ("failures", JsonValue::Num(s.failures as f64)),
                                ("downtime_s", JsonValue::Num(s.downtime_s)),
                                ("active_s", JsonValue::Num(s.active_s)),
                                ("energy_j", JsonValue::Num(s.energy_j)),
                                ("cost", JsonValue::Num(s.cost)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn to_json(&self) -> String {
        self.json().render()
    }
}

/// Outcome of a report stage: every paper exhibit, rendered. The tables
/// are held structurally so both the CLI path (`to_tables`) and the JSON
/// path can replay them.
#[derive(Debug, Clone)]
pub struct ReportOutcome {
    pub threads: usize,
    pub tables: Vec<Table>,
}

impl ReportOutcome {
    pub fn to_table(&self) -> Table {
        self.tables.first().cloned().unwrap_or_default()
    }

    pub fn to_tables(&self) -> Vec<Table> {
        self.tables.clone()
    }

    pub fn json(&self) -> JsonValue {
        obj(vec![
            ("command", JsonValue::Str("report".into())),
            ("threads", JsonValue::Num(self.threads as f64)),
            (
                "tables",
                JsonValue::Arr(
                    self.tables
                        .iter()
                        .map(|t| {
                            obj(vec![
                                (
                                    "title",
                                    t.title()
                                        .map(|s| JsonValue::Str(s.to_string()))
                                        .unwrap_or(JsonValue::Null),
                                ),
                                ("header", str_arr(t.header())),
                                (
                                    "rows",
                                    JsonValue::Arr(
                                        t.rows().iter().map(|r| str_arr(r)).collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn to_json(&self) -> String {
        self.json().render()
    }
}

/// Any Session outcome — lets callers hold/render results uniformly.
#[derive(Debug, Clone)]
pub enum Outcome {
    Sim(SimOutcome),
    Sweep(SweepOutcome),
    Compare(CompareOutcome),
    Serve(ServeOutcome),
    Workload(WorkloadOutcome),
    Report(ReportOutcome),
}

impl Outcome {
    /// Primary table.
    pub fn to_table(&self) -> Table {
        match self {
            Outcome::Sim(o) => o.to_table(),
            Outcome::Sweep(o) => o.to_table(),
            Outcome::Compare(o) => o.to_table(),
            Outcome::Serve(o) => o.to_table(),
            Outcome::Workload(o) => o.to_table(),
            Outcome::Report(o) => o.to_table(),
        }
    }

    /// Every table the outcome renders (compare yields two).
    pub fn to_tables(&self) -> Vec<Table> {
        match self {
            Outcome::Sim(o) => o.to_tables(),
            Outcome::Sweep(o) => o.to_tables(),
            Outcome::Compare(o) => o.to_tables(),
            Outcome::Serve(o) => o.to_tables(),
            Outcome::Workload(o) => o.to_tables(),
            Outcome::Report(o) => o.to_tables(),
        }
    }

    /// Machine-readable JSON document (structured form).
    pub fn json(&self) -> JsonValue {
        match self {
            Outcome::Sim(o) => o.json(),
            Outcome::Sweep(o) => o.json(),
            Outcome::Compare(o) => o.json(),
            Outcome::Serve(o) => o.json(),
            Outcome::Workload(o) => o.json(),
            Outcome::Report(o) => o.json(),
        }
    }

    /// Machine-readable JSON document.
    pub fn to_json(&self) -> String {
        self.json().render()
    }
}
