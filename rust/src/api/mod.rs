//! The unified PhotoGAN API: a [`Session`] facade plus builder-style
//! request types — the single front door for simulation, design-space
//! exploration, platform comparison, report generation, and serving.
//!
//! Every consumer (the five CLI subcommands, the benches, the examples,
//! the report generator) routes through a `Session`, which owns:
//!
//! - an assembled [`crate::arch::Accelerator`],
//! - a model registry (the 8-model zoo by default: paper Table 1 plus
//!   SRGAN, Pix2Pix, StyleGAN2, ProGAN),
//! - a **memoized mapping cache** keyed by `(model, batch, OptFlags)` so
//!   repeated requests — DSE sweeps, ablation grids, full report runs —
//!   map each workload exactly once.
//!
//! Failures are typed ([`ApiError`]) instead of `assert!`s or process
//! exits, and every outcome renders as both an ASCII table and JSON.
//!
//! # Example
//!
//! ```
//! use photogan::api::{Session, SimRequest};
//!
//! let session = Session::new()?;
//! let request = SimRequest::builder().model("dcgan").batch(4).build()?;
//! let outcome = session.simulate(&request)?;
//! assert_eq!(outcome.rows.len(), 1);
//! assert!(outcome.rows[0].gops > 0.0);
//! println!("{}", outcome.to_table().render());
//! // machine-readable rendering of the same outcome
//! let json = outcome.to_json();
//! assert!(json.contains("\"command\":\"simulate\""));
//! # Ok::<(), photogan::api::ApiError>(())
//! ```
//!
//! Unknown names, invalid configurations, and over-cap chips are typed
//! errors:
//!
//! ```
//! use photogan::api::{ApiError, Session, SimRequest};
//!
//! let session = Session::new()?;
//! let request = SimRequest::builder().model("nope").build()?;
//! assert!(matches!(
//!     session.simulate(&request),
//!     Err(ApiError::UnknownModel { .. })
//! ));
//! # Ok::<(), photogan::api::ApiError>(())
//! ```
//!
//! Serving runs the same facade over the multi-shard coordinator
//! ([`crate::coordinator`]): the default [`ServeBackend::Sim`] executes
//! batches at photonic-simulator timing through a [`SimExecutor`] (no
//! PJRT artifacts), while `--backend pjrt` swaps in the real AOT-HLO
//! engine. See [`serve`] for the request knobs (shards, routing policy,
//! bounded queue depth, pacing).
//!
//! On top of the request types sits the declarative [`scenario`] layer:
//! a JSON [`Scenario`] (traffic mixes, arrival processes, SLO targets,
//! stage lists) compiles via [`Session::plan`] into a [`Plan`] and
//! executes via [`Session::run`] into one [`ScenarioOutcome`] envelope
//! with per-stage SLO verdicts — `photogan run scenario.json`. The five
//! legacy subcommands are thin presets over the same path.

// The typed-error contract is enforced mechanically: no `unwrap`/`expect`
// may land in the API layer (test modules opt out locally).
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod error;
pub mod executor;
pub mod lint;
pub mod outcome;
pub mod request;
pub mod scenario;
pub mod serve;
pub mod session;

pub use error::{ApiError, ApiResult};
pub use executor::SimExecutor;
pub use lint::{Diagnostic, LintReport, Severity};
pub use outcome::{
    CompareOutcome, Outcome, PlatformSeries, ReportOutcome, ResourceRow, ServeOutcome,
    SimOutcome, SimRow, SweepOutcome, WorkloadOutcome,
};
pub use request::{
    default_threads, ModelSelect, SimRequest, SimRequestBuilder, SweepRequest,
    SweepRequestBuilder,
};
pub use scenario::{
    CalibrationSpec, CompareStage, DseStage, Plan, PlannedStage, ReportStage, Scenario,
    ScenarioOutcome, ServeEngine, ServeStage, SimStage, SloCheck, SloSpec, SloVerdict,
    StageOutcome, StageSpec,
};
pub use serve::{ServeBackend, ServeCore, ServeRequest, ServeRequestBuilder};
pub use session::Session;
