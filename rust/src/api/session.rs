//! The [`Session`] facade: one accelerator + one model registry + one
//! memoized mapping cache behind every consumer (CLI, benches, report
//! generation, DSE, serving).
//!
//! Layer mapping (including the sparse-dataflow census) is the expensive,
//! configuration-independent half of a simulation; the cache keys it by
//! `(model, batch, OptFlags)` so repeated requests — a DSE sweep, the
//! Fig. 12 ablation grid, a report run touching every exhibit, the
//! sim-serving executor's per-batch costing — map each workload exactly
//! once. `Session` is `Send + Sync`; the cache is behind a `Mutex` and
//! mappings are handed out as `Arc`s.
//!
//! Serving lives in [`super::serve`] (`Session::serve`, which takes an
//! `Arc<Session>` receiver so shard workers can keep using this cache),
//! and the sim-backed executor in [`super::executor`].

use super::error::ApiError;
use super::outcome::{CompareOutcome, PlatformSeries, SimOutcome, SimRow, SweepOutcome};
use super::request::{ModelSelect, SimRequest, SweepRequest};
use crate::arch::accelerator::Accelerator;
use crate::arch::config::ArchConfig;
use crate::baselines::platform::all_platforms;
use crate::dse::{explore_mapped, MappedModel};
use crate::models::{zoo, Model};
use crate::report::figures::PAPER_OPTIMUM;
use crate::sim::engine::simulate_mapped;
use crate::sim::mapper::{map_model, LayerJob};
use crate::sim::{OptFlags, SimReport};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, PoisonError};

/// Mapping-cache key: model name × batch × optimization flags. The
/// accelerator configuration is deliberately absent — mappings are
/// configuration-independent (see [`crate::sim::engine::simulate_mapped`]),
/// which is exactly what makes the cache reusable across a DSE sweep.
type MapKey = (String, usize, OptFlags);

/// The unified PhotoGAN API entry point.
pub struct Session {
    acc: Accelerator,
    models: Vec<Model>,
    cache: Mutex<HashMap<MapKey, Arc<Vec<LayerJob>>>>,
}

impl Session {
    /// Session on the paper's DSE-optimal chip `[16,2,11,3]` with the full
    /// extended zoo registered — the four Table 1 generators plus SRGAN,
    /// Pix2Pix, StyleGAN2, and ProGAN — so every consumer (simulate, DSE,
    /// compare, serve) runs the 8-model study.
    pub fn new() -> Result<Session, ApiError> {
        Session::with_config(ArchConfig::paper_optimum())
    }

    /// Session on an arbitrary configuration (structurally validated).
    pub fn with_config(cfg: ArchConfig) -> Result<Session, ApiError> {
        let acc = Accelerator::new(cfg).map_err(ApiError::from)?;
        Ok(Session {
            acc,
            models: zoo::extended_generators(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// The session's assembled chip.
    pub fn accelerator(&self) -> &Accelerator {
        &self.acc
    }

    /// Registered models, in registration order (paper Table 1 four
    /// first, then the extended zoo).
    pub fn models(&self) -> &[Model] {
        &self.models
    }

    /// Registered model names.
    pub fn model_names(&self) -> Vec<String> {
        self.models.iter().map(|m| m.name.clone()).collect()
    }

    /// Resolve a model by case-insensitive name.
    pub fn model(&self, name: &str) -> Result<&Model, ApiError> {
        self.models
            .iter()
            .find(|m| m.name.eq_ignore_ascii_case(name))
            .ok_or_else(|| ApiError::UnknownModel {
                name: name.to_string(),
                available: self.model_names(),
            })
    }

    /// Register (or replace, by case-insensitive name) a model. Stale
    /// cache entries for that name are evicted.
    pub fn register_model(&mut self, model: Model) {
        let name = model.name.clone();
        let mut guard = self.cache.lock().unwrap_or_else(PoisonError::into_inner);
        guard.retain(|(cached, _, _), _| !cached.eq_ignore_ascii_case(&name));
        drop(guard);
        match self.models.iter_mut().find(|m| m.name.eq_ignore_ascii_case(&name)) {
            Some(slot) => *slot = model,
            None => self.models.push(model),
        }
    }

    /// Number of memoized mappings (observability / tests).
    pub fn mapping_cache_entries(&self) -> usize {
        self.cache.lock().unwrap_or_else(PoisonError::into_inner).len()
    }

    /// The memoized layer mapping for `(model, batch, opts)`. Computes and
    /// caches on first use; mapping runs outside the cache lock so
    /// concurrent misses don't serialize (first writer wins).
    ///
    /// The cache key is the model *name*, so only models structurally
    /// equal to the registered one participate; a same-named modified
    /// clone is mapped fresh (uncached) rather than served stale jobs —
    /// register it via [`Session::register_model`] to cache it.
    ///
    /// The `overlap` bit is normalized out of the key: it selects the
    /// timing engine, never the mapping, so an analytical and an
    /// overlapped request for the same `(model, batch, sparse…)` share
    /// one cached mapping instead of doubling the work.
    pub fn mapped(&self, model: &Model, batch: usize, opts: OptFlags) -> Arc<Vec<LayerJob>> {
        if !self.models.iter().any(|m| m == model) {
            return Arc::new(map_model(model, batch, &opts));
        }
        let key: MapKey = (model.name.clone(), batch, opts.with_overlap(false));
        {
            let guard = self.cache.lock().unwrap_or_else(PoisonError::into_inner);
            if let Some(jobs) = guard.get(&key) {
                return Arc::clone(jobs);
            }
        }
        let jobs = Arc::new(map_model(model, batch, &opts));
        let mut guard = self.cache.lock().unwrap_or_else(PoisonError::into_inner);
        Arc::clone(guard.entry(key).or_insert(jobs))
    }

    /// Cached simulation of one model on the session accelerator —
    /// bit-identical to [`crate::sim::simulate`] (the mapping is memoized,
    /// the cost model is the same code).
    pub fn sim_report(&self, model: &Model, batch: usize, opts: OptFlags) -> SimReport {
        self.sim_report_on(&self.acc, model, batch, opts)
    }

    /// Cached simulation on an explicit accelerator (the mapping cache is
    /// still shared — mappings are configuration-independent).
    pub fn sim_report_on(
        &self,
        acc: &Accelerator,
        model: &Model,
        batch: usize,
        opts: OptFlags,
    ) -> SimReport {
        let jobs = self.mapped(model, batch, opts);
        simulate_mapped(&model.name, &jobs, acc, batch, opts)
    }

    /// Monte Carlo fidelity evaluation of one model: the cached mapping
    /// and timing report plus a noise envelope from
    /// [`crate::fidelity::evaluate`]. The timing path is untouched — with
    /// [`crate::fidelity::NoiseModel::ideal`] the latency/energy numbers
    /// are bit-identical to [`Session::sim_report`].
    pub fn fidelity_report(
        &self,
        model: &Model,
        batch: usize,
        opts: OptFlags,
        mc: &crate::fidelity::MonteCarlo,
    ) -> crate::fidelity::FidelityReport {
        let jobs = self.mapped(model, batch, opts);
        let report = self.sim_report(model, batch, opts);
        crate::fidelity::evaluate(mc, &jobs, &report)
    }

    /// Execute a [`SimRequest`].
    pub fn simulate(&self, req: &SimRequest) -> Result<SimOutcome, ApiError> {
        if req.batch == 0 {
            return Err(ApiError::InvalidBatch(0));
        }
        let models: Vec<&Model> = match &req.models {
            ModelSelect::All => self.models.iter().collect(),
            ModelSelect::Named(name) => vec![self.model(name)?],
            ModelSelect::Subset(names) => {
                let mut subset = Vec::with_capacity(names.len());
                for name in names {
                    subset.push(self.model(name)?);
                }
                subset
            }
        };
        let custom;
        let acc = match &req.config {
            Some(cfg) => {
                custom = Accelerator::new(cfg.clone()).map_err(ApiError::from)?;
                &custom
            }
            None => &self.acc,
        };
        if req.strict_power {
            acc.validate(req.opts.power_gated).map_err(ApiError::from)?;
        }
        let rows = models
            .into_iter()
            .map(|m| SimRow::from_report(&self.sim_report_on(acc, m, req.batch, req.opts)))
            .collect();
        Ok(SimOutcome {
            config: (acc.cfg.n, acc.cfg.k, acc.cfg.l, acc.cfg.m),
            batch: req.batch,
            opts: req.opts,
            rows,
        })
    }

    /// Execute a [`SweepRequest`] — the Fig. 11 design-space exploration,
    /// fed from the session mapping cache (each model maps once; every
    /// grid point re-costs the shared jobs).
    pub fn sweep(&self, req: &SweepRequest) -> Result<SweepOutcome, ApiError> {
        if req.grid.is_empty() {
            return Err(ApiError::EmptyGrid);
        }
        // malformed axis values (zeros) are a typed error here instead of
        // silently evaluating degenerate chips (or worse, panicking in a
        // downstream assert) — requests built field-by-field bypass the
        // builder, so the boundary re-checks
        req.grid.validate().map_err(|reason| ApiError::InvalidGrid { reason })?;
        if req.threads == 0 {
            return Err(ApiError::InvalidThreads(0));
        }
        let mapped: Vec<MappedModel> = self
            .models
            .iter()
            .map(|m| (m.name.clone(), self.mapped(m, 1, req.opts)))
            .collect();
        let points = explore_mapped(&req.grid, &mapped, req.opts, req.threads);
        Ok(SweepOutcome {
            grid_configs: req.grid.len(),
            threads: req.threads,
            opts: req.opts,
            points,
            paper_optimum: PAPER_OPTIMUM,
        })
    }

    /// PhotoGAN (on the session chip, all optimizations, batch 1) vs. the
    /// five analytic baseline platforms — the Figs. 13/14 data, widened to
    /// every registered model (the 8-model study by default). Uses the
    /// closed-form analytical engine (the paper's calibration window);
    /// [`Session::compare_opts`] with [`OptFlags::overlapped`] shows the
    /// event scheduler's throughput instead.
    pub fn compare(&self) -> CompareOutcome {
        self.compare_opts(OptFlags::all())
    }

    /// [`Session::compare`] under explicit optimization flags (e.g.
    /// `OptFlags::overlapped()` for `photogan compare --overlap`).
    pub fn compare_opts(&self, opts: OptFlags) -> CompareOutcome {
        let model_names = self.model_names();
        let mut series = Vec::new();
        let pg: Vec<SimReport> =
            self.models.iter().map(|m| self.sim_report(m, 1, opts)).collect();
        series.push(PlatformSeries {
            platform: "PhotoGAN".to_string(),
            gops: pg.iter().map(|r| r.gops()).collect(),
            epb: pg.iter().map(|r| r.epb()).collect(),
        });
        for p in all_platforms() {
            let rs: Vec<_> = self.models.iter().map(|m| p.evaluate(m, 1)).collect();
            series.push(PlatformSeries {
                platform: p.name.to_string(),
                gops: rs.iter().map(|r| r.gops()).collect(),
                epb: rs.iter().map(|r| r.epb()).collect(),
            });
        }
        CompareOutcome { model_names, series }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::sim::simulate;

    #[test]
    fn cache_hits_reuse_mappings() {
        let s = Session::new().unwrap();
        let m = s.model("dcgan").unwrap().clone();
        assert_eq!(s.mapping_cache_entries(), 0);
        let a = s.mapped(&m, 1, OptFlags::all());
        assert_eq!(s.mapping_cache_entries(), 1);
        let b = s.mapped(&m, 1, OptFlags::all());
        assert!(Arc::ptr_eq(&a, &b), "second lookup must be a cache hit");
        // different batch / opts are distinct entries
        s.mapped(&m, 2, OptFlags::all());
        s.mapped(&m, 1, OptFlags::baseline());
        assert_eq!(s.mapping_cache_entries(), 3);
        // the overlap bit selects the timing engine, not the mapping:
        // overlapped requests share the analytical entry
        let o = s.mapped(&m, 1, OptFlags::overlapped());
        assert!(Arc::ptr_eq(&a, &o), "overlap must reuse the analytical mapping");
        assert_eq!(s.mapping_cache_entries(), 3);
    }

    #[test]
    fn cached_simulation_is_bit_identical() {
        let s = Session::new().unwrap();
        for name in ["DCGAN", "CondGAN"] {
            let m = s.model(name).unwrap().clone();
            for (batch, opts) in [(1, OptFlags::all()), (4, OptFlags::baseline())] {
                let direct = simulate(&m, s.accelerator(), batch, opts);
                let cached = s.sim_report(&m, batch, opts);
                let again = s.sim_report(&m, batch, opts);
                assert_eq!(direct.latency, cached.latency, "{name} latency");
                assert_eq!(direct.energy.total(), cached.energy.total(), "{name} energy");
                assert_eq!(direct.gops(), cached.gops(), "{name} gops");
                assert_eq!(cached.latency, again.latency, "{name} repeat");
            }
        }
    }

    #[test]
    fn unknown_model_is_typed() {
        let s = Session::new().unwrap();
        let err = s.model("stylegan9").unwrap_err();
        assert!(matches!(err, ApiError::UnknownModel { ref name, .. } if name == "stylegan9"));
    }

    #[test]
    fn model_lookup_is_case_insensitive() {
        let s = Session::new().unwrap();
        assert_eq!(s.model("cycleGAN").unwrap().name, "CycleGAN");
    }

    #[test]
    fn register_model_evicts_stale_mappings() {
        let mut s = Session::new().unwrap();
        let m = s.model("dcgan").unwrap().clone();
        s.mapped(&m, 1, OptFlags::all());
        let n_models = s.models().len();
        assert_eq!(s.mapping_cache_entries(), 1);
        s.register_model(m.clone());
        assert_eq!(s.mapping_cache_entries(), 0, "re-registration must evict");
        assert_eq!(s.models().len(), n_models, "replacement, not append");
    }

    #[test]
    fn modified_clone_is_never_served_stale_cache() {
        let s = Session::new().unwrap();
        let m = s.model("dcgan").unwrap().clone();
        let cached = s.sim_report(&m, 1, OptFlags::all());
        assert_eq!(s.mapping_cache_entries(), 1);
        // a same-named but structurally different model maps fresh (uncached)
        let mut trimmed = m.layers().to_vec();
        trimmed.truncate(2);
        let modified = Model::new(&m.name, m.input().clone(), trimmed);
        let fresh = s.sim_report(&modified, 1, OptFlags::all());
        assert_eq!(s.mapping_cache_entries(), 1, "foreign model must not touch the cache");
        assert!(
            fresh.energy.total() < cached.energy.total(),
            "a 2-layer prefix must cost less than the full model"
        );
    }

    #[test]
    fn compare_opts_overlapped_raises_gops_and_keeps_epb() {
        let s = Session::new().unwrap();
        let analytic = s.compare();
        let overlapped = s.compare_opts(OptFlags::overlapped());
        let (a, o) = (&analytic.series[0], &overlapped.series[0]);
        assert_eq!(a.gops.len(), o.gops.len());
        for i in 0..a.gops.len() {
            assert!(o.gops[i] > a.gops[i], "overlap must raise PhotoGAN GOPS");
            assert!(
                (o.epb[i] - a.epb[i]).abs() <= 1e-9 * a.epb[i],
                "EPB (pure energy) must be unchanged"
            );
        }
    }

    #[test]
    fn strict_power_trips_the_cap() {
        // a 0.5 W cap no real chip can meet → PowerCapExceeded
        let mut cfg = ArchConfig::paper_optimum();
        cfg.params.system.power_cap_w = 0.5;
        let s = Session::with_config(cfg).unwrap();
        let req = SimRequest::builder().model("dcgan").strict_power(true).build().unwrap();
        assert!(matches!(
            s.simulate(&req).unwrap_err(),
            ApiError::PowerCapExceeded { cap_w, .. } if cap_w == 0.5
        ));
        // without strict_power the same request simulates fine
        let relaxed = SimRequest::builder().model("dcgan").build().unwrap();
        assert!(s.simulate(&relaxed).is_ok());
    }
}
