//! Typed errors for the [`crate::api`] layer.
//!
//! Every failure mode a [`crate::api::Session`] request can hit is a named
//! variant — no `panic!`/`assert!`/`process::exit` and no stringly-typed
//! `anyhow` chains. The CLI maps these onto exit codes; library callers
//! match on them.

use crate::arch::config::ConfigError;
use crate::coordinator::server::SubmitError;
use crate::util::cli::CliError;
use std::fmt;

/// Result alias for the API layer.
pub type ApiResult<T> = Result<T, ApiError>;

/// Typed API error.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiError {
    /// The requested model is not in the session's registry (or, for
    /// serving, not among the loaded artifacts).
    UnknownModel { name: String, available: Vec<String> },
    /// The architectural configuration is structurally invalid
    /// (degenerate, over the crosstalk bound, bad `N,K,L,M` string…).
    InvalidConfig(ConfigError),
    /// The configuration's peak operational power exceeds the system cap
    /// (only checked when a request opts into strict power validation —
    /// the paper's Fig. 12 baselines intentionally run ungated).
    PowerCapExceeded { peak_w: f64, cap_w: f64 },
    /// Batch size must be ≥ 1.
    InvalidBatch(usize),
    /// A sweep grid with zero configurations.
    EmptyGrid,
    /// A sweep grid with malformed axis values (e.g. a zero dimension) —
    /// previously these either panicked in downstream asserts or were
    /// silently dropped during evaluation.
    InvalidGrid { reason: String },
    /// Thread count must be ≥ 1.
    InvalidThreads(usize),
    /// Serving worker count must be ≥ 1.
    InvalidWorkers(usize),
    /// Serving shard count must be ≥ 1.
    InvalidShards(usize),
    /// Sim-serving time scale must be finite and ≥ 0.
    InvalidTimeScale(f64),
    /// A serving submission was rejected because the routed shard's
    /// bounded queue is full and nothing was in flight to drain —
    /// typed backpressure instead of unbounded queuing.
    Backpressure { shard: usize, outstanding: usize, limit: usize },
    /// A serving submission was refused by SLO-aware admission control:
    /// the routed shard's predicted queueing delay exceeds the request
    /// deadline (async core only — see
    /// [`crate::api::ServeRequestBuilder::deadline`]).
    Shed { shard: usize, predicted_ms: u64, deadline_ms: u64 },
    /// A scenario file could not be read (the `photogan run` front door).
    ScenarioIo { path: String, reason: String },
    /// A scenario document is structurally malformed: bad JSON, a missing
    /// or mistyped member, an unknown stage kind / routing policy / opts
    /// preset… `field` is the JSON path of the offending member (e.g.
    /// `stages[2].routing`); `$` means the document root.
    ScenarioParse { field: String, reason: String },
    /// A traffic-mix entry with a non-positive (or non-finite) weight.
    /// `field` names the offending member (e.g. `stages[1].mix[0].weight`).
    InvalidMixWeight { field: String, model: String, weight: f64 },
    /// An arrival rate that is non-finite or non-positive (NaN included).
    InvalidRate { field: String, rate: f64 },
    /// A fleet group names a service platform the baselines layer does not
    /// know. `field` is the JSON path of the offending member (e.g.
    /// `stages[0].fleet[1].platform`).
    UnknownPlatform { field: String, name: String },
    /// A duration/window that is non-finite or non-positive (zero-duration
    /// stages can generate no traffic).
    InvalidDuration { field: String, seconds: f64 },
    /// A referenced model failed plan-time static analysis: its layer list
    /// does not lift to a verifiable dataflow IR
    /// ([`crate::models::ir::Graph::verify`]). `reason` is the typed
    /// [`crate::models::ir::IrError`] rendered (it names the op position).
    InvalidModel { model: String, reason: String },
    /// `photogan lint` found error-severity diagnostics; `errors` is how
    /// many (the diagnostics themselves were already reported).
    LintFailed { errors: usize },
    /// A command-line flag failed to parse (carried into the API layer so
    /// the CLI has a single error channel). An empty `flag` means the
    /// error is not attributable to one flag (e.g. a stray positional).
    InvalidFlag { flag: String, reason: String },
    /// Loading or compiling the PJRT artifacts failed.
    ArtifactError(String),
    /// Serving infrastructure failure (worker/channel death).
    Internal(String),
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ApiError::UnknownModel { name, available } => {
                write!(f, "unknown model '{name}' (available: {})", available.join(", "))
            }
            ApiError::InvalidConfig(e) => write!(f, "invalid config: {e}"),
            ApiError::PowerCapExceeded { peak_w, cap_w } => {
                write!(f, "peak power {peak_w:.1} W exceeds the {cap_w:.1} W cap")
            }
            ApiError::InvalidBatch(b) => write!(f, "batch must be ≥ 1 (got {b})"),
            ApiError::EmptyGrid => write!(f, "sweep grid contains no configurations"),
            ApiError::InvalidGrid { reason } => write!(f, "invalid sweep grid: {reason}"),
            ApiError::InvalidThreads(t) => write!(f, "threads must be ≥ 1 (got {t})"),
            ApiError::InvalidWorkers(w) => write!(f, "workers must be ≥ 1 (got {w})"),
            ApiError::InvalidShards(s) => write!(f, "shards must be ≥ 1 (got {s})"),
            ApiError::InvalidTimeScale(t) => {
                write!(f, "time scale must be finite and ≥ 0 (got {t})")
            }
            ApiError::Backpressure { shard, outstanding, limit } => {
                write!(
                    f,
                    "backpressure: shard {shard} queue is full \
                     ({outstanding}/{limit} samples outstanding)"
                )
            }
            ApiError::Shed { shard, predicted_ms, deadline_ms } => {
                write!(
                    f,
                    "shed: shard {shard} predicted {predicted_ms}ms queueing delay \
                     against a {deadline_ms}ms deadline"
                )
            }
            ApiError::ScenarioIo { path, reason } => {
                write!(f, "cannot read scenario '{path}': {reason}")
            }
            ApiError::ScenarioParse { field, reason } => {
                write!(f, "scenario field '{field}': {reason}")
            }
            ApiError::InvalidMixWeight { field, model, weight } => {
                write!(
                    f,
                    "scenario field '{field}': mix weight for '{model}' must be finite \
                     and > 0 (got {weight})"
                )
            }
            ApiError::InvalidRate { field, rate } => {
                write!(
                    f,
                    "scenario field '{field}': rate must be finite and > 0 (got {rate})"
                )
            }
            ApiError::UnknownPlatform { field, name } => {
                write!(
                    f,
                    "scenario field '{field}': unknown platform '{name}' \
                     (expected photonic, gpu, cpu, tpu, fpga, reram, or a full \
                     platform name)"
                )
            }
            ApiError::InvalidDuration { field, seconds } => {
                write!(
                    f,
                    "scenario field '{field}': duration must be finite and > 0 \
                     (got {seconds})"
                )
            }
            ApiError::InvalidModel { model, reason } => {
                write!(f, "model '{model}' failed static analysis: {reason}")
            }
            ApiError::LintFailed { errors } => {
                write!(f, "lint found {errors} error(s)")
            }
            ApiError::InvalidFlag { flag, reason } if flag.is_empty() => {
                write!(f, "invalid arguments: {reason}")
            }
            ApiError::InvalidFlag { flag, reason } => write!(f, "flag '--{flag}': {reason}"),
            ApiError::ArtifactError(msg) => write!(f, "artifact error: {msg}"),
            ApiError::Internal(msg) => write!(f, "internal serving error: {msg}"),
        }
    }
}

impl std::error::Error for ApiError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ApiError::InvalidConfig(e) => Some(e),
            _ => None,
        }
    }
}

impl From<ConfigError> for ApiError {
    /// Configuration errors map onto the API's vocabulary; the power-cap
    /// case gets its own first-class variant.
    fn from(e: ConfigError) -> Self {
        match e {
            ConfigError::PowerCap(peak, cap) => {
                ApiError::PowerCapExceeded { peak_w: peak, cap_w: cap }
            }
            other => ApiError::InvalidConfig(other),
        }
    }
}

impl From<CliError> for ApiError {
    fn from(e: CliError) -> Self {
        let flag = match &e {
            CliError::UnknownFlag { flag }
            | CliError::MissingValue { flag }
            | CliError::UnexpectedValue { flag, .. }
            | CliError::InvalidValue { flag, .. }
            | CliError::DuplicateFlag { flag } => flag.clone(),
            CliError::StrayToken { .. } => String::new(),
        };
        ApiError::InvalidFlag { flag, reason: e.to_string() }
    }
}

impl From<SubmitError> for ApiError {
    /// Coordinator submission failures map onto the API vocabulary:
    /// rejection by a full shard queue is first-class backpressure.
    fn from(e: SubmitError) -> Self {
        match e {
            SubmitError::UnknownModel { name, available } => {
                ApiError::UnknownModel { name, available }
            }
            SubmitError::QueueFull { shard, outstanding, limit } => {
                ApiError::Backpressure { shard, outstanding, limit }
            }
            SubmitError::Shed { shard, predicted_ms, deadline_ms, .. } => {
                ApiError::Shed { shard, predicted_ms, deadline_ms }
            }
            SubmitError::Shutdown => {
                ApiError::Internal("serving coordinator is shut down".into())
            }
        }
    }
}

impl ApiError {
    /// Process exit code for the CLI: `2` for usage/validation errors,
    /// `1` for runtime failures — matching the pre-Session `main.rs`
    /// conventions.
    pub fn exit_code(&self) -> i32 {
        match self {
            ApiError::ArtifactError(_)
            | ApiError::Internal(_)
            | ApiError::Backpressure { .. }
            | ApiError::Shed { .. }
            | ApiError::ScenarioIo { .. } => 1,
            _ => 2,
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn every_variant_renders() {
        let variants = [
            ApiError::UnknownModel { name: "gan5".into(), available: vec!["DCGAN".into()] },
            ApiError::InvalidConfig(ConfigError::TooManyWavelengths(40, 36)),
            ApiError::PowerCapExceeded { peak_w: 120.0, cap_w: 100.0 },
            ApiError::InvalidBatch(0),
            ApiError::EmptyGrid,
            ApiError::InvalidGrid { reason: "axis n contains 0".into() },
            ApiError::InvalidThreads(0),
            ApiError::InvalidWorkers(0),
            ApiError::InvalidShards(0),
            ApiError::InvalidTimeScale(-1.0),
            ApiError::Backpressure { shard: 2, outstanding: 64, limit: 64 },
            ApiError::Shed { shard: 1, predicted_ms: 40, deadline_ms: 25 },
            ApiError::ScenarioIo { path: "x.json".into(), reason: "no such file".into() },
            ApiError::ScenarioParse { field: "stages[0].kind".into(), reason: "bad".into() },
            ApiError::InvalidMixWeight {
                field: "stages[1].mix[0].weight".into(),
                model: "dcgan".into(),
                weight: -1.0,
            },
            ApiError::InvalidRate { field: "stages[1].arrival.rate_hz".into(), rate: f64::NAN },
            ApiError::UnknownPlatform {
                field: "stages[0].fleet[1].platform".into(),
                name: "quantum".into(),
            },
            ApiError::InvalidDuration {
                field: "stages[1].arrival.duration_s".into(),
                seconds: 0.0,
            },
            ApiError::InvalidModel { model: "bad".into(), reason: "op 3: cycle".into() },
            ApiError::LintFailed { errors: 2 },
            ApiError::InvalidFlag { flag: "batch".into(), reason: "missing value".into() },
            ApiError::InvalidFlag { flag: String::new(), reason: "stray 'x'".into() },
            ApiError::ArtifactError("no artifacts".into()),
            ApiError::Internal("worker died".into()),
        ];
        for v in &variants {
            assert!(!v.to_string().is_empty(), "{v:?}");
        }
    }

    #[test]
    fn power_cap_config_error_promotes() {
        let e: ApiError = ConfigError::PowerCap(150.0, 100.0).into();
        assert_eq!(e, ApiError::PowerCapExceeded { peak_w: 150.0, cap_w: 100.0 });
        let e: ApiError = ConfigError::Degenerate { n: 0, k: 1, l: 1, m: 1 }.into();
        assert!(matches!(e, ApiError::InvalidConfig(_)));
    }

    #[test]
    fn exit_codes_split_usage_vs_runtime() {
        assert_eq!(ApiError::EmptyGrid.exit_code(), 2);
        assert_eq!(ApiError::InvalidBatch(0).exit_code(), 2);
        assert_eq!(ApiError::ArtifactError("x".into()).exit_code(), 1);
        assert_eq!(ApiError::Internal("x".into()).exit_code(), 1);
        // a malformed scenario is a usage error; an unreadable file is not
        assert_eq!(
            ApiError::ScenarioParse { field: "$".into(), reason: "x".into() }.exit_code(),
            2
        );
        assert_eq!(
            ApiError::InvalidRate { field: "f".into(), rate: 0.0 }.exit_code(),
            2
        );
        assert_eq!(
            ApiError::ScenarioIo { path: "x".into(), reason: "gone".into() }.exit_code(),
            1
        );
        // static-analysis rejections are usage errors
        assert_eq!(
            ApiError::InvalidModel { model: "m".into(), reason: "r".into() }.exit_code(),
            2
        );
        assert_eq!(ApiError::LintFailed { errors: 1 }.exit_code(), 2);
    }

    #[test]
    fn submit_errors_convert_with_backpressure_first_class() {
        let e: ApiError = SubmitError::QueueFull { shard: 1, outstanding: 8, limit: 8 }.into();
        assert_eq!(e, ApiError::Backpressure { shard: 1, outstanding: 8, limit: 8 });
        assert_eq!(e.exit_code(), 1, "overload is a runtime condition, not a usage error");
        let e: ApiError = SubmitError::UnknownModel {
            name: "gan5".into(),
            available: vec!["DCGAN".into()],
        }
        .into();
        assert!(matches!(e, ApiError::UnknownModel { ref name, .. } if name == "gan5"));
        let e: ApiError = SubmitError::Shutdown.into();
        assert!(matches!(e, ApiError::Internal(_)));
        let e: ApiError = SubmitError::Shed {
            shard: 3,
            outstanding: 17,
            predicted_ms: 40,
            deadline_ms: 25,
        }
        .into();
        assert_eq!(e, ApiError::Shed { shard: 3, predicted_ms: 40, deadline_ms: 25 });
        assert_eq!(e.exit_code(), 1, "a shed is a runtime overload signal, like backpressure");
    }

    #[test]
    fn cli_errors_convert() {
        let e: ApiError = CliError::MissingValue { flag: "batch".into() }.into();
        assert!(matches!(e, ApiError::InvalidFlag { ref flag, .. } if flag == "batch"));
    }

    #[test]
    fn stray_token_renders_without_flag_prefix() {
        let e: ApiError = CliError::StrayToken { token: "junk".into() }.into();
        assert_eq!(
            e.to_string(),
            "invalid arguments: unexpected argument 'junk' (flags start with '--')"
        );
    }
}
