//! Typed errors for the [`crate::api`] layer.
//!
//! Every failure mode a [`crate::api::Session`] request can hit is a named
//! variant — no `panic!`/`assert!`/`process::exit` and no stringly-typed
//! `anyhow` chains. The CLI maps these onto exit codes; library callers
//! match on them.

use crate::arch::config::ConfigError;
use crate::util::cli::CliError;
use std::fmt;

/// Result alias for the API layer.
pub type ApiResult<T> = Result<T, ApiError>;

/// Typed API error.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiError {
    /// The requested model is not in the session's registry (or, for
    /// serving, not among the loaded artifacts).
    UnknownModel { name: String, available: Vec<String> },
    /// The architectural configuration is structurally invalid
    /// (degenerate, over the crosstalk bound, bad `N,K,L,M` string…).
    InvalidConfig(ConfigError),
    /// The configuration's peak operational power exceeds the system cap
    /// (only checked when a request opts into strict power validation —
    /// the paper's Fig. 12 baselines intentionally run ungated).
    PowerCapExceeded { peak_w: f64, cap_w: f64 },
    /// Batch size must be ≥ 1.
    InvalidBatch(usize),
    /// A sweep grid with zero configurations.
    EmptyGrid,
    /// Thread count must be ≥ 1.
    InvalidThreads(usize),
    /// Serving worker count must be ≥ 1.
    InvalidWorkers(usize),
    /// A command-line flag failed to parse (carried into the API layer so
    /// the CLI has a single error channel). An empty `flag` means the
    /// error is not attributable to one flag (e.g. a stray positional).
    InvalidFlag { flag: String, reason: String },
    /// Loading or compiling the PJRT artifacts failed.
    ArtifactError(String),
    /// Serving infrastructure failure (worker/channel death).
    Internal(String),
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ApiError::UnknownModel { name, available } => {
                write!(f, "unknown model '{name}' (available: {})", available.join(", "))
            }
            ApiError::InvalidConfig(e) => write!(f, "invalid config: {e}"),
            ApiError::PowerCapExceeded { peak_w, cap_w } => {
                write!(f, "peak power {peak_w:.1} W exceeds the {cap_w:.1} W cap")
            }
            ApiError::InvalidBatch(b) => write!(f, "batch must be ≥ 1 (got {b})"),
            ApiError::EmptyGrid => write!(f, "sweep grid contains no configurations"),
            ApiError::InvalidThreads(t) => write!(f, "threads must be ≥ 1 (got {t})"),
            ApiError::InvalidWorkers(w) => write!(f, "workers must be ≥ 1 (got {w})"),
            ApiError::InvalidFlag { flag, reason } if flag.is_empty() => {
                write!(f, "invalid arguments: {reason}")
            }
            ApiError::InvalidFlag { flag, reason } => write!(f, "flag '--{flag}': {reason}"),
            ApiError::ArtifactError(msg) => write!(f, "artifact error: {msg}"),
            ApiError::Internal(msg) => write!(f, "internal serving error: {msg}"),
        }
    }
}

impl std::error::Error for ApiError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ApiError::InvalidConfig(e) => Some(e),
            _ => None,
        }
    }
}

impl From<ConfigError> for ApiError {
    /// Configuration errors map onto the API's vocabulary; the power-cap
    /// case gets its own first-class variant.
    fn from(e: ConfigError) -> Self {
        match e {
            ConfigError::PowerCap(peak, cap) => {
                ApiError::PowerCapExceeded { peak_w: peak, cap_w: cap }
            }
            other => ApiError::InvalidConfig(other),
        }
    }
}

impl From<CliError> for ApiError {
    fn from(e: CliError) -> Self {
        let flag = match &e {
            CliError::UnknownFlag { flag }
            | CliError::MissingValue { flag }
            | CliError::UnexpectedValue { flag, .. }
            | CliError::InvalidValue { flag, .. }
            | CliError::DuplicateFlag { flag } => flag.clone(),
            CliError::StrayToken { .. } => String::new(),
        };
        ApiError::InvalidFlag { flag, reason: e.to_string() }
    }
}

impl ApiError {
    /// Process exit code for the CLI: `2` for usage/validation errors,
    /// `1` for runtime failures — matching the pre-Session `main.rs`
    /// conventions.
    pub fn exit_code(&self) -> i32 {
        match self {
            ApiError::ArtifactError(_) | ApiError::Internal(_) => 1,
            _ => 2,
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn every_variant_renders() {
        let variants = [
            ApiError::UnknownModel { name: "gan5".into(), available: vec!["DCGAN".into()] },
            ApiError::InvalidConfig(ConfigError::TooManyWavelengths(40, 36)),
            ApiError::PowerCapExceeded { peak_w: 120.0, cap_w: 100.0 },
            ApiError::InvalidBatch(0),
            ApiError::EmptyGrid,
            ApiError::InvalidThreads(0),
            ApiError::InvalidWorkers(0),
            ApiError::InvalidFlag { flag: "batch".into(), reason: "missing value".into() },
            ApiError::InvalidFlag { flag: String::new(), reason: "stray 'x'".into() },
            ApiError::ArtifactError("no artifacts".into()),
            ApiError::Internal("worker died".into()),
        ];
        for v in &variants {
            assert!(!v.to_string().is_empty(), "{v:?}");
        }
    }

    #[test]
    fn power_cap_config_error_promotes() {
        let e: ApiError = ConfigError::PowerCap(150.0, 100.0).into();
        assert_eq!(e, ApiError::PowerCapExceeded { peak_w: 150.0, cap_w: 100.0 });
        let e: ApiError = ConfigError::Degenerate { n: 0, k: 1, l: 1, m: 1 }.into();
        assert!(matches!(e, ApiError::InvalidConfig(_)));
    }

    #[test]
    fn exit_codes_split_usage_vs_runtime() {
        assert_eq!(ApiError::EmptyGrid.exit_code(), 2);
        assert_eq!(ApiError::InvalidBatch(0).exit_code(), 2);
        assert_eq!(ApiError::ArtifactError("x".into()).exit_code(), 1);
        assert_eq!(ApiError::Internal("x".into()).exit_code(), 1);
    }

    #[test]
    fn cli_errors_convert() {
        let e: ApiError = CliError::MissingValue { flag: "batch".into() }.into();
        assert!(matches!(e, ApiError::InvalidFlag { ref flag, .. } if flag == "batch"));
    }

    #[test]
    fn stray_token_renders_without_flag_prefix() {
        let e: ApiError = CliError::StrayToken { token: "junk".into() }.into();
        assert_eq!(
            e.to_string(),
            "invalid arguments: unexpected argument 'junk' (flags start with '--')"
        );
    }
}
