//! Baseline accelerator models: A100 GPU, Xeon CPU, TPU v2, FlexiGAN
//! (FPGA, [13]) and ReGAN (ReRAM PIM, [15]) — the five comparison
//! platforms of paper Figs. 13/14.
//!
//! These are *calibrated analytic comparators* (DESIGN.md §2/§7): each
//! platform is a per-layer-kind effective-throughput model plus an
//! effective inference power. The **structure** (which layer kinds a
//! platform is bad at — e.g. systolic arrays on zero-inserted transposed
//! convs, GPUs on batch-1 dense layers, FlexiGAN's tconv-friendly
//! reordering, ReGAN's in-memory MVMs) is taken from the platforms'
//! published characteristics; the **absolute scale** is calibrated once,
//! globally, against the paper's reported average GOPS/EPB ratios, so that
//! per-model spread emerges from layer mixes rather than per-model fudging.
//! The implied platform powers are derived from the paper's EPB and GOPS
//! numbers together and are NOT independently physical — a known
//! inconsistency of the source paper recorded in EXPERIMENTS.md.

pub mod platform;

pub use platform::{all_platforms, platform_named, LayerClass, Platform, PlatformReport};
