//! Per-layer-kind analytic platform model.

use crate::models::layer::Layer;
use crate::models::Model;

/// Coarse layer classification driving platform efficiency.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerClass {
    Dense,
    Conv,
    TConv,
    Elementwise,
}

impl LayerClass {
    pub fn of(layer: &Layer) -> LayerClass {
        match layer {
            Layer::Dense { .. } => LayerClass::Dense,
            Layer::Conv2d { .. } => LayerClass::Conv,
            Layer::ConvT2d { .. } => LayerClass::TConv,
            // norm/act/residual — and the zero-MAC data movers (upsample,
            // pixel shuffle, concat), which `evaluate` skips anyway
            _ => LayerClass::Elementwise,
        }
    }
}

/// An analytic comparison platform.
#[derive(Debug, Clone)]
pub struct Platform {
    pub name: &'static str,
    /// Achieved GOPS on plain convolution layers (the anchor).
    pub conv_gops: f64,
    /// Relative efficiency of other layer classes vs convolution.
    pub rel_dense: f64,
    pub rel_tconv: f64,
    pub rel_elementwise: f64,
    /// Effective power draw during inference (W) — calibrated jointly with
    /// the GOPS scale against the paper's EPB ratios (see module docs).
    pub power_w: f64,
    /// Fixed per-inference overhead (s): kernel-launch / reconfiguration /
    /// NVM access setup. Penalizes small models (CondGAN/ArtGAN) exactly
    /// where the platforms' published weaknesses are.
    pub overhead_s: f64,
}

/// Evaluation result of one model on one platform.
#[derive(Debug, Clone)]
pub struct PlatformReport {
    pub platform: &'static str,
    pub model: String,
    pub latency: f64,
    pub energy: f64,
    pub total_ops: f64,
    pub total_bits: f64,
}

impl PlatformReport {
    pub fn gops(&self) -> f64 {
        self.total_ops / self.latency / 1e9
    }

    pub fn epb(&self) -> f64 {
        self.energy / self.total_bits
    }
}

impl Platform {
    fn class_gops(&self, class: LayerClass) -> f64 {
        let rel = match class {
            LayerClass::Conv => 1.0,
            LayerClass::Dense => self.rel_dense,
            LayerClass::TConv => self.rel_tconv,
            LayerClass::Elementwise => self.rel_elementwise,
        };
        self.conv_gops * rel
    }

    /// Evaluate a model at the given batch size (ops scale linearly; the
    /// fixed overhead is charged once per batch — exactly how launch
    /// overhead amortizes on real platforms).
    pub fn evaluate(&self, model: &Model, batch: usize) -> PlatformReport {
        let infos = model.infos().expect("valid model");
        let mut latency = self.overhead_s;
        let mut total_ops = 0f64;
        for info in infos {
            let ops = 2.0 * info.macs as f64 * batch as f64;
            if ops == 0.0 {
                continue;
            }
            total_ops += ops;
            latency += ops / (self.class_gops(LayerClass::of(&info.layer)) * 1e9);
        }
        let energy = self.power_w * latency;
        PlatformReport {
            platform: self.name,
            model: model.name.clone(),
            latency,
            energy,
            total_ops,
            total_bits: total_ops * 8.0,
        }
    }
}

/// The five comparison platforms of Figs. 13/14.
///
/// Relative layer-class efficiencies reflect the platforms' published
/// behavior; `conv_gops` / `power_w` are the calibrated global scales
/// (see module docs and `calibration` test below).
pub fn all_platforms() -> Vec<Platform> {
    vec![
        Platform {
            // A100: massive peak, but batch-1 GAN inference is launch- and
            // memory-bound; zero-inserted transposed convs waste ~s² work.
            name: "GPU (A100)",
            conv_gops: 11.94,
            rel_dense: 0.15,
            rel_tconv: 0.28,
            rel_elementwise: 0.50,
            power_w: 2.42,
            overhead_s: 40e-6,
        },
        Platform {
            // Xeon: low throughput, no massive launch overhead, but high
            // energy per op.
            name: "CPU (Xeon)",
            conv_gops: 3.56,
            rel_dense: 0.55,
            rel_tconv: 0.50,
            rel_elementwise: 0.70,
            power_w: 0.145,
            overhead_s: 5e-6,
        },
        Platform {
            // TPU v2: systolic array great at dense convs, terrible at
            // zero-inserted tconvs (structural zeros fill the array).
            name: "TPU v2",
            conv_gops: 29.56,
            rel_dense: 0.30,
            rel_tconv: 0.12,
            rel_elementwise: 0.25,
            power_w: 1.62,
            overhead_s: 25e-6,
        },
        Platform {
            // FlexiGAN [13]: FPGA fabric reorders tconv compute (its whole
            // point), so tconv ≈ conv — just at a low absolute clip and
            // with reconfiguration overhead.
            name: "FPGA (FlexiGAN)",
            conv_gops: 1.732,
            rel_dense: 0.80,
            rel_tconv: 1.00,
            rel_elementwise: 0.60,
            power_w: 0.693,
            overhead_s: 60e-6,
        },
        Platform {
            // ReGAN [15]: in-memory MVMs make it the closest competitor;
            // NVM access latency bounds the clip.
            name: "ReRAM (ReGAN)",
            conv_gops: 145.7,
            rel_dense: 0.90,
            rel_tconv: 0.75,
            rel_elementwise: 0.40,
            power_w: 0.314,
            overhead_s: 10e-6,
        },
    ]
}

/// Resolve a fleet-group platform key to an index into [`all_platforms`]:
/// the short keys `"gpu"`, `"cpu"`, `"tpu"`, `"fpga"`, `"reram"` (plus
/// the platforms' proper names `"a100"`, `"xeon"`, `"flexigan"`,
/// `"regan"`), or a full display name, all case-insensitively. `None`
/// when nothing matches — the scenario layer maps that onto a typed
/// unknown-platform error.
pub fn platform_named(key: &str) -> Option<usize> {
    let lower = key.to_ascii_lowercase();
    match lower.as_str() {
        "gpu" | "a100" => return Some(0),
        "cpu" | "xeon" => return Some(1),
        "tpu" => return Some(2),
        "fpga" | "flexigan" => return Some(3),
        "reram" | "regan" => return Some(4),
        _ => {}
    }
    all_platforms().iter().position(|p| p.name.to_ascii_lowercase() == lower)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn classes_cover_all_layers() {
        for m in zoo::extended_generators() {
            for info in m.infos().unwrap() {
                let _ = LayerClass::of(&info.layer); // must not panic
            }
        }
    }

    #[test]
    fn platform_keys_resolve_case_insensitively() {
        assert_eq!(platform_named("gpu"), Some(0));
        assert_eq!(platform_named("Xeon"), Some(1));
        assert_eq!(platform_named("TPU"), Some(2));
        assert_eq!(platform_named("fpga (flexigan)"), Some(3));
        assert_eq!(platform_named("ReRAM (ReGAN)"), Some(4));
        assert_eq!(platform_named("quantum"), None);
    }

    #[test]
    fn evaluation_produces_positive_metrics() {
        for p in all_platforms() {
            for m in zoo::extended_generators() {
                let r = p.evaluate(&m, 1);
                assert!(r.latency > 0.0 && r.energy > 0.0, "{} {}", p.name, m.name);
                assert!(r.gops() > 0.0 && r.epb() > 0.0);
                // achieved can never exceed the conv anchor by construction
                assert!(r.gops() <= p.conv_gops * 1.001);
            }
        }
    }

    #[test]
    fn tpu_suffers_most_on_tconv_heavy_models() {
        // relative GOPS drop from CycleGAN (conv-heavy) to DCGAN
        // (tconv-heavy) must be worst on the systolic TPU
        let drop = |p: &Platform| {
            let cycle = p.evaluate(&zoo::cyclegan(), 1).gops();
            let dc = p.evaluate(&zoo::dcgan(), 1).gops();
            dc / cycle
        };
        let ps = all_platforms();
        let tpu = drop(&ps[2]);
        let fpga = drop(&ps[3]);
        assert!(tpu < fpga, "TPU {tpu:.2} should drop more than FPGA {fpga:.2}");
    }

    #[test]
    fn batching_amortizes_overhead() {
        let p = &all_platforms()[0]; // GPU
        let r1 = p.evaluate(&zoo::condgan(), 1);
        let r16 = p.evaluate(&zoo::condgan(), 16);
        assert!(r16.gops() > r1.gops());
    }
}

#[cfg(test)]
mod calibration {
    use super::*;
    use crate::arch::accelerator::Accelerator;
    use crate::arch::config::ArchConfig;
    use crate::models::zoo;
    use crate::sim::{simulate, OptFlags};

    /// Paper Figs. 13/14 average ratios — locked in by calibration; if a
    /// model or simulator change moves these by >15%, recalibrate the
    /// platform constants (see `print_ratio_calibration`).
    #[test]
    fn average_ratios_track_paper() {
        let acc = Accelerator::new(ArchConfig::paper_optimum()).unwrap();
        let models = zoo::all_generators();
        let pg: Vec<_> = models
            .iter()
            .map(|m| simulate(m, &acc, 1, OptFlags::all()))
            .collect();
        let targets_gops = [134.64, 260.13, 123.43, 286.38, 4.40];
        let targets_epb = [514.67, 60.0, 313.50, 317.85, 2.18];
        for (i, p) in all_platforms().iter().enumerate() {
            let mut gr = 0.0;
            let mut er = 0.0;
            for (m, r) in models.iter().zip(&pg) {
                let b = p.evaluate(m, 1);
                gr += r.gops() / b.gops();
                er += b.epb() / r.epb();
            }
            gr /= models.len() as f64;
            er /= models.len() as f64;
            assert!(
                (gr / targets_gops[i] - 1.0).abs() < 0.15,
                "{}: GOPS ratio {gr:.2} drifted from paper {:.2}",
                p.name,
                targets_gops[i]
            );
            assert!(
                (er / targets_epb[i] - 1.0).abs() < 0.15,
                "{}: EPB ratio {er:.2} drifted from paper {:.2}",
                p.name,
                targets_epb[i]
            );
        }
    }

    /// Prints the calibration table: PhotoGAN vs each platform, average
    /// GOPS and EPB ratios vs the paper's targets. Used to set the
    /// constants in `all_platforms`.
    #[test]
    #[ignore]
    fn print_ratio_calibration() {
        let acc = Accelerator::new(ArchConfig::paper_optimum()).unwrap();
        let models = zoo::all_generators();
        let pg: Vec<_> = models
            .iter()
            .map(|m| simulate(m, &acc, 1, OptFlags::all()))
            .collect();
        let targets_gops = [134.64, 260.13, 123.43, 286.38, 4.40];
        let targets_epb = [514.67, 60.0, 313.50, 317.85, 2.18];
        for (i, p) in all_platforms().iter().enumerate() {
            let mut gr = 0.0;
            let mut er = 0.0;
            for (m, r) in models.iter().zip(&pg) {
                let b = p.evaluate(m, 1);
                gr += r.gops() / b.gops();
                er += b.epb() / r.epb();
            }
            gr /= models.len() as f64;
            er /= models.len() as f64;
            println!(
                "{:16} GOPSx={:8.2} (target {:7.2})  EPBx={:8.2} (target {:7.2})",
                p.name, gr, targets_gops[i], er, targets_epb[i]
            );
        }
    }
}
