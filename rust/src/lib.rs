//! # PhotoGAN
//!
//! Reproduction of *PhotoGAN: Generative Adversarial Neural Network
//! Acceleration with Silicon Photonics* (Suresh, Afifi, Pasricha).
//!
//! The crate is organised bottom-up:
//!
//! - [`photonics`] — opto-electronic device models (MRs, VCSELs, PDs, SOAs,
//!   DAC/ADC, PCMCs, tuning circuits, waveguide loss budget, laser power).
//! - [`arch`] — PhotoGAN's architecture blocks (dense / convolution /
//!   normalization / activation units) and whole-chip assembly `[N,K,L,M]`.
//! - [`models`] — GAN workload IR and the four evaluated models (Table 1).
//! - [`sparse`] — the paper's sparse computation dataflow for transposed
//!   convolutions (Fig. 9): zero-column census + functional reference.
//! - [`sim`] — the architectural simulator: mapping, two-level pipelining,
//!   power gating, per-layer latency/energy traces, GOPS / EPB.
//! - [`baselines`] — analytic GPU / CPU / TPU / FPGA / ReRAM comparators.
//! - [`dse`] — design-space exploration over `[N,K,L,M]` (Fig. 11).
//! - [`runtime`] — PJRT client that loads the AOT HLO artifacts produced by
//!   `python/compile/aot.py` and executes real GAN inference.
//! - [`coordinator`] — serving layer: request router, dynamic batcher,
//!   worker pool, latency metrics.
//! - [`report`] — regenerates every table and figure of the paper.
//! - [`util`] — RNG, stats, table printing, mini property-test harness.

pub mod arch;
pub mod baselines;
pub mod coordinator;
pub mod dse;
pub mod metrics;
pub mod models;
pub mod photonics;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod sparse;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
