//! # PhotoGAN
//!
//! Reproduction of *PhotoGAN: Generative Adversarial Neural Network
//! Acceleration with Silicon Photonics* (Suresh, Afifi, Pasricha).
//!
//! ## Front door: `photogan::api`
//!
//! All evaluation flows — single-model simulation, the Fig. 11
//! design-space exploration, the Figs. 13/14 platform comparison, report
//! generation, and artifact serving — go through one typed facade,
//! [`api::Session`]:
//!
//! ```
//! use photogan::api::{Session, SimRequest, SweepRequest};
//! use photogan::dse::Grid;
//!
//! let session = Session::new()?; // the paper's [16,2,11,3] chip
//!
//! // simulate all eight registered generators (Table 1 + extended zoo)
//! // at batch 8
//! let sim = session.simulate(&SimRequest::builder().batch(8).build()?)?;
//! sim.to_table().print();
//!
//! // sweep a small grid; the session's mapping cache is reused, so the
//! // models are mapped once, not once per configuration
//! let dse = session.sweep(
//!     &SweepRequest::builder().grid(Grid::smoke()).threads(2).build()?,
//! )?;
//! assert!(dse.optimum().is_some());
//! println!("{}", dse.to_json()); // every outcome also renders as JSON
//! # Ok::<(), photogan::api::ApiError>(())
//! ```
//!
//! Requests are validated builders, failures are [`api::ApiError`]
//! variants (no panics, no process exits), and every outcome renders as
//! both an ASCII table and machine-readable JSON (`--json` on the CLI).
//!
//! Serving needs no PJRT artifacts: the default sim backend executes
//! batches at photonic-simulator timing through the session mapping cache,
//! across N coordinator shards with pluggable routing:
//!
//! ```
//! use photogan::api::{ServeRequest, Session};
//! use photogan::coordinator::RoutingPolicy;
//! use std::sync::Arc;
//!
//! let session = Arc::new(Session::new()?);
//! let served = session.serve(
//!     &ServeRequest::builder()
//!         .requests(8)
//!         .shards(2)
//!         .routing(RoutingPolicy::LeastOutstanding)
//!         .time_scale(0.0) // cost model only: don't sleep sim latencies
//!         .build()?,
//! )?;
//! assert_eq!(served.total_requests, 8);
//! assert!(served.p99_ms >= served.p50_ms);
//! # Ok::<(), photogan::api::ApiError>(())
//! ```
//!
//! ## Layer map (bottom-up)
//!
//! - [`photonics`] — opto-electronic device models (MRs, VCSELs, PDs, SOAs,
//!   DAC/ADC, PCMCs, tuning circuits, waveguide loss budget, laser power).
//! - [`arch`] — PhotoGAN's architecture blocks (dense / convolution /
//!   normalization / activation units) and whole-chip assembly `[N,K,L,M]`.
//! - [`models`] — GAN workload IR and the model zoo: the four Table 1
//!   models plus the extended paper-adjacent generators (SRGAN, Pix2Pix,
//!   StyleGAN2, ProGAN).
//! - [`sparse`] — the paper's sparse computation dataflow (Fig. 9) for
//!   transposed convolutions *and* its upsample+conv generalization:
//!   static censuses + functional references.
//! - [`sim`] — the architectural simulator: mapping, two-level pipelining,
//!   power gating, per-layer latency/energy traces, GOPS / EPB.
//! - [`baselines`] — analytic GPU / CPU / TPU / FPGA / ReRAM comparators.
//! - [`fidelity`] — noise- and variation-aware accuracy proxy: a typed
//!   [`fidelity::NoiseModel`] derived from the `photonics` constants, a
//!   deterministic Monte Carlo driver over the mapped layers (SNR /
//!   effective bits per layer), and the drift-budget calibration
//!   schedule behind virtual-serve re-calibration outages.
//! - [`dse`] — design-space exploration over `[N,K,L,M]` (Fig. 11).
//! - `runtime` — PJRT client that loads the AOT HLO artifacts produced by
//!   `python/compile/aot.py` and executes real GAN inference (requires the
//!   `pjrt` feature; the `xla` crate is optional in the offline set, so
//!   the module is absent from default-feature docs).
//! - [`coordinator`] — serving layer: N shards with routing policies
//!   ([`coordinator::RoutingPolicy`]), dynamic batchers, bounded queues
//!   with typed backpressure, worker pools, latency metrics.
//! - [`workload`] — declarative traffic: weighted model mixes, seeded
//!   arrival processes (closed-loop / Poisson / bursty / trace), threaded
//!   load generators for the coordinator, and a deterministic
//!   virtual-time serving simulation ([`workload::vserve`]).
//! - [`api`] — the [`api::Session`] facade over all of the above,
//!   including sim-backed serving via [`api::SimExecutor`] and the
//!   declarative scenario layer ([`api::scenario`]: JSON → `Plan` →
//!   `ScenarioOutcome` with SLO verdicts).
//! - [`report`] — regenerates every table and figure of the paper.
//! - [`util`] — RNG, stats, tables, JSON, CLI parsing, error plumbing,
//!   mini property-test harness, and the in-tree concurrency model
//!   checker ([`util::check`]) behind the serving core's sync shims.

// Unsafe hygiene, crate-wide: every unsafe operation sits in an explicit
// `unsafe` block (even inside `unsafe fn`), and every such block carries
// a `// SAFETY:` comment (`undocumented_unsafe_blocks` is `warn` here and
// promoted to an error by CI's `-D warnings`; `deny` outright would need
// the lint in every dependent's config). The only unsafe code lives in
// `coordinator::queue` and `util::check::alloc`.
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod api;
pub mod arch;
pub mod baselines;
pub mod coordinator;
pub mod dse;
pub mod fidelity;
pub mod metrics;
pub mod models;
pub mod photonics;
pub mod report;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sim;
pub mod sparse;
pub mod util;
pub mod workload;

/// Crate-wide untyped result (I/O-ish paths); the API layer uses the
/// typed [`api::ApiError`] instead.
pub type Result<T> = crate::util::error::Result<T>;
