//! Minimal property-based testing harness.
//!
//! `proptest` is not available in this offline environment (DESIGN.md §2),
//! so this module provides the subset we need: run a property over many
//! pseudo-random cases from a deterministic seed, and on failure report the
//! failing case index + seed so it can be replayed exactly. A simple
//! halving shrinker is provided for integer-vector inputs.
//!
//! Usage (``no_run``: doctest executables don't inherit the rpath to
//! `libxla_extension`'s bundled libstdc++ in this environment, so the
//! example is compile-checked only):
//! ```no_run
//! use photogan::util::prop::{check, Gen};
//! check("addition commutes", 256, |g: &mut Gen| {
//!     let a = g.i64_in(-1000, 1000);
//!     let b = g.i64_in(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Pcg32;

/// Per-case generator handed to properties; wraps the RNG with convenience
/// samplers.
pub struct Gen {
    rng: Pcg32,
    /// Case index (0-based) — useful for size-scaling inputs.
    pub case: usize,
}

impl Gen {
    /// Uniform `u32` below `bound`.
    pub fn u32_below(&mut self, bound: u32) -> u32 {
        self.rng.below(bound)
    }

    /// Uniform `i64` in `[lo, hi]`.
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range(lo, hi)
    }

    /// Uniform `usize` in `[lo, hi]`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo as i64, hi as i64) as usize
    }

    /// Uniform `f64` in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    /// Uniform `f32` in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.f32() * (hi - lo)
    }

    /// Coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u32) as usize]
    }

    /// Vector of f32s in `[lo, hi)` of the given length.
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Access the raw RNG.
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// Fixed default seed; override with the `PHOTOGAN_PROP_SEED` env var to
/// replay a reported failure.
fn base_seed() -> u64 {
    std::env::var("PHOTOGAN_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FF_EE00_D15E_A5E5)
}

/// Run `prop` over `cases` pseudo-random cases. Panics (with replay
/// information) on the first failing case.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: usize, prop: F) {
    let seed = base_seed();
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen { rng: Pcg32::new(case_seed), case };
            prop(&mut g);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay with PHOTOGAN_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("tautology", 64, |g| {
            let x = g.i64_in(0, 10);
            assert!((0..=10).contains(&x));
        });
    }

    #[test]
    fn failing_property_reports_case() {
        let r = std::panic::catch_unwind(|| {
            check("always-fails", 8, |_g| panic!("boom"));
        });
        let payload = r.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("always-fails"), "{msg}");
        assert!(msg.contains("replay"), "{msg}");
    }

    #[test]
    fn gen_samplers_respect_ranges() {
        check("sampler ranges", 128, |g| {
            let a = g.f64_in(2.0, 3.0);
            assert!((2.0..3.0).contains(&a));
            let v = g.vec_f32(5, -1.0, 1.0);
            assert_eq!(v.len(), 5);
            assert!(v.iter().all(|x| (-1.0..1.0).contains(x)));
            let pick = *g.choose(&[1, 2, 3]);
            assert!([1, 2, 3].contains(&pick));
        });
    }
}
