//! Node-accounting ledger for raw-pointer code under the model checker.
//!
//! The lock-free [`crate::coordinator::queue::JobQueue`] moves heap
//! nodes through `Box::into_raw` / `Box::from_raw`. Routing those two
//! calls through this module gives every model execution an exact
//! allocation ledger: a `from_raw` of a pointer the ledger does not
//! know fails the schedule as a double free, and any pointer still live
//! when the execution quiesces fails it as a leak. Outside a model run
//! both functions compile down to the plain `Box` calls (the ledger
//! branch is one thread-local read).

use super::sched;

/// [`Box::into_raw`], recorded in the model execution's allocation
/// ledger when called from a model thread.
#[inline]
pub fn box_into_raw<T>(b: Box<T>) -> *mut T {
    let p = Box::into_raw(b);
    if let Some(c) = sched::ctx() {
        sched::ledger_alloc(&c, p as usize);
    }
    p
}

/// [`Box::from_raw`], checked against the model execution's allocation
/// ledger when called from a model thread (double frees and frees of
/// foreign pointers fail the schedule).
///
/// # Safety
///
/// Exactly the [`Box::from_raw`] contract: `p` must have come from
/// [`box_into_raw`] (or `Box::into_raw`) and ownership must not have
/// been reclaimed already. The ledger *detects* violations under the
/// model checker; it does not make them safe.
#[inline]
pub unsafe fn box_from_raw<T>(p: *mut T) -> Box<T> {
    if let Some(c) = sched::ctx() {
        sched::ledger_free(&c, p as usize);
    }
    // SAFETY: forwarded caller contract — `p` is a live, uniquely-owned
    // pointer produced by `box_into_raw`.
    unsafe { Box::from_raw(p) }
}
