//! The deterministic controlled scheduler behind [`super::sync`].
//!
//! One *model execution* runs the user closure on real OS threads, but
//! only one model thread holds the run token at a time: every shim
//! operation (atomic access, lock, park, wake) first reaches a
//! *decision point* where the scheduler picks the next thread to run.
//! Recording the option list and the chosen index at every decision
//! point makes executions replayable; depth-first backtracking over the
//! recorded choices enumerates interleavings, bounded CHESS-style by a
//! preemption budget (a decision that switches away from a still-runnable
//! thread costs one preemption).
//!
//! Simplifications relative to loom, stated so nobody over-trusts the
//! tool: only sequentially-consistent interleavings are explored (no C11
//! weak-memory reorderings — Miri/TSan cover the ordering axis in CI),
//! mutex release hands off to the longest-waiting thread (no barging),
//! and a timed condvar wait only times out when nothing else can run
//! (model time advances only at quiescence). Panics inside a model
//! thread fail the whole execution; code that *intends* to panic (e.g.
//! exercising RAII unwind paths) must wrap the panic in
//! `std::panic::catch_unwind` inside the model closure.

use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex, Once, PoisonError};

/// True when the crate was compiled with `--cfg model_check`: the
/// exhaustive mode the CI checker job uses. It raises the default
/// schedule budget so [`CheckOpts::default`] explores until completion
/// instead of stopping at the bounded tier-1 budget.
pub const EXHAUSTIVE: bool = cfg!(model_check);

/// Budgets and exploration knobs for [`model`].
#[derive(Debug, Clone, Copy)]
pub struct CheckOpts {
    /// CHESS-style bound: how many times the scheduler may switch away
    /// from a thread that could have kept running. Bound 2 finds the
    /// overwhelming majority of real bugs at a tiny fraction of the
    /// full interleaving space.
    pub preemption_bound: usize,
    /// Stop exploring after this many schedules even if the DFS
    /// frontier is not exhausted (tier-1 time budget).
    pub max_schedules: usize,
    /// Per-execution decision cap; exceeding it fails the execution as
    /// a livelock.
    pub max_steps: usize,
    /// Exploration seed: 0 explores in canonical order; any other value
    /// deterministically rotates non-default options so repeated seeded
    /// runs walk the space from different directions.
    pub seed: u64,
}

impl Default for CheckOpts {
    fn default() -> CheckOpts {
        CheckOpts {
            preemption_bound: 2,
            max_schedules: if EXHAUSTIVE { usize::MAX / 2 } else { 2_000 },
            max_steps: 20_000,
            seed: 0,
        }
    }
}

/// Result of a [`model`] run.
#[derive(Debug, Clone)]
pub enum CheckOutcome {
    /// No explored schedule violated an assertion, deadlocked, leaked,
    /// or double-freed.
    Pass {
        /// Number of distinct schedules executed.
        schedules: usize,
        /// True when the DFS frontier was exhausted (every schedule
        /// within the preemption bound ran); false when the
        /// `max_schedules` budget stopped exploration early.
        complete: bool,
    },
    /// A schedule failed; `token` replays it via [`replay`].
    Fail {
        /// Replay token (`mc1:s<seed>:b<bound>:<i.i.i>`).
        token: String,
        /// Human-readable failure (panic message, deadlock dump, ledger
        /// violation).
        message: String,
        /// Number of schedules executed up to and including the failure.
        schedules: usize,
    },
}

impl CheckOutcome {
    /// True on [`CheckOutcome::Pass`].
    pub fn is_pass(&self) -> bool {
        matches!(self, CheckOutcome::Pass { .. })
    }

    /// Number of schedules executed.
    pub fn schedules(&self) -> usize {
        match self {
            CheckOutcome::Pass { schedules, .. } => *schedules,
            CheckOutcome::Fail { schedules, .. } => *schedules,
        }
    }

    /// The replay token of a failing schedule, if any.
    pub fn failure_token(&self) -> Option<&str> {
        match self {
            CheckOutcome::Fail { token, .. } => Some(token),
            CheckOutcome::Pass { .. } => None,
        }
    }

    /// Panic with the failure message and replay token on
    /// [`CheckOutcome::Fail`]. When the `MODEL_CHECK_TOKEN_DIR`
    /// environment variable is set, the token is also written there so
    /// CI can upload it as an artifact.
    pub fn assert_pass(&self) {
        if let CheckOutcome::Fail { token, message, schedules } = self {
            dump_token(token, message);
            panic!(
                "model check failed after {schedules} schedule(s): {message}\n  \
                 replay token: {token}\n  \
                 reproduce with photogan::util::check::replay(\"{token}\", ...)"
            );
        }
    }
}

fn dump_token(token: &str, message: &str) {
    if let Ok(dir) = std::env::var("MODEL_CHECK_TOKEN_DIR") {
        let _ = std::fs::create_dir_all(&dir);
        let path = format!("{dir}/token-{:016x}.txt", mix(0x746f6b, token.len() as u64));
        let _ = std::fs::write(path, format!("{token}\n{message}\n"));
    }
}

/// splitmix64-style mixer: the only "randomness" in the checker, used
/// for seeded option rotation and token file names. Fully deterministic.
fn mix(seed: u64, step: u64) -> u64 {
    let mut z = seed ^ step.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

// ---------------------------------------------------------------------------
// Execution state
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Status {
    Runnable,
    BlockedMutex(usize),
    BlockedCv(usize),
    BlockedJoin(usize),
    Finished,
}

#[derive(Debug)]
struct ThreadState {
    status: Status,
    timed_out: bool,
}

impl ThreadState {
    fn new() -> ThreadState {
        ThreadState { status: Status::Runnable, timed_out: false }
    }
}

#[derive(Debug, Default)]
struct MutexModel {
    owner: Option<usize>,
    waiting: Vec<usize>,
}

#[derive(Debug)]
struct CvWaiter {
    tid: usize,
    mutex: usize,
    timed: bool,
}

#[derive(Debug, Clone)]
struct Choice {
    options: Vec<usize>,
    chosen: usize,
}

struct ExecState {
    threads: Vec<ThreadState>,
    running: Option<usize>,
    replay: Vec<usize>,
    cursor: usize,
    trace: Vec<Choice>,
    preemptions: usize,
    preemption_bound: usize,
    steps: usize,
    max_steps: usize,
    seed: u64,
    abort: bool,
    failure: Option<String>,
    mutexes: HashMap<usize, MutexModel>,
    cvs: HashMap<usize, Vec<CvWaiter>>,
    live_nodes: HashSet<usize>,
}

impl ExecState {
    fn new(opts: CheckOpts, replay: Vec<usize>) -> ExecState {
        ExecState {
            threads: Vec::new(),
            running: None,
            replay,
            cursor: 0,
            trace: Vec::new(),
            preemptions: 0,
            preemption_bound: opts.preemption_bound,
            steps: 0,
            max_steps: opts.max_steps,
            seed: opts.seed,
            abort: false,
            failure: None,
            mutexes: HashMap::new(),
            cvs: HashMap::new(),
            live_nodes: HashSet::new(),
        }
    }
}

/// One model execution: the scheduler state plus the real thread handles
/// the controller joins between schedules.
pub(crate) struct Execution {
    state: StdMutex<ExecState>,
    cv: StdCondvar,
    handles: StdMutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Execution {
    fn new(opts: CheckOpts, replay: Vec<usize>) -> Execution {
        Execution {
            state: StdMutex::new(ExecState::new(opts, replay)),
            cv: StdCondvar::new(),
            handles: StdMutex::new(Vec::new()),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, ExecState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

/// Per-thread handle to the active model execution. `None` outside a
/// model run — the shim's production fast path.
pub(crate) struct Ctx {
    pub(crate) exec: Arc<Execution>,
    pub(crate) tid: usize,
}

impl Clone for Ctx {
    fn clone(&self) -> Ctx {
        Ctx { exec: Arc::clone(&self.exec), tid: self.tid }
    }
}

thread_local! {
    static CTX: RefCell<Option<Ctx>> = RefCell::new(None);
}

/// The calling thread's model context, if it is a model thread.
pub(crate) fn ctx() -> Option<Ctx> {
    CTX.with(|c| c.borrow().clone())
}

fn set_ctx(ctx: Option<Ctx>) {
    CTX.with(|c| *c.borrow_mut() = ctx);
}

/// Unwind sentinel used to tear model threads down after an abort; the
/// thread wrapper catches it and does not report it as a user panic.
struct SchedAbort;

fn panic_abort() -> ! {
    std::panic::panic_any(SchedAbort)
}

/// Panic payload for unwinds a model body raises *on purpose* (e.g. to
/// exercise RAII release-on-unwind paths under `catch_unwind`): the
/// panic hook stays silent for it, so exploring hundreds of schedules
/// does not print hundreds of expected backtraces. Raise it with
/// `std::panic::panic_any(QuietPanic("why"))`.
#[derive(Debug)]
pub struct QuietPanic(pub &'static str);

/// Silence the default panic hook for [`SchedAbort`] teardown unwinds —
/// they are control flow, not failures, and would otherwise print a
/// "thread 'model-N' panicked" line per torn-down thread — and for
/// deliberate [`QuietPanic`]s. User panics still reach the previous hook
/// unchanged. Installed once, process-wide (the wrapped hook chain keeps
/// working for everything else).
fn install_teardown_hook() {
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().is::<SchedAbort>() || info.payload().is::<QuietPanic>() {
                return;
            }
            prev(info);
        }));
    });
}

fn fail(st: &mut ExecState, msg: String) {
    if st.failure.is_none() {
        st.failure = Some(msg);
    }
    st.abort = true;
}

// ---------------------------------------------------------------------------
// The decision procedure
// ---------------------------------------------------------------------------

/// Pick the next thread to run. `cur` is the thread that just yielded
/// (it may have blocked or finished, in which case it is absent from
/// the runnable set and switching away from it is free). Returns `Err`
/// after recording a failure (deadlock or step-budget livelock); the
/// caller must notify and unwind.
fn choose_next(st: &mut ExecState, cur: Option<usize>) -> Result<(), ()> {
    st.steps += 1;
    if st.steps > st.max_steps {
        fail(
            st,
            format!("step budget exceeded ({} decisions): possible livelock", st.max_steps),
        );
        return Err(());
    }
    loop {
        let runnable: Vec<usize> = st
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| t.status == Status::Runnable)
            .map(|(i, _)| i)
            .collect();
        if runnable.is_empty() {
            if st.threads.iter().all(|t| t.status == Status::Finished) {
                st.running = None;
                return Ok(());
            }
            // Model time advances only at quiescence: when nothing can
            // run, the lowest-tid timed condvar waiter times out.
            if let Some(tid) = lowest_timed_waiter(st) {
                wake_timed_waiter(st, tid);
                continue;
            }
            let dump: Vec<String> = st
                .threads
                .iter()
                .enumerate()
                .map(|(i, t)| format!("t{i}:{:?}", t.status))
                .collect();
            fail(st, format!("deadlock: no runnable threads [{}]", dump.join(" ")));
            return Err(());
        }

        let cur_runnable = match cur {
            Some(c) => runnable.contains(&c),
            None => false,
        };
        let mut options: Vec<usize> = Vec::with_capacity(runnable.len());
        if cur_runnable {
            // Canonical order: keep running first; alternatives are
            // preemptions and only offered while budget remains.
            if let Some(c) = cur {
                options.push(c);
                if st.preemptions < st.preemption_bound {
                    options.extend(runnable.iter().copied().filter(|&t| t != c));
                }
            }
        } else {
            options.extend(runnable.iter().copied());
        }
        if st.seed != 0 && options.len() > 1 {
            let start = usize::from(cur_runnable);
            let n = options.len() - start;
            if n > 1 {
                let r = (mix(st.seed, st.trace.len() as u64) as usize) % n;
                options[start..].rotate_left(r);
            }
        }

        let idx = if st.cursor < st.replay.len() {
            st.replay[st.cursor].min(options.len() - 1)
        } else {
            0
        };
        st.cursor += 1;
        st.trace.push(Choice { options: options.clone(), chosen: idx });
        let next = options[idx];
        if cur_runnable && Some(next) != cur {
            st.preemptions += 1;
        }
        st.running = Some(next);
        return Ok(());
    }
}

fn lowest_timed_waiter(st: &ExecState) -> Option<usize> {
    for (tid, t) in st.threads.iter().enumerate() {
        if let Status::BlockedCv(cv) = t.status {
            let timed = st
                .cvs
                .get(&cv)
                .map(|ws| ws.iter().any(|w| w.tid == tid && w.timed))
                .unwrap_or(false);
            if timed {
                return Some(tid);
            }
        }
    }
    None
}

fn wake_timed_waiter(st: &mut ExecState, tid: usize) {
    let cv = match st.threads[tid].status {
        Status::BlockedCv(cv) => cv,
        _ => return,
    };
    let mutex = {
        let waiters = match st.cvs.get_mut(&cv) {
            Some(w) => w,
            None => return,
        };
        let pos = match waiters.iter().position(|w| w.tid == tid) {
            Some(p) => p,
            None => return,
        };
        waiters.remove(pos).mutex
    };
    st.threads[tid].timed_out = true;
    wake_into_mutex(st, tid, mutex);
}

/// A condvar waiter woken (by notify or timeout) re-contends its mutex:
/// it becomes runnable owning the mutex if free, else joins the mutex
/// wait queue.
fn wake_into_mutex(st: &mut ExecState, tid: usize, mutex: usize) {
    let m = st.mutexes.entry(mutex).or_default();
    if m.owner.is_none() {
        m.owner = Some(tid);
        st.threads[tid].status = Status::Runnable;
    } else {
        m.waiting.push(tid);
        st.threads[tid].status = Status::BlockedMutex(mutex);
    }
}

fn release_mutex_inner(st: &mut ExecState, mutex: usize) {
    let handoff = {
        let m = st.mutexes.entry(mutex).or_default();
        m.owner = None;
        if m.waiting.is_empty() {
            None
        } else {
            let w = m.waiting.remove(0);
            m.owner = Some(w);
            Some(w)
        }
    };
    if let Some(w) = handoff {
        st.threads[w].status = Status::Runnable;
    }
}

// ---------------------------------------------------------------------------
// Thread-side primitives (called from the shim on a model thread)
// ---------------------------------------------------------------------------

/// Park until this thread holds the run token (or the execution aborts).
///
/// Abort teardown: a thread that is not already unwinding leaves via the
/// [`SchedAbort`] sentinel; a thread that *is* unwinding (its Drop
/// handlers reached a shim op mid-panic) just returns — panicking again
/// would double-panic and abort the whole process. After an abort the
/// scheduler no longer serializes threads; that is safe because the real
/// `std::sync` primitives underneath still protect the data.
fn park(c: &Ctx) {
    let mut st = c.exec.lock();
    loop {
        if st.abort {
            drop(st);
            if std::thread::panicking() {
                return;
            }
            panic_abort();
        }
        if st.running == Some(c.tid) && st.threads[c.tid].status == Status::Runnable {
            return;
        }
        st = c.exec.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
    }
}

/// A schedule point before a shared-memory operation: the scheduler may
/// hand the token to any runnable thread (costing a preemption) before
/// the operation executes.
pub(crate) fn op_point(c: &Ctx) {
    let mut st = c.exec.lock();
    if st.abort {
        drop(st);
        if std::thread::panicking() {
            return;
        }
        panic_abort();
    }
    let ok = choose_next(&mut st, Some(c.tid)).is_ok();
    let next = st.running;
    drop(st);
    c.exec.cv.notify_all();
    if !ok {
        if std::thread::panicking() {
            return;
        }
        panic_abort();
    }
    if next != Some(c.tid) {
        park(c);
    }
}

/// Model-acquire a mutex (schedule point included). On return the
/// calling thread owns the model mutex; the caller then takes the real
/// lock, which is uncontended modulo a transient hand-over window.
pub(crate) fn mutex_lock(c: &Ctx, mutex: usize) {
    op_point(c);
    let mut st = c.exec.lock();
    if st.abort {
        drop(st);
        if std::thread::panicking() {
            return;
        }
        panic_abort();
    }
    let m = st.mutexes.entry(mutex).or_default();
    if m.owner.is_none() {
        m.owner = Some(c.tid);
        return;
    }
    m.waiting.push(c.tid);
    st.threads[c.tid].status = Status::BlockedMutex(mutex);
    let ok = choose_next(&mut st, Some(c.tid)).is_ok();
    drop(st);
    c.exec.cv.notify_all();
    if !ok {
        if std::thread::panicking() {
            return;
        }
        panic_abort();
    }
    park(c);
}

/// Model-release a mutex. Not itself a decision point (the next shared
/// operation is); a no-op during abort teardown so guards can drop
/// freely while unwinding.
pub(crate) fn mutex_unlock(c: &Ctx, mutex: usize) {
    let mut st = c.exec.lock();
    if st.abort {
        return;
    }
    release_mutex_inner(&mut st, mutex);
    drop(st);
    c.exec.cv.notify_all();
}

/// Atomically release the mutex and join the condvar wait queue (no
/// decision point in between — exactly the release-and-sleep atomicity
/// real condvars guarantee), then hand the token on. The caller drops
/// the real lock *after* this returns and parks via [`cv_wait_finish`].
pub(crate) fn cv_wait_begin(c: &Ctx, cv: usize, mutex: usize, timed: bool) {
    let mut st = c.exec.lock();
    if st.abort {
        drop(st);
        if std::thread::panicking() {
            return;
        }
        panic_abort();
    }
    release_mutex_inner(&mut st, mutex);
    st.cvs.entry(cv).or_default().push(CvWaiter { tid: c.tid, mutex, timed });
    st.threads[c.tid].status = Status::BlockedCv(cv);
    st.threads[c.tid].timed_out = false;
    let ok = choose_next(&mut st, Some(c.tid)).is_ok();
    drop(st);
    c.exec.cv.notify_all();
    if !ok && !std::thread::panicking() {
        panic_abort();
    }
}

/// Park after [`cv_wait_begin`]; on return the thread owns the model
/// mutex again. Returns true when the wake was the timeout fallback.
pub(crate) fn cv_wait_finish(c: &Ctx) -> bool {
    park(c);
    let st = c.exec.lock();
    st.threads[c.tid].timed_out
}

/// Wake one (or all) condvar waiters. Waiters move to the mutex queue
/// exactly as a real notify does; a no-op during abort teardown.
pub(crate) fn cv_notify(c: &Ctx, cv: usize, all: bool) {
    op_point(c);
    let mut st = c.exec.lock();
    if st.abort {
        return;
    }
    loop {
        let next = match st.cvs.get_mut(&cv) {
            Some(ws) if !ws.is_empty() => ws.remove(0),
            _ => break,
        };
        wake_into_mutex(&mut st, next.tid, next.mutex);
        if !all {
            break;
        }
    }
    drop(st);
    c.exec.cv.notify_all();
}

// ---------------------------------------------------------------------------
// Allocation ledger
// ---------------------------------------------------------------------------

/// Record a node allocation handed to raw-pointer code.
pub(crate) fn ledger_alloc(c: &Ctx, ptr: usize) {
    let mut st = c.exec.lock();
    if st.abort {
        return;
    }
    st.live_nodes.insert(ptr);
}

/// Record a node reclamation; a pointer the ledger does not know is a
/// double free (or a free of foreign memory) and fails the execution.
pub(crate) fn ledger_free(c: &Ctx, ptr: usize) {
    let mut st = c.exec.lock();
    if st.abort {
        return;
    }
    if !st.live_nodes.remove(&ptr) {
        fail(&mut st, format!("allocation ledger: double free of node {ptr:#x}"));
        drop(st);
        c.exec.cv.notify_all();
        // Never panic inside an unwind (double panic aborts the process);
        // the recorded failure already dooms the execution.
        if !std::thread::panicking() {
            panic_abort();
        }
    }
}

// ---------------------------------------------------------------------------
// Model threads
// ---------------------------------------------------------------------------

/// Join half of [`spawn_model`].
pub(crate) struct ModelJoin<T> {
    tid: usize,
    result: Arc<StdMutex<Option<T>>>,
}

impl<T> ModelJoin<T> {
    /// Block (model-blocking) until the target thread finishes, then
    /// take its result. If the target panicked the execution is already
    /// aborting and this unwinds with the abort sentinel.
    pub(crate) fn join(self) -> T {
        let c = match ctx() {
            Some(c) => c,
            None => panic!("model JoinHandle joined outside the model execution"),
        };
        let mut st = c.exec.lock();
        if st.abort {
            drop(st);
            panic_abort();
        }
        if st.threads[self.tid].status != Status::Finished {
            st.threads[c.tid].status = Status::BlockedJoin(self.tid);
            let ok = choose_next(&mut st, Some(c.tid)).is_ok();
            drop(st);
            c.exec.cv.notify_all();
            if !ok {
                panic_abort();
            }
            park(&c);
        } else {
            drop(st);
        }
        let taken = self.result.lock().unwrap_or_else(PoisonError::into_inner).take();
        match taken {
            Some(v) => v,
            // The target unwound (user panic recorded as the failure, or
            // abort teardown) — propagate the teardown.
            None => panic_abort(),
        }
    }
}

/// Spawn a model thread; registering it is a schedule point, so the new
/// thread may run immediately or later, like a real spawn.
pub(crate) fn spawn_model<F, T>(c: &Ctx, f: F) -> ModelJoin<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    let tid = {
        let mut st = c.exec.lock();
        st.threads.push(ThreadState::new());
        st.threads.len() - 1
    };
    let result: Arc<StdMutex<Option<T>>> = Arc::new(StdMutex::new(None));
    let out = Arc::clone(&result);
    let exec = Arc::clone(&c.exec);
    let handle = std::thread::Builder::new()
        .name(format!("model-{tid}"))
        .spawn(move || {
            run_model_thread(exec, tid, f, out);
        })
        .unwrap_or_else(|e| panic!("model checker could not spawn an OS thread: {e}"));
    c.exec
        .handles
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .push(handle);
    op_point(c);
    ModelJoin { tid, result }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else if let Some(q) = p.downcast_ref::<QuietPanic>() {
        q.0.to_string()
    } else {
        "non-string panic payload".to_string()
    }
}

fn run_model_thread<F, T>(
    exec: Arc<Execution>,
    tid: usize,
    f: F,
    out: Arc<StdMutex<Option<T>>>,
) where
    F: FnOnce() -> T,
{
    let c = Ctx { exec: Arc::clone(&exec), tid };
    set_ctx(Some(c.clone()));
    // Wait for the first turn.
    let aborted_before_start = {
        let mut st = exec.lock();
        loop {
            if st.abort {
                break true;
            }
            if st.running == Some(tid) && st.threads[tid].status == Status::Runnable {
                break false;
            }
            st = exec.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    };
    if !aborted_before_start {
        match catch_unwind(AssertUnwindSafe(f)) {
            Ok(v) => {
                *out.lock().unwrap_or_else(PoisonError::into_inner) = Some(v);
            }
            Err(p) => {
                if !p.is::<SchedAbort>() {
                    let mut st = exec.lock();
                    fail(&mut st, format!("model thread {tid} panicked: {}", panic_message(&*p)));
                }
            }
        }
    }
    // Finish: wake joiners, pass the token on (or quiesce).
    {
        let mut st = exec.lock();
        st.threads[tid].status = Status::Finished;
        for i in 0..st.threads.len() {
            if st.threads[i].status == Status::BlockedJoin(tid) {
                st.threads[i].status = Status::Runnable;
            }
        }
        if !st.abort {
            let _ = choose_next(&mut st, Some(tid));
        } else if st.threads.iter().all(|t| t.status == Status::Finished) {
            st.running = None;
        }
    }
    exec.cv.notify_all();
    set_ctx(None);
}

// ---------------------------------------------------------------------------
// The controller: explore / replay
// ---------------------------------------------------------------------------

/// Run `body` under the controlled scheduler, exploring interleavings
/// by depth-first backtracking until the space (within the preemption
/// bound) is exhausted or the schedule budget runs out. The closure is
/// re-run once per schedule, so it must be `Fn` and self-contained.
pub fn model<F>(opts: CheckOpts, body: F) -> CheckOutcome
where
    F: Fn() + Send + Sync + 'static,
{
    explore(opts, None, Arc::new(body))
}

/// Re-run exactly one schedule from a replay token produced by a
/// failing [`model`] run (see [`CheckOutcome::Fail`]). The closure must
/// be the same model body that produced the token.
pub fn replay<F>(token: &str, body: F) -> CheckOutcome
where
    F: Fn() + Send + Sync + 'static,
{
    let (seed, bound, choices) = match parse_token(token) {
        Some(t) => t,
        None => {
            return CheckOutcome::Fail {
                token: token.to_string(),
                message: format!("unparseable replay token '{token}'"),
                schedules: 0,
            }
        }
    };
    let opts = CheckOpts {
        preemption_bound: bound,
        max_schedules: 1,
        seed,
        ..CheckOpts::default()
    };
    explore(opts, Some(choices), Arc::new(body))
}

fn encode_token(seed: u64, bound: usize, trace: &[Choice]) -> String {
    let idx: Vec<String> = trace.iter().map(|c| c.chosen.to_string()).collect();
    format!("mc1:s{seed}:b{bound}:{}", idx.join("."))
}

/// Parse `mc1:s<seed>:b<bound>:<i.i.i>` back into its parts.
pub fn parse_token(token: &str) -> Option<(u64, usize, Vec<usize>)> {
    let rest = token.strip_prefix("mc1:s")?;
    let (seed_s, rest) = rest.split_once(":b")?;
    let (bound_s, idx_s) = rest.split_once(':')?;
    let seed = seed_s.parse::<u64>().ok()?;
    let bound = bound_s.parse::<usize>().ok()?;
    let choices = if idx_s.is_empty() {
        Vec::new()
    } else {
        idx_s
            .split('.')
            .map(|s| s.parse::<usize>())
            .collect::<Result<Vec<_>, _>>()
            .ok()?
    };
    Some((seed, bound, choices))
}

fn explore(opts: CheckOpts, forced: Option<Vec<usize>>, body: Arc<dyn Fn() + Send + Sync>) -> CheckOutcome {
    install_teardown_hook();
    let replay_only = forced.is_some();
    let mut next_replay: Vec<usize> = forced.unwrap_or_default();
    let mut schedules = 0usize;
    loop {
        let exec = Arc::new(Execution::new(opts, std::mem::take(&mut next_replay)));
        run_one(&exec, Arc::clone(&body));
        schedules += 1;
        let st = exec.lock();
        if let Some(msg) = &st.failure {
            return CheckOutcome::Fail {
                token: encode_token(opts.seed, opts.preemption_bound, &st.trace),
                message: msg.clone(),
                schedules,
            };
        }
        if replay_only {
            return CheckOutcome::Pass { schedules, complete: false };
        }
        // Backtrack: deepest decision with an untried option.
        let mut found = false;
        for i in (0..st.trace.len()).rev() {
            if st.trace[i].chosen + 1 < st.trace[i].options.len() {
                next_replay = st.trace[..i].iter().map(|c| c.chosen).collect();
                next_replay.push(st.trace[i].chosen + 1);
                found = true;
                break;
            }
        }
        if !found {
            return CheckOutcome::Pass { schedules, complete: true };
        }
        if schedules >= opts.max_schedules {
            return CheckOutcome::Pass { schedules, complete: false };
        }
    }
}

fn run_one(exec: &Arc<Execution>, body: Arc<dyn Fn() + Send + Sync>) {
    {
        let mut st = exec.lock();
        st.threads.push(ThreadState::new());
        st.running = Some(0);
    }
    let e2 = Arc::clone(exec);
    let out: Arc<StdMutex<Option<()>>> = Arc::new(StdMutex::new(None));
    let handle = std::thread::Builder::new()
        .name("model-0".to_string())
        .spawn(move || {
            run_model_thread(e2, 0, move || body(), out);
        })
        .unwrap_or_else(|e| panic!("model checker could not spawn an OS thread: {e}"));
    exec.handles
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .push(handle);
    // Wait for quiescence: every model thread finished (normally or via
    // abort teardown).
    {
        let mut st = exec.lock();
        while !st.threads.iter().all(|t| t.status == Status::Finished) {
            st = exec.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }
    // Join the real OS threads so nothing leaks into the next schedule.
    let handles = std::mem::take(
        &mut *exec.handles.lock().unwrap_or_else(PoisonError::into_inner),
    );
    for h in handles {
        let _ = h.join();
    }
    // Leak check: every ledger allocation must have been reclaimed.
    let mut st = exec.lock();
    if st.failure.is_none() && !st.live_nodes.is_empty() {
        let n = st.live_nodes.len();
        fail(&mut st, format!("allocation ledger: {n} node(s) leaked at end of execution"));
    }
}
