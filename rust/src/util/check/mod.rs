//! In-tree systematic concurrency model checker (a loom-lite).
//!
//! The serving core's lock-free structures — the Treiber-stack
//! [`crate::coordinator::queue::JobQueue`], the condvar-parked oneshot
//! [`crate::coordinator::completion`] channel, the RAII
//! [`crate::coordinator::completion::CapacityGuard`] — are correct only
//! under claims about *interleavings*, and ordinary tests execute a
//! handful of lucky ones. This module makes the claims checkable:
//!
//! - [`sync`] shims `std::sync` (atomics, `Mutex`, `Condvar`, `Arc`).
//!   In a normal build every operation is the `std` operation plus one
//!   thread-local read; inside [`model`] every operation first reaches
//!   a deterministic scheduler decision point.
//! - [`sched`] explores interleavings of 2–4 model threads by
//!   depth-first backtracking with a CHESS-style bounded preemption
//!   budget. A failing schedule (panic, deadlock, livelock, ledger
//!   violation) prints a replay token; [`replay`] re-runs exactly that
//!   schedule.
//! - [`alloc`] is a node-accounting ledger for the queue's raw-pointer
//!   paths: double frees and leaked nodes fail the schedule that
//!   produced them.
//! - [`thread`] spawns model threads that the scheduler controls.
//!
//! ```
//! use photogan::util::check;
//! use photogan::util::check::sync::{Arc, AtomicUsize, Ordering};
//!
//! let outcome = check::model(check::CheckOpts::default(), || {
//!     let n = Arc::new(AtomicUsize::new(0));
//!     let n2 = Arc::clone(&n);
//!     let t = check::thread::spawn(move || {
//!         n2.fetch_add(1, Ordering::SeqCst);
//!     });
//!     n.fetch_add(1, Ordering::SeqCst);
//!     t.join().unwrap();
//!     assert_eq!(n.load(Ordering::SeqCst), 2);
//! });
//! outcome.assert_pass();
//! assert!(outcome.schedules() >= 2); // both orders of the two adds ran
//! ```
//!
//! Compiling with `--cfg model_check` switches [`CheckOpts::default`]
//! to an effectively unbounded schedule budget (the CI exhaustive
//! mode); the tier-1 default keeps every suite under a few seconds.

pub mod alloc;
pub mod sched;
pub mod sync;
pub mod thread;

pub use sched::{model, parse_token, replay, CheckOpts, CheckOutcome, QuietPanic, EXHAUSTIVE};

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::sync::{Arc, AtomicUsize, Condvar, Mutex, Ordering};
    use super::*;
    use std::sync::PoisonError;

    #[test]
    fn explores_both_orders_of_a_two_thread_race() {
        // A classic increment race written with plain load/store: some
        // interleaving must lose an update, and the checker must find it.
        let outcome = model(CheckOpts::default(), || {
            let n = Arc::new(AtomicUsize::new(0));
            let n2 = Arc::clone(&n);
            let t = thread::spawn(move || {
                let v = n2.load(Ordering::SeqCst);
                n2.store(v + 1, Ordering::SeqCst);
            });
            let v = n.load(Ordering::SeqCst);
            n.store(v + 1, Ordering::SeqCst);
            t.join().unwrap();
            assert_eq!(n.load(Ordering::SeqCst), 2, "lost update");
        });
        match outcome {
            CheckOutcome::Fail { ref message, ref token, .. } => {
                assert!(message.contains("lost update"), "wrong failure: {message}");
                assert!(parse_token(token).is_some(), "token must parse: {token}");
            }
            CheckOutcome::Pass { .. } => panic!("checker missed the load/store race"),
        }
    }

    #[test]
    fn cas_increments_pass_under_all_schedules() {
        let outcome = model(CheckOpts::default(), || {
            let n = Arc::new(AtomicUsize::new(0));
            let n2 = Arc::clone(&n);
            let bump = |a: &AtomicUsize| loop {
                let v = a.load(Ordering::SeqCst);
                if a.compare_exchange(v, v + 1, Ordering::SeqCst, Ordering::SeqCst).is_ok() {
                    break;
                }
            };
            let t = thread::spawn(move || bump(&n2));
            bump(&n);
            t.join().unwrap();
            assert_eq!(n.load(Ordering::SeqCst), 2);
        });
        outcome.assert_pass();
        assert!(outcome.schedules() >= 2, "must explore more than one schedule");
    }

    #[test]
    fn lock_order_inversion_is_reported_as_deadlock() {
        let outcome = model(CheckOpts::default(), || {
            let a = Arc::new(Mutex::new(0u32));
            let b = Arc::new(Mutex::new(0u32));
            let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
            let t = thread::spawn(move || {
                let _ga = a2.lock().unwrap_or_else(PoisonError::into_inner);
                let _gb = b2.lock().unwrap_or_else(PoisonError::into_inner);
            });
            let _gb = b.lock().unwrap_or_else(PoisonError::into_inner);
            let _ga = a.lock().unwrap_or_else(PoisonError::into_inner);
            drop((_ga, _gb));
            t.join().unwrap();
        });
        match outcome {
            CheckOutcome::Fail { ref message, .. } => {
                assert!(message.contains("deadlock"), "expected a deadlock, got: {message}")
            }
            CheckOutcome::Pass { .. } => panic!("checker missed the lock-order deadlock"),
        }
    }

    #[test]
    fn failing_schedule_replays_to_the_same_failure() {
        let body = || {
            let n = Arc::new(AtomicUsize::new(0));
            let n2 = Arc::clone(&n);
            let t = thread::spawn(move || {
                let v = n2.load(Ordering::SeqCst);
                n2.store(v + 1, Ordering::SeqCst);
            });
            let v = n.load(Ordering::SeqCst);
            n.store(v + 1, Ordering::SeqCst);
            t.join().unwrap();
            assert_eq!(n.load(Ordering::SeqCst), 2, "lost update");
        };
        let token = match model(CheckOpts::default(), body) {
            CheckOutcome::Fail { token, .. } => token,
            CheckOutcome::Pass { .. } => panic!("race must be found"),
        };
        match replay(&token, body) {
            CheckOutcome::Fail { message, schedules, .. } => {
                assert!(message.contains("lost update"), "replay diverged: {message}");
                assert_eq!(schedules, 1, "replay runs exactly one schedule");
            }
            CheckOutcome::Pass { .. } => panic!("replay token did not reproduce the failure"),
        }
    }

    #[test]
    fn condvar_handshake_has_no_lost_wakeup() {
        // flag-under-mutex + condvar: the textbook protocol must pass
        // under every explored interleaving (a lost notify would park
        // the waiter forever and be reported as a deadlock).
        let outcome = model(CheckOpts::default(), || {
            let pair = Arc::new((Mutex::new(false), Condvar::new()));
            let p2 = Arc::clone(&pair);
            let t = thread::spawn(move || {
                let (m, cv) = &*p2;
                *m.lock().unwrap_or_else(PoisonError::into_inner) = true;
                cv.notify_one();
            });
            let (m, cv) = &*pair;
            let mut done = m.lock().unwrap_or_else(PoisonError::into_inner);
            while !*done {
                done = cv.wait(done).unwrap_or_else(PoisonError::into_inner);
            }
            drop(done);
            t.join().unwrap();
        });
        outcome.assert_pass();
    }

    #[test]
    fn seeded_exploration_still_finds_the_race() {
        let outcome = model(CheckOpts { seed: 0xfeed, ..CheckOpts::default() }, || {
            let n = Arc::new(AtomicUsize::new(0));
            let n2 = Arc::clone(&n);
            let t = thread::spawn(move || {
                let v = n2.load(Ordering::SeqCst);
                n2.store(v + 1, Ordering::SeqCst);
            });
            let v = n.load(Ordering::SeqCst);
            n.store(v + 1, Ordering::SeqCst);
            t.join().unwrap();
            assert_eq!(n.load(Ordering::SeqCst), 2, "lost update");
        });
        assert!(!outcome.is_pass(), "seeded run must still find the race");
    }

    #[test]
    fn token_round_trips_through_parse() {
        assert_eq!(parse_token("mc1:s7:b2:0.1.0"), Some((7, 2, vec![0, 1, 0])));
        assert_eq!(parse_token("mc1:s0:b3:"), Some((0, 3, vec![])));
        assert_eq!(parse_token("mc2:s0:b3:"), None);
        assert_eq!(parse_token("garbage"), None);
    }
}
