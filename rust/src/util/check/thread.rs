//! Thread spawn/join that participates in the model scheduler.
//!
//! [`spawn`] from a production thread is exactly
//! [`std::thread::spawn`]. From inside a model execution it registers a
//! new model thread: the spawn is a scheduler decision point (the child
//! may run immediately or much later), `join` blocks through the model
//! (so join cycles surface as detected deadlocks), and a child panic
//! fails the whole execution with a replayable schedule token.

use super::sched;

/// Join handle returned by [`spawn`]; OS-backed or model-backed.
pub struct JoinHandle<T>(Inner<T>);

enum Inner<T> {
    Os(std::thread::JoinHandle<T>),
    Model(sched::ModelJoin<T>),
}

impl<T> JoinHandle<T> {
    /// Wait for the thread to finish and take its result. The model
    /// path always returns `Ok` — a panicking model thread aborts the
    /// execution (recorded as the schedule failure) instead of
    /// surfacing here.
    pub fn join(self) -> std::thread::Result<T> {
        match self.0 {
            Inner::Os(h) => h.join(),
            Inner::Model(m) => Ok(m.join()),
        }
    }
}

/// [`std::thread::spawn`] outside a model execution; a scheduled model
/// thread inside one.
pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    match sched::ctx() {
        Some(c) => JoinHandle(Inner::Model(sched::spawn_model(&c, f))),
        None => JoinHandle(Inner::Os(std::thread::spawn(f))),
    }
}
