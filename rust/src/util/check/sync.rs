//! Shim `std::sync` types routed through the model-check scheduler.
//!
//! Outside a model execution every type here is a thin newtype over the
//! corresponding `std::sync` primitive: the only added cost is one
//! thread-local read and an untaken branch per operation (the
//! `perf_hotpaths` checker-overhead guard pins this at noise level).
//! Inside [`super::model`], every atomic access, lock, park, and wake
//! first reaches a scheduler decision point, which is what lets the
//! checker enumerate interleavings deterministically.
//!
//! Drop-in compatibility: `lock`/`wait`/`wait_timeout` return
//! [`std::sync::LockResult`]-shaped values so existing
//! `unwrap_or_else(PoisonError::into_inner)` call sites compile
//! unchanged. [`WaitTimeoutResult`] is this module's own type because
//! std's has no public constructor. Under the model, `Ordering` is
//! accepted but interleavings are explored at sequential consistency
//! (see `super::sched` for the documented simplification).

use super::sched;
use std::sync::{LockResult, PoisonError};
use std::time::Duration;

pub use std::sync::atomic::Ordering;
pub use std::sync::Arc;

// ---------------------------------------------------------------------------
// Atomics
// ---------------------------------------------------------------------------

/// Insert a scheduler decision point when called from a model thread;
/// free (one TLS read) otherwise.
#[inline]
fn point() {
    if let Some(c) = sched::ctx() {
        sched::op_point(&c);
    }
}

macro_rules! shim_atomic {
    ($(#[$doc:meta])* $name:ident, $std:ty, $prim:ty) => {
        $(#[$doc])*
        #[derive(Debug, Default)]
        pub struct $name {
            inner: $std,
        }

        impl $name {
            /// Create the atomic (identical to the `std` constructor).
            pub const fn new(v: $prim) -> $name {
                $name { inner: <$std>::new(v) }
            }

            /// Atomic load (a model decision point under checking).
            #[inline]
            pub fn load(&self, order: Ordering) -> $prim {
                point();
                self.inner.load(order)
            }

            /// Atomic store (a model decision point under checking).
            #[inline]
            pub fn store(&self, v: $prim, order: Ordering) {
                point();
                self.inner.store(v, order)
            }

            /// Atomic swap (a model decision point under checking).
            #[inline]
            pub fn swap(&self, v: $prim, order: Ordering) -> $prim {
                point();
                self.inner.swap(v, order)
            }

            /// Atomic compare-and-exchange (one decision point for the
            /// whole read-modify-write, like a single instruction).
            #[inline]
            pub fn compare_exchange(
                &self,
                current: $prim,
                new: $prim,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$prim, $prim> {
                point();
                self.inner.compare_exchange(current, new, success, failure)
            }

            /// Weak compare-and-exchange (may spuriously fail on real
            /// hardware; deterministic under the model).
            #[inline]
            pub fn compare_exchange_weak(
                &self,
                current: $prim,
                new: $prim,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$prim, $prim> {
                point();
                self.inner.compare_exchange_weak(current, new, success, failure)
            }

            /// Exclusive access needs no scheduling: `&mut self` proves
            /// no other thread can observe the value.
            #[inline]
            pub fn get_mut(&mut self) -> &mut $prim {
                self.inner.get_mut()
            }

            /// Consume the atomic, returning the value.
            #[inline]
            pub fn into_inner(self) -> $prim {
                self.inner.into_inner()
            }
        }
    };
}

shim_atomic!(
    /// Shim over [`std::sync::atomic::AtomicUsize`].
    AtomicUsize,
    std::sync::atomic::AtomicUsize,
    usize
);
shim_atomic!(
    /// Shim over [`std::sync::atomic::AtomicU64`].
    AtomicU64,
    std::sync::atomic::AtomicU64,
    u64
);
shim_atomic!(
    /// Shim over [`std::sync::atomic::AtomicBool`].
    AtomicBool,
    std::sync::atomic::AtomicBool,
    bool
);

macro_rules! shim_fetch_arith {
    ($name:ident, $prim:ty) => {
        impl $name {
            /// Atomic add, returning the previous value.
            #[inline]
            pub fn fetch_add(&self, v: $prim, order: Ordering) -> $prim {
                point();
                self.inner.fetch_add(v, order)
            }

            /// Atomic subtract, returning the previous value.
            #[inline]
            pub fn fetch_sub(&self, v: $prim, order: Ordering) -> $prim {
                point();
                self.inner.fetch_sub(v, order)
            }
        }
    };
}

shim_fetch_arith!(AtomicUsize, usize);
shim_fetch_arith!(AtomicU64, u64);

/// Shim over [`std::sync::atomic::AtomicPtr`].
#[derive(Debug)]
pub struct AtomicPtr<T> {
    inner: std::sync::atomic::AtomicPtr<T>,
}

impl<T> AtomicPtr<T> {
    /// Create the atomic pointer (identical to the `std` constructor).
    pub const fn new(p: *mut T) -> AtomicPtr<T> {
        AtomicPtr { inner: std::sync::atomic::AtomicPtr::new(p) }
    }

    /// Atomic load (a model decision point under checking).
    #[inline]
    pub fn load(&self, order: Ordering) -> *mut T {
        point();
        self.inner.load(order)
    }

    /// Atomic store (a model decision point under checking).
    #[inline]
    pub fn store(&self, p: *mut T, order: Ordering) {
        point();
        self.inner.store(p, order)
    }

    /// Atomic swap (a model decision point under checking).
    #[inline]
    pub fn swap(&self, p: *mut T, order: Ordering) -> *mut T {
        point();
        self.inner.swap(p, order)
    }

    /// Atomic compare-and-exchange (one decision point).
    #[inline]
    pub fn compare_exchange(
        &self,
        current: *mut T,
        new: *mut T,
        success: Ordering,
        failure: Ordering,
    ) -> Result<*mut T, *mut T> {
        point();
        self.inner.compare_exchange(current, new, success, failure)
    }

    /// Weak compare-and-exchange (one decision point).
    #[inline]
    pub fn compare_exchange_weak(
        &self,
        current: *mut T,
        new: *mut T,
        success: Ordering,
        failure: Ordering,
    ) -> Result<*mut T, *mut T> {
        point();
        self.inner.compare_exchange_weak(current, new, success, failure)
    }

    /// Exclusive access needs no scheduling.
    #[inline]
    pub fn get_mut(&mut self) -> &mut *mut T {
        self.inner.get_mut()
    }
}

impl<T> Default for AtomicPtr<T> {
    fn default() -> AtomicPtr<T> {
        AtomicPtr::new(std::ptr::null_mut())
    }
}

// ---------------------------------------------------------------------------
// Mutex
// ---------------------------------------------------------------------------

/// Shim over [`std::sync::Mutex`]. Under the model the scheduler owns
/// the blocking protocol (so lock-ordering deadlocks and lost wake-ups
/// are detected deterministically); the real inner lock is only ever
/// taken by the thread the model granted ownership to, so it is
/// uncontended modulo a transient hand-over window.
#[derive(Debug, Default)]
pub struct Mutex<T> {
    inner: std::sync::Mutex<T>,
}

impl<T> Mutex<T> {
    /// Create the mutex (identical to the `std` constructor).
    pub const fn new(value: T) -> Mutex<T> {
        Mutex { inner: std::sync::Mutex::new(value) }
    }

    /// The model identity of this mutex: its address. Stable for the
    /// lifetime of the value, which is all the per-execution scheduler
    /// tables need.
    fn key(&self) -> usize {
        self as *const Mutex<T> as usize
    }

    /// Acquire the lock, blocking through the model scheduler on a
    /// model thread and through the OS otherwise. Poisoning is
    /// reported exactly as `std` does on the production path; the model
    /// path never observes poison (a panicking model execution aborts).
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        if let Some(c) = sched::ctx() {
            sched::mutex_lock(&c, self.key());
            let inner = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
            return Ok(MutexGuard {
                inner: Some(inner),
                lock_ref: &self.inner,
                mutex_key: self.key(),
                model: true,
            });
        }
        match self.inner.lock() {
            Ok(g) => Ok(MutexGuard {
                inner: Some(g),
                lock_ref: &self.inner,
                mutex_key: 0,
                model: false,
            }),
            Err(pe) => Err(PoisonError::new(MutexGuard {
                inner: Some(pe.into_inner()),
                lock_ref: &self.inner,
                mutex_key: 0,
                model: false,
            })),
        }
    }

    /// Consume the mutex, returning the value (never blocks).
    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }

    /// Exclusive access to the value (never blocks).
    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        self.inner.get_mut()
    }
}

/// Guard returned by [`Mutex::lock`]; releases the model ownership (and
/// the real lock) on drop.
pub struct MutexGuard<'a, T> {
    /// `None` only after the guard was consumed by a condvar wait or
    /// already dropped — the two paths that hand the real lock back
    /// without the model release below.
    inner: Option<std::sync::MutexGuard<'a, T>>,
    lock_ref: &'a std::sync::Mutex<T>,
    mutex_key: usize,
    model: bool,
}

impl<'a, T> MutexGuard<'a, T> {
    fn inner(&self) -> &std::sync::MutexGuard<'a, T> {
        match &self.inner {
            Some(g) => g,
            None => unreachable!("mutex guard used after release"),
        }
    }

    fn inner_mut(&mut self) -> &mut std::sync::MutexGuard<'a, T> {
        match &mut self.inner {
            Some(g) => g,
            None => unreachable!("mutex guard used after release"),
        }
    }
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.inner()
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner_mut()
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for MutexGuard<'_, T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        std::fmt::Debug::fmt(&**self, f)
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        if let Some(g) = self.inner.take() {
            drop(g);
            if self.model {
                if let Some(c) = sched::ctx() {
                    sched::mutex_unlock(&c, self.mutex_key);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Condvar
// ---------------------------------------------------------------------------

/// Timeout verdict returned by [`Condvar::wait_timeout`]. This module's
/// own type ([`std::sync::WaitTimeoutResult`] has no public
/// constructor); API-compatible via [`WaitTimeoutResult::timed_out`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WaitTimeoutResult {
    timed: bool,
}

impl WaitTimeoutResult {
    /// True when the wait returned because the timeout elapsed.
    pub fn timed_out(&self) -> bool {
        self.timed
    }
}

/// Shim over [`std::sync::Condvar`]. Under the model, waiters queue in
/// FIFO order, release-and-sleep is atomic with respect to scheduler
/// decisions (so a lost notify manifests as a detected deadlock, not a
/// flaky hang), and a timed wait only times out when nothing else in
/// the model can run.
#[derive(Debug, Default)]
pub struct Condvar {
    inner: std::sync::Condvar,
}

impl Condvar {
    /// Create the condvar (identical to the `std` constructor).
    pub const fn new() -> Condvar {
        Condvar { inner: std::sync::Condvar::new() }
    }

    fn key(&self) -> usize {
        self as *const Condvar as usize
    }

    /// Release the lock and park until notified.
    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        if guard.model {
            return Ok(self.model_wait(guard, false).0);
        }
        self.std_wait(guard)
    }

    /// Release the lock and park until notified or `timeout` elapses
    /// (under the model: until nothing else can run).
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        timeout: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        if guard.model {
            let (g, timed) = self.model_wait(guard, true);
            return Ok((g, WaitTimeoutResult { timed }));
        }
        let lock_ref = guard.lock_ref;
        let inner = take_inner(guard);
        match self.inner.wait_timeout(inner, timeout) {
            Ok((g, t)) => Ok((
                remade(g, lock_ref),
                WaitTimeoutResult { timed: t.timed_out() },
            )),
            Err(pe) => {
                let (g, t) = pe.into_inner();
                Err(PoisonError::new((
                    remade(g, lock_ref),
                    WaitTimeoutResult { timed: t.timed_out() },
                )))
            }
        }
    }

    /// Wake one waiter (FIFO under the model).
    pub fn notify_one(&self) {
        if let Some(c) = sched::ctx() {
            sched::cv_notify(&c, self.key(), false);
            return;
        }
        self.inner.notify_one();
    }

    /// Wake every waiter.
    pub fn notify_all(&self) {
        if let Some(c) = sched::ctx() {
            sched::cv_notify(&c, self.key(), true);
            return;
        }
        self.inner.notify_all();
    }

    fn model_wait<'a, T>(&self, guard: MutexGuard<'a, T>, timed: bool) -> (MutexGuard<'a, T>, bool) {
        let c = match sched::ctx() {
            Some(c) => c,
            None => unreachable!("model guard outside a model thread"),
        };
        let mutex_key = guard.mutex_key;
        let lock_ref = guard.lock_ref;
        // Atomically (w.r.t. scheduler decisions) release the model
        // mutex and join the wait queue, then release the real lock and
        // park. On wake the scheduler has already granted the model
        // mutex back, so retaking the real lock cannot deadlock.
        sched::cv_wait_begin(&c, self.key(), mutex_key, timed);
        drop(take_inner(guard));
        let timed_out = sched::cv_wait_finish(&c);
        let inner = lock_ref.lock().unwrap_or_else(PoisonError::into_inner);
        (
            MutexGuard { inner: Some(inner), lock_ref, mutex_key, model: true },
            timed_out,
        )
    }

    fn std_wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        let lock_ref = guard.lock_ref;
        let inner = take_inner(guard);
        match self.inner.wait(inner) {
            Ok(g) => Ok(remade(g, lock_ref)),
            Err(pe) => Err(PoisonError::new(remade(pe.into_inner(), lock_ref))),
        }
    }
}

/// Extract the real guard; the shim guard's drop then becomes a no-op
/// (its model release, if any, is the caller's responsibility).
fn take_inner<'a, T>(mut guard: MutexGuard<'a, T>) -> std::sync::MutexGuard<'a, T> {
    match guard.inner.take() {
        Some(g) => g,
        None => unreachable!("mutex guard consumed twice"),
    }
}

fn remade<'a, T>(
    inner: std::sync::MutexGuard<'a, T>,
    lock_ref: &'a std::sync::Mutex<T>,
) -> MutexGuard<'a, T> {
    MutexGuard { inner: Some(inner), lock_ref, mutex_key: 0, model: false }
}
