//! Minimal JSON document model: a writer for `--json` CLI output and a
//! small recursive-descent parser used by the round-trip tests (no `serde`
//! in the offline crate set — DESIGN.md §2).
//!
//! Objects preserve insertion order so rendered output is deterministic.
//! Non-finite floats render as `null` (JSON has no NaN/Inf); numbers whose
//! magnitude falls outside a readable decimal range render in exponent
//! notation, which `f64::from_str` (and any JSON parser) accepts.

use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Object member by key (first match).
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(members) => {
                members.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            JsonValue::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Render to a compact JSON string.
    pub fn render(&self) -> String {
        self.to_string()
    }
}

/// Convenience: build an object from `(key, value)` pairs.
pub fn obj(members: Vec<(&str, JsonValue)>) -> JsonValue {
    JsonValue::Obj(members.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience: build an array of numbers.
pub fn num_arr(xs: &[f64]) -> JsonValue {
    JsonValue::Arr(xs.iter().map(|&x| JsonValue::Num(x)).collect())
}

/// Convenience: build an array of strings.
pub fn str_arr<S: AsRef<str>>(xs: &[S]) -> JsonValue {
    JsonValue::Arr(xs.iter().map(|s| JsonValue::Str(s.as_ref().to_string())).collect())
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

fn write_num(f: &mut fmt::Formatter<'_>, x: f64) -> fmt::Result {
    if !x.is_finite() {
        return f.write_str("null");
    }
    if x == 0.0 {
        return f.write_str("0");
    }
    let mag = x.abs();
    if (1e-4..1e15).contains(&mag) {
        // shortest round-trip decimal (Rust's float Display)
        write!(f, "{x}")
    } else {
        // exponent form keeps very small EPB / very large op counts readable
        write!(f, "{x:e}")
    }
}

impl fmt::Display for JsonValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonValue::Null => f.write_str("null"),
            JsonValue::Bool(b) => write!(f, "{b}"),
            JsonValue::Num(x) => write_num(f, *x),
            JsonValue::Str(s) => write_escaped(f, s),
            JsonValue::Arr(items) => {
                f.write_str("[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            JsonValue::Obj(members) => {
                f.write_str("{")?;
                for (i, (k, v)) in members.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

/// Parse a JSON document (must be a single value with only trailing
/// whitespace after it).
pub fn parse(text: &str) -> Result<JsonValue, JsonError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, message: &str) -> JsonError {
        JsonError { offset: self.pos, message: message.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: JsonValue) -> Result<JsonValue, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<JsonValue, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string().map(JsonValue::Str),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<JsonValue, JsonError> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            members.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Obj(members));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let rest = &self.bytes[self.pos..];
            let c = match std::str::from_utf8(rest)
                .ok()
                .and_then(|s| s.chars().next())
            {
                Some(c) => c,
                None => return Err(self.err("unterminated string")),
            };
            self.pos += c.len_utf8();
            match c {
                '"' => return Ok(out),
                '\\' => {
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // surrogate pairs unsupported (writer never emits them)
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad \\u codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c => out.push(c),
            }
        }
    }

    fn number(&mut self) -> Result<JsonValue, JsonError> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(JsonValue::Num)
            .map_err(|_| self.err(&format!("bad number '{text}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_and_parses_back() {
        let doc = obj(vec![
            ("name", JsonValue::Str("DCGAN \"v2\"\n".into())),
            ("gops", JsonValue::Num(1234.56)),
            ("epb", JsonValue::Num(3.21e-18)),
            ("ok", JsonValue::Bool(true)),
            ("none", JsonValue::Null),
            ("xs", num_arr(&[1.0, 0.0, -2.5])),
        ]);
        let text = doc.render();
        let back = parse(&text).unwrap();
        assert_eq!(back, doc);
        assert_eq!(back.get("gops").and_then(|v| v.as_f64()), Some(1234.56));
        assert_eq!(back.get("epb").and_then(|v| v.as_f64()), Some(3.21e-18));
        assert_eq!(back.get("name").and_then(|v| v.as_str()), Some("DCGAN \"v2\"\n"));
    }

    #[test]
    fn extreme_numbers_round_trip_exactly() {
        for &x in &[1.0e300, -7.25e-300, 1.0e-18, 123456789.123, 0.0, -0.0, 1e15] {
            let text = JsonValue::Num(x).render();
            let back = parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(back, x, "{text}");
        }
    }

    #[test]
    fn nan_renders_null() {
        assert_eq!(JsonValue::Num(f64::NAN).render(), "null");
        assert_eq!(JsonValue::Num(f64::INFINITY).render(), "null");
    }

    #[test]
    fn parses_whitespace_and_nesting() {
        let v = parse(" { \"a\" : [ 1 , { \"b\" : \"x\" } , null ] } ").unwrap();
        let arr = v.get("a").and_then(|v| v.as_array()).unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].get("b").and_then(|v| v.as_str()), Some("x"));
        assert_eq!(arr[2], JsonValue::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{\"a\":}").is_err());
        assert!(parse("[1,2,]").is_err());
        assert!(parse("{\"a\":1} x").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("12..3").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            parse("\"\\u0041\\u00e9\"").unwrap(),
            JsonValue::Str("Aé".into())
        );
    }
}
