//! Shared utilities: deterministic RNG, statistics, SI-unit helpers, ASCII
//! table rendering, JSON writing/parsing, error plumbing, a minimal
//! property-based-testing harness, and an in-tree concurrency model
//! checker ([`check`]) for the serving core's lock-free structures.
//!
//! The offline crate cache for this environment carries neither `rand` nor
//! `proptest` nor `criterion` nor `loom`, so this module provides the
//! small, audited subset of each that the rest of the crate needs (see
//! DESIGN.md §2).

pub mod check;
pub mod cli;
pub mod error;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod units;

pub use rng::Pcg32;
pub use table::Table;
