//! Tiny CLI argument parser (no `clap` in the offline crate set).
//!
//! Grammar: `<command> [--switch | --key value | --key=value]...`.
//!
//! Unlike the original lookahead heuristic ("a flag followed by a non-`--`
//! token takes it as a value"), flags are now declared **explicitly** as
//! either [`FlagKind::Switch`] (boolean, takes no value) or
//! [`FlagKind::Value`] (requires a value). A valued flag with a missing
//! value, a switch given a value, an unknown flag, or a stray positional
//! token all produce a typed [`CliError`] instead of being silently
//! misparsed or ignored.

use std::collections::HashMap;
use std::fmt;

/// Whether a flag is a boolean switch or requires a value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlagKind {
    /// Boolean: present or absent; `--flag=value` is an error.
    Switch,
    /// Requires a value: `--flag value` or `--flag=value`.
    Value,
}

/// Declaration of one accepted flag.
#[derive(Debug, Clone, Copy)]
pub struct FlagDef {
    pub name: &'static str,
    pub kind: FlagKind,
}

/// Declare a switch flag.
pub const fn switch(name: &'static str) -> FlagDef {
    FlagDef { name, kind: FlagKind::Switch }
}

/// Declare a valued flag.
pub const fn value(name: &'static str) -> FlagDef {
    FlagDef { name, kind: FlagKind::Value }
}

/// Typed CLI parse error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CliError {
    /// `--flag` is not in the command's spec.
    UnknownFlag { flag: String },
    /// A [`FlagKind::Value`] flag had no value (end of args, or the next
    /// token is another flag).
    MissingValue { flag: String },
    /// A [`FlagKind::Switch`] flag was given `=value`.
    UnexpectedValue { flag: String, value: String },
    /// A valued flag's value failed to parse.
    InvalidValue { flag: String, value: String, expected: &'static str },
    /// A bare token where a flag was expected.
    StrayToken { token: String },
    /// The same flag appeared twice.
    DuplicateFlag { flag: String },
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CliError::UnknownFlag { flag } => write!(f, "unknown flag '--{flag}'"),
            CliError::MissingValue { flag } => {
                write!(f, "flag '--{flag}' requires a value (use '--{flag} <value>')")
            }
            CliError::UnexpectedValue { flag, value } => {
                write!(f, "switch '--{flag}' takes no value (got '{value}')")
            }
            CliError::InvalidValue { flag, value, expected } => {
                write!(f, "flag '--{flag}': '{value}' is not a valid {expected}")
            }
            CliError::StrayToken { token } => {
                write!(f, "unexpected argument '{token}' (flags start with '--')")
            }
            CliError::DuplicateFlag { flag } => write!(f, "flag '--{flag}' given twice"),
        }
    }
}

impl std::error::Error for CliError {}

/// Parsed flags for one command.
#[derive(Debug, Clone, Default)]
pub struct ParsedFlags {
    values: HashMap<String, String>,
}

impl ParsedFlags {
    /// Parse an argument list (without the command token) against a spec.
    pub fn parse(args: &[String], spec: &[FlagDef]) -> Result<ParsedFlags, CliError> {
        let mut values: HashMap<String, String> = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let token = &args[i];
            let Some(body) = token.strip_prefix("--") else {
                return Err(CliError::StrayToken { token: token.clone() });
            };
            let (name, inline) = match body.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (body, None),
            };
            let def = spec
                .iter()
                .find(|d| d.name == name)
                .ok_or_else(|| CliError::UnknownFlag { flag: name.to_string() })?;
            if values.contains_key(def.name) {
                return Err(CliError::DuplicateFlag { flag: name.to_string() });
            }
            let stored = match (def.kind, inline) {
                (FlagKind::Switch, None) => "true".to_string(),
                (FlagKind::Switch, Some(v)) => {
                    return Err(CliError::UnexpectedValue { flag: name.to_string(), value: v })
                }
                (FlagKind::Value, Some(v)) => v,
                (FlagKind::Value, None) => {
                    let next = args.get(i + 1);
                    match next {
                        Some(v) if !v.starts_with("--") => {
                            i += 1;
                            v.clone()
                        }
                        _ => return Err(CliError::MissingValue { flag: name.to_string() }),
                    }
                }
            };
            values.insert(def.name.to_string(), stored);
            i += 1;
        }
        Ok(ParsedFlags { values })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.values.contains_key(key)
    }

    /// Parse a valued flag as `usize`, with a default when absent and a
    /// typed error when present-but-garbled.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::InvalidValue {
                flag: key.to_string(),
                value: v.to_string(),
                expected: "integer",
            }),
        }
    }
}

/// Parse an `N,K,L,M` quadruple.
pub fn parse_quad(s: &str) -> Option<(usize, usize, usize, usize)> {
    let parts: Vec<usize> = s.split(',').filter_map(|p| p.trim().parse().ok()).collect();
    if parts.len() == 4 && s.split(',').count() == 4 {
        Some((parts[0], parts[1], parts[2], parts[3]))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    const SPEC: &[FlagDef] = &[
        value("model"),
        value("batch"),
        switch("no-sparse"),
        switch("json"),
    ];

    #[test]
    fn parses_values_and_switches_by_spec() {
        let f = ParsedFlags::parse(
            &argv(&["--model", "dcgan", "--no-sparse", "--batch", "4"]),
            SPEC,
        )
        .unwrap();
        assert_eq!(f.get("model"), Some("dcgan"));
        assert!(f.has("no-sparse"));
        assert!(!f.has("json"));
        assert_eq!(f.usize_or("batch", 1).unwrap(), 4);
        assert_eq!(f.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn equals_syntax() {
        let f = ParsedFlags::parse(&argv(&["--batch=8", "--model=artgan"]), SPEC).unwrap();
        assert_eq!(f.usize_or("batch", 1).unwrap(), 8);
        assert_eq!(f.get("model"), Some("artgan"));
    }

    #[test]
    fn missing_value_is_an_error_not_a_switch() {
        // trailing valued flag
        assert_eq!(
            ParsedFlags::parse(&argv(&["--batch"]), SPEC),
            Err(CliError::MissingValue { flag: "batch".into() })
        );
        // valued flag followed by another flag (the old lookahead heuristic
        // silently turned this into a boolean)
        assert_eq!(
            ParsedFlags::parse(&argv(&["--batch", "--json"]), SPEC),
            Err(CliError::MissingValue { flag: "batch".into() })
        );
    }

    #[test]
    fn switch_with_value_rejected() {
        assert_eq!(
            ParsedFlags::parse(&argv(&["--no-sparse=1"]), SPEC),
            Err(CliError::UnexpectedValue { flag: "no-sparse".into(), value: "1".into() })
        );
    }

    #[test]
    fn unknown_and_stray_and_duplicate() {
        assert_eq!(
            ParsedFlags::parse(&argv(&["--frobnicate"]), SPEC),
            Err(CliError::UnknownFlag { flag: "frobnicate".into() })
        );
        assert_eq!(
            ParsedFlags::parse(&argv(&["stray"]), SPEC),
            Err(CliError::StrayToken { token: "stray".into() })
        );
        assert_eq!(
            ParsedFlags::parse(&argv(&["--json", "--json"]), SPEC),
            Err(CliError::DuplicateFlag { flag: "json".into() })
        );
    }

    #[test]
    fn bad_integer_value_is_typed() {
        let f = ParsedFlags::parse(&argv(&["--batch", "four"]), SPEC).unwrap();
        assert!(matches!(
            f.usize_or("batch", 1),
            Err(CliError::InvalidValue { .. })
        ));
    }

    #[test]
    fn empty_args_are_fine() {
        let f = ParsedFlags::parse(&[], SPEC).unwrap();
        assert!(!f.has("json"));
    }

    #[test]
    fn quad_parsing() {
        assert_eq!(parse_quad("16,2,11,3"), Some((16, 2, 11, 3)));
        assert_eq!(parse_quad(" 16 , 2 , 11 , 3 "), Some((16, 2, 11, 3)));
        assert_eq!(parse_quad("16,2,11"), None);
        assert_eq!(parse_quad("a,b,c,d"), None);
        assert_eq!(parse_quad("1,2,3,4,5"), None);
    }
}
