//! Tiny CLI argument parser (no clap in the offline crate set).
//!
//! Grammar: `<command> [--key value | --switch]...`. A flag followed by a
//! non-`--` token takes it as its value; otherwise it is a boolean switch.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Cli {
    pub command: String,
    pub flags: HashMap<String, String>,
}

impl Cli {
    /// Parse from an argument list (without argv[0]).
    pub fn parse(args: &[String]) -> Cli {
        let command = args.first().cloned().unwrap_or_default();
        let mut flags = HashMap::new();
        let mut i = 1;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                let next_is_value =
                    args.get(i + 1).map(|n| !n.starts_with("--")).unwrap_or(false);
                if next_is_value {
                    flags.insert(key.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                i += 1; // stray token: ignored (caller may warn)
            }
        }
        Cli { command, flags }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

/// Parse an `N,K,L,M` quadruple.
pub fn parse_quad(s: &str) -> Option<(usize, usize, usize, usize)> {
    let parts: Vec<usize> = s.split(',').filter_map(|p| p.trim().parse().ok()).collect();
    if parts.len() == 4 {
        Some((parts[0], parts[1], parts[2], parts[3]))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_values_and_switches() {
        let c = Cli::parse(&argv(&["simulate", "--model", "dcgan", "--no-sparse", "--batch", "4"]));
        assert_eq!(c.command, "simulate");
        assert_eq!(c.get("model"), Some("dcgan"));
        assert!(c.has("no-sparse"));
        assert_eq!(c.get_usize("batch", 1), 4);
        assert_eq!(c.get_usize("missing", 7), 7);
    }

    #[test]
    fn empty_args_are_fine() {
        let c = Cli::parse(&[]);
        assert_eq!(c.command, "");
        assert!(c.flags.is_empty());
    }

    #[test]
    fn trailing_switch_is_boolean() {
        let c = Cli::parse(&argv(&["dse", "--verbose"]));
        assert_eq!(c.get("verbose"), Some("true"));
    }

    #[test]
    fn quad_parsing() {
        assert_eq!(parse_quad("16,2,11,3"), Some((16, 2, 11, 3)));
        assert_eq!(parse_quad(" 16 , 2 , 11 , 3 "), Some((16, 2, 11, 3)));
        assert_eq!(parse_quad("16,2,11"), None);
        assert_eq!(parse_quad("a,b,c,d"), None);
    }
}
