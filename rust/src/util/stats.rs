//! Small statistics helpers used by the simulator, benches and the
//! coordinator's latency metrics.

/// Arithmetic mean; `0.0` for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean; `0.0` for an empty slice. All inputs must be positive.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    debug_assert!(xs.iter().all(|&x| x > 0.0), "geomean needs positive inputs");
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, `q` in `[0, 100]`. Sorts a copy —
/// callers computing several quantiles of the same data should sort once
/// and use [`percentile_sorted`].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in percentile input"));
    percentile_sorted(&v, q)
}

/// [`percentile`] over data that is already sorted ascending (no copy,
/// no re-sort).
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (rank - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Online latency/size histogram with fixed power-of-two style buckets.
///
/// Used by the coordinator's metrics endpoint; allocation-free on the record
/// path.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Bucket upper bounds (exclusive), ascending; final bucket is +inf.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    min: f64,
    max: f64,
    n: u64,
}

impl Histogram {
    /// Exponential buckets covering `[lo, hi]` with `per_decade` buckets per
    /// decade.
    pub fn exponential(lo: f64, hi: f64, per_decade: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && per_decade > 0);
        let mut bounds = Vec::new();
        let step = 10f64.powf(1.0 / per_decade as f64);
        let mut b = lo;
        while b < hi * step {
            bounds.push(b);
            b *= step;
        }
        let n_buckets = bounds.len() + 1;
        Histogram {
            bounds,
            counts: vec![0; n_buckets],
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            n: 0,
        }
    }

    /// Record one observation.
    pub fn record(&mut self, x: f64) {
        let idx = self.bounds.partition_point(|&b| b <= x);
        self.counts[idx] += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.n += 1;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Fold another histogram with the *same bucket specification* into
    /// this one (the coordinator merges per-shard histograms this way).
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(
            self.bounds, other.bounds,
            "histogram merge requires identical bucket specs"
        );
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sum += other.sum;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Approximate quantile from the histogram buckets (upper-bound biased).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (q * self.n as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    self.max
                };
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_geomean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((geomean(&[1.0, 10.0, 100.0]) - 10.0).abs() < 1e-9);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(percentile(&xs, 50.0), 25.0);
        // pre-sorted fast path agrees with the sorting version
        let unsorted = [30.0, 10.0, 40.0, 20.0];
        for q in [0.0, 37.5, 50.0, 95.0, 100.0] {
            assert_eq!(percentile(&unsorted, q), percentile_sorted(&xs, q), "q={q}");
        }
    }

    #[test]
    fn stddev_constant_is_zero() {
        assert_eq!(stddev(&[5.0, 5.0, 5.0]), 0.0);
    }

    #[test]
    fn histogram_records_and_quantiles() {
        let mut h = Histogram::exponential(1e-6, 10.0, 10);
        for i in 1..=1000 {
            h.record(i as f64 / 1000.0);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 0.5005).abs() < 1e-9);
        let p50 = h.quantile(0.5);
        assert!(p50 > 0.4 && p50 < 0.65, "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!(p99 > 0.9, "p99={p99}");
        assert!(h.min() > 0.0 && h.max() <= 1.0);
    }

    #[test]
    fn histogram_merge_equals_recording_everything_in_one() {
        let mut a = Histogram::exponential(1e-3, 10.0, 5);
        let mut b = Histogram::exponential(1e-3, 10.0, 5);
        let mut whole = Histogram::exponential(1e-3, 10.0, 5);
        for i in 1..=50 {
            let x = i as f64 / 10.0;
            if i % 2 == 0 {
                a.record(x);
            } else {
                b.record(x);
            }
            whole.record(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(a.quantile(q), whole.quantile(q), "q={q}");
        }
    }

    #[test]
    fn histogram_out_of_range_goes_to_edge_buckets() {
        let mut h = Histogram::exponential(1.0, 10.0, 5);
        h.record(0.001); // below lo -> first bucket
        h.record(1e9); // above hi -> overflow bucket
        assert_eq!(h.count(), 2);
        assert_eq!(h.max(), 1e9);
    }
}
