//! Small statistics helpers used by the simulator, benches and the
//! coordinator's latency metrics.

use std::fmt;

/// Arithmetic mean; `0.0` for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean over the **positive, finite** entries of `xs`.
///
/// Contract: non-positive and non-finite entries (0, negatives, NaN, ±inf)
/// are skipped — the geometric mean is undefined for them, and the old
/// `debug_assert!` guard meant release builds silently returned NaN.
/// Returns `0.0` when no entry qualifies (including the empty slice).
pub fn geomean(xs: &[f64]) -> f64 {
    let mut log_sum = 0.0f64;
    let mut n = 0usize;
    for &x in xs {
        if x > 0.0 && x.is_finite() {
            log_sum += x.ln();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        (log_sum / n as f64).exp()
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, `q` in `[0, 100]`. Sorts a copy —
/// callers computing several quantiles of the same data should sort once
/// and use [`percentile_sorted`]. Panics on NaN input; the serving path
/// never produces one ([`Histogram::record`] drops non-finite samples and
/// driver latencies come from `Instant` differences).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in percentile input"));
    percentile_sorted(&v, q)
}

/// [`percentile`] over data that is already sorted ascending (no copy,
/// no re-sort).
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (rank - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Typed error from [`Histogram::try_merge`]: the operands were built with
/// different bucket specifications, so folding their counts would silently
/// attribute observations to the wrong latency ranges.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BucketMismatch {
    /// Bucket-bound count of the left (receiving) histogram.
    pub left_bounds: usize,
    /// Bucket-bound count of the right (merged-in) histogram.
    pub right_bounds: usize,
    /// First index at which the bound values differ, when the counts
    /// match but the edges do not.
    pub first_divergence: Option<usize>,
}

impl fmt::Display for BucketMismatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.first_divergence {
            Some(i) => write!(
                f,
                "histograms share {} bounds but diverge at bucket {i}",
                self.left_bounds
            ),
            None => write!(
                f,
                "histograms have {} vs {} bucket bounds",
                self.left_bounds, self.right_bounds
            ),
        }
    }
}

impl std::error::Error for BucketMismatch {}

/// Online latency/size histogram with fixed power-of-two style buckets.
///
/// Used by the coordinator's metrics endpoint; allocation-free on the record
/// path. Non-finite observations are dropped (see [`Histogram::record`]), so
/// `min`/`max`/`sum` — and every quantile derived from them — stay finite.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Bucket upper bounds (exclusive), ascending; final bucket is +inf.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    min: f64,
    max: f64,
    n: u64,
    /// Non-finite observations rejected by [`Histogram::record`].
    dropped: u64,
}

impl Histogram {
    /// Exponential buckets covering `[lo, hi]` with `per_decade` buckets per
    /// decade.
    ///
    /// Bounds are computed in closed form (`lo · step^i`), not by an
    /// accumulating multiply: the running-product version drifts by an ulp
    /// per bucket, so two histograms covering a large `hi/lo` ratio could
    /// disagree on their edges depending on how they were built. The final
    /// bound is asserted to cover `hi`.
    pub fn exponential(lo: f64, hi: f64, per_decade: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && per_decade > 0);
        assert!(hi.is_finite());
        let step = 10f64.powf(1.0 / per_decade as f64);
        let mut bounds = Vec::new();
        let mut i = 0i32;
        loop {
            let b = lo * step.powi(i);
            bounds.push(b);
            if b >= hi {
                break;
            }
            i += 1;
        }
        let last = *bounds.last().unwrap_or(&lo);
        assert!(last >= hi, "final bucket bound {last} must cover hi={hi}");
        let n_buckets = bounds.len() + 1;
        Histogram {
            bounds,
            counts: vec![0; n_buckets],
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            n: 0,
            dropped: 0,
        }
    }

    /// Record one observation.
    ///
    /// Non-finite observations (NaN, ±inf) are **ignored** and counted in
    /// [`Histogram::dropped`]: a single poisoned sample must not corrupt
    /// `min`/`max`/`sum` — and through them every p50/p95/p99 this
    /// histogram reports — for the rest of the serving run.
    pub fn record(&mut self, x: f64) {
        if !x.is_finite() {
            self.dropped += 1;
            return;
        }
        let idx = self.bounds.partition_point(|&b| b <= x);
        self.counts[idx] += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.n += 1;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Observations rejected by [`Histogram::record`] as non-finite.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Fold another histogram with the *same bucket specification* into
    /// this one. Returns [`BucketMismatch`] when the bucket bounds differ —
    /// merging differently-shaped histograms would silently mis-attribute
    /// counts. The coordinator merges per-shard histograms this way; they
    /// are all built by `ServingMetrics::new`, so a mismatch there is a
    /// construction bug, not an operational condition.
    pub fn try_merge(&mut self, other: &Histogram) -> Result<(), BucketMismatch> {
        if self.bounds != other.bounds {
            let first_divergence = if self.bounds.len() == other.bounds.len() {
                self.bounds.iter().zip(&other.bounds).position(|(a, b)| a != b)
            } else {
                None
            };
            return Err(BucketMismatch {
                left_bounds: self.bounds.len(),
                right_bounds: other.bounds.len(),
                first_divergence,
            });
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sum += other.sum;
        self.n += other.n;
        self.dropped += other.dropped;
        if other.n > 0 {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
        Ok(())
    }

    /// [`Histogram::try_merge`] for callers that construct both operands
    /// from one spec (the coordinator path). Panics — with the typed
    /// error's message — on mismatched bucket specifications.
    pub fn merge(&mut self, other: &Histogram) {
        if let Err(e) = self.try_merge(other) {
            panic!("histogram merge requires identical bucket specs: {e}");
        }
    }

    /// Approximate quantile from the histogram buckets (upper-bound biased).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (q * self.n as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    self.max
                };
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_geomean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((geomean(&[1.0, 10.0, 100.0]) - 10.0).abs() < 1e-9);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn geomean_skips_nonpositive_and_nonfinite() {
        // the documented contract: only positive finite entries participate,
        // in release builds too (the old guard was a debug_assert!)
        let gm = geomean(&[1.0, 10.0, 100.0, 0.0, -5.0, f64::NAN, f64::INFINITY]);
        assert!((gm - 10.0).abs() < 1e-9, "gm={gm}");
        assert!(gm.is_finite());
        assert_eq!(geomean(&[-1.0, 0.0, f64::NAN]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(percentile(&xs, 50.0), 25.0);
        // pre-sorted fast path agrees with the sorting version
        let unsorted = [30.0, 10.0, 40.0, 20.0];
        for q in [0.0, 37.5, 50.0, 95.0, 100.0] {
            assert_eq!(percentile(&unsorted, q), percentile_sorted(&xs, q), "q={q}");
        }
    }

    #[test]
    fn stddev_constant_is_zero() {
        assert_eq!(stddev(&[5.0, 5.0, 5.0]), 0.0);
    }

    #[test]
    fn histogram_records_and_quantiles() {
        let mut h = Histogram::exponential(1e-6, 10.0, 10);
        for i in 1..=1000 {
            h.record(i as f64 / 1000.0);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 0.5005).abs() < 1e-9);
        let p50 = h.quantile(0.5);
        assert!(p50 > 0.4 && p50 < 0.65, "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!(p99 > 0.9, "p99={p99}");
        assert!(h.min() > 0.0 && h.max() <= 1.0);
    }

    #[test]
    fn histogram_rejects_nonfinite_records() {
        let mut h = Histogram::exponential(1e-3, 10.0, 5);
        h.record(1.0);
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(f64::NEG_INFINITY);
        h.record(2.0);
        // poisoned samples are dropped, not folded into min/max/sum
        assert_eq!(h.count(), 2);
        assert_eq!(h.dropped(), 3);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 2.0);
        assert!((h.mean() - 1.5).abs() < 1e-12);
        assert!(h.quantile(0.99).is_finite());
    }

    #[test]
    fn histogram_merge_equals_recording_everything_in_one() {
        let mut a = Histogram::exponential(1e-3, 10.0, 5);
        let mut b = Histogram::exponential(1e-3, 10.0, 5);
        let mut whole = Histogram::exponential(1e-3, 10.0, 5);
        for i in 1..=50 {
            let x = i as f64 / 10.0;
            if i % 2 == 0 {
                a.record(x);
            } else {
                b.record(x);
            }
            whole.record(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(a.quantile(q), whole.quantile(q), "q={q}");
        }
    }

    #[test]
    fn merge_carries_dropped_and_handles_empty_operands() {
        let mut a = Histogram::exponential(1e-3, 10.0, 5);
        let mut b = Histogram::exponential(1e-3, 10.0, 5);
        b.record(f64::NAN);
        b.record(0.5);
        a.merge(&b);
        assert_eq!(a.count(), 1);
        assert_eq!(a.dropped(), 1);
        assert_eq!(a.min(), 0.5);
        // merging an empty histogram must not disturb min/max
        let empty = Histogram::exponential(1e-3, 10.0, 5);
        a.merge(&empty);
        assert_eq!(a.min(), 0.5);
        assert_eq!(a.max(), 0.5);
    }

    #[test]
    fn cross_shape_merge_is_a_typed_error() {
        let mut a = Histogram::exponential(1e-3, 10.0, 5);
        let b = Histogram::exponential(1e-3, 10.0, 10);
        let err = a.try_merge(&b).unwrap_err();
        assert!(err.left_bounds != err.right_bounds);
        assert!(err.to_string().contains("bucket bounds"));
        // same count, different edges → divergence index reported
        let mut c = Histogram::exponential(1e-3, 10.0, 5);
        let d = Histogram::exponential(2e-3, 20.0, 5);
        if c.bounds.len() == d.bounds.len() {
            let err = c.try_merge(&d).unwrap_err();
            assert_eq!(err.first_divergence, Some(0));
        }
    }

    #[test]
    #[should_panic(expected = "identical bucket specs")]
    fn cross_shape_merge_panics_with_typed_message() {
        let mut a = Histogram::exponential(1e-3, 10.0, 5);
        let b = Histogram::exponential(1e-3, 10.0, 10);
        a.merge(&b);
    }

    #[test]
    fn exponential_bounds_are_closed_form_over_wide_ranges() {
        // 18 decades × 10 buckets/decade: the accumulating `b *= step`
        // construction drifts ~1 ulp per bucket; the closed form must stay
        // within a few ulps of lo·10^(i/per_decade) at every index.
        let per_decade = 10usize;
        let (lo, hi) = (1e-9, 1e9);
        let h = Histogram::exponential(lo, hi, per_decade);
        assert!(h.bounds.len() > 180, "expected ≥ one bound per bucket-step");
        for (i, &b) in h.bounds.iter().enumerate() {
            let reference = lo * 10f64.powf(i as f64 / per_decade as f64);
            let rel = (b - reference).abs() / reference;
            assert!(rel < 1e-13, "bound {i}: {b} vs {reference} (rel {rel:.2e})");
        }
        // the final bound covers hi, so in-range samples never land in the
        // +inf overflow bucket
        assert!(*h.bounds.last().unwrap() >= hi);
        // two histograms over the same spec agree bit-for-bit → mergeable
        let mut a = Histogram::exponential(lo, hi, per_decade);
        let b = Histogram::exponential(lo, hi, per_decade);
        assert!(a.try_merge(&b).is_ok());
    }

    #[test]
    fn histogram_out_of_range_goes_to_edge_buckets() {
        let mut h = Histogram::exponential(1.0, 10.0, 5);
        h.record(0.001); // below lo -> first bucket
        h.record(1e9); // above hi -> overflow bucket
        assert_eq!(h.count(), 2);
        assert_eq!(h.max(), 1e9);
    }
}
