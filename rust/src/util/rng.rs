//! Deterministic pseudo-random number generation.
//!
//! PCG32 (O'Neill 2014, `pcg32_random_r` reference constants) seeded through
//! SplitMix64. Deterministic across platforms; good enough statistical
//! quality for workload generation, DSE sampling, and property tests. Not
//! cryptographic.

/// SplitMix64 step — used to expand a single `u64` seed into stream state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Minimal PCG32 generator (XSH-RR variant).
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    const MULT: u64 = 6_364_136_223_846_793_005;

    /// Construct from a seed; the stream id is derived via SplitMix64 so two
    /// generators with different seeds are decorrelated.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let init_state = splitmix64(&mut sm);
        let init_inc = splitmix64(&mut sm) | 1;
        let mut rng = Pcg32 { state: 0, inc: init_inc };
        rng.state = init_state.wrapping_add(rng.inc);
        rng.next_u32();
        rng
    }

    /// Next uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next uniform `u64`.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`, 32 bits of entropy.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[0, 1)`, 53 bits of entropy.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire-style rejection-free debiased
    /// multiply-shift; slight bias < 2^-32 acceptable for our uses).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        ((self.next_u32() as u64 * bound as u64) >> 32) as u32
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// Standard normal sample (Box–Muller; one of the pair discarded for
    /// simplicity — this RNG is not on any hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with uniform `[-1, 1)` f32 values (weight-style init).
    pub fn fill_uniform_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.f32() * 2.0 - 1.0;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Derive a deterministic child stream for `stream_id`.
    ///
    /// The child seed is expanded from the parent's *current* `(state,
    /// inc)` pair and the stream id via SplitMix64, so:
    ///
    /// - forking is a pure read — the parent's own sequence is unchanged;
    /// - the same parent state and the same `stream_id` always yield the
    ///   same child, no matter which thread forks or when it is consumed
    ///   (this is what makes per-worker / per-model arrival streams
    ///   reproducible independent of scheduling);
    /// - different stream ids yield decorrelated, effectively disjoint
    ///   streams (distinct PCG32 increments select distinct sequences).
    pub fn fork(&self, stream_id: u64) -> Pcg32 {
        let mut s = self.state.rotate_left(29)
            ^ self.inc
            ^ stream_id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Pcg32::new(splitmix64(&mut s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_decorrelated() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let equal = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(equal <= 1, "streams should differ: {equal} collisions");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Pcg32::new(9);
        for bound in [1u32, 2, 3, 17, 255, 1 << 20] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn uniform_mean_is_centered() {
        let mut r = Pcg32::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn fork_is_deterministic_and_pure() {
        let parent = Pcg32::new(42);
        let mut a = parent.fork(3);
        let mut b = parent.fork(3);
        for _ in 0..64 {
            assert_eq!(a.next_u32(), b.next_u32(), "same id must yield the same child");
        }
        // forking never advances the parent
        let mut p1 = Pcg32::new(42);
        let mut p2 = Pcg32::new(42);
        let _ = p1.fork(0);
        let _ = p1.fork(u64::MAX);
        for _ in 0..64 {
            assert_eq!(p1.next_u32(), p2.next_u32(), "fork must be a pure read");
        }
    }

    #[test]
    fn forked_streams_are_disjoint() {
        let mut parent = Pcg32::new(7);
        parent.next_u32(); // fork from a mid-sequence state, not just the seed
        let ids = [0u64, 1, 2, 63, u64::MAX];
        let mut streams: Vec<Vec<u32>> = ids
            .iter()
            .map(|&id| {
                let mut c = parent.fork(id);
                (0..256).map(|_| c.next_u32()).collect()
            })
            .collect();
        // the parent's own continuation is one more stream to compare
        streams.push((0..256).map(|_| parent.next_u32()).collect());
        for i in 0..streams.len() {
            for j in (i + 1)..streams.len() {
                let collisions =
                    streams[i].iter().zip(&streams[j]).filter(|(a, b)| a == b).count();
                assert!(
                    collisions <= 1,
                    "streams {i} and {j} overlap ({collisions} positionwise collisions)"
                );
            }
        }
    }

    #[test]
    fn fork_depends_on_parent_state() {
        // the same id forked from two different parent positions must differ
        let mut parent = Pcg32::new(11);
        let mut early = parent.fork(5);
        parent.next_u32();
        let mut late = parent.fork(5);
        let equal = (0..64).filter(|_| early.next_u32() == late.next_u32()).count();
        assert!(equal <= 1, "children must track the parent state: {equal} collisions");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
