//! Minimal ASCII table renderer for bench/report output.
//!
//! The bench harness prints paper-style tables with it (no external
//! table/formatting crates are available offline).

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table: a header row plus data rows, rendered with
/// box-drawing-free ASCII so it survives any terminal / log file.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: Option<String>,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            title: None,
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Attach a title printed above the table.
    pub fn with_title<S: Into<String>>(mut self, title: S) -> Self {
        self.title = Some(title.into());
        self
    }

    /// Append a data row; must match the header width.
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// The table title, if one was attached.
    pub fn title(&self) -> Option<&str> {
        self.title.as_deref()
    }

    /// Column headers.
    pub fn header(&self) -> &[String] {
        &self.header
    }

    /// Data rows (used by the JSON/table round-trip tests).
    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    /// True if no data rows have been added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to a string. First column is left-aligned, the rest
    /// right-aligned (numeric convention), unless a cell is non-numeric.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let aligns: Vec<Align> = (0..ncol)
            .map(|i| if i == 0 { Align::Left } else { Align::Right })
            .collect();

        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| {
                    let pad = widths[i].saturating_sub(c.chars().count());
                    match aligns[i] {
                        Align::Left => format!(" {}{} ", c, " ".repeat(pad)),
                        Align::Right => format!(" {}{} ", " ".repeat(pad), c),
                    }
                })
                .collect::<Vec<_>>()
                .join("|")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render and print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a ratio like the paper does ("134.64x").
pub fn fmt_ratio(r: f64) -> String {
    format!("{:.2}x", r)
}

/// Format a float with 2 decimals.
pub fn f2(x: f64) -> String {
    format!("{:.2}", x)
}

/// Format a float with 3 significant-ish decimals for small values.
pub fn f3(x: f64) -> String {
    format!("{:.3}", x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["model", "GOPS"]).with_title("demo");
        t.row(vec!["DCGAN", "123.4"]);
        t.row(vec!["CycleGAN-long-name", "7.0"]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.lines().count() == 5, "{s}");
        // header separator row present
        assert!(s.lines().nth(2).unwrap().starts_with('-'));
        // right alignment of numeric column: "7.0" ends the line-ish
        assert!(s.lines().last().unwrap().trim_end().ends_with("7.0"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn ratio_formatting() {
        assert_eq!(fmt_ratio(4.4), "4.40x");
        assert_eq!(f2(45.589), "45.59");
    }
}
