//! SI-unit conversion helpers and dB/dBm arithmetic.
//!
//! Internal convention across the crate: **seconds, watts, joules, hertz**
//! as `f64`. These helpers exist so device constants can be written in the
//! units the paper quotes them in (ns, µs, ps, mW, µW, dBm).

/// Nanoseconds → seconds.
#[inline]
pub const fn ns(x: f64) -> f64 {
    x * 1e-9
}

/// Microseconds → seconds.
#[inline]
pub const fn us(x: f64) -> f64 {
    x * 1e-6
}

/// Picoseconds → seconds.
#[inline]
pub const fn ps(x: f64) -> f64 {
    x * 1e-12
}

/// Milliwatts → watts.
#[inline]
pub const fn mw(x: f64) -> f64 {
    x * 1e-3
}

/// Microwatts → watts.
#[inline]
pub const fn uw(x: f64) -> f64 {
    x * 1e-6
}

/// Gigahertz → hertz.
#[inline]
pub const fn ghz(x: f64) -> f64 {
    x * 1e9
}

/// Watts → dBm.
#[inline]
pub fn watts_to_dbm(w: f64) -> f64 {
    10.0 * (w / 1e-3).log10()
}

/// dBm → watts.
#[inline]
pub fn dbm_to_watts(dbm: f64) -> f64 {
    1e-3 * 10f64.powf(dbm / 10.0)
}

/// Linear power ratio → dB.
#[inline]
pub fn ratio_to_db(r: f64) -> f64 {
    10.0 * r.log10()
}

/// dB → linear power ratio.
#[inline]
pub fn db_to_ratio(db: f64) -> f64 {
    10f64.powf(db / 10.0)
}

/// Pretty-print a seconds value with an adaptive unit (ns/µs/ms/s).
pub fn fmt_time(secs: f64) -> String {
    let a = secs.abs();
    if a < 1e-6 {
        format!("{:.2} ns", secs * 1e9)
    } else if a < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if a < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.2} s", secs)
    }
}

/// Pretty-print a joules value with an adaptive unit (pJ/nJ/µJ/mJ/J).
pub fn fmt_energy(j: f64) -> String {
    let a = j.abs();
    if a < 1e-9 {
        format!("{:.2} pJ", j * 1e12)
    } else if a < 1e-6 {
        format!("{:.2} nJ", j * 1e9)
    } else if a < 1e-3 {
        format!("{:.2} µJ", j * 1e6)
    } else if a < 1.0 {
        format!("{:.2} mJ", j * 1e3)
    } else {
        format!("{:.2} J", j)
    }
}

/// Pretty-print watts (µW/mW/W).
pub fn fmt_power(w: f64) -> String {
    let a = w.abs();
    if a < 1e-3 {
        format!("{:.2} µW", w * 1e6)
    } else if a < 1.0 {
        format!("{:.2} mW", w * 1e3)
    } else {
        format!("{:.2} W", w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_roundtrip() {
        assert!((dbm_to_watts(watts_to_dbm(0.01)) - 0.01).abs() < 1e-12);
        assert!((db_to_ratio(ratio_to_db(42.0)) - 42.0).abs() < 1e-9);
    }

    #[test]
    fn known_points() {
        assert!((watts_to_dbm(1e-3) - 0.0).abs() < 1e-12); // 1 mW = 0 dBm
        assert!((dbm_to_watts(30.0) - 1.0).abs() < 1e-12); // 30 dBm = 1 W
        assert!((db_to_ratio(3.0103) - 2.0).abs() < 1e-3); // 3 dB ≈ 2x
        assert_eq!(ns(20.0), 20e-9);
        assert_eq!(mw(27.5), 27.5e-3);
    }

    #[test]
    fn formatting_picks_units() {
        assert_eq!(fmt_time(2.5e-9), "2.50 ns");
        assert_eq!(fmt_time(3.1e-5), "31.00 µs");
        assert_eq!(fmt_energy(1.5e-12), "1.50 pJ");
        assert_eq!(fmt_power(0.0275), "27.50 mW");
    }
}
