//! Minimal error plumbing for the offline crate set (no `anyhow`): a boxed
//! dynamic error alias, a [`Context`] extension trait for annotating error
//! chains, and the [`crate::bail!`] early-return macro.
//!
//! This covers the small slice of `anyhow`'s surface the crate actually
//! uses; typed errors live next to their subsystems ([`crate::api::ApiError`],
//! [`crate::arch::config::ConfigError`], [`crate::models::layer::ShapeError`]).

use std::error::Error as StdError;
use std::fmt;

/// Boxed dynamic error, thread-safe so it can cross channel/thread seams.
pub type BoxError = Box<dyn StdError + Send + Sync + 'static>;

/// Result alias used by the untyped (I/O-ish) paths of the crate.
pub type Result<T> = std::result::Result<T, BoxError>;

/// A plain string error.
#[derive(Debug)]
pub struct Message(pub String);

impl fmt::Display for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for Message {}

/// Build a [`BoxError`] from a message.
pub fn err(msg: impl Into<String>) -> BoxError {
    Box::new(Message(msg.into()))
}

/// An error wrapped with a context message; `Display` renders the whole
/// chain (`context: cause`) so `{}`/`{:#}` both read like anyhow's chains.
#[derive(Debug)]
pub struct Contexted {
    context: String,
    source: BoxError,
}

impl fmt::Display for Contexted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.context, self.source)
    }
}

impl StdError for Contexted {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        Some(self.source.as_ref() as &(dyn StdError + 'static))
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`, mirroring the `anyhow::Context` API.
pub trait Context<T> {
    /// Annotate the error with a fixed message.
    fn context(self, msg: impl Into<String>) -> Result<T>;
    /// Annotate the error with a lazily-built message.
    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<BoxError>,
{
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.map_err(|e| {
            Box::new(Contexted { context: msg.into(), source: e.into() }) as BoxError
        })
    }

    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            Box::new(Contexted { context: f().into(), source: e.into() }) as BoxError
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.ok_or_else(|| err(msg))
    }

    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T> {
        self.ok_or_else(|| err(f()))
    }
}

/// Early-return with a formatted [`BoxError`] (anyhow's `bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::err(format!($($arg)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(err("root cause"))
    }

    #[test]
    fn context_chains_render() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: root cause");
        assert!(e.source().is_some());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        assert_eq!(Some(7).context("fine").unwrap(), 7);
    }

    #[test]
    fn io_errors_convert() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.context("reading file").unwrap_err();
        assert!(e.to_string().starts_with("reading file:"));
    }

    #[test]
    fn bail_macro_formats() {
        fn f(x: usize) -> Result<()> {
            if x > 3 {
                bail!("x too big: {x}");
            }
            Ok(())
        }
        assert!(f(1).is_ok());
        assert_eq!(f(9).unwrap_err().to_string(), "x too big: 9");
    }
}
