//! PJRT execution engine: compile HLO-text artifacts once, then serve
//! batched generation requests from the rust hot path.
//!
//! Design (per /opt/xla-example/load_hlo and aot_recipe):
//! - interchange is **HLO text** (`HloModuleProto::from_text_file`) — the
//!   image's xla_extension 0.5.1 rejects jax≥0.5 serialized protos;
//! - models are lowered with `return_tuple=True`, so results unwrap with
//!   `to_tuple1`;
//! - each artifact is compiled to a fixed-batch executable; the engine
//!   pads smaller batches up to the compiled batch and slices the output
//!   (weights are passed as runtime arguments, resident since startup);
//! - the `xla` crate's handles are **not `Send`** (raw PJRT pointers, `Rc`
//!   client), so all XLA state lives on one dedicated *executor thread*;
//!   [`Engine`] itself is just channels + metadata and is freely shared
//!   across the coordinator's workers. XLA's CPU backend parallelizes
//!   internally, so one executor thread does not serialize the math.

use super::artifacts::ArtifactSet;
use crate::coordinator::server::BatchExecutor;
use crate::util::rng::Pcg32;
use crate::bail;
use crate::util::error::{err, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;
use std::thread::JoinHandle;

/// One compiled model living on the executor thread.
pub struct ModelRuntime {
    pub name: String,
    pub input_elements: usize,
    pub output_elements: usize,
    /// Compiled (fixed) batch size.
    pub batch: usize,
    /// Optional conditioning input width (one-hot label planes).
    pub label_elements: usize,
    weights: Vec<xla::Literal>,
    exe: xla::PjRtLoadedExecutable,
}

impl ModelRuntime {
    /// Generate `entries.len()` (≤ `batch`) samples; deterministic in the
    /// seeds. Returns `entries.len() × output_elements` f32s.
    pub fn generate(&self, entries: &[(u64, Option<u32>)]) -> Result<Vec<f32>> {
        if entries.is_empty() {
            return Ok(vec![]);
        }
        if entries.len() > self.batch {
            bail!("batch {} exceeds compiled batch {}", entries.len(), self.batch);
        }
        // z ~ N(0,1) from the per-sample seed, padded to the compiled batch
        let mut z = vec![0f32; self.batch * self.input_elements];
        for (i, &(seed, _)) in entries.iter().enumerate() {
            let mut rng = Pcg32::new(seed);
            for v in z[i * self.input_elements..(i + 1) * self.input_elements].iter_mut() {
                *v = rng.normal() as f32;
            }
        }
        let mut owned: Vec<xla::Literal> = Vec::with_capacity(2);
        owned.push(
            xla::Literal::vec1(&z)
                .reshape(&[self.batch as i64, self.input_elements as i64])?,
        );
        if self.label_elements > 0 {
            let mut labels = vec![0f32; self.batch * self.label_elements];
            for (i, &(_, label)) in entries.iter().enumerate() {
                let idx = label.unwrap_or(0) as usize % self.label_elements;
                labels[i * self.label_elements + idx] = 1.0;
            }
            owned.push(
                xla::Literal::vec1(&labels)
                    .reshape(&[self.batch as i64, self.label_elements as i64])?,
            );
        }
        self.execute(owned).map(|v| v[..entries.len() * self.output_elements].to_vec())
    }

    /// Run with an explicit full-batch input (and label planes when the
    /// model is conditioned) — the golden-parity and image-to-image path.
    pub fn run_raw(&self, input: &[f32], label: Option<&[f32]>) -> Result<Vec<f32>> {
        if input.len() != self.batch * self.input_elements {
            bail!(
                "raw input has {} elements, expected {}x{}",
                input.len(),
                self.batch,
                self.input_elements
            );
        }
        let mut owned: Vec<xla::Literal> = Vec::with_capacity(2);
        owned.push(
            xla::Literal::vec1(input)
                .reshape(&[self.batch as i64, self.input_elements as i64])?,
        );
        if self.label_elements > 0 {
            let label = label.context("model requires label planes")?;
            if label.len() != self.batch * self.label_elements {
                bail!(
                    "label has {} elements, expected {}",
                    label.len(),
                    self.batch * self.label_elements
                );
            }
            owned.push(
                xla::Literal::vec1(label)
                    .reshape(&[self.batch as i64, self.label_elements as i64])?,
            );
        }
        self.execute(owned)
    }

    /// Shared execute path: inputs ++ resident weights, unwrap the 1-tuple.
    fn execute(&self, owned: Vec<xla::Literal>) -> Result<Vec<f32>> {
        let args: Vec<&xla::Literal> = owned.iter().chain(self.weights.iter()).collect();
        let result = self.exe.execute::<&xla::Literal>(&args)?[0][0]
            .to_literal_sync()
            .context("device → host transfer")?;
        let out = result.to_tuple1().context("unwrapping 1-tuple result")?;
        let values = out.to_vec::<f32>()?;
        let expect = self.batch * self.output_elements;
        if values.len() != expect {
            bail!("output size {} != expected {}", values.len(), expect);
        }
        Ok(values)
    }
}

/// Model metadata mirrored outside the executor thread.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub input_elements: usize,
    pub output_elements: usize,
    pub batch: usize,
    pub label_elements: usize,
}

enum Payload {
    /// Seed-derived latent inputs (the serving path).
    Seeded(Vec<(u64, Option<u32>)>),
    /// Explicit input (+ optional label planes) — golden parity tests and
    /// image-to-image models (CycleGAN takes an image, not a latent).
    Raw { input: Vec<f32>, label: Option<Vec<f32>> },
}

struct Job {
    model: String,
    payload: Payload,
    reply: Sender<Result<Vec<f32>>>,
}

/// The engine: executor-thread handle + metadata. `Send + Sync`.
pub struct Engine {
    job_tx: Mutex<Option<Sender<Job>>>,
    meta: HashMap<String, ModelMeta>,
    thread: Mutex<Option<JoinHandle<()>>>,
}

impl Engine {
    /// Load every artifact under `artifacts_dir` (spawns the executor
    /// thread, compiles everything, fails fast on any load error).
    pub fn load(artifacts_dir: &Path) -> Result<Engine> {
        let dir: PathBuf = artifacts_dir.to_path_buf();
        let (job_tx, job_rx) = channel::<Job>();
        let (ready_tx, ready_rx) = channel::<Result<HashMap<String, ModelMeta>>>();
        let thread = std::thread::Builder::new()
            .name("photogan-pjrt".into())
            .spawn(move || executor_thread(dir, job_rx, ready_tx))
            .context("spawning executor thread")?;
        let meta = ready_rx
            .recv()
            .context("executor thread died during startup")??;
        if meta.is_empty() {
            bail!(
                "no artifacts in {} — run `make artifacts`",
                artifacts_dir.display()
            );
        }
        Ok(Engine {
            job_tx: Mutex::new(Some(job_tx)),
            meta,
            thread: Mutex::new(Some(thread)),
        })
    }

    pub fn model_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.meta.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn meta(&self, name: &str) -> Option<&ModelMeta> {
        self.meta.get(name)
    }

    /// Compiled batch size of a model (serving callers chunk to this).
    pub fn compiled_batch(&self, name: &str) -> Option<usize> {
        self.meta.get(name).map(|m| m.batch)
    }

    fn submit_job(&self, model: &str, payload: Payload) -> Result<Vec<f32>> {
        let (tx, rx) = channel();
        {
            let guard = self.job_tx.lock().unwrap();
            guard
                .as_ref()
                .context("engine shut down")?
                .send(Job { model: model.to_string(), payload, reply: tx })
                .context("executor thread gone")?;
        }
        rx.recv().context("executor thread dropped job")?
    }

    /// Run a full compiled batch with explicit inputs (golden parity /
    /// image-to-image path). Returns the whole batch output.
    pub fn run_raw(&self, model: &str, input: &[f32], label: Option<&[f32]>) -> Result<Vec<f32>> {
        self.submit_job(
            model,
            Payload::Raw { input: input.to_vec(), label: label.map(|l| l.to_vec()) },
        )
    }

    /// Synchronous generation (chunks to the compiled batch internally).
    pub fn generate_sync(
        &self,
        model: &str,
        entries: &[(u64, Option<u32>)],
    ) -> Result<Vec<f32>> {
        let meta = self
            .meta
            .get(model)
            .with_context(|| format!("unknown model '{model}'"))?;
        let mut out = Vec::with_capacity(entries.len() * meta.output_elements);
        for chunk in entries.chunks(meta.batch) {
            let mut v = self.submit_job(model, Payload::Seeded(chunk.to_vec()))?;
            out.append(&mut v);
        }
        Ok(out)
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        // close the job channel, then join the executor thread
        self.job_tx.lock().unwrap().take();
        if let Some(h) = self.thread.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

fn executor_thread(
    dir: PathBuf,
    jobs: Receiver<Job>,
    ready: Sender<Result<HashMap<String, ModelMeta>>>,
) {
    let startup = (|| -> Result<(HashMap<String, ModelRuntime>, HashMap<String, ModelMeta>)> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let sets = ArtifactSet::discover(&dir)?;
        let mut models = HashMap::new();
        let mut meta = HashMap::new();
        for set in sets {
            let rt = load_one(&client, &set)
                .with_context(|| format!("loading artifact '{}'", set.name))?;
            meta.insert(
                set.name.clone(),
                ModelMeta {
                    input_elements: rt.input_elements,
                    output_elements: rt.output_elements,
                    batch: rt.batch,
                    label_elements: rt.label_elements,
                },
            );
            models.insert(set.name.clone(), rt);
        }
        Ok((models, meta))
    })();
    let models = match startup {
        Ok((models, meta)) => {
            let _ = ready.send(Ok(meta));
            models
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(job) = jobs.recv() {
        let result = match models.get(&job.model) {
            Some(rt) => match &job.payload {
                Payload::Seeded(entries) => rt.generate(entries),
                Payload::Raw { input, label } => rt.run_raw(input, label.as_deref()),
            },
            None => Err(err(format!("unknown model '{}'", job.model))),
        };
        let _ = job.reply.send(result);
    }
}

fn load_one(client: &xla::PjRtClient, set: &ArtifactSet) -> Result<ModelRuntime> {
    let proto = xla::HloModuleProto::from_text_file(
        set.hlo_path.to_str().context("non-utf8 path")?,
    )
    .context("parsing HLO text")?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).context("PJRT compile")?;
    let input_elements = set.manifest.get_usize("input_elements")?;
    let output_elements = set.manifest.get_usize("output_elements")?;
    let batch = set.manifest.get_usize("batch")?;
    let label_elements = set.manifest.get_opt_usize("label_elements").unwrap_or(0);
    // resident weights as literals with their compiled shapes
    let mut weights = Vec::new();
    let bufs = set.weights()?;
    for (i, buf) in bufs.iter().enumerate() {
        let shape_key = format!("weights_{i}_shape");
        let lit = match set.manifest.fields.get(&shape_key) {
            Some(shape_str) => {
                let dims: Vec<i64> = shape_str
                    .split('x')
                    .map(|d| d.parse().context("bad shape dim"))
                    .collect::<Result<_>>()?;
                xla::Literal::vec1(buf).reshape(&dims)?
            }
            None => xla::Literal::vec1(buf),
        };
        weights.push(lit);
    }
    Ok(ModelRuntime {
        name: set.name.clone(),
        input_elements,
        output_elements,
        batch,
        label_elements,
        weights,
        exe,
    })
}

/// The `--backend pjrt` serving executor: real AOT-HLO inference behind
/// the same coordinator interface as `api::SimExecutor`.
impl BatchExecutor for Engine {
    fn models(&self) -> Vec<String> {
        self.model_names()
    }

    fn elements_per_sample(&self, model: &str) -> usize {
        self.meta.get(model).map(|m| m.output_elements).unwrap_or(0)
    }

    fn generate(&self, model: &str, entries: &[(u64, Option<u32>)]) -> Vec<f32> {
        match self.generate_sync(model, entries) {
            Ok(v) => v,
            Err(e) => {
                // serving must not crash the worker: log + zero-fill
                eprintln!("[photogan] generate({model}) failed: {e:#}");
                let n = self.elements_per_sample(model) * entries.len();
                vec![0f32; n]
            }
        }
    }
}
