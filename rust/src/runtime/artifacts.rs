//! Artifact discovery and binary I/O.
//!
//! `make artifacts` (python) writes, per model variant:
//!
//! ```text
//! artifacts/<model>/model.hlo.txt   — HLO text (the AOT interchange format)
//! artifacts/<model>/meta.txt        — key=value metadata (shapes, seeds)
//! artifacts/<model>/weights.bin     — f32 LE weight buffers, in call order
//! artifacts/<model>/golden_in.bin   — f32 LE golden input (z vector batch)
//! artifacts/<model>/golden_out.bin  — f32 LE expected output (jax-computed)
//! ```
//!
//! `meta.txt` is a deliberately trivial `key=value` format (no serde in the
//! offline crate set). Keys used: `name`, `input_elements`,
//! `output_elements`, `batch`, `weight_buffers`, `weights_<i>_elements`,
//! `label_elements` (optional conditioning input).

use crate::bail;
use crate::util::error::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Parsed `meta.txt`.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub fields: HashMap<String, String>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let mut fields = HashMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("meta.txt line {}: missing '='", lineno + 1))?;
            fields.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(Manifest { fields })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Result<&str> {
        self.fields
            .get(key)
            .map(|s| s.as_str())
            .with_context(|| format!("meta.txt missing key '{key}'"))
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        self.get(key)?
            .parse()
            .with_context(|| format!("meta.txt key '{key}' is not an integer"))
    }

    pub fn get_opt_usize(&self, key: &str) -> Option<usize> {
        self.fields.get(key).and_then(|v| v.parse().ok())
    }
}

/// All artifacts for one model variant.
#[derive(Debug, Clone)]
pub struct ArtifactSet {
    pub name: String,
    pub dir: PathBuf,
    pub manifest: Manifest,
    pub hlo_path: PathBuf,
}

impl ArtifactSet {
    /// Open `artifacts/<name>` and validate the expected files exist.
    pub fn open(artifacts_dir: &Path, name: &str) -> Result<Self> {
        let dir = artifacts_dir.join(name);
        if !dir.is_dir() {
            bail!(
                "artifact dir {} not found — run `make artifacts` first",
                dir.display()
            );
        }
        let hlo_path = dir.join("model.hlo.txt");
        if !hlo_path.is_file() {
            bail!("missing {}", hlo_path.display());
        }
        let manifest = Manifest::load(&dir.join("meta.txt"))?;
        Ok(ArtifactSet { name: name.to_string(), dir, manifest, hlo_path })
    }

    /// Discover every model under `artifacts/` (directories with meta.txt).
    pub fn discover(artifacts_dir: &Path) -> Result<Vec<ArtifactSet>> {
        let mut out = Vec::new();
        if !artifacts_dir.is_dir() {
            return Ok(out);
        }
        let mut names: Vec<String> = std::fs::read_dir(artifacts_dir)?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().join("meta.txt").is_file())
            .filter_map(|e| e.file_name().into_string().ok())
            .collect();
        names.sort();
        for n in names {
            out.push(ArtifactSet::open(artifacts_dir, &n)?);
        }
        Ok(out)
    }

    /// Read one of the `.bin` files as little-endian f32s.
    pub fn read_f32(&self, file: &str) -> Result<Vec<f32>> {
        read_f32_file(&self.dir.join(file))
    }

    /// The weight buffers, in the call order the HLO expects.
    pub fn weights(&self) -> Result<Vec<Vec<f32>>> {
        let n = self.manifest.get_usize("weight_buffers")?;
        let all = self.read_f32("weights.bin")?;
        let mut out = Vec::with_capacity(n);
        let mut offset = 0usize;
        for i in 0..n {
            let len = self.manifest.get_usize(&format!("weights_{i}_elements"))?;
            if offset + len > all.len() {
                bail!(
                    "weights.bin too short: need {} for buffer {i}, have {}",
                    offset + len,
                    all.len()
                );
            }
            out.push(all[offset..offset + len].to_vec());
            offset += len;
        }
        if offset != all.len() {
            bail!("weights.bin has {} trailing floats", all.len() - offset);
        }
        Ok(out)
    }
}

/// Read a little-endian f32 binary file.
pub fn read_f32_file(path: &Path) -> Result<Vec<f32>> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.display(), bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write a little-endian f32 binary file (used by tests and tools).
pub fn write_f32_file(path: &Path, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_key_values() {
        let m = Manifest::parse("# comment\nname=condgan_tiny\nbatch = 4\n\nx=1\n").unwrap();
        assert_eq!(m.get("name").unwrap(), "condgan_tiny");
        assert_eq!(m.get_usize("batch").unwrap(), 4);
        assert!(m.get("missing").is_err());
        assert_eq!(m.get_opt_usize("x"), Some(1));
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(Manifest::parse("no equals sign here").is_err());
    }

    #[test]
    fn f32_roundtrip() {
        let dir = std::env::temp_dir().join("photogan_test_f32");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.bin");
        let data = vec![1.5f32, -2.25, 0.0, 3.14159];
        write_f32_file(&p, &data).unwrap();
        assert_eq!(read_f32_file(&p).unwrap(), data);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn truncated_f32_file_rejected() {
        let dir = std::env::temp_dir().join("photogan_test_f32b");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, [0u8, 1, 2]).unwrap();
        assert!(read_f32_file(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn artifact_set_weight_slicing() {
        let base = std::env::temp_dir().join("photogan_test_artifacts");
        let dir = base.join("toy");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("model.hlo.txt"), "HloModule toy").unwrap();
        std::fs::write(
            dir.join("meta.txt"),
            "name=toy\ninput_elements=2\noutput_elements=2\nbatch=1\n\
             weight_buffers=2\nweights_0_elements=3\nweights_1_elements=1\n",
        )
        .unwrap();
        write_f32_file(&dir.join("weights.bin"), &[1.0, 2.0, 3.0, 4.0]).unwrap();
        let set = ArtifactSet::open(&base, "toy").unwrap();
        let w = set.weights().unwrap();
        assert_eq!(w, vec![vec![1.0, 2.0, 3.0], vec![4.0]]);
        // discovery finds it
        let found = ArtifactSet::discover(&base).unwrap();
        assert!(found.iter().any(|a| a.name == "toy"));
        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn missing_artifacts_dir_is_empty_not_error() {
        let found = ArtifactSet::discover(Path::new("/nonexistent/xyz")).unwrap();
        assert!(found.is_empty());
    }
}
