//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO text + weight/golden binaries) and executes real GAN inference from
//! the rust request path via the `xla` crate's PJRT CPU client.
//!
//! Python never runs at serving time: `make artifacts` is the only python
//! step, and this module is the only consumer of its outputs.

pub mod artifacts;
pub mod client;

pub use artifacts::{ArtifactSet, Manifest};
pub use client::{Engine, ModelMeta, ModelRuntime};
