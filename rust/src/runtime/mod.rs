//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO text + weight/golden binaries) and executes real GAN inference from
//! the rust request path via the `xla` crate's PJRT CPU client.
//!
//! Python never runs at serving time: `make artifacts` is the only python
//! step, and this module is the only consumer of its outputs.
//!
//! In the serving stack this is the `--backend pjrt` executor: [`Engine`]
//! implements `coordinator::server::BatchExecutor`, interchangeable with
//! the artifact-free `api::SimExecutor` behind the same multi-shard
//! coordinator.

pub mod artifacts;
pub mod client;

pub use artifacts::{ArtifactSet, Manifest};
pub use client::{Engine, ModelMeta, ModelRuntime};
