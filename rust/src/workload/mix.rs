//! Weighted model traffic mixes.
//!
//! A [`TrafficMix`] is the "what" of a workload scenario: which models a
//! request stream draws from and with what relative weight (the GANAX
//! observation — GAN serving traffic is an irregular mix of architectures,
//! not one model — made first-class). Sampling is deterministic given a
//! [`Pcg32`] stream, so a mix plus a seed fully determines the model
//! sequence of a generated workload.

use crate::util::rng::Pcg32;
use std::fmt;

/// A typed, mix-local validation failure. The API layer maps these onto
/// per-field [`crate::api::ApiError`] variants with the offending JSON
/// path attached.
#[derive(Debug, Clone, PartialEq)]
pub enum MixError {
    /// A mix with no entries cannot generate traffic.
    Empty,
    /// A weight that is non-positive or non-finite (index into the entry
    /// list, model name, and the rejected weight).
    BadWeight { index: usize, model: String, weight: f64 },
}

impl fmt::Display for MixError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MixError::Empty => write!(f, "traffic mix has no entries"),
            MixError::BadWeight { index, model, weight } => write!(
                f,
                "mix entry {index} ('{model}') has non-positive weight {weight}"
            ),
        }
    }
}

impl std::error::Error for MixError {}

/// A validated weighted mix of model names.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficMix {
    entries: Vec<(String, f64)>,
    /// Cumulative normalized weights, same length as `entries`; the last
    /// element is exactly 1.0.
    cumulative: Vec<f64>,
}

impl TrafficMix {
    /// Build a mix from `(model, weight)` pairs. Weights must be finite
    /// and strictly positive; they need not sum to 1 (normalization is
    /// internal).
    pub fn new(entries: Vec<(String, f64)>) -> Result<TrafficMix, MixError> {
        if entries.is_empty() {
            return Err(MixError::Empty);
        }
        for (index, (model, weight)) in entries.iter().enumerate() {
            if !weight.is_finite() || *weight <= 0.0 {
                return Err(MixError::BadWeight {
                    index,
                    model: model.clone(),
                    weight: *weight,
                });
            }
        }
        let total: f64 = entries.iter().map(|(_, w)| w).sum();
        let mut acc = 0.0;
        let mut cumulative: Vec<f64> = entries
            .iter()
            .map(|(_, w)| {
                acc += w / total;
                acc
            })
            .collect();
        // pin the top so rounding can never leave sample() past the end
        if let Some(last) = cumulative.last_mut() {
            *last = 1.0;
        }
        Ok(TrafficMix { entries, cumulative })
    }

    /// A single-model mix (weight 1) — what the legacy single-model serve
    /// path reduces to.
    pub fn single(model: impl Into<String>) -> TrafficMix {
        TrafficMix {
            entries: vec![(model.into(), 1.0)],
            cumulative: vec![1.0],
        }
    }

    /// A uniform mix over `models`.
    pub fn uniform<S: AsRef<str>>(models: &[S]) -> Result<TrafficMix, MixError> {
        TrafficMix::new(models.iter().map(|m| (m.as_ref().to_string(), 1.0)).collect())
    }

    /// The raw `(model, weight)` entries, in declaration order.
    pub fn entries(&self) -> &[(String, f64)] {
        &self.entries
    }

    /// The entries with weights normalized to sum to 1.
    pub fn normalized(&self) -> Vec<(String, f64)> {
        let total: f64 = self.entries.iter().map(|(_, w)| w).sum();
        self.entries.iter().map(|(m, w)| (m.clone(), w / total)).collect()
    }

    /// Model names in declaration order.
    pub fn models(&self) -> Vec<String> {
        self.entries.iter().map(|(m, _)| m.clone()).collect()
    }

    /// Number of models in the mix.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True for the (unconstructible) empty mix — present for API
    /// symmetry with `len`.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Sample a model *index* from the mix (one `rng` draw). Indices are
    /// what the virtual-time engine keys its queues by; use
    /// [`TrafficMix::sample`] for the name.
    pub fn sample_index(&self, rng: &mut Pcg32) -> usize {
        let u = rng.f64();
        // cumulative is ascending and ends at exactly 1.0 > u
        self.cumulative
            .iter()
            .position(|&c| u < c)
            .unwrap_or(self.entries.len() - 1)
    }

    /// Sample a model name from the mix (one `rng` draw).
    pub fn sample(&self, rng: &mut Pcg32) -> &str {
        &self.entries[self.sample_index(rng)].0
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn rejects_empty_and_bad_weights() {
        assert_eq!(TrafficMix::new(vec![]), Err(MixError::Empty));
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let err = TrafficMix::new(vec![
                ("a".into(), 1.0),
                ("b".into(), bad),
            ])
            .unwrap_err();
            assert!(
                matches!(err, MixError::BadWeight { index: 1, ref model, .. } if model == "b"),
                "{bad}: {err:?}"
            );
        }
    }

    #[test]
    fn normalization_and_single() {
        let mix = TrafficMix::new(vec![("a".into(), 3.0), ("b".into(), 1.0)]).unwrap();
        let norm = mix.normalized();
        assert!((norm[0].1 - 0.75).abs() < 1e-12);
        assert!((norm[1].1 - 0.25).abs() < 1e-12);
        assert_eq!(mix.models(), vec!["a".to_string(), "b".to_string()]);
        let solo = TrafficMix::single("only");
        assert_eq!(solo.len(), 1);
        assert_eq!(solo.normalized()[0], ("only".to_string(), 1.0));
    }

    #[test]
    fn sampling_is_deterministic_and_tracks_weights() {
        let mix = TrafficMix::new(vec![("hot".into(), 9.0), ("cold".into(), 1.0)]).unwrap();
        let draw = |seed: u64| -> Vec<usize> {
            let mut rng = Pcg32::new(seed);
            (0..2_000).map(|_| mix.sample_index(&mut rng)).collect()
        };
        assert_eq!(draw(5), draw(5), "same seed must reproduce the sequence");
        let hot = draw(5).iter().filter(|&&i| i == 0).count();
        let frac = hot as f64 / 2_000.0;
        assert!((frac - 0.9).abs() < 0.03, "hot fraction {frac}");
    }

    #[test]
    fn uniform_covers_every_model() {
        let mix = TrafficMix::uniform(&["a", "b", "c"]).unwrap();
        let mut rng = Pcg32::new(3);
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[mix.sample_index(&mut rng)] = true;
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }
}
