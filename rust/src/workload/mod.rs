//! Library-level workload description and traffic generation.
//!
//! This module is the "load" half of the declarative scenario layer
//! ([`crate::api::scenario`]): it owns *what* traffic looks like and *how*
//! it is generated, independent of which engine serves it.
//!
//! - [`TrafficMix`] — weighted model mixes (GAN serving traffic is an
//!   irregular mix of architectures, not a single model).
//! - [`ArrivalProcess`] — closed-loop, open-loop Poisson, bursty on/off,
//!   and recorded-trace arrival processes, materialized deterministically
//!   from seeded [`crate::util::rng::Pcg32`] streams.
//! - [`generator`] — threaded load drivers generic over
//!   [`crate::coordinator::TrafficSink`], so one implementation drives
//!   both the threaded coordinator and the async continuous-batching
//!   core (promoted out of `benches/e2e_serving.rs`); traffic sequences
//!   are reproducible under a fixed seed regardless of worker
//!   interleaving.
//! - [`vserve`] — a deterministic virtual-time discrete-event simulation
//!   of the same serving semantics (routing, bounded queues, dynamic
//!   batching, worker pools) with service times from a pluggable
//!   [`vserve::ServiceModel`]; this is what makes scenario outcomes
//!   byte-identical for a fixed seed.
//!
//! Layering: `workload` sits between `coordinator` (it drives
//! [`crate::coordinator::TrafficSink`]s and mirrors
//! [`crate::coordinator::RoutingPolicy`]) and `api` (which compiles
//! scenarios into mixes, arrivals, and virtual fleet shapes). It never
//! depends on `api`.

// Same error-handling contract as `crate::api` and `crate::coordinator`:
// typed errors on every fallible path, no panicking shortcuts.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod arrival;
pub mod generator;
pub mod mix;
pub mod vserve;
pub mod wheel;

pub use arrival::{ArrivalError, ArrivalProcess};
pub use generator::TrafficReport;
pub use mix::{MixError, TrafficMix};
pub use vserve::{
    simulate_fleet, simulate_serve, AutoscaleConfig, AutoscalePolicy, CalibrationConfig,
    FailureConfig, FleetConfig, FleetCost, QueueKind, ServiceModel, ShardClass, VirtualOutcome,
    VirtualServeConfig, VirtualShardLoad,
};
pub use wheel::EventWheel;
