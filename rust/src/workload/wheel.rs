//! Indexed event wheel (calendar queue) for the virtual-time DES.
//!
//! [`crate::workload::vserve`] drives fleet-scale runs through hundreds of
//! thousands of timestamped events. A `BinaryHeap` costs `O(log n)` per
//! operation with poor cache behavior at that size; a calendar queue
//! (Brown, CACM 1988) buckets events by time and makes insert/pop `O(1)`
//! amortized when the bucket width tracks the event density.
//!
//! **Determinism contract**: [`EventWheel::pop`] always returns the event
//! with the globally smallest `(time, seq)` key — independent of bucket
//! width, bucket count, or resize history. Bucket geometry is purely a
//! performance knob, so swapping the wheel for a `BinaryHeap` (or
//! resizing mid-run) can never change a simulation outcome. The engine's
//! `QueueKind` ablation and the wheel-vs-heap property tests lean on this.
//!
//! Mechanics: a virtual bucket index `vb(t) = t / width` maps each event
//! onto an unbounded calendar; the finite bucket array holds calendar slot
//! `vb % n`. A cursor `vcur` tracks the earliest virtual bucket that may
//! still hold events. `pop` scans the cursor's bucket for the earliest
//! event *belonging to that virtual bucket* (later "years" sharing the
//! slot are skipped), advancing the cursor over empty buckets; after a
//! full lap without a hit it falls back to a direct `O(len)` global-min
//! search and re-anchors the cursor — which keeps sparse far-future tails
//! (timers, re-calibration cycles) from degrading the common case. `push`
//! rewinds the cursor when an event lands behind it (handlers push events
//! at the current virtual time). Resizes re-estimate the width from the
//! *median* sampled inter-event gap, so one far-future outlier cannot
//! collapse every live event into a single bucket.

/// Timestamped, uniquely sequenced item a wheel can order.
///
/// `seq` must be unique per item; `(time, seq)` is the total order
/// (`time` compares via `f64::total_cmp`). Times must be non-negative
/// and non-NaN.
pub trait WheelItem {
    /// Virtual timestamp (seconds).
    fn time(&self) -> f64;
    /// Unique insertion sequence number (the tiebreak).
    fn seq(&self) -> u64;
}

const INITIAL_BUCKETS: usize = 32;
const INITIAL_WIDTH: f64 = 1e-4;
/// Upper bound on the number of timestamps sampled per width estimate.
const MAX_WIDTH_SAMPLE: usize = 1024;

/// `(time, seq)` strictly-earlier comparison with the same total order the
/// DES `BinaryHeap` uses.
fn earlier(t_a: f64, s_a: u64, t_b: f64, s_b: u64) -> bool {
    match t_a.total_cmp(&t_b) {
        std::cmp::Ordering::Less => true,
        std::cmp::Ordering::Greater => false,
        std::cmp::Ordering::Equal => s_a < s_b,
    }
}

/// A calendar queue over [`WheelItem`]s. See the module docs for the
/// determinism contract and mechanics.
pub struct EventWheel<T> {
    /// `buckets[vb % n]` holds the events of virtual bucket `vb` (and of
    /// every other virtual bucket congruent mod `n`).
    buckets: Vec<Vec<T>>,
    /// Virtual seconds per bucket (strictly positive).
    width: f64,
    /// Earliest virtual bucket index that may still hold events.
    vcur: u64,
    len: usize,
}

impl<T: WheelItem> Default for EventWheel<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: WheelItem> EventWheel<T> {
    pub fn new() -> Self {
        EventWheel {
            buckets: (0..INITIAL_BUCKETS).map(|_| Vec::new()).collect(),
            width: INITIAL_WIDTH,
            vcur: 0,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// All queued items, in no particular order (the DES only uses this
    /// for existence checks).
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.buckets.iter().flatten()
    }

    /// Virtual bucket index of time `t`. The `f64 → u64` cast saturates,
    /// so far-future times all land in the last virtual bucket — still
    /// correctly ordered by the in-bucket `(time, seq)` scan.
    fn vb(&self, t: f64) -> u64 {
        debug_assert!(!t.is_nan() && t >= 0.0, "event time must be a non-negative number");
        (t / self.width) as u64
    }

    pub fn push(&mut self, item: T) {
        let vb = self.vb(item.time());
        // handlers push events at the current virtual time: rewind the
        // cursor so nothing lands behind it and gets lapped
        if vb < self.vcur {
            self.vcur = vb;
        }
        let n = self.buckets.len() as u64;
        self.buckets[(vb % n) as usize].push(item);
        self.len += 1;
        if self.len > 2 * self.buckets.len() {
            self.rebuild();
        }
    }

    /// Remove and return the event with the globally smallest
    /// `(time, seq)` key.
    pub fn pop(&mut self) -> Option<T> {
        if self.len == 0 {
            return None;
        }
        let n = self.buckets.len() as u64;
        let mut scanned = 0u64;
        loop {
            if scanned >= n {
                // a full lap found nothing in-bucket: the next event is
                // far ahead (or times saturated) — direct global-min
                // search, then re-anchor the cursor on its year
                return self.pop_direct();
            }
            let b = (self.vcur % n) as usize;
            let mut best: Option<(usize, f64, u64)> = None;
            for (i, it) in self.buckets[b].iter().enumerate() {
                if self.vb(it.time()) != self.vcur {
                    continue; // a later lap sharing this slot
                }
                let (t, s) = (it.time(), it.seq());
                if best.map_or(true, |(_, bt, bs)| earlier(t, s, bt, bs)) {
                    best = Some((i, t, s));
                }
            }
            if let Some((i, _, _)) = best {
                self.len -= 1;
                let item = self.buckets[b].swap_remove(i);
                self.maybe_shrink();
                return Some(item);
            }
            self.vcur = self.vcur.saturating_add(1);
            scanned += 1;
        }
    }

    /// `O(len)` fallback: global `(time, seq)` minimum across every
    /// bucket, cursor re-anchored on its virtual bucket.
    fn pop_direct(&mut self) -> Option<T> {
        let mut best: Option<(usize, usize, f64, u64)> = None;
        for (bi, bucket) in self.buckets.iter().enumerate() {
            for (i, it) in bucket.iter().enumerate() {
                let (t, s) = (it.time(), it.seq());
                if best.map_or(true, |(_, _, bt, bs)| earlier(t, s, bt, bs)) {
                    best = Some((bi, i, t, s));
                }
            }
        }
        let (bi, i, t, _) = best?;
        self.vcur = self.vb(t);
        self.len -= 1;
        let item = self.buckets[bi].swap_remove(i);
        self.maybe_shrink();
        Some(item)
    }

    fn maybe_shrink(&mut self) {
        if self.buckets.len() > INITIAL_BUCKETS && self.len * 8 < self.buckets.len() {
            self.rebuild();
        }
    }

    /// Re-bucket every event into a table sized for the current
    /// population, with the width re-estimated from the live events.
    /// Purely a performance operation: the pop order is unaffected.
    fn rebuild(&mut self) {
        let items: Vec<T> = self.buckets.iter_mut().flat_map(std::mem::take).collect();
        let n = (items.len() * 2).next_power_of_two().max(INITIAL_BUCKETS);
        if let Some(w) = estimate_width(&items) {
            self.width = w;
        }
        self.buckets = (0..n).map(|_| Vec::new()).collect();
        self.len = items.len();
        // re-anchor on the earliest live event (u64::MAX when empty: the
        // next push rewinds the cursor)
        self.vcur = u64::MAX;
        let n64 = n as u64;
        for it in items {
            let vb = self.vb(it.time());
            self.vcur = self.vcur.min(vb);
            self.buckets[(vb % n64) as usize].push(it);
        }
    }
}

/// Bucket-width estimate: twice the median per-event time gap, from a
/// strided sample of at most [`MAX_WIDTH_SAMPLE`] timestamps. The median
/// (not the span) keeps one far-future outlier from inflating the width
/// until every live event shares a bucket. `None` when the population is
/// too small or fully degenerate (identical timestamps).
fn estimate_width<T: WheelItem>(items: &[T]) -> Option<f64> {
    if items.len() < 2 {
        return None;
    }
    let stride = (items.len() / MAX_WIDTH_SAMPLE).max(1);
    let mut sample: Vec<f64> = items.iter().step_by(stride).map(|it| it.time()).collect();
    sample.sort_by(f64::total_cmp);
    let mut gaps: Vec<f64> = sample.windows(2).map(|w| w[1] - w[0]).collect();
    gaps.sort_by(f64::total_cmp);
    let median = gaps[gaps.len() / 2];
    // the sampled gap spans `stride` events; aim for ~2 events per bucket
    let width = 2.0 * median / stride as f64;
    (width.is_finite() && width > 0.0).then_some(width)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[derive(Debug, Clone, Copy, PartialEq)]
    struct Ev {
        time: f64,
        seq: u64,
    }

    impl WheelItem for Ev {
        fn time(&self) -> f64 {
            self.time
        }
        fn seq(&self) -> u64 {
            self.seq
        }
    }

    #[test]
    fn pops_in_time_then_seq_order() {
        let mut w = EventWheel::new();
        for (time, seq) in [(0.5, 0), (0.1, 1), (0.1, 2), (0.3, 3), (0.0, 4)] {
            w.push(Ev { time, seq });
        }
        assert_eq!(w.len(), 5);
        let order: Vec<u64> = std::iter::from_fn(|| w.pop()).map(|e| e.seq).collect();
        assert_eq!(order, vec![4, 1, 2, 3, 0]);
        assert!(w.is_empty() && w.pop().is_none());
    }

    #[test]
    fn push_behind_the_cursor_is_not_lapped() {
        let mut w = EventWheel::new();
        w.push(Ev { time: 1.0, seq: 0 });
        assert_eq!(w.pop().unwrap().seq, 0); // cursor is now deep in the calendar
        w.push(Ev { time: 0.0, seq: 1 }); // behind the cursor
        w.push(Ev { time: 2.0, seq: 2 });
        assert_eq!(w.pop().unwrap().seq, 1, "the rewound event must pop first");
        assert_eq!(w.pop().unwrap().seq, 2);
    }

    #[test]
    fn far_future_outliers_and_ties_stay_ordered() {
        let mut w = EventWheel::new();
        // an outlier 12 orders of magnitude out, plus same-bucket ties
        for (time, seq) in [(1e9, 0), (1e-3, 1), (1e-3, 2), (2e-3, 3)] {
            w.push(Ev { time, seq });
        }
        // trigger rebuilds around the outlier
        for seq in 4..200u64 {
            w.push(Ev { time: 1e-5 * seq as f64, seq });
        }
        let mut last: Option<Ev> = None;
        let mut n = 0;
        while let Some(e) = w.pop() {
            if let Some(p) = last {
                assert!(
                    earlier(p.time, p.seq, e.time, e.seq),
                    "out of order: {p:?} then {e:?}"
                );
            }
            last = Some(e);
            n += 1;
        }
        assert_eq!(n, 200);
        assert_eq!(last.unwrap().seq, 0, "the outlier pops last");
    }

    #[test]
    fn randomized_pop_order_matches_a_binary_heap() {
        // the determinism contract, property-tested: identical (time, seq)
        // pop sequences against a reference BinaryHeap under interleaved
        // pushes and pops at mixed time scales
        for seed in 0..20u64 {
            let mut rng = Pcg32::new(seed);
            let mut wheel = EventWheel::new();
            let mut heap: std::collections::BinaryHeap<std::cmp::Reverse<(u64, u64)>> =
                std::collections::BinaryHeap::new();
            let mut seq = 0u64;
            let mut base = 0.0f64;
            for _ in 0..400 {
                let burst = 1 + (rng.next_u64() % 8) as usize;
                for _ in 0..burst {
                    // mixed scales: microsecond gaps, occasional big jumps
                    let gap = if rng.next_u64() % 16 == 0 { 1.0 } else { 1e-6 };
                    let t = base + gap * rng.f64();
                    wheel.push(Ev { time: t, seq });
                    heap.push(std::cmp::Reverse((t.to_bits(), seq)));
                    seq += 1;
                }
                let pops = (rng.next_u64() % burst as u64) as usize;
                for _ in 0..pops {
                    let got = wheel.pop().unwrap();
                    let std::cmp::Reverse((bits, s)) = heap.pop().unwrap();
                    assert_eq!((got.time.to_bits(), got.seq), (bits, s), "seed {seed}");
                    base = base.max(got.time);
                }
            }
            while let Some(std::cmp::Reverse((bits, s))) = heap.pop() {
                let got = wheel.pop().unwrap();
                assert_eq!((got.time.to_bits(), got.seq), (bits, s), "drain, seed {seed}");
            }
            assert!(wheel.pop().is_none());
        }
    }

    #[test]
    fn iter_sees_every_queued_event() {
        let mut w = EventWheel::new();
        for seq in 0..50u64 {
            w.push(Ev { time: seq as f64 * 1e-3, seq });
        }
        let mut seqs: Vec<u64> = w.iter().map(|e| e.seq).collect();
        seqs.sort_unstable();
        assert_eq!(seqs, (0..50).collect::<Vec<_>>());
    }
}
