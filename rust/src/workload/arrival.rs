//! Arrival processes — the "when" of a workload scenario.
//!
//! Six processes cover the serving studies the paper's evaluation (and
//! the byte-size scaling literature) call for:
//!
//! - **closed-loop** — N clients, each keeping exactly one request in
//!   flight: the classic saturation benchmark.
//! - **open-loop Poisson** — memoryless arrivals at a fixed rate: the
//!   latency-under-load benchmark.
//! - **bursty on/off** — Poisson arrivals modulated by an on/off square
//!   wave: stresses queue drain and backpressure.
//! - **diurnal** — Poisson arrivals whose rate follows a raised-cosine
//!   day/night wave between a trough and a crest (materialized exactly by
//!   thinning): the autoscaling benchmark.
//! - **flash crowd** — baseline Poisson traffic with one rate spike at a
//!   known offset: stresses admission and scale-up reaction time.
//! - **trace replay** — an explicit list of arrival offsets: reproduces a
//!   recorded production trace exactly.
//!
//! Open-loop schedules are *materialized up front* from a seeded
//! [`Pcg32`] stream, so the arrival times of a scenario are a pure
//! function of `(process, seed)` — independent of threads, wall clock,
//! and host speed.

use crate::util::rng::Pcg32;
use std::fmt;

/// Typed, process-local validation failure. The API layer maps these onto
/// per-field [`crate::api::ApiError`] variants with the offending JSON
/// path attached.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalError {
    /// A rate that is non-finite or non-positive.
    BadRate(f64),
    /// A duration (or on/off window) that is non-finite or non-positive.
    BadDuration(f64),
    /// A closed loop needs at least one client issuing at least one
    /// request.
    BadClients { clients: usize, per_client: usize },
    /// A trace offset that is negative, non-finite, or out of order.
    BadTrace { index: usize, offset_s: f64 },
    /// A trace with no arrivals.
    EmptyTrace,
    /// A time offset (e.g. a flash crowd's start) that is negative or
    /// non-finite.
    BadOffset(f64),
}

impl fmt::Display for ArrivalError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArrivalError::BadRate(r) => {
                write!(f, "arrival rate must be finite and > 0 (got {r})")
            }
            ArrivalError::BadDuration(d) => {
                write!(f, "duration must be finite and > 0 (got {d})")
            }
            ArrivalError::BadClients { clients, per_client } => write!(
                f,
                "closed loop needs clients >= 1 and per_client >= 1 \
                 (got {clients} x {per_client})"
            ),
            ArrivalError::BadTrace { index, offset_s } => write!(
                f,
                "trace offset {index} must be finite, >= 0, and non-decreasing \
                 (got {offset_s})"
            ),
            ArrivalError::EmptyTrace => write!(f, "trace replay has no arrivals"),
            ArrivalError::BadOffset(o) => {
                write!(f, "time offset must be finite and >= 0 (got {o})")
            }
        }
    }
}

impl std::error::Error for ArrivalError {}

/// When requests of a scenario arrive.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// `clients` concurrent clients, each keeping one request in flight
    /// and issuing `per_client` requests total.
    ClosedLoop { clients: usize, per_client: usize },
    /// Open-loop Poisson arrivals at `rate_hz` for `duration_s` seconds.
    Poisson { rate_hz: f64, duration_s: f64 },
    /// Poisson arrivals at `rate_hz` gated by an on/off square wave
    /// (`on_s` seconds of traffic, `off_s` of silence, repeating) for
    /// `duration_s` seconds total.
    Bursty { rate_hz: f64, on_s: f64, off_s: f64, duration_s: f64 },
    /// Poisson arrivals whose rate follows a raised cosine between
    /// `base_hz` (trough, at t = 0) and `peak_hz` (crest, at half a
    /// period): `rate(t) = base + (peak − base) · (1 − cos 2πt/period)/2`,
    /// for `duration_s` seconds. Materialized exactly by thinning a
    /// homogeneous `peak_hz` stream (`peak_hz >= base_hz > 0`).
    Diurnal { base_hz: f64, peak_hz: f64, period_s: f64, duration_s: f64 },
    /// Baseline Poisson traffic at `base_hz` with one flash crowd: the
    /// rate jumps to `spike_hz` at `spike_at_s` for `spike_s` seconds,
    /// then falls back, for `duration_s` seconds total. Gaps restart at
    /// each boundary (valid by memorylessness).
    FlashCrowd { base_hz: f64, spike_hz: f64, spike_at_s: f64, spike_s: f64, duration_s: f64 },
    /// Replay recorded arrival offsets (seconds from stream start,
    /// non-decreasing).
    Trace { arrivals_s: Vec<f64> },
}

impl ArrivalProcess {
    /// Stable kind name (the JSON `process` discriminator).
    pub fn kind(&self) -> &'static str {
        match self {
            ArrivalProcess::ClosedLoop { .. } => "closed-loop",
            ArrivalProcess::Poisson { .. } => "poisson",
            ArrivalProcess::Bursty { .. } => "bursty",
            ArrivalProcess::Diurnal { .. } => "diurnal",
            ArrivalProcess::FlashCrowd { .. } => "flash-crowd",
            ArrivalProcess::Trace { .. } => "trace",
        }
    }

    /// Structural validation (rates, durations, trace monotonicity).
    pub fn validate(&self) -> Result<(), ArrivalError> {
        match self {
            ArrivalProcess::ClosedLoop { clients, per_client } => {
                if *clients == 0 || *per_client == 0 {
                    return Err(ArrivalError::BadClients {
                        clients: *clients,
                        per_client: *per_client,
                    });
                }
            }
            ArrivalProcess::Poisson { rate_hz, duration_s } => {
                if !rate_hz.is_finite() || *rate_hz <= 0.0 {
                    return Err(ArrivalError::BadRate(*rate_hz));
                }
                if !duration_s.is_finite() || *duration_s <= 0.0 {
                    return Err(ArrivalError::BadDuration(*duration_s));
                }
            }
            ArrivalProcess::Bursty { rate_hz, on_s, off_s, duration_s } => {
                if !rate_hz.is_finite() || *rate_hz <= 0.0 {
                    return Err(ArrivalError::BadRate(*rate_hz));
                }
                for d in [on_s, duration_s] {
                    if !d.is_finite() || *d <= 0.0 {
                        return Err(ArrivalError::BadDuration(*d));
                    }
                }
                // a zero off window is legal (degenerates to pure Poisson)
                if !off_s.is_finite() || *off_s < 0.0 {
                    return Err(ArrivalError::BadDuration(*off_s));
                }
            }
            ArrivalProcess::Diurnal { base_hz, peak_hz, period_s, duration_s } => {
                if !base_hz.is_finite() || *base_hz <= 0.0 {
                    return Err(ArrivalError::BadRate(*base_hz));
                }
                // the thinning envelope needs peak >= base
                if !peak_hz.is_finite() || *peak_hz < *base_hz {
                    return Err(ArrivalError::BadRate(*peak_hz));
                }
                for d in [period_s, duration_s] {
                    if !d.is_finite() || *d <= 0.0 {
                        return Err(ArrivalError::BadDuration(*d));
                    }
                }
            }
            ArrivalProcess::FlashCrowd { base_hz, spike_hz, spike_at_s, spike_s, duration_s } => {
                for r in [base_hz, spike_hz] {
                    if !r.is_finite() || *r <= 0.0 {
                        return Err(ArrivalError::BadRate(*r));
                    }
                }
                if !spike_at_s.is_finite() || *spike_at_s < 0.0 {
                    return Err(ArrivalError::BadOffset(*spike_at_s));
                }
                for d in [spike_s, duration_s] {
                    if !d.is_finite() || *d <= 0.0 {
                        return Err(ArrivalError::BadDuration(*d));
                    }
                }
            }
            ArrivalProcess::Trace { arrivals_s } => {
                if arrivals_s.is_empty() {
                    return Err(ArrivalError::EmptyTrace);
                }
                let mut prev = 0.0f64;
                for (index, &t) in arrivals_s.iter().enumerate() {
                    if !t.is_finite() || t < 0.0 || t < prev {
                        return Err(ArrivalError::BadTrace { index, offset_s: t });
                    }
                    prev = t;
                }
            }
        }
        Ok(())
    }

    /// Materialize the open-loop arrival offsets (seconds from stream
    /// start, non-decreasing), drawing inter-arrival gaps from `rng`.
    /// Returns `None` for [`ArrivalProcess::ClosedLoop`], whose arrivals
    /// are completion-driven rather than scheduled.
    pub fn schedule(&self, rng: &mut Pcg32) -> Option<Vec<f64>> {
        match self {
            ArrivalProcess::ClosedLoop { .. } => None,
            ArrivalProcess::Poisson { rate_hz, duration_s } => {
                let mut out = Vec::new();
                let mut t = 0.0f64;
                loop {
                    t += exp_gap(rng, *rate_hz);
                    if t >= *duration_s {
                        return Some(out);
                    }
                    out.push(t);
                }
            }
            ArrivalProcess::Bursty { rate_hz, on_s, off_s, duration_s } => {
                let mut out = Vec::new();
                let cycle = on_s + off_s;
                let mut window_start = 0.0f64;
                // walk on-windows; inside each, draw Poisson gaps at rate_hz
                while window_start < *duration_s {
                    let window_end = if *off_s == 0.0 {
                        // degenerate square wave: one continuous window
                        *duration_s
                    } else {
                        (window_start + on_s).min(*duration_s)
                    };
                    let mut t = window_start;
                    loop {
                        t += exp_gap(rng, *rate_hz);
                        if t >= window_end {
                            break;
                        }
                        out.push(t);
                    }
                    if *off_s == 0.0 {
                        break;
                    }
                    window_start += cycle;
                }
                Some(out)
            }
            ArrivalProcess::Diurnal { base_hz, peak_hz, period_s, duration_s } => {
                // Lewis–Shedler thinning: draw a homogeneous candidate
                // stream at the peak rate and keep each candidate with
                // probability rate(t)/peak — exact for an inhomogeneous
                // Poisson process, and two draws per candidate keeps the
                // stream layout a pure function of the process parameters.
                let mut out = Vec::new();
                let mut t = 0.0f64;
                let two_pi = 2.0 * std::f64::consts::PI;
                loop {
                    t += exp_gap(rng, *peak_hz);
                    if t >= *duration_s {
                        return Some(out);
                    }
                    let rate = base_hz
                        + (peak_hz - base_hz) * 0.5 * (1.0 - (two_pi * t / period_s).cos());
                    if rng.f64() * peak_hz < rate {
                        out.push(t);
                    }
                }
            }
            ArrivalProcess::FlashCrowd { base_hz, spike_hz, spike_at_s, spike_s, duration_s } => {
                // piecewise-constant rate; restarting the exponential gap
                // at each boundary is valid by memorylessness
                let spike_start = spike_at_s.min(*duration_s);
                let spike_end = (spike_at_s + spike_s).min(*duration_s);
                let mut out = Vec::new();
                for (start, end, rate) in [
                    (0.0, spike_start, *base_hz),
                    (spike_start, spike_end, *spike_hz),
                    (spike_end, *duration_s, *base_hz),
                ] {
                    if end <= start {
                        continue;
                    }
                    let mut t = start;
                    loop {
                        t += exp_gap(rng, rate);
                        if t >= end {
                            break;
                        }
                        out.push(t);
                    }
                }
                Some(out)
            }
            ArrivalProcess::Trace { arrivals_s } => Some(arrivals_s.clone()),
        }
    }

    /// One-line human description (used in outcome tables and JSON).
    pub fn describe(&self) -> String {
        match self {
            ArrivalProcess::ClosedLoop { clients, per_client } => {
                format!("closed-loop {clients} clients x {per_client} req")
            }
            ArrivalProcess::Poisson { rate_hz, duration_s } => {
                format!("poisson {rate_hz} req/s for {duration_s}s")
            }
            ArrivalProcess::Bursty { rate_hz, on_s, off_s, duration_s } => {
                format!("bursty {rate_hz} req/s ({on_s}s on / {off_s}s off) for {duration_s}s")
            }
            ArrivalProcess::Diurnal { base_hz, peak_hz, period_s, duration_s } => format!(
                "diurnal {base_hz}..{peak_hz} req/s (period {period_s}s) for {duration_s}s"
            ),
            ArrivalProcess::FlashCrowd { base_hz, spike_hz, spike_at_s, spike_s, duration_s } => {
                format!(
                    "flash crowd {base_hz} req/s with {spike_hz} req/s spike \
                     at {spike_at_s}s for {spike_s}s, total {duration_s}s"
                )
            }
            ArrivalProcess::Trace { arrivals_s } => {
                format!("trace replay of {} arrivals", arrivals_s.len())
            }
        }
    }
}

/// Exponential inter-arrival gap at `rate_hz` (inverse-CDF of `1 - u`,
/// which is never zero, so the gap is always finite and positive).
fn exp_gap(rng: &mut Pcg32, rate_hz: f64) -> f64 {
    -(1.0 - rng.f64()).ln() / rate_hz
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn validation_catches_each_malformed_case() {
        for bad in [0.0, -3.0, f64::NAN, f64::NEG_INFINITY] {
            assert!(matches!(
                ArrivalProcess::Poisson { rate_hz: bad, duration_s: 1.0 }.validate(),
                Err(ArrivalError::BadRate(_))
            ));
        }
        assert!(matches!(
            ArrivalProcess::Poisson { rate_hz: 10.0, duration_s: 0.0 }.validate(),
            Err(ArrivalError::BadDuration(_))
        ));
        assert!(matches!(
            ArrivalProcess::ClosedLoop { clients: 0, per_client: 4 }.validate(),
            Err(ArrivalError::BadClients { .. })
        ));
        assert!(matches!(
            ArrivalProcess::Trace { arrivals_s: vec![] }.validate(),
            Err(ArrivalError::EmptyTrace)
        ));
        assert!(matches!(
            ArrivalProcess::Trace { arrivals_s: vec![0.0, 0.5, 0.2] }.validate(),
            Err(ArrivalError::BadTrace { index: 2, .. })
        ));
        // negative off window is rejected, zero is allowed
        assert!(ArrivalProcess::Bursty {
            rate_hz: 10.0,
            on_s: 0.1,
            off_s: -0.1,
            duration_s: 1.0
        }
        .validate()
        .is_err());
        assert!(ArrivalProcess::Bursty {
            rate_hz: 10.0,
            on_s: 0.1,
            off_s: 0.0,
            duration_s: 1.0
        }
        .validate()
        .is_ok());
    }

    #[test]
    fn poisson_schedule_is_deterministic_and_plausible() {
        let p = ArrivalProcess::Poisson { rate_hz: 1_000.0, duration_s: 2.0 };
        let a = p.schedule(&mut Pcg32::new(9)).unwrap();
        let b = p.schedule(&mut Pcg32::new(9)).unwrap();
        assert_eq!(a, b, "same seed must yield the same schedule");
        // ~2000 expected arrivals; allow wide slack
        assert!((1_500..2_500).contains(&a.len()), "{} arrivals", a.len());
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "must be non-decreasing");
        assert!(a.iter().all(|&t| (0.0..2.0).contains(&t)));
    }

    #[test]
    fn bursty_schedule_respects_off_windows() {
        let p = ArrivalProcess::Bursty {
            rate_hz: 2_000.0,
            on_s: 0.1,
            off_s: 0.1,
            duration_s: 1.0,
        };
        let times = p.schedule(&mut Pcg32::new(4)).unwrap();
        assert!(!times.is_empty());
        for &t in &times {
            let phase = t % 0.2;
            assert!(phase < 0.1, "arrival at {t} falls in an off window");
        }
        // roughly half the pure-Poisson count
        assert!((700..1_300).contains(&times.len()), "{} arrivals", times.len());
    }

    #[test]
    fn diurnal_schedule_modulates_density_deterministically() {
        let p = ArrivalProcess::Diurnal {
            base_hz: 200.0,
            peak_hz: 4_000.0,
            period_s: 1.0,
            duration_s: 1.0,
        };
        let a = p.schedule(&mut Pcg32::new(7)).unwrap();
        assert_eq!(a, p.schedule(&mut Pcg32::new(7)).unwrap(), "same seed, same schedule");
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "must be non-decreasing");
        assert!(a.iter().all(|&t| (0.0..1.0).contains(&t)));
        // mean rate = base + (peak − base)/2 = 2100/s over one period
        assert!((1_500..2_700).contains(&a.len()), "{} arrivals", a.len());
        // the crest (around t = 0.5) must be much denser than the trough
        let trough = a.iter().filter(|&&t| t < 0.1 || t >= 0.9).count();
        let crest = a.iter().filter(|&&t| (0.4..0.6).contains(&t)).count();
        assert!(
            crest > 3 * trough.max(1),
            "crest {crest} arrivals vs trough {trough}: no diurnal shape"
        );
    }

    #[test]
    fn flash_crowd_spikes_in_its_window() {
        let p = ArrivalProcess::FlashCrowd {
            base_hz: 500.0,
            spike_hz: 10_000.0,
            spike_at_s: 0.4,
            spike_s: 0.2,
            duration_s: 1.0,
        };
        let a = p.schedule(&mut Pcg32::new(5)).unwrap();
        assert_eq!(a, p.schedule(&mut Pcg32::new(5)).unwrap());
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "must be non-decreasing");
        let inside = a.iter().filter(|&&t| (0.4..0.6).contains(&t)).count();
        let outside = a.len() - inside;
        // ~2000 in the spike window vs ~400 outside
        assert!(inside > 3 * outside, "spike {inside} vs baseline {outside}");
        // a spike past the end degenerates to pure baseline traffic
        let tail = ArrivalProcess::FlashCrowd {
            base_hz: 500.0,
            spike_hz: 10_000.0,
            spike_at_s: 5.0,
            spike_s: 0.2,
            duration_s: 1.0,
        };
        let b = tail.schedule(&mut Pcg32::new(5)).unwrap();
        assert!((300..700).contains(&b.len()), "{} arrivals", b.len());
    }

    #[test]
    fn diurnal_and_flash_crowd_validation() {
        // peak below base breaks the thinning envelope
        assert!(matches!(
            ArrivalProcess::Diurnal {
                base_hz: 100.0,
                peak_hz: 50.0,
                period_s: 1.0,
                duration_s: 1.0
            }
            .validate(),
            Err(ArrivalError::BadRate(_))
        ));
        // peak == base is a legal degenerate (flat Poisson)
        assert!(ArrivalProcess::Diurnal {
            base_hz: 100.0,
            peak_hz: 100.0,
            period_s: 1.0,
            duration_s: 1.0
        }
        .validate()
        .is_ok());
        assert!(matches!(
            ArrivalProcess::Diurnal {
                base_hz: 100.0,
                peak_hz: 200.0,
                period_s: 0.0,
                duration_s: 1.0
            }
            .validate(),
            Err(ArrivalError::BadDuration(_))
        ));
        assert!(matches!(
            ArrivalProcess::FlashCrowd {
                base_hz: 100.0,
                spike_hz: 200.0,
                spike_at_s: -0.1,
                spike_s: 0.1,
                duration_s: 1.0
            }
            .validate(),
            Err(ArrivalError::BadOffset(_))
        ));
        assert!(matches!(
            ArrivalProcess::FlashCrowd {
                base_hz: 0.0,
                spike_hz: 200.0,
                spike_at_s: 0.1,
                spike_s: 0.1,
                duration_s: 1.0
            }
            .validate(),
            Err(ArrivalError::BadRate(_))
        ));
    }

    #[test]
    fn trace_replays_verbatim_and_closed_loop_has_no_schedule() {
        let offs = vec![0.0, 0.25, 0.25, 1.5];
        let p = ArrivalProcess::Trace { arrivals_s: offs.clone() };
        assert!(p.validate().is_ok());
        assert_eq!(p.schedule(&mut Pcg32::new(1)).unwrap(), offs);
        assert!(ArrivalProcess::ClosedLoop { clients: 2, per_client: 2 }
            .schedule(&mut Pcg32::new(1))
            .is_none());
    }
}
