//! Threaded load generation against a live [`crate::coordinator::Server`].
//!
//! These are the drivers that used to live as private copies inside
//! `benches/e2e_serving.rs`, promoted to the library so benches, examples,
//! and the scenario layer all share one implementation:
//!
//! - [`closed_loop`] — `clients` threads, each keeping exactly one request
//!   in flight for `per_client` requests (saturation load).
//! - [`open_loop`] — one pacing thread submitting at pre-materialized
//!   arrival offsets (latency-under-load / burst load), dropping rejected
//!   requests instead of retrying.
//!
//! **Traffic is deterministic under a fixed seed regardless of worker
//! interleaving**: every client owns a [`Pcg32::fork`] child stream keyed
//! by its client id (the same stream layout as
//! [`crate::workload::vserve`]), so the *sequence of (model, seed, label)
//! submissions* is a pure function of `(mix, seed)`. Wall-clock latencies
//! of course still vary run to run — for bit-reproducible serving
//! results, use the virtual-time engine.

use super::mix::TrafficMix;
use crate::coordinator::server::{SubmitError, SubmitHandle};
use crate::util::rng::Pcg32;
use crate::util::stats::percentile;
use std::time::{Duration, Instant};

/// Aggregate result of one generated traffic run.
#[derive(Debug, Clone, Default)]
pub struct TrafficReport {
    /// Submission attempts (closed-loop retries count again).
    pub submitted: usize,
    /// Responses received.
    pub completed: usize,
    /// Typed queue-full rejections observed.
    pub rejections: u64,
    /// End-to-end wall latencies (ms), in completion-collection order.
    pub latencies_ms: Vec<f64>,
    /// Requests admitted per mix model, in mix declaration order.
    pub per_model: Vec<(String, u64)>,
}

impl TrafficReport {
    /// Latency percentile (`q` in `[0, 100]`), in milliseconds.
    pub fn latency_percentile_ms(&self, q: f64) -> f64 {
        percentile(&self.latencies_ms, q)
    }
}

/// Closed-loop load: `clients` threads each keep one request in flight
/// until they have completed `per_client` requests. Queue-full rejections
/// are counted and retried (after a yield), so every request eventually
/// lands unless the server shuts down.
pub fn closed_loop(
    handle: &SubmitHandle,
    mix: &TrafficMix,
    clients: usize,
    per_client: usize,
    seed: u64,
) -> TrafficReport {
    let root = Pcg32::new(seed);
    let threads: Vec<_> = (0..clients)
        .map(|c| {
            let handle = handle.clone();
            let mix = mix.clone();
            // stream ids 2+c match the virtual engine's client streams
            let mut rng = root.fork(2 + c as u64);
            std::thread::spawn(move || {
                let mut lats = Vec::with_capacity(per_client);
                let mut rejected = 0u64;
                let mut submitted = 0usize;
                let mut counts = vec![0u64; mix.len()];
                for i in 0..per_client {
                    let m = mix.sample_index(&mut rng);
                    let model = mix.entries()[m].0.clone();
                    let req_seed = rng.next_u64();
                    loop {
                        submitted += 1;
                        match handle.submit(&model, req_seed, Some((i % 10) as u32), 1) {
                            Ok(rx) => {
                                if let Ok(resp) = rx.recv() {
                                    lats.push(resp.total_time * 1e3);
                                }
                                counts[m] += 1;
                                break;
                            }
                            Err(SubmitError::QueueFull { .. }) => {
                                rejected += 1;
                                std::thread::yield_now();
                            }
                            // server shut down mid-run: stop this client
                            Err(_) => return (lats, rejected, submitted, counts),
                        }
                    }
                }
                (lats, rejected, submitted, counts)
            })
        })
        .collect();

    let mut report = TrafficReport {
        per_model: mix.models().into_iter().map(|m| (m, 0u64)).collect(),
        ..TrafficReport::default()
    };
    for t in threads {
        let (lats, rejected, submitted, counts) =
            t.join().expect("workload client thread panicked");
        report.completed += lats.len();
        report.latencies_ms.extend(lats);
        report.rejections += rejected;
        report.submitted += submitted;
        for (slot, n) in report.per_model.iter_mut().zip(counts) {
            slot.1 += n;
        }
    }
    report
}

/// Open-loop load: submit one request per arrival offset (seconds from
/// stream start, non-decreasing — see
/// [`crate::workload::ArrivalProcess::schedule`]), pacing the submissions
/// at `offset × time_scale` wall seconds (`time_scale = 0` submits the
/// whole stream as one burst). Queue-full rejections are *dropped*, not
/// retried — open-loop sources do not slow down for an overloaded server,
/// which is exactly what makes this the backpressure probe.
pub fn open_loop(
    handle: &SubmitHandle,
    mix: &TrafficMix,
    offsets_s: &[f64],
    time_scale: f64,
    seed: u64,
) -> TrafficReport {
    let root = Pcg32::new(seed);
    // stream id 1 matches the virtual engine's open-loop mix stream
    let mut rng = root.fork(1);
    let mut report = TrafficReport {
        per_model: mix.models().into_iter().map(|m| (m, 0u64)).collect(),
        ..TrafficReport::default()
    };
    let mut pending = Vec::with_capacity(offsets_s.len());
    let start = Instant::now();
    for (i, &off) in offsets_s.iter().enumerate() {
        let target = off * time_scale;
        if target > 0.0 && target.is_finite() {
            let target = Duration::from_secs_f64(target);
            let elapsed = start.elapsed();
            if target > elapsed {
                std::thread::sleep(target - elapsed);
            }
        }
        let m = mix.sample_index(&mut rng);
        let model = &mix.entries()[m].0;
        let req_seed = rng.next_u64();
        report.submitted += 1;
        match handle.submit(model, req_seed, Some((i % 10) as u32), 1) {
            Ok(rx) => {
                report.per_model[m].1 += 1;
                pending.push(rx);
            }
            Err(SubmitError::QueueFull { .. }) => report.rejections += 1,
            Err(_) => break, // server shut down mid-run
        }
    }
    for rx in pending {
        if let Ok(resp) = rx.recv() {
            report.latencies_ms.push(resp.total_time * 1e3);
            report.completed += 1;
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::{BatchExecutor, Server, ServerConfig};
    use crate::coordinator::BatchPolicy;
    use std::sync::Arc;

    /// Instant stub executor serving two models.
    struct Stub;

    impl BatchExecutor for Stub {
        fn models(&self) -> Vec<String> {
            vec!["a".into(), "b".into()]
        }

        fn elements_per_sample(&self, _m: &str) -> usize {
            2
        }

        fn generate(&self, _m: &str, entries: &[(u64, Option<u32>)]) -> Vec<f32> {
            vec![0.0; entries.len() * 2]
        }
    }

    fn mix_ab() -> TrafficMix {
        TrafficMix::new(vec![("a".into(), 1.0), ("b".into(), 1.0)]).unwrap()
    }

    #[test]
    fn closed_loop_completes_every_request() {
        let server = Server::start(Arc::new(Stub), ServerConfig::default());
        let report = closed_loop(&server.handle(), &mix_ab(), 4, 16, 42);
        assert_eq!(report.completed, 64);
        assert_eq!(report.latencies_ms.len(), 64);
        assert_eq!(report.per_model.iter().map(|(_, n)| n).sum::<u64>(), 64);
        // both mix entries see traffic under a uniform split of 64 draws
        assert!(report.per_model.iter().all(|(_, n)| *n > 0), "{:?}", report.per_model);
        server.shutdown();
    }

    #[test]
    fn open_loop_burst_counts_rejections_against_a_tiny_queue() {
        let server = Server::start(
            Arc::new(Stub),
            ServerConfig {
                policy: BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_millis(5),
                },
                workers: 1,
                queue_depth: 4,
                ..ServerConfig::default()
            },
        );
        // one simultaneous burst far over the queue depth
        let offsets = vec![0.0; 256];
        let report = open_loop(&server.handle(), &mix_ab(), &offsets, 0.0, 7);
        assert_eq!(report.submitted, 256);
        assert_eq!(report.completed + report.rejections as usize, 256);
        assert!(report.rejections > 0, "queue of 4 must shed a 256 burst");
        server.shutdown();
    }

    #[test]
    fn traffic_sequence_is_seed_deterministic() {
        // the per-model admission counts depend only on (mix, seed): run
        // the same closed loop against two separate servers
        let run = || {
            let server = Server::start(Arc::new(Stub), ServerConfig::default());
            let r = closed_loop(&server.handle(), &mix_ab(), 3, 32, 9);
            server.shutdown();
            r.per_model
        };
        assert_eq!(run(), run(), "model sequence must not depend on scheduling");
    }
}
