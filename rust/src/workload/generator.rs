//! Threaded load generation against a live serving core.
//!
//! These are the drivers that used to live as private copies inside
//! `benches/e2e_serving.rs`, promoted to the library so benches, examples,
//! and the scenario layer all share one implementation:
//!
//! - [`closed_loop`] — `clients` threads, each keeping exactly one request
//!   in flight for `per_client` requests (saturation load).
//! - [`open_loop`] — one pacing thread submitting at pre-materialized
//!   arrival offsets (latency-under-load / burst load), dropping rejected
//!   requests instead of retrying.
//!
//! Both are generic over [`TrafficSink`], so one implementation drives the
//! threaded [`crate::coordinator::Server`] and the continuous-batching
//! [`crate::coordinator::AsyncServer`] identically — which is what the
//! cross-engine conformance suite leans on.
//!
//! Rejection semantics differ by error and loop discipline:
//!
//! - `QueueFull` is *transient* backpressure. The closed loop counts it
//!   and retries (the slot will free); the open loop drops the request
//!   (open-loop sources do not slow down).
//! - `Shed` is a *server decision* — the request was refused against the
//!   deadline SLO, and retrying the identical request would be refused
//!   again for as long as the backlog stands (a livelock under saturation).
//!   Both loops count it and move on to the next request.
//!
//! **Traffic is deterministic under a fixed seed regardless of worker
//! interleaving**: every client owns a [`Pcg32::fork`] child stream keyed
//! by its client id (the same stream layout as
//! [`crate::workload::vserve`]), so the *sequence of (model, seed, label)
//! submissions* is a pure function of `(mix, seed)`. Wall-clock latencies
//! of course still vary run to run — for bit-reproducible serving
//! results, use the virtual-time engine.

use super::mix::TrafficMix;
use crate::coordinator::request::PendingReply;
use crate::coordinator::server::{SubmitError, TrafficSink};
use crate::util::rng::Pcg32;
use crate::util::stats::percentile;
use std::time::{Duration, Instant};

/// Aggregate result of one generated traffic run.
#[derive(Debug, Clone, Default)]
pub struct TrafficReport {
    /// Submission attempts (closed-loop retries count again).
    pub submitted: usize,
    /// Responses received.
    pub completed: usize,
    /// Typed queue-full rejections observed.
    pub rejections: u64,
    /// Typed SLO sheds observed (never retried — see the module docs).
    pub sheds: u64,
    /// End-to-end wall latencies (ms), in completion-collection order.
    pub latencies_ms: Vec<f64>,
    /// Requests admitted per mix model, in mix declaration order.
    pub per_model: Vec<(String, u64)>,
}

impl TrafficReport {
    /// Latency percentile (`q` in `[0, 100]`), in milliseconds.
    pub fn latency_percentile_ms(&self, q: f64) -> f64 {
        percentile(&self.latencies_ms, q)
    }
}

/// Closed-loop load: `clients` threads each keep one request in flight
/// until they have walked `per_client` requests. Queue-full rejections
/// are counted and retried (after a yield); sheds are counted and the
/// client moves on to its next request.
pub fn closed_loop<S: TrafficSink>(
    handle: &S,
    mix: &TrafficMix,
    clients: usize,
    per_client: usize,
    seed: u64,
) -> TrafficReport {
    let root = Pcg32::new(seed);
    let threads: Vec<_> = (0..clients)
        .map(|c| {
            let handle = handle.clone();
            let mix = mix.clone();
            // stream ids 2+c match the virtual engine's client streams
            let mut rng = root.fork(2 + c as u64);
            std::thread::spawn(move || {
                let mut lats = Vec::with_capacity(per_client);
                let mut rejected = 0u64;
                let mut shed = 0u64;
                let mut submitted = 0usize;
                let mut counts = vec![0u64; mix.len()];
                for i in 0..per_client {
                    let m = mix.sample_index(&mut rng);
                    let model = mix.entries()[m].0.clone();
                    let req_seed = rng.next_u64();
                    loop {
                        submitted += 1;
                        match handle.submit(&model, req_seed, Some((i % 10) as u32), 1) {
                            Ok(rx) => {
                                if let Some(resp) = rx.wait() {
                                    lats.push(resp.total_time * 1e3);
                                }
                                counts[m] += 1;
                                break;
                            }
                            Err(SubmitError::QueueFull { .. }) => {
                                rejected += 1;
                                std::thread::yield_now();
                            }
                            Err(SubmitError::Shed { .. }) => {
                                // server refusal, not transient: next request
                                shed += 1;
                                break;
                            }
                            // server shut down mid-run: stop this client
                            Err(_) => return (lats, rejected, shed, submitted, counts),
                        }
                    }
                }
                (lats, rejected, shed, submitted, counts)
            })
        })
        .collect();

    let mut report = TrafficReport {
        per_model: mix.models().into_iter().map(|m| (m, 0u64)).collect(),
        ..TrafficReport::default()
    };
    for t in threads {
        // a panicking client thread is a test/driver bug: propagate the
        // original panic payload instead of masking it with a new one
        let (lats, rejected, shed, submitted, counts) = match t.join() {
            Ok(r) => r,
            Err(payload) => std::panic::resume_unwind(payload),
        };
        report.completed += lats.len();
        report.latencies_ms.extend(lats);
        report.rejections += rejected;
        report.sheds += shed;
        report.submitted += submitted;
        for (slot, n) in report.per_model.iter_mut().zip(counts) {
            slot.1 += n;
        }
    }
    report
}

/// Open-loop load: submit one request per arrival offset (seconds from
/// stream start, non-decreasing — see
/// [`crate::workload::ArrivalProcess::schedule`]), pacing the submissions
/// at `offset × time_scale` wall seconds (`time_scale = 0` submits the
/// whole stream as one burst). Queue-full rejections and sheds are both
/// *dropped*, not retried — open-loop sources do not slow down for an
/// overloaded server, which is exactly what makes this the backpressure
/// probe — but they are counted separately.
pub fn open_loop<S: TrafficSink>(
    handle: &S,
    mix: &TrafficMix,
    offsets_s: &[f64],
    time_scale: f64,
    seed: u64,
) -> TrafficReport {
    let root = Pcg32::new(seed);
    // stream id 1 matches the virtual engine's open-loop mix stream
    let mut rng = root.fork(1);
    let mut report = TrafficReport {
        per_model: mix.models().into_iter().map(|m| (m, 0u64)).collect(),
        ..TrafficReport::default()
    };
    let mut pending = Vec::with_capacity(offsets_s.len());
    let start = Instant::now();
    for (i, &off) in offsets_s.iter().enumerate() {
        let target = off * time_scale;
        if target > 0.0 && target.is_finite() {
            let target = Duration::from_secs_f64(target);
            let elapsed = start.elapsed();
            if target > elapsed {
                std::thread::sleep(target - elapsed);
            }
        }
        let m = mix.sample_index(&mut rng);
        let model = &mix.entries()[m].0;
        let req_seed = rng.next_u64();
        report.submitted += 1;
        match handle.submit(model, req_seed, Some((i % 10) as u32), 1) {
            Ok(rx) => {
                report.per_model[m].1 += 1;
                pending.push(rx);
            }
            Err(SubmitError::QueueFull { .. }) => report.rejections += 1,
            Err(SubmitError::Shed { .. }) => report.sheds += 1,
            Err(_) => break, // server shut down mid-run
        }
    }
    for rx in pending {
        if let Some(resp) = rx.wait() {
            report.latencies_ms.push(resp.total_time * 1e3);
            report.completed += 1;
        }
    }
    report
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::coordinator::async_server::{AsyncServer, AsyncServerConfig};
    use crate::coordinator::server::{BatchExecutor, Server, ServerConfig};
    use crate::coordinator::BatchPolicy;
    use std::sync::Arc;

    /// Instant stub executor serving two models.
    struct Stub;

    impl BatchExecutor for Stub {
        fn models(&self) -> Vec<String> {
            vec!["a".into(), "b".into()]
        }

        fn elements_per_sample(&self, _m: &str) -> usize {
            2
        }

        fn generate(&self, _m: &str, entries: &[(u64, Option<u32>)]) -> Vec<f32> {
            vec![0.0; entries.len() * 2]
        }
    }

    fn mix_ab() -> TrafficMix {
        TrafficMix::new(vec![("a".into(), 1.0), ("b".into(), 1.0)]).unwrap()
    }

    #[test]
    fn closed_loop_completes_every_request() {
        let server = Server::start(Arc::new(Stub), ServerConfig::default());
        let report = closed_loop(&server.handle(), &mix_ab(), 4, 16, 42);
        assert_eq!(report.completed, 64);
        assert_eq!(report.latencies_ms.len(), 64);
        assert_eq!(report.per_model.iter().map(|(_, n)| n).sum::<u64>(), 64);
        // both mix entries see traffic under a uniform split of 64 draws
        assert!(report.per_model.iter().all(|(_, n)| *n > 0), "{:?}", report.per_model);
        server.shutdown();
    }

    #[test]
    fn closed_loop_drives_the_async_core_too() {
        let server = AsyncServer::start(Arc::new(Stub), AsyncServerConfig::default());
        let report = closed_loop(&server.handle(), &mix_ab(), 4, 16, 42);
        assert_eq!(report.completed, 64);
        assert_eq!(report.sheds, 0);
        server.shutdown();
    }

    #[test]
    fn open_loop_burst_counts_rejections_against_a_tiny_queue() {
        let server = Server::start(
            Arc::new(Stub),
            ServerConfig {
                policy: BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_millis(5),
                },
                workers: 1,
                queue_depth: 4,
                ..ServerConfig::default()
            },
        );
        // one simultaneous burst far over the queue depth
        let offsets = vec![0.0; 256];
        let report = open_loop(&server.handle(), &mix_ab(), &offsets, 0.0, 7);
        assert_eq!(report.submitted, 256);
        assert_eq!(report.completed + report.rejections as usize, 256);
        assert!(report.rejections > 0, "queue of 4 must shed a 256 burst");
        server.shutdown();
    }

    #[test]
    fn traffic_sequence_is_seed_deterministic() {
        // the per-model admission counts depend only on (mix, seed): run
        // the same closed loop against two separate servers
        let run = || {
            let server = Server::start(Arc::new(Stub), ServerConfig::default());
            let r = closed_loop(&server.handle(), &mix_ab(), 3, 32, 9);
            server.shutdown();
            r.per_model
        };
        assert_eq!(run(), run(), "model sequence must not depend on scheduling");
    }

    #[test]
    fn engines_admit_identical_model_sequences() {
        // the whole point of TrafficSink: the submission stream a seed
        // produces must be engine-independent
        let threaded = {
            let server = Server::start(Arc::new(Stub), ServerConfig::default());
            let r = closed_loop(&server.handle(), &mix_ab(), 3, 32, 9);
            server.shutdown();
            r.per_model
        };
        let async_ = {
            let server = AsyncServer::start(Arc::new(Stub), AsyncServerConfig::default());
            let r = closed_loop(&server.handle(), &mix_ab(), 3, 32, 9);
            server.shutdown();
            r.per_model
        };
        assert_eq!(threaded, async_);
    }

    /// Slow executor for shedding: every batch takes ~2ms.
    struct SlowStub;

    impl BatchExecutor for SlowStub {
        fn models(&self) -> Vec<String> {
            vec!["a".into(), "b".into()]
        }

        fn elements_per_sample(&self, _m: &str) -> usize {
            1
        }

        fn generate(&self, _m: &str, entries: &[(u64, Option<u32>)]) -> Vec<f32> {
            std::thread::sleep(Duration::from_millis(2));
            vec![0.0; entries.len()]
        }
    }

    #[test]
    fn closed_loop_moves_past_sheds_instead_of_livelocking() {
        // deadline far below the service estimate: once the estimate is
        // seeded, nearly everything sheds — the loop must still terminate
        // with submitted bounded by clients × per_client (no shed retries)
        let server = AsyncServer::start(
            Arc::new(SlowStub),
            AsyncServerConfig {
                policy: BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
                workers: 1,
                deadline: Some(Duration::from_micros(10)),
                ..AsyncServerConfig::default()
            },
        );
        let report = closed_loop(&server.handle(), &mix_ab(), 2, 8, 11);
        assert!(report.sheds > 0, "tiny deadline must shed");
        assert_eq!(
            report.completed as u64 + report.sheds,
            16,
            "every walked request either completed or shed exactly once"
        );
        let stats = server.shutdown();
        assert_eq!(stats.total_sheds, report.sheds, "server and client shed counts agree");
    }
}
