//! Virtual-time multi-shard serving simulation.
//!
//! The threaded coordinator ([`crate::coordinator::Server`]) measures real
//! wall-clock latencies, which makes its outputs irreproducible by
//! construction. Scenario runs need the opposite: **bit-identical results
//! for a fixed seed**, so SLO verdicts and regression diffs are stable
//! across hosts and runs. This module re-implements the coordinator's
//! serving semantics — shard routing, bounded per-shard queues with
//! rejection, per-model dynamic batching under `(max_batch, max_wait)`,
//! and a fixed worker pool per shard — as a deterministic discrete-event
//! simulation in *virtual seconds*, with batch service times supplied by a
//! pluggable [`ServiceModel`] (the API layer plugs in the photonic
//! simulator through the session mapping cache).
//!
//! Every source of nondeterminism is removed: arrivals are materialized
//! from seeded [`Pcg32`] streams ([`crate::workload::ArrivalProcess`]),
//! event ties break on insertion order, routing ties break on the lowest
//! shard index, and all accounting is plain `f64` arithmetic. Running the
//! same `(config, mix, arrival, seed)` twice yields byte-identical
//! outcomes.
//!
//! An optional [`CalibrationConfig`] injects the fidelity layer's drift
//! dynamics ([`crate::fidelity::calibration`]): each shard periodically
//! goes down for a re-calibration outage, during which its in-flight
//! batches finish but nothing new dispatches. Arrivals still enqueue (and
//! the bounded queue still rejects), so the run surfaces the
//! tail-latency/availability cost of drift and how routing/admission
//! absorb shards going offline.

use super::arrival::ArrivalProcess;
use super::mix::TrafficMix;
use crate::coordinator::routing::{affinity_hash, RoutingPolicy};
use crate::util::rng::Pcg32;
use crate::util::stats::percentile_sorted;
use std::collections::{BinaryHeap, VecDeque};

/// Supplies the virtual service time of one dispatched batch.
///
/// (Deliberately not blanket-implemented for closures: downstream code
/// implements it for named types — e.g. the API layer's session-backed
/// cost model — which a `Fn` blanket impl would conflict with under
/// coherence.)
pub trait ServiceModel {
    /// End-to-end latency (seconds) of serving `batch` samples of `model`
    /// on one chip. Must be deterministic for determinism of the DES.
    fn batch_latency_s(&self, model: &str, batch: usize) -> f64;
}

/// Periodic per-shard re-calibration outages (virtual seconds).
///
/// Models the fidelity layer's drift budget: a shard serves for
/// `interval_s`, then goes offline for `outage_s` to re-lock its MR
/// banks and re-program PCM weights. Shard start times are staggered
/// across the interval so the fleet never calibrates all at once.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CalibrationConfig {
    /// Virtual seconds of serving between outages (must be positive).
    pub interval_s: f64,
    /// Virtual seconds a shard is down per outage (must be `>= 0`).
    pub outage_s: f64,
}

impl CalibrationConfig {
    /// Derive the schedule from a physics-grounded
    /// [`CalibrationModel`][crate::fidelity::CalibrationModel] for a
    /// shard that re-calibrates `banks` MR banks per outage.
    pub fn from_model(model: &crate::fidelity::CalibrationModel, banks: usize) -> Self {
        CalibrationConfig { interval_s: model.interval_s(), outage_s: model.outage_s(banks) }
    }
}

/// Virtual serving fleet shape — the deterministic mirror of
/// [`crate::coordinator::ServerConfig`].
#[derive(Debug, Clone, PartialEq)]
pub struct VirtualServeConfig {
    /// Independent serving shards (chips).
    pub shards: usize,
    /// Virtual workers per shard (concurrent batches in flight per chip).
    pub workers: usize,
    /// Maximum samples per dispatched batch.
    pub max_batch: usize,
    /// Maximum virtual seconds the oldest pending request waits before its
    /// batch is dispatched anyway.
    pub max_wait_s: f64,
    /// Bounded in-flight samples per shard; arrivals beyond are rejected.
    pub queue_depth: usize,
    /// How arrivals pick a shard.
    pub routing: RoutingPolicy,
    /// Periodic re-calibration outages; `None` (the default) keeps the
    /// pre-fidelity behavior byte-identical.
    pub calibration: Option<CalibrationConfig>,
    /// Completion-deadline SLO (virtual seconds) — the deterministic
    /// mirror of [`crate::coordinator::AsyncServerConfig::deadline`]. A
    /// submission whose predicted completion (post-admission backlog ×
    /// per-sample service estimate ÷ workers) exceeds the deadline is
    /// shed instead of queued. The estimate here is
    /// `batch_latency_s(model, max_batch) / max_batch` from the cost
    /// model — known upfront, where the async core learns it by EWMA, so
    /// the virtual engine sheds from the first arrival while the real
    /// core's first request always passes. `None` disables shedding.
    pub deadline_s: Option<f64>,
}

impl Default for VirtualServeConfig {
    fn default() -> Self {
        VirtualServeConfig {
            shards: 1,
            workers: 2,
            max_batch: 8,
            max_wait_s: 5e-4,
            queue_depth: 1024,
            routing: RoutingPolicy::RoundRobin,
            calibration: None,
            deadline_s: None,
        }
    }
}

/// Per-shard load accounting of a virtual run.
#[derive(Debug, Clone, PartialEq)]
pub struct VirtualShardLoad {
    pub shard: usize,
    /// Requests admitted onto this shard.
    pub requests: u64,
    /// Worker-seconds spent serving batches.
    pub busy_s: f64,
    /// `busy_s / (workers × makespan)` — mean worker occupancy.
    pub utilization: f64,
    /// Re-calibration outages this shard took within the makespan.
    pub outages: u64,
    /// Virtual seconds this shard was down for re-calibration (clipped
    /// to the makespan).
    pub downtime_s: f64,
}

/// Deterministic outcome of a virtual serving run.
#[derive(Debug, Clone, PartialEq)]
pub struct VirtualOutcome {
    /// Submission attempts (closed-loop retries count again).
    pub offered: usize,
    /// Requests admitted past the bounded queues (all complete by end).
    pub admitted: usize,
    /// Typed queue-full rejections.
    pub rejected: usize,
    /// Requests refused at admission by the deadline SLO (never retried —
    /// a shed is a server decision, not transient backpressure).
    pub shed: usize,
    /// Virtual time from stream start to the last completion/arrival.
    pub makespan_s: f64,
    /// Per-request virtual latencies in milliseconds, sorted ascending.
    pub latencies_ms: Vec<f64>,
    /// Dispatched batches and their mean size.
    pub batches: u64,
    pub mean_batch: f64,
    /// Admitted requests per mix model, in mix declaration order.
    pub per_model: Vec<(String, u64)>,
    pub per_shard: Vec<VirtualShardLoad>,
    /// Re-calibration outages across all shards (within the makespan).
    pub outages: u64,
    /// Total shard-seconds of re-calibration downtime.
    pub downtime_s: f64,
    /// `1 − downtime / (shards × makespan)` — fraction of fleet
    /// capacity that was up (1.0 without calibration).
    pub availability: f64,
}

impl VirtualOutcome {
    /// Admitted requests per virtual second.
    pub fn throughput_rps(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.admitted as f64 / self.makespan_s
        } else {
            0.0
        }
    }

    /// Latency percentile (`q` in `[0, 100]`), in milliseconds.
    pub fn latency_percentile_ms(&self, q: f64) -> f64 {
        percentile_sorted(&self.latencies_ms, q)
    }

    /// Mean latency in milliseconds.
    pub fn mean_latency_ms(&self) -> f64 {
        if self.latencies_ms.is_empty() {
            0.0
        } else {
            self.latencies_ms.iter().sum::<f64>() / self.latencies_ms.len() as f64
        }
    }

    /// Fraction of offered requests refused — queue-full rejections and
    /// SLO sheds both count (the `max_reject_frac` SLO bounds refusals of
    /// any kind).
    pub fn reject_fraction(&self) -> f64 {
        if self.offered > 0 {
            (self.rejected + self.shed) as f64 / self.offered as f64
        } else {
            0.0
        }
    }
}

/// Virtual backoff before a rejected closed-loop client retries (the
/// deterministic analogue of the threaded generator's `yield_now`).
const RETRY_BACKOFF_S: f64 = 1e-5;

#[derive(Debug, Clone, Copy)]
enum EventKind {
    /// A scheduled open-loop arrival of one `mix` model.
    Arrival { model: usize },
    /// A closed-loop client is ready to issue its next request.
    ClientNext { client: usize },
    /// A rejected closed-loop submission retries (same sampled model).
    ClientRetry { client: usize, model: usize },
    /// A shard worker finished a batch, releasing `release` samples of
    /// the shard's bounded queue capacity (the coordinator holds capacity
    /// until the response is delivered, not until dispatch).
    WorkerFree { shard: usize, release: usize },
    /// A shard's oldest pending request reached `max_wait_s`.
    Deadline { shard: usize },
    /// A shard's drift budget is spent: it goes down for re-calibration.
    CalibrationStart { shard: usize },
    /// A shard finished re-calibrating and resumes dispatching.
    CalibrationEnd { shard: usize },
}

#[derive(Debug, Clone, Copy)]
struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
}

// BinaryHeap is a max-heap: invert the ordering so the earliest (time,
// seq) pops first. seq is unique, so the order is total and deterministic.
impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

#[derive(Debug, Clone)]
struct Req {
    arrival: f64,
    /// The closed-loop client to wake on completion, if any. (The model
    /// is identified by which per-model queue holds the request.)
    client: Option<usize>,
}

struct Shard {
    /// Free-at virtual time per worker.
    worker_free: Vec<f64>,
    /// Pending requests per mix model (FIFO).
    pending: Vec<VecDeque<Req>>,
    outstanding: usize,
    requests: u64,
    busy_s: f64,
    /// Down for re-calibration until this virtual time (0.0 = up).
    down_until: f64,
}

struct Dispatcher<'a, C: ServiceModel> {
    cfg: &'a VirtualServeConfig,
    names: &'a [String],
    cost: &'a C,
    heap: BinaryHeap<Event>,
    seq: u64,
    latencies_ms: Vec<f64>,
    per_model: Vec<u64>,
    batches: u64,
    batch_samples: u64,
    makespan: f64,
    /// `(client, completion)` wakeups produced by the last dispatch pass.
    completions: Vec<(usize, f64)>,
}

impl<'a, C: ServiceModel> Dispatcher<'a, C> {
    fn push(&mut self, time: f64, kind: EventKind) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Event { time, seq, kind });
    }

    /// Dispatch every batch that is ready on `shard` at virtual time
    /// `now`; schedules the deadline/worker-free events that guarantee
    /// progress for anything left pending.
    fn try_dispatch(&mut self, shard_idx: usize, sh: &mut Shard, now: f64) {
        // a shard that is down for re-calibration dispatches nothing;
        // the CalibrationEnd event re-runs dispatch, so pending heads
        // cannot starve
        if now < sh.down_until {
            return;
        }
        loop {
            // idle worker with the earliest free-at (ties → lowest index)
            let mut worker: Option<(usize, f64)> = None;
            for (i, &free) in sh.worker_free.iter().enumerate() {
                if free <= now {
                    match worker {
                        Some((_, best)) if best <= free => {}
                        _ => worker = Some((i, free)),
                    }
                }
            }
            let Some((w, _)) = worker else { break };
            // A batch is ready when it is full or its head has waited
            // max_wait. The coordinator drains *every* ready batcher
            // (`Batcher::ready`), so an unready queue must never block a
            // ready one: serve the ready model with the oldest head, and
            // remember the oldest unready head for the progress deadline.
            let mut ready: Option<(usize, f64)> = None;
            let mut waiting: Option<f64> = None;
            for (m, q) in sh.pending.iter().enumerate() {
                if let Some(r) = q.front() {
                    let head = r.arrival;
                    if q.len() >= self.cfg.max_batch || now >= head + self.cfg.max_wait_s {
                        match ready {
                            Some((_, best)) if best <= head => {}
                            _ => ready = Some((m, head)),
                        }
                    } else {
                        match waiting {
                            Some(best) if best <= head => {}
                            _ => waiting = Some(head),
                        }
                    }
                }
            }
            let Some((m, _)) = ready else {
                if let Some(head) = waiting {
                    // progress guarantee: revisit when the oldest unready
                    // head times out
                    self.push(
                        head + self.cfg.max_wait_s,
                        EventKind::Deadline { shard: shard_idx },
                    );
                }
                break;
            };
            let k = sh.pending[m].len().min(self.cfg.max_batch);
            let service = self.cost.batch_latency_s(&self.names[m], k).max(0.0);
            let done = now + service;
            sh.worker_free[w] = done;
            sh.busy_s += service;
            self.batches += 1;
            self.batch_samples += k as u64;
            for _ in 0..k {
                if let Some(r) = sh.pending[m].pop_front() {
                    self.latencies_ms.push((done - r.arrival) * 1e3);
                    self.per_model[m] += 1;
                    if let Some(c) = r.client {
                        self.completions.push((c, done));
                    }
                }
            }
            self.makespan = self.makespan.max(done);
            // queue capacity stays reserved until the batch completes
            self.push(done, EventKind::WorkerFree { shard: shard_idx, release: k });
        }
    }
}

/// Pick a shard for `model` under `routing` (deterministic; ties break
/// toward the lowest shard index).
fn route(routing: RoutingPolicy, rr: &mut usize, shards: &[Shard], model: &str) -> usize {
    match routing {
        RoutingPolicy::RoundRobin => {
            let s = *rr % shards.len();
            *rr += 1;
            s
        }
        RoutingPolicy::LeastOutstanding => {
            let mut best = 0usize;
            let mut best_load = usize::MAX;
            for (i, sh) in shards.iter().enumerate() {
                if sh.outstanding < best_load {
                    best = i;
                    best_load = sh.outstanding;
                }
            }
            best
        }
        RoutingPolicy::ModelAffinity => (affinity_hash(model) % shards.len() as u64) as usize,
    }
}

/// Run a deterministic virtual-time serving simulation.
///
/// `seed` derives every random stream ([`Pcg32::fork`]): stream 0 feeds
/// the open-loop arrival schedule, stream 1 the open-loop model mix, and
/// streams `2 + c` the closed-loop clients — the same stream layout the
/// threaded [`crate::workload::generator`] uses, so virtual and threaded
/// runs of one scenario draw identical traffic.
pub fn simulate_serve<C: ServiceModel>(
    cfg: &VirtualServeConfig,
    mix: &TrafficMix,
    arrival: &ArrivalProcess,
    cost: &C,
    seed: u64,
) -> VirtualOutcome {
    assert!(cfg.shards >= 1, "at least one shard");
    assert!(cfg.workers >= 1, "at least one worker per shard");
    assert!(cfg.max_batch >= 1, "batches must admit a sample");
    assert!(cfg.queue_depth >= 1, "queue depth must admit a sample");
    assert!(
        cfg.max_wait_s.is_finite() && cfg.max_wait_s >= 0.0,
        "max_wait must be finite and >= 0"
    );
    if let Some(dl) = cfg.deadline_s {
        assert!(dl.is_finite() && dl >= 0.0, "deadline must be finite and >= 0");
    }
    if let Some(cal) = cfg.calibration {
        assert!(
            cal.interval_s.is_finite() && cal.interval_s > 0.0,
            "calibration interval must be finite and positive"
        );
        assert!(
            cal.outage_s.is_finite() && cal.outage_s >= 0.0,
            "calibration outage must be finite and >= 0"
        );
    }

    let root = Pcg32::new(seed);
    let names = mix.models();
    let n_models = names.len();
    // deterministic per-sample service estimate backing the deadline SLO
    // (the virtual analogue of the async core's EWMA)
    let est_sample_s: Vec<f64> = if cfg.deadline_s.is_some() {
        names
            .iter()
            .map(|m| cost.batch_latency_s(m, cfg.max_batch).max(0.0) / cfg.max_batch as f64)
            .collect()
    } else {
        Vec::new()
    };
    let mut shards: Vec<Shard> = (0..cfg.shards)
        .map(|_| Shard {
            worker_free: vec![0.0; cfg.workers],
            pending: (0..n_models).map(|_| VecDeque::new()).collect(),
            outstanding: 0,
            requests: 0,
            busy_s: 0.0,
            down_until: 0.0,
        })
        .collect();
    let mut outage_windows: Vec<Vec<(f64, f64)>> = vec![Vec::new(); cfg.shards];

    let mut d = Dispatcher {
        cfg,
        names: &names,
        cost,
        heap: BinaryHeap::new(),
        seq: 0,
        latencies_ms: Vec::new(),
        per_model: vec![0u64; n_models],
        batches: 0,
        batch_samples: 0,
        makespan: 0.0,
        completions: Vec::new(),
    };

    // seed the event stream
    if let Some(cal) = cfg.calibration {
        for s in 0..cfg.shards {
            // stagger the first outage across the interval so the fleet
            // never calibrates all at once
            let offset = cal.interval_s * s as f64 / cfg.shards as f64;
            d.push(cal.interval_s + offset, EventKind::CalibrationStart { shard: s });
        }
    }
    let mut client_rngs: Vec<Pcg32> = Vec::new();
    let mut client_remaining: Vec<usize> = Vec::new();
    match arrival.schedule(&mut root.fork(0)) {
        Some(times) => {
            let mut mix_rng = root.fork(1);
            for t in times {
                let model = mix.sample_index(&mut mix_rng);
                // burn the draw the threaded generator spends on the
                // per-request seed, so both engines sample the same
                // model sequence from one scenario seed
                let _ = mix_rng.next_u64();
                d.push(t, EventKind::Arrival { model });
            }
        }
        None => {
            if let ArrivalProcess::ClosedLoop { clients, per_client } = arrival {
                for c in 0..*clients {
                    client_rngs.push(root.fork(2 + c as u64));
                    client_remaining.push(*per_client);
                    d.push(0.0, EventKind::ClientNext { client: c });
                }
            }
        }
    }

    let mut offered = 0usize;
    let mut rejected = 0usize;
    let mut shed = 0usize;
    let mut rr = 0usize;

    while let Some(ev) = d.heap.pop() {
        let now = ev.time;
        match ev.kind {
            EventKind::Arrival { model } => {
                // makespan tracks arrivals and completions only — stale
                // deadline/retry events must not inflate it
                d.makespan = d.makespan.max(now);
                offered += 1;
                let s = route(cfg.routing, &mut rr, &shards, &names[model]);
                let sh = &mut shards[s];
                if sh.outstanding + 1 > cfg.queue_depth {
                    rejected += 1;
                } else if sheds_at(cfg, &est_sample_s, model, sh.outstanding + 1) {
                    // open-loop sources never retry: the shed is terminal
                    shed += 1;
                } else {
                    sh.outstanding += 1;
                    sh.requests += 1;
                    sh.pending[model].push_back(Req { arrival: now, client: None });
                    d.try_dispatch(s, sh, now);
                }
            }
            EventKind::ClientNext { client } => {
                if client_remaining[client] == 0 {
                    continue;
                }
                let model = mix.sample_index(&mut client_rngs[client]);
                // keep the per-client stream aligned with the threaded
                // generator (which also draws a request seed here)
                let _ = client_rngs[client].next_u64();
                submit_closed(
                    &mut d, cfg, &names, &est_sample_s, &mut shards, &mut rr, &mut offered,
                    &mut rejected, &mut shed, &mut client_remaining, client, model, now,
                );
            }
            EventKind::ClientRetry { client, model } => {
                submit_closed(
                    &mut d, cfg, &names, &est_sample_s, &mut shards, &mut rr, &mut offered,
                    &mut rejected, &mut shed, &mut client_remaining, client, model, now,
                );
            }
            EventKind::WorkerFree { shard, release } => {
                let sh = &mut shards[shard];
                sh.outstanding -= release;
                d.try_dispatch(shard, sh, now);
            }
            EventKind::Deadline { shard } => {
                let sh = &mut shards[shard];
                d.try_dispatch(shard, sh, now);
            }
            EventKind::CalibrationStart { shard } => {
                if let Some(cal) = cfg.calibration {
                    // the calibration cycle re-arms itself only while
                    // traffic is still live (requests in flight, or any
                    // non-calibration event still queued) — otherwise
                    // the cycle would keep the event loop alive forever
                    let live = shards.iter().any(|sh| sh.outstanding > 0)
                        || d.heap.iter().any(|e| {
                            !matches!(
                                e.kind,
                                EventKind::CalibrationStart { .. }
                                    | EventKind::CalibrationEnd { .. }
                            )
                        });
                    if live {
                        let end = now + cal.outage_s;
                        shards[shard].down_until = end;
                        outage_windows[shard].push((now, end));
                        d.push(end, EventKind::CalibrationEnd { shard });
                    }
                }
            }
            EventKind::CalibrationEnd { shard } => {
                if let Some(cal) = cfg.calibration {
                    let sh = &mut shards[shard];
                    d.try_dispatch(shard, sh, now);
                    d.push(now + cal.interval_s, EventKind::CalibrationStart { shard });
                }
            }
        }
        // wake closed-loop clients whose requests just completed
        let wakeups = std::mem::take(&mut d.completions);
        for (client, done) in wakeups {
            if client_remaining[client] > 0 {
                d.push(done, EventKind::ClientNext { client });
            }
        }
    }

    let mut latencies_ms = d.latencies_ms;
    latencies_ms.sort_by(f64::total_cmp);
    let admitted = latencies_ms.len();
    debug_assert_eq!(offered, admitted + rejected + shed, "request conservation");
    let makespan_s = d.makespan;
    let mut outages = 0u64;
    let mut downtime_s = 0.0;
    let per_shard: Vec<VirtualShardLoad> = shards
        .iter()
        .enumerate()
        .map(|(i, sh)| {
            // count only the downtime the workload actually saw: windows
            // clipped to the makespan (post-traffic calibration noise is
            // not an availability cost)
            let mut shard_outages = 0u64;
            let mut shard_down = 0.0;
            for &(start, end) in &outage_windows[i] {
                if start >= makespan_s {
                    continue;
                }
                shard_outages += 1;
                shard_down += end.min(makespan_s) - start;
            }
            outages += shard_outages;
            downtime_s += shard_down;
            VirtualShardLoad {
                shard: i,
                requests: sh.requests,
                busy_s: sh.busy_s,
                utilization: if makespan_s > 0.0 {
                    sh.busy_s / (cfg.workers as f64 * makespan_s)
                } else {
                    0.0
                },
                outages: shard_outages,
                downtime_s: shard_down,
            }
        })
        .collect();
    let availability = if makespan_s > 0.0 {
        1.0 - downtime_s / (cfg.shards as f64 * makespan_s)
    } else {
        1.0
    };
    let mean_batch = if d.batches > 0 {
        d.batch_samples as f64 / d.batches as f64
    } else {
        0.0
    };
    VirtualOutcome {
        offered,
        admitted,
        rejected,
        shed,
        makespan_s,
        latencies_ms,
        batches: d.batches,
        mean_batch,
        // cloned, not moved: the dispatcher still borrows `names`
        per_model: names.iter().cloned().zip(d.per_model.clone()).collect(),
        per_shard,
        outages,
        downtime_s,
        availability,
    }
}

/// Deadline-SLO admission check: would a request that brings `model`'s
/// shard to `queued` outstanding samples (itself included) be predicted
/// past the deadline? Mirrors the async core's check with the cost
/// model's upfront estimate in place of the learned EWMA.
fn sheds_at(
    cfg: &VirtualServeConfig,
    est_sample_s: &[f64],
    model: usize,
    queued: usize,
) -> bool {
    match cfg.deadline_s {
        Some(deadline) => queued as f64 * est_sample_s[model] / cfg.workers as f64 > deadline,
        None => false,
    }
}

/// One closed-loop submission attempt: admit (consuming one of the
/// client's remaining requests), count a queue-full rejection and
/// schedule a deterministic retry with the *same* sampled model, or count
/// a shed and move the client straight to its next request (sheds are
/// server decisions and are never retried — retrying into the same
/// backlog would livelock).
#[allow(clippy::too_many_arguments)]
fn submit_closed<C: ServiceModel>(
    d: &mut Dispatcher<'_, C>,
    cfg: &VirtualServeConfig,
    names: &[String],
    est_sample_s: &[f64],
    shards: &mut [Shard],
    rr: &mut usize,
    offered: &mut usize,
    rejected: &mut usize,
    shed: &mut usize,
    client_remaining: &mut [usize],
    client: usize,
    model: usize,
    now: f64,
) {
    *offered += 1;
    d.makespan = d.makespan.max(now);
    let s = route(cfg.routing, rr, shards, &names[model]);
    let sh = &mut shards[s];
    if sh.outstanding + 1 > cfg.queue_depth {
        *rejected += 1;
        d.push(now + RETRY_BACKOFF_S, EventKind::ClientRetry { client, model });
        return;
    }
    if sheds_at(cfg, est_sample_s, model, sh.outstanding + 1) {
        *shed += 1;
        client_remaining[client] -= 1;
        if client_remaining[client] > 0 {
            d.push(now, EventKind::ClientNext { client });
        }
        return;
    }
    client_remaining[client] -= 1;
    sh.outstanding += 1;
    sh.requests += 1;
    sh.pending[model].push_back(Req { arrival: now, client: Some(client) });
    d.try_dispatch(s, sh, now);
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    /// Constant service time regardless of model/batch.
    struct FlatCost(f64);

    impl ServiceModel for FlatCost {
        fn batch_latency_s(&self, _model: &str, _batch: usize) -> f64 {
            self.0
        }
    }

    fn mix_ab() -> TrafficMix {
        TrafficMix::new(vec![("a".into(), 1.0), ("b".into(), 1.0)]).unwrap()
    }

    #[test]
    fn identical_inputs_yield_identical_outcomes() {
        let cfg = VirtualServeConfig { shards: 2, ..VirtualServeConfig::default() };
        let arrival = ArrivalProcess::Poisson { rate_hz: 5_000.0, duration_s: 0.1 };
        let run = || simulate_serve(&cfg, &mix_ab(), &arrival, &FlatCost(1e-4), 42);
        let (a, b) = (run(), run());
        assert_eq!(a, b, "virtual serving must be bit-deterministic");
        assert!(a.admitted > 0);
        assert_eq!(a.offered, a.admitted + a.rejected + a.shed);
    }

    #[test]
    fn closed_loop_conserves_requests() {
        let cfg = VirtualServeConfig::default();
        let arrival = ArrivalProcess::ClosedLoop { clients: 4, per_client: 25 };
        let out = simulate_serve(&cfg, &mix_ab(), &arrival, &FlatCost(1e-4), 7);
        assert_eq!(out.admitted, 100, "{out:?}");
        assert_eq!(out.rejected, 0);
        assert_eq!(out.per_model.iter().map(|(_, n)| n).sum::<u64>(), 100);
        assert!(out.makespan_s > 0.0);
        assert!(out.throughput_rps() > 0.0);
    }

    #[test]
    fn closed_loop_retries_through_a_tiny_queue() {
        let cfg = VirtualServeConfig {
            queue_depth: 1,
            workers: 1,
            max_batch: 1,
            max_wait_s: 0.0,
            ..VirtualServeConfig::default()
        };
        let arrival = ArrivalProcess::ClosedLoop { clients: 4, per_client: 10 };
        let out = simulate_serve(&cfg, &mix_ab(), &arrival, &FlatCost(1e-3), 11);
        // every request eventually lands despite the 1-deep queue
        assert_eq!(out.admitted, 40);
        assert!(out.rejected > 0, "contended clients must see rejections");
    }

    #[test]
    fn open_loop_overload_rejects_deterministically() {
        let cfg = VirtualServeConfig {
            shards: 1,
            workers: 1,
            max_batch: 1,
            max_wait_s: 0.0,
            queue_depth: 2,
            routing: RoutingPolicy::RoundRobin,
            calibration: None,
            deadline_s: None,
        };
        // service is 10x slower than the arrival gap: the queue must shed
        let arrival = ArrivalProcess::Poisson { rate_hz: 1_000.0, duration_s: 0.1 };
        let out = simulate_serve(&cfg, &TrafficMix::single("a"), &arrival, &FlatCost(1e-2), 3);
        assert!(out.rejected > 0);
        assert_eq!(out.offered, out.admitted + out.rejected + out.shed);
        let again = simulate_serve(&cfg, &TrafficMix::single("a"), &arrival, &FlatCost(1e-2), 3);
        assert_eq!(out, again);
    }

    #[test]
    fn simultaneous_burst_batches_under_max_wait() {
        let cfg = VirtualServeConfig {
            shards: 1,
            workers: 1,
            max_batch: 4,
            max_wait_s: 1e-3,
            queue_depth: 64,
            routing: RoutingPolicy::RoundRobin,
            calibration: None,
            deadline_s: None,
        };
        let arrival = ArrivalProcess::Trace { arrivals_s: vec![0.0; 8] };
        let out = simulate_serve(&cfg, &TrafficMix::single("a"), &arrival, &FlatCost(1e-4), 1);
        assert_eq!(out.admitted, 8);
        assert_eq!(out.batches, 2, "8 simultaneous arrivals → two max_batch batches");
        assert_eq!(out.mean_batch, 4.0);
    }

    #[test]
    fn zero_wait_dispatches_immediately() {
        let cfg = VirtualServeConfig {
            workers: 4,
            max_batch: 8,
            max_wait_s: 0.0,
            ..VirtualServeConfig::default()
        };
        let arrival = ArrivalProcess::Trace { arrivals_s: vec![0.0, 1e-5, 2e-5] };
        let out = simulate_serve(&cfg, &TrafficMix::single("a"), &arrival, &FlatCost(1e-6), 1);
        // each arrival found an idle worker and zero wait → singleton batches
        assert_eq!(out.batches, 3);
        assert_eq!(out.mean_batch, 1.0);
    }

    #[test]
    fn model_affinity_pins_each_model_to_one_shard() {
        let cfg = VirtualServeConfig {
            shards: 4,
            routing: RoutingPolicy::ModelAffinity,
            ..VirtualServeConfig::default()
        };
        let arrival = ArrivalProcess::Poisson { rate_hz: 2_000.0, duration_s: 0.05 };
        let out = simulate_serve(&cfg, &TrafficMix::single("a"), &arrival, &FlatCost(1e-5), 9);
        let loaded: Vec<_> = out.per_shard.iter().filter(|s| s.requests > 0).collect();
        assert_eq!(loaded.len(), 1, "one model must land on exactly one shard: {out:?}");
        assert_eq!(loaded[0].requests as usize, out.admitted);
    }

    #[test]
    fn least_outstanding_spreads_load() {
        let cfg = VirtualServeConfig {
            shards: 2,
            workers: 1,
            max_batch: 1,
            max_wait_s: 0.0,
            queue_depth: 1024,
            routing: RoutingPolicy::LeastOutstanding,
            calibration: None,
            deadline_s: None,
        };
        let arrival = ArrivalProcess::Poisson { rate_hz: 5_000.0, duration_s: 0.05 };
        let out = simulate_serve(&cfg, &mix_ab(), &arrival, &FlatCost(1e-3), 5);
        assert!(out.per_shard.iter().all(|s| s.requests > 0), "{:?}", out.per_shard);
    }

    #[test]
    fn a_full_batch_is_not_blocked_by_a_colder_queue() {
        // one stale "cold" request (not yet at max_wait) must not block a
        // full "hot" batch — the coordinator drains every ready batcher
        let cfg = VirtualServeConfig {
            shards: 1,
            workers: 1,
            max_batch: 4,
            max_wait_s: 1e-3,
            queue_depth: 64,
            routing: RoutingPolicy::RoundRobin,
            calibration: None,
            deadline_s: None,
        };
        let names = vec!["cold".to_string(), "hot".to_string()];
        let cost = FlatCost(1e-3);
        let mut d = Dispatcher {
            cfg: &cfg,
            names: &names,
            cost: &cost,
            heap: BinaryHeap::new(),
            seq: 0,
            latencies_ms: Vec::new(),
            per_model: vec![0; 2],
            batches: 0,
            batch_samples: 0,
            makespan: 0.0,
            completions: Vec::new(),
        };
        let mut sh = Shard {
            worker_free: vec![0.0],
            pending: vec![VecDeque::new(), VecDeque::new()],
            outstanding: 5,
            requests: 5,
            busy_s: 0.0,
            down_until: 0.0,
        };
        sh.pending[0].push_back(Req { arrival: 0.0, client: None });
        for _ in 0..4 {
            sh.pending[1].push_back(Req { arrival: 1e-4, client: None });
        }
        d.try_dispatch(0, &mut sh, 2e-4);
        assert_eq!(d.batches, 1, "the full hot batch must dispatch immediately");
        assert_eq!(d.per_model[1], 4, "hot requests served");
        assert_eq!(d.per_model[0], 0, "cold head still pending");
        assert_eq!(sh.pending[0].len(), 1);
        // the cold head got a progress deadline after the worker freed up?
        // (the worker is busy until 1.2e-4 + service; a WorkerFree event is
        // queued, which re-runs dispatch — here we just check one was pushed)
        assert!(!d.heap.is_empty(), "a follow-up event must exist for the cold head");
    }

    #[test]
    fn stale_deadlines_do_not_inflate_the_makespan() {
        // burst of 8 at t=0 fills two batches fast; the deadlines pushed by
        // the early not-ready passes must not stretch the makespan
        let cfg = VirtualServeConfig {
            shards: 1,
            workers: 2,
            max_batch: 4,
            max_wait_s: 1e-2,
            queue_depth: 64,
            routing: RoutingPolicy::RoundRobin,
            calibration: None,
            deadline_s: None,
        };
        let arrival = ArrivalProcess::Trace { arrivals_s: vec![0.0; 8] };
        let out = simulate_serve(&cfg, &TrafficMix::single("a"), &arrival, &FlatCost(1e-4), 2);
        assert_eq!(out.admitted, 8);
        assert!(
            (out.makespan_s - 1e-4).abs() < 1e-12,
            "makespan must be the last completion (1e-4), got {}",
            out.makespan_s
        );
    }

    #[test]
    fn utilization_and_percentiles_are_sane() {
        let cfg = VirtualServeConfig::default();
        let arrival = ArrivalProcess::Poisson { rate_hz: 1_000.0, duration_s: 0.1 };
        let out = simulate_serve(&cfg, &mix_ab(), &arrival, &FlatCost(2e-4), 13);
        assert!(out.latency_percentile_ms(50.0) <= out.latency_percentile_ms(99.0));
        assert!(out.mean_latency_ms() > 0.0);
        for s in &out.per_shard {
            assert!((0.0..=1.0 + 1e-9).contains(&s.utilization), "{s:?}");
        }
        assert!(out.reject_fraction() >= 0.0);
    }

    #[test]
    fn no_calibration_reports_full_availability() {
        let cfg = VirtualServeConfig::default();
        let arrival = ArrivalProcess::Poisson { rate_hz: 2_000.0, duration_s: 0.05 };
        let out = simulate_serve(&cfg, &mix_ab(), &arrival, &FlatCost(1e-4), 17);
        assert_eq!(out.outages, 0);
        assert_eq!(out.downtime_s, 0.0);
        assert_eq!(out.availability, 1.0);
        assert!(out.per_shard.iter().all(|s| s.outages == 0 && s.downtime_s == 0.0));
    }

    #[test]
    fn calibration_outages_cost_availability_and_tail_latency() {
        let base = VirtualServeConfig {
            shards: 2,
            workers: 1,
            max_batch: 4,
            max_wait_s: 1e-4,
            queue_depth: 256,
            routing: RoutingPolicy::LeastOutstanding,
            calibration: None,
            deadline_s: None,
        };
        let with_cal = VirtualServeConfig {
            calibration: Some(CalibrationConfig { interval_s: 2e-2, outage_s: 1e-2 }),
            deadline_s: None,
            ..base.clone()
        };
        let arrival = ArrivalProcess::Poisson { rate_hz: 3_000.0, duration_s: 0.2 };
        let quiet = simulate_serve(&base, &mix_ab(), &arrival, &FlatCost(2e-4), 23);
        let noisy = simulate_serve(&with_cal, &mix_ab(), &arrival, &FlatCost(2e-4), 23);
        // run twice: the calibration cycle must stay bit-deterministic
        assert_eq!(noisy, simulate_serve(&with_cal, &mix_ab(), &arrival, &FlatCost(2e-4), 23));
        assert!(noisy.outages > 0, "{noisy:?}");
        assert!(noisy.downtime_s > 0.0);
        assert!(noisy.availability < 1.0, "availability {}", noisy.availability);
        assert!(noisy.availability > 0.0);
        assert_eq!(
            noisy.per_shard.iter().map(|s| s.outages).sum::<u64>(),
            noisy.outages
        );
        // every admitted request still completes (conservation holds)
        assert_eq!(noisy.offered, noisy.admitted + noisy.rejected + noisy.shed);
        // the outages must be visible in the tail, not hidden
        assert!(
            noisy.latency_percentile_ms(99.0) > quiet.latency_percentile_ms(99.0),
            "p99 with outages {} must exceed p99 without {}",
            noisy.latency_percentile_ms(99.0),
            quiet.latency_percentile_ms(99.0)
        );
    }

    #[test]
    fn in_flight_batches_finish_through_an_outage() {
        // one shard, one worker: a long batch is in flight when the
        // outage starts; it must complete, and the queued head must
        // dispatch at calibration end rather than starve
        let cfg = VirtualServeConfig {
            shards: 1,
            workers: 1,
            max_batch: 1,
            max_wait_s: 0.0,
            queue_depth: 64,
            routing: RoutingPolicy::RoundRobin,
            calibration: Some(CalibrationConfig { interval_s: 5e-3, outage_s: 2e-3 }),
            deadline_s: None,
        };
        let arrival = ArrivalProcess::Trace { arrivals_s: vec![0.0, 4.9e-3, 5.5e-3] };
        let out = simulate_serve(&cfg, &TrafficMix::single("a"), &arrival, &FlatCost(1e-3), 1);
        assert_eq!(out.admitted, 3, "{out:?}");
        assert_eq!(out.rejected, 0);
        assert_eq!(out.outages, 1);
        // the request that arrived mid-outage waited for calibration end
        let worst = out.latencies_ms.last().copied().unwrap_or(0.0);
        assert!(worst >= 1.0, "a mid-outage arrival must absorb the outage: {out:?}");
    }

    #[test]
    fn calibration_config_derives_from_the_fidelity_model() {
        use crate::fidelity::{CalibrationModel, NoiseModel};
        let model = CalibrationModel::from_noise(&NoiseModel::paper());
        let cfg = CalibrationConfig::from_model(&model, 16);
        assert_eq!(cfg.interval_s, model.interval_s());
        assert_eq!(cfg.outage_s, model.outage_s(16));
        assert!(cfg.interval_s > 0.0 && cfg.outage_s > 0.0);
    }

    #[test]
    fn deadline_sheds_deterministically_under_open_loop_overload() {
        // per-sample estimate is 1e-2/1 = 10ms ≫ the 1ms deadline once a
        // couple of requests queue — a saturating Poisson stream must shed
        let cfg = VirtualServeConfig {
            shards: 1,
            workers: 1,
            max_batch: 1,
            max_wait_s: 0.0,
            queue_depth: 1024,
            routing: RoutingPolicy::RoundRobin,
            calibration: None,
            deadline_s: Some(1e-3),
        };
        let arrival = ArrivalProcess::Poisson { rate_hz: 1_000.0, duration_s: 0.1 };
        let out = simulate_serve(&cfg, &TrafficMix::single("a"), &arrival, &FlatCost(1e-2), 3);
        assert!(out.shed > 0, "{out:?}");
        assert_eq!(out.offered, out.admitted + out.rejected + out.shed);
        // deep queue: overload shows up as sheds, not queue-full rejects
        assert_eq!(out.rejected, 0);
        let again = simulate_serve(&cfg, &TrafficMix::single("a"), &arrival, &FlatCost(1e-2), 3);
        assert_eq!(out, again, "shedding must stay bit-deterministic");
    }

    #[test]
    fn closed_loop_sheds_consume_requests_instead_of_livelocking() {
        // the deadline is below even a single request's predicted service:
        // every submission sheds, and the run must still terminate with
        // each client's budget fully consumed
        let cfg = VirtualServeConfig {
            shards: 1,
            workers: 1,
            max_batch: 1,
            max_wait_s: 0.0,
            queue_depth: 64,
            routing: RoutingPolicy::RoundRobin,
            calibration: None,
            deadline_s: Some(1e-6),
        };
        let arrival = ArrivalProcess::ClosedLoop { clients: 3, per_client: 10 };
        let out = simulate_serve(&cfg, &mix_ab(), &arrival, &FlatCost(1e-3), 19);
        assert_eq!(out.shed, 30, "all 30 requests shed exactly once: {out:?}");
        assert_eq!(out.admitted, 0);
        assert_eq!(out.offered, 30);
    }

    #[test]
    fn no_deadline_matches_pre_slo_behavior_exactly() {
        // deadline_s: None must leave outcomes byte-identical to the
        // config that predates the field
        let base = VirtualServeConfig { shards: 2, ..VirtualServeConfig::default() };
        let arrival = ArrivalProcess::Poisson { rate_hz: 5_000.0, duration_s: 0.1 };
        let out = simulate_serve(&base, &mix_ab(), &arrival, &FlatCost(1e-4), 42);
        assert_eq!(out.shed, 0);
        // and a generous deadline that never binds changes nothing either
        let roomy = VirtualServeConfig { deadline_s: Some(1e9), ..base };
        let same = simulate_serve(&roomy, &mix_ab(), &arrival, &FlatCost(1e-4), 42);
        assert_eq!(out, same);
    }
}
