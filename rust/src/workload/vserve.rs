//! Virtual-time multi-shard serving simulation.
//!
//! The threaded coordinator ([`crate::coordinator::Server`]) measures real
//! wall-clock latencies, which makes its outputs irreproducible by
//! construction. Scenario runs need the opposite: **bit-identical results
//! for a fixed seed**, so SLO verdicts and regression diffs are stable
//! across hosts and runs. This module re-implements the coordinator's
//! serving semantics — shard routing, bounded per-shard queues with
//! rejection, per-model dynamic batching under `(max_batch, max_wait)`,
//! and a fixed worker pool per shard — as a deterministic discrete-event
//! simulation in *virtual seconds*, with batch service times supplied by a
//! pluggable [`ServiceModel`] (the API layer plugs in the photonic
//! simulator through the session mapping cache).
//!
//! Every source of nondeterminism is removed: arrivals are materialized
//! from seeded [`Pcg32`] streams ([`crate::workload::ArrivalProcess`]),
//! event ties break on insertion order, routing ties break on the lowest
//! shard index, and all accounting is plain `f64` arithmetic. Running the
//! same `(config, mix, arrival, seed)` twice yields byte-identical
//! outcomes.
//!
//! ## Fleet scale
//!
//! [`simulate_fleet`] generalizes the engine to datacenter-fleet studies
//! (GANAX-style cross-platform accounting, arxiv 1806.01107):
//!
//! - the event loop runs on an indexed **event wheel**
//!   ([`crate::workload::wheel::EventWheel`]) instead of a `BinaryHeap` —
//!   O(1) amortized insert/pop at fleet event rates, with pop order
//!   provably identical to the heap's `(time, seq)` total order
//!   ([`QueueKind`] keeps the heap available as an ablation baseline);
//! - requests live in a central **arena**; per-shard pending queues hold
//!   4-byte handles, so queue memory stays flat as shards multiply;
//! - shards are grouped into **heterogeneous classes**
//!   ([`ShardClass`] + [`FleetCost`]): photonic configs can be mixed with
//!   GPU/TPU baseline platforms, each with its own worker count, service
//!   times, batch energy, idle power, and $ cost rate, all accounted into
//!   [`VirtualOutcome`];
//! - [`FailureConfig`] injects shard failure/recovery (exponential
//!   MTBF/MTTR draws from dedicated seeded streams) alongside the
//!   calibration outages of [`CalibrationConfig`]; downtime merges the
//!   two window sets per shard so availability never double-counts;
//! - [`AutoscaleConfig`] grows/shrinks the *active* routing set one shard
//!   per decision interval (target-utilization or queue-depth policy);
//!   deactivated shards drain their queues but receive no new work.
//!
//! An optional [`CalibrationConfig`] injects the fidelity layer's drift
//! dynamics ([`crate::fidelity::calibration`]): each shard periodically
//! goes down for a re-calibration outage, during which its in-flight
//! batches finish but nothing new dispatches. Arrivals still enqueue (and
//! the bounded queue still rejects), so the run surfaces the
//! tail-latency/availability cost of drift and how routing/admission
//! absorb shards going offline.

use super::arrival::ArrivalProcess;
use super::mix::TrafficMix;
use super::wheel::{EventWheel, WheelItem};
use crate::coordinator::routing::{affinity_hash, RoutingPolicy};
use crate::util::json::{num_arr, obj, JsonValue};
use crate::util::rng::Pcg32;
use crate::util::stats::percentile_sorted;
use std::collections::{BinaryHeap, VecDeque};

/// Supplies the virtual service time of one dispatched batch.
///
/// (Deliberately not blanket-implemented for closures: downstream code
/// implements it for named types — e.g. the API layer's session-backed
/// cost model — which a `Fn` blanket impl would conflict with under
/// coherence.)
pub trait ServiceModel {
    /// End-to-end latency (seconds) of serving `batch` samples of `model`
    /// on one chip. Must be deterministic for determinism of the DES.
    fn batch_latency_s(&self, model: &str, batch: usize) -> f64;
}

/// Class-aware cost model for heterogeneous fleets: service time and
/// energy may depend on which [`ShardClass`] serves the batch (photonic
/// vs GPU/TPU baseline platforms). `class` is an index into
/// [`FleetConfig::classes`]. Must be deterministic.
pub trait FleetCost {
    /// End-to-end latency (seconds) of serving `batch` samples of `model`
    /// on one shard of `class`.
    fn batch_latency_s(&self, class: usize, model: &str, batch: usize) -> f64;

    /// Energy (joules) consumed serving that batch. Defaults to zero —
    /// uniform photonic fleets without an energy model stay byte-identical
    /// to the pre-fleet engine.
    fn batch_energy_j(&self, _class: usize, _model: &str, _batch: usize) -> f64 {
        0.0
    }
}

/// Adapts a class-blind [`ServiceModel`] to [`FleetCost`] for the
/// homogeneous [`simulate_serve`] path.
struct UniformCost<'a, C: ServiceModel>(&'a C);

impl<C: ServiceModel> FleetCost for UniformCost<'_, C> {
    fn batch_latency_s(&self, _class: usize, model: &str, batch: usize) -> f64 {
        self.0.batch_latency_s(model, batch)
    }
}

/// Periodic per-shard re-calibration outages (virtual seconds).
///
/// Models the fidelity layer's drift budget: a shard serves for
/// `interval_s`, then goes offline for `outage_s` to re-lock its MR
/// banks and re-program PCM weights. Shard start times are staggered
/// across the interval so the fleet never calibrates all at once.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CalibrationConfig {
    /// Virtual seconds of serving between outages (must be positive).
    pub interval_s: f64,
    /// Virtual seconds a shard is down per outage (must be `>= 0`).
    pub outage_s: f64,
}

impl CalibrationConfig {
    /// Derive the schedule from a physics-grounded
    /// [`CalibrationModel`][crate::fidelity::CalibrationModel] for a
    /// shard that re-calibrates `banks` MR banks per outage.
    pub fn from_model(model: &crate::fidelity::CalibrationModel, banks: usize) -> Self {
        CalibrationConfig { interval_s: model.interval_s(), outage_s: model.outage_s(banks) }
    }
}

/// Virtual serving fleet shape — the deterministic mirror of
/// [`crate::coordinator::ServerConfig`].
#[derive(Debug, Clone, PartialEq)]
pub struct VirtualServeConfig {
    /// Independent serving shards (chips).
    pub shards: usize,
    /// Virtual workers per shard (concurrent batches in flight per chip).
    pub workers: usize,
    /// Maximum samples per dispatched batch.
    pub max_batch: usize,
    /// Maximum virtual seconds the oldest pending request waits before its
    /// batch is dispatched anyway.
    pub max_wait_s: f64,
    /// Bounded in-flight samples per shard; arrivals beyond are rejected.
    pub queue_depth: usize,
    /// How arrivals pick a shard.
    pub routing: RoutingPolicy,
    /// Periodic re-calibration outages; `None` (the default) keeps the
    /// pre-fidelity behavior byte-identical.
    pub calibration: Option<CalibrationConfig>,
    /// Completion-deadline SLO (virtual seconds) — the deterministic
    /// mirror of [`crate::coordinator::AsyncServerConfig::deadline`]. A
    /// submission whose predicted completion (post-admission backlog ×
    /// per-sample service estimate ÷ workers) exceeds the deadline is
    /// shed instead of queued. The estimate here is
    /// `batch_latency_s(model, max_batch) / max_batch` from the cost
    /// model — known upfront, where the async core learns it by EWMA, so
    /// the virtual engine sheds from the first arrival while the real
    /// core's first request always passes. `None` disables shedding.
    pub deadline_s: Option<f64>,
}

impl Default for VirtualServeConfig {
    fn default() -> Self {
        VirtualServeConfig {
            shards: 1,
            workers: 2,
            max_batch: 8,
            max_wait_s: 5e-4,
            queue_depth: 1024,
            routing: RoutingPolicy::RoundRobin,
            calibration: None,
            deadline_s: None,
        }
    }
}

/// Which event-queue implementation drives the DES. Both produce
/// byte-identical outcomes ([`EventWheel`]'s determinism contract); the
/// heap exists as the perf-ablation baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueKind {
    /// Indexed calendar queue — O(1) amortized, the default.
    Wheel,
    /// `BinaryHeap` — O(log n), kept for ablation.
    Heap,
}

/// One hardware class of a heterogeneous fleet (a photonic config, a GPU
/// platform, ...). Service time/energy per class come from [`FleetCost`].
#[derive(Debug, Clone, PartialEq)]
pub struct ShardClass {
    /// Display name ("photonic", "GPU (A100)", ...).
    pub name: String,
    /// Virtual workers per shard of this class.
    pub workers: usize,
    /// Idle power draw (watts) while a shard is active but not serving —
    /// charged on `active_s − busy_s`.
    pub idle_w: f64,
    /// Billing rate ($/hour of active shard time).
    pub cost_per_hour: f64,
}

/// Random shard failure/recovery injection: time-to-failure and repair
/// times are exponential draws with these means, from per-shard seeded
/// streams (deterministic per seed).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureConfig {
    /// Mean virtual seconds between failures (must be positive).
    pub mtbf_s: f64,
    /// Mean virtual seconds to repair (must be `>= 0`).
    pub mttr_s: f64,
}

/// How the autoscaler decides to grow or shrink the active set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AutoscalePolicy {
    /// Scale up when mean worker occupancy over the last interval exceeds
    /// `target`; scale down below `target / 2`.
    TargetUtilization { target: f64 },
    /// Scale up when mean outstanding samples per active shard exceed
    /// `high`; scale down below `low`.
    QueueDepth { high: usize, low: usize },
}

/// Autoscaling of the active routing set: every `interval_s` the policy
/// is evaluated and the active set grows or shrinks by one shard within
/// `[min_shards, max_shards]`. Shards activate in index order; a
/// deactivated shard drains its queue but receives no new requests.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscaleConfig {
    pub policy: AutoscalePolicy,
    /// Smallest active set (must be `>= 1`).
    pub min_shards: usize,
    /// Largest active set (must not exceed the fleet).
    pub max_shards: usize,
    /// Active set at time zero (must lie in `[min_shards, max_shards]`).
    pub initial: usize,
    /// Virtual seconds between decisions (must be positive).
    pub interval_s: f64,
}

/// Fleet-level configuration wrapping the per-shard serving shape of
/// [`VirtualServeConfig`] with heterogeneity, failures, and autoscaling.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetConfig {
    /// Batching/queueing/routing shape shared by every shard.
    /// `base.shards` must equal `shard_class.len()`; `base.workers` is
    /// superseded by the per-class worker counts.
    pub base: VirtualServeConfig,
    /// The hardware classes present in the fleet.
    pub classes: Vec<ShardClass>,
    /// Class index of each shard (`shard_class[i]` indexes `classes`).
    pub shard_class: Vec<usize>,
    /// Shard failure/recovery injection; `None` disables it.
    pub failures: Option<FailureConfig>,
    /// Autoscaling of the active set; `None` keeps every shard active.
    pub autoscale: Option<AutoscaleConfig>,
    /// Event-queue implementation (ablation knob; default wheel).
    pub queue: QueueKind,
}

impl FleetConfig {
    /// A uniform single-class fleet equivalent to the plain
    /// [`simulate_serve`] semantics: no energy/cost rates, no failures,
    /// no autoscaling, wheel-backed.
    pub fn homogeneous(base: VirtualServeConfig) -> Self {
        let class = ShardClass {
            name: "uniform".to_string(),
            workers: base.workers,
            idle_w: 0.0,
            cost_per_hour: 0.0,
        };
        let shard_class = vec![0; base.shards];
        FleetConfig {
            base,
            classes: vec![class],
            shard_class,
            failures: None,
            autoscale: None,
            queue: QueueKind::Wheel,
        }
    }

    /// Number of shards in the fleet.
    pub fn shards(&self) -> usize {
        self.shard_class.len()
    }
}

/// Per-shard load accounting of a virtual run.
#[derive(Debug, Clone, PartialEq)]
pub struct VirtualShardLoad {
    pub shard: usize,
    /// Index into [`FleetConfig::classes`] (0 for homogeneous runs).
    pub class: usize,
    /// Requests admitted onto this shard.
    pub requests: u64,
    /// Worker-seconds spent serving batches.
    pub busy_s: f64,
    /// `busy_s / (workers × makespan)` — mean worker occupancy.
    pub utilization: f64,
    /// Re-calibration outages this shard took within the makespan.
    pub outages: u64,
    /// Injected failures this shard took within the makespan.
    pub failures: u64,
    /// Virtual seconds this shard was down (calibration and failure
    /// windows merged, overlaps counted once, clipped to the makespan).
    pub downtime_s: f64,
    /// Virtual seconds this shard was in the active routing set (equals
    /// the makespan without autoscaling).
    pub active_s: f64,
    /// Batch energy plus idle draw (joules).
    pub energy_j: f64,
    /// `cost_per_hour × active_s` ($).
    pub cost: f64,
}

/// Deterministic outcome of a virtual serving run.
#[derive(Debug, Clone, PartialEq)]
pub struct VirtualOutcome {
    /// Submission attempts (closed-loop retries count again).
    pub offered: usize,
    /// Requests admitted past the bounded queues (all complete by end).
    pub admitted: usize,
    /// Typed queue-full rejections.
    pub rejected: usize,
    /// Requests refused at admission by the deadline SLO (never retried —
    /// a shed is a server decision, not transient backpressure).
    pub shed: usize,
    /// Virtual time from stream start to the last completion/arrival.
    pub makespan_s: f64,
    /// Per-request virtual latencies in milliseconds, sorted ascending.
    pub latencies_ms: Vec<f64>,
    /// Dispatched batches and their mean size.
    pub batches: u64,
    pub mean_batch: f64,
    /// Admitted requests per mix model, in mix declaration order.
    pub per_model: Vec<(String, u64)>,
    pub per_shard: Vec<VirtualShardLoad>,
    /// Re-calibration outages across all shards (within the makespan).
    pub outages: u64,
    /// Injected shard failures across the fleet (within the makespan).
    pub failures: u64,
    /// Total shard-seconds of downtime (calibration ∪ failure windows).
    pub downtime_s: f64,
    /// `1 − downtime / (shards × makespan)`, clamped to `[0, 1]` —
    /// fraction of fleet capacity that was up (1.0 without outages, and
    /// 1.0 by definition when the makespan is zero).
    pub availability: f64,
    /// Total fleet energy (batch energy + idle draw), joules.
    pub energy_j: f64,
    /// Total fleet cost ($) from per-class billing rates.
    pub cost: f64,
    /// Autoscaler scale-up / scale-down decisions taken.
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// Time-weighted mean size of the active routing set (equals the
    /// shard count without autoscaling).
    pub avg_active_shards: f64,
}

impl VirtualOutcome {
    /// Admitted requests per virtual second.
    pub fn throughput_rps(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.admitted as f64 / self.makespan_s
        } else {
            0.0
        }
    }

    /// Latency percentile (`q` in `[0, 100]`), in milliseconds.
    pub fn latency_percentile_ms(&self, q: f64) -> f64 {
        percentile_sorted(&self.latencies_ms, q)
    }

    /// Mean latency in milliseconds.
    pub fn mean_latency_ms(&self) -> f64 {
        if self.latencies_ms.is_empty() {
            0.0
        } else {
            self.latencies_ms.iter().sum::<f64>() / self.latencies_ms.len() as f64
        }
    }

    /// Fraction of offered requests refused — queue-full rejections and
    /// SLO sheds both count (the `max_reject_frac` SLO bounds refusals of
    /// any kind).
    pub fn reject_fraction(&self) -> f64 {
        if self.offered > 0 {
            (self.rejected + self.shed) as f64 / self.offered as f64
        } else {
            0.0
        }
    }

    /// Full outcome as a deterministic JSON document (fixed member order,
    /// shortest-round-trip floats) — the byte-comparison surface for the
    /// wheel-vs-heap equivalence tests and CI same-seed `cmp`s.
    pub fn json(&self) -> JsonValue {
        let per_model = JsonValue::Obj(
            self.per_model
                .iter()
                .map(|(name, n)| (name.clone(), JsonValue::Num(*n as f64)))
                .collect(),
        );
        let per_shard = JsonValue::Arr(
            self.per_shard
                .iter()
                .map(|s| {
                    obj(vec![
                        ("shard", JsonValue::Num(s.shard as f64)),
                        ("class", JsonValue::Num(s.class as f64)),
                        ("requests", JsonValue::Num(s.requests as f64)),
                        ("busy_s", JsonValue::Num(s.busy_s)),
                        ("utilization", JsonValue::Num(s.utilization)),
                        ("outages", JsonValue::Num(s.outages as f64)),
                        ("failures", JsonValue::Num(s.failures as f64)),
                        ("downtime_s", JsonValue::Num(s.downtime_s)),
                        ("active_s", JsonValue::Num(s.active_s)),
                        ("energy_j", JsonValue::Num(s.energy_j)),
                        ("cost", JsonValue::Num(s.cost)),
                    ])
                })
                .collect(),
        );
        obj(vec![
            ("offered", JsonValue::Num(self.offered as f64)),
            ("admitted", JsonValue::Num(self.admitted as f64)),
            ("rejected", JsonValue::Num(self.rejected as f64)),
            ("shed", JsonValue::Num(self.shed as f64)),
            ("makespan_s", JsonValue::Num(self.makespan_s)),
            ("batches", JsonValue::Num(self.batches as f64)),
            ("mean_batch", JsonValue::Num(self.mean_batch)),
            ("outages", JsonValue::Num(self.outages as f64)),
            ("failures", JsonValue::Num(self.failures as f64)),
            ("downtime_s", JsonValue::Num(self.downtime_s)),
            ("availability", JsonValue::Num(self.availability)),
            ("energy_j", JsonValue::Num(self.energy_j)),
            ("cost", JsonValue::Num(self.cost)),
            ("scale_ups", JsonValue::Num(self.scale_ups as f64)),
            ("scale_downs", JsonValue::Num(self.scale_downs as f64)),
            ("avg_active_shards", JsonValue::Num(self.avg_active_shards)),
            ("latencies_ms", num_arr(&self.latencies_ms)),
            ("per_model", per_model),
            ("per_shard", per_shard),
        ])
    }
}

/// Base virtual backoff before a rejected closed-loop client's first
/// retry (the deterministic analogue of the threaded generator's
/// `yield_now`).
const RETRY_BASE_BACKOFF_S: f64 = 1e-5;
/// Ceiling of the exponential backoff schedule.
const RETRY_MAX_BACKOFF_S: f64 = 5e-3;
/// Shift cap: `base << RETRY_MAX_EXP` already clears the ceiling.
const RETRY_MAX_EXP: u32 = 16;

#[derive(Debug, Clone, Copy, PartialEq)]
enum EventKind {
    /// A scheduled open-loop arrival of one `mix` model.
    Arrival { model: usize },
    /// A closed-loop client is ready to issue its next request.
    ClientNext { client: usize },
    /// A rejected closed-loop submission retries (same sampled model).
    ClientRetry { client: usize, model: usize },
    /// A shard worker finished a batch, releasing `release` samples of
    /// the shard's bounded queue capacity (the coordinator holds capacity
    /// until the response is delivered, not until dispatch).
    WorkerFree { shard: usize, release: usize },
    /// A shard's oldest pending request reached `max_wait_s`.
    Deadline { shard: usize },
    /// A shard's drift budget is spent: it goes down for re-calibration.
    CalibrationStart { shard: usize },
    /// A shard finished re-calibrating and resumes dispatching.
    CalibrationEnd { shard: usize },
    /// A shard fails (MTBF draw elapsed); it goes down until repaired.
    FailureStart { shard: usize },
    /// A failed shard is repaired and resumes dispatching.
    FailureEnd { shard: usize },
    /// The autoscaler evaluates its policy.
    AutoscaleTick,
}

impl EventKind {
    /// Fleet-maintenance bookkeeping (calibration/failure/autoscale
    /// cycles). Maintenance events re-arm themselves only while real
    /// traffic exists, so they never count as liveness themselves.
    fn is_maintenance(self) -> bool {
        matches!(
            self,
            EventKind::CalibrationStart { .. }
                | EventKind::CalibrationEnd { .. }
                | EventKind::FailureStart { .. }
                | EventKind::FailureEnd { .. }
                | EventKind::AutoscaleTick
        )
    }
}

#[derive(Debug, Clone, Copy)]
struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
}

// BinaryHeap is a max-heap: invert the ordering so the earliest (time,
// seq) pops first. seq is unique, so the order is total and deterministic.
impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

// The wheel orders by the same (time, seq) key the heap's Ord encodes,
// which is what makes the two queues pop-for-pop interchangeable.
impl WheelItem for Event {
    fn time(&self) -> f64 {
        self.time
    }
    fn seq(&self) -> u64 {
        self.seq
    }
}

/// The pluggable DES priority queue (see [`QueueKind`]).
enum EventQueue {
    Wheel(EventWheel<Event>),
    Heap(BinaryHeap<Event>),
}

impl EventQueue {
    fn new(kind: QueueKind) -> Self {
        match kind {
            QueueKind::Wheel => EventQueue::Wheel(EventWheel::new()),
            QueueKind::Heap => EventQueue::Heap(BinaryHeap::new()),
        }
    }

    fn push(&mut self, ev: Event) {
        match self {
            EventQueue::Wheel(w) => w.push(ev),
            EventQueue::Heap(h) => h.push(ev),
        }
    }

    fn pop(&mut self) -> Option<Event> {
        match self {
            EventQueue::Wheel(w) => w.pop(),
            EventQueue::Heap(h) => h.pop(),
        }
    }

    /// Any queued non-maintenance event? Iteration order differs between
    /// the queues, but existence is order-free, so the liveness guard is
    /// representation-independent.
    fn any_live(&self) -> bool {
        match self {
            EventQueue::Wheel(w) => w.iter().any(|e| !e.kind.is_maintenance()),
            EventQueue::Heap(h) => h.iter().any(|e| !e.kind.is_maintenance()),
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct Req {
    arrival: f64,
    /// The closed-loop client to wake on completion, if any. (The model
    /// is identified by which per-model queue holds the request.)
    client: Option<usize>,
}

/// Central request arena: pending queues hold 4-byte handles into
/// `slots`, and freed slots recycle across shards — fleet-scale queue
/// memory stays proportional to peak in-flight requests, not to
/// (shards × models × depth).
#[derive(Default)]
struct ReqArena {
    slots: Vec<Req>,
    free: Vec<u32>,
}

impl ReqArena {
    fn alloc(&mut self, req: Req) -> u32 {
        match self.free.pop() {
            Some(h) => {
                self.slots[h as usize] = req;
                h
            }
            None => {
                let h = self.slots.len() as u32;
                self.slots.push(req);
                h
            }
        }
    }

    fn arrival(&self, h: u32) -> f64 {
        self.slots[h as usize].arrival
    }

    fn take(&mut self, h: u32) -> Req {
        self.free.push(h);
        self.slots[h as usize]
    }
}

struct Shard {
    /// Index into [`FleetConfig::classes`].
    class: usize,
    /// Free-at virtual time per worker.
    worker_free: Vec<f64>,
    /// Pending request handles per mix model (FIFO).
    pending: Vec<VecDeque<u32>>,
    outstanding: usize,
    requests: u64,
    busy_s: f64,
    /// Down (calibration or failure) until this virtual time (0.0 = up).
    down_until: f64,
    /// Batch energy accumulated so far (idle draw is added at the end).
    energy_j: f64,
    /// `busy_s` snapshot at the last autoscale tick.
    busy_at_tick: f64,
}

struct Dispatcher<'a, C: FleetCost> {
    base: &'a VirtualServeConfig,
    classes: &'a [ShardClass],
    names: &'a [String],
    cost: &'a C,
    /// Per-class per-model per-sample service estimate backing the
    /// deadline SLO (empty when no deadline is set).
    est_sample_s: &'a [Vec<f64>],
    queue: EventQueue,
    seq: u64,
    arena: ReqArena,
    latencies_ms: Vec<f64>,
    per_model: Vec<u64>,
    batches: u64,
    batch_samples: u64,
    makespan: f64,
    /// `(client, completion)` wakeups produced by the last dispatch pass.
    completions: Vec<(usize, f64)>,
}

impl<'a, C: FleetCost> Dispatcher<'a, C> {
    fn push(&mut self, time: f64, kind: EventKind) {
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(Event { time, seq, kind });
    }

    /// Deadline-SLO admission check: would a request that brings a
    /// `class` shard to `queued` outstanding samples (itself included) be
    /// predicted past the deadline? Mirrors the async core's check with
    /// the cost model's upfront estimate in place of the learned EWMA.
    fn sheds_at(&self, class: usize, model: usize, queued: usize) -> bool {
        match self.base.deadline_s {
            Some(deadline) => {
                queued as f64 * self.est_sample_s[class][model]
                    / self.classes[class].workers as f64
                    > deadline
            }
            None => false,
        }
    }

    /// Dispatch every batch that is ready on `shard` at virtual time
    /// `now`; schedules the deadline/worker-free events that guarantee
    /// progress for anything left pending.
    fn try_dispatch(&mut self, shard_idx: usize, sh: &mut Shard, now: f64) {
        // a shard that is down (re-calibration or failure) dispatches
        // nothing; the CalibrationEnd/FailureEnd event re-runs dispatch,
        // so pending heads cannot starve
        if now < sh.down_until {
            return;
        }
        loop {
            // idle worker with the earliest free-at (ties → lowest index)
            let mut worker: Option<(usize, f64)> = None;
            for (i, &free) in sh.worker_free.iter().enumerate() {
                if free <= now {
                    match worker {
                        Some((_, best)) if best <= free => {}
                        _ => worker = Some((i, free)),
                    }
                }
            }
            let Some((w, _)) = worker else { break };
            // A batch is ready when it is full or its head has waited
            // max_wait. The coordinator drains *every* ready batcher
            // (`Batcher::ready`), so an unready queue must never block a
            // ready one: serve the ready model with the oldest head, and
            // remember the oldest unready head for the progress deadline.
            let mut ready: Option<(usize, f64)> = None;
            let mut waiting: Option<f64> = None;
            for (m, q) in sh.pending.iter().enumerate() {
                if let Some(&h) = q.front() {
                    let head = self.arena.arrival(h);
                    if q.len() >= self.base.max_batch || now >= head + self.base.max_wait_s {
                        match ready {
                            Some((_, best)) if best <= head => {}
                            _ => ready = Some((m, head)),
                        }
                    } else {
                        match waiting {
                            Some(best) if best <= head => {}
                            _ => waiting = Some(head),
                        }
                    }
                }
            }
            let Some((m, _)) = ready else {
                if let Some(head) = waiting {
                    // progress guarantee: revisit when the oldest unready
                    // head times out
                    self.push(
                        head + self.base.max_wait_s,
                        EventKind::Deadline { shard: shard_idx },
                    );
                }
                break;
            };
            let k = sh.pending[m].len().min(self.base.max_batch);
            let service = self.cost.batch_latency_s(sh.class, &self.names[m], k).max(0.0);
            let done = now + service;
            sh.worker_free[w] = done;
            sh.busy_s += service;
            sh.energy_j += self.cost.batch_energy_j(sh.class, &self.names[m], k).max(0.0);
            self.batches += 1;
            self.batch_samples += k as u64;
            for _ in 0..k {
                if let Some(h) = sh.pending[m].pop_front() {
                    let r = self.arena.take(h);
                    self.latencies_ms.push((done - r.arrival) * 1e3);
                    self.per_model[m] += 1;
                    if let Some(c) = r.client {
                        self.completions.push((c, done));
                    }
                }
            }
            self.makespan = self.makespan.max(done);
            // queue capacity stays reserved until the batch completes
            self.push(done, EventKind::WorkerFree { shard: shard_idx, release: k });
        }
    }
}

/// Pick a shard for `model` under `routing` from the first `active`
/// shards (deterministic; ties break toward the lowest shard index).
fn route(
    routing: RoutingPolicy,
    rr: &mut usize,
    shards: &[Shard],
    active: usize,
    model: &str,
) -> usize {
    match routing {
        RoutingPolicy::RoundRobin => {
            let s = *rr % active;
            *rr += 1;
            s
        }
        RoutingPolicy::LeastOutstanding => {
            let mut best = 0usize;
            let mut best_load = usize::MAX;
            for (i, sh) in shards.iter().take(active).enumerate() {
                if sh.outstanding < best_load {
                    best = i;
                    best_load = sh.outstanding;
                }
            }
            best
        }
        RoutingPolicy::ModelAffinity => (affinity_hash(model) % active as u64) as usize,
    }
}

/// Admission counters of one run.
#[derive(Default)]
struct Tally {
    offered: usize,
    rejected: usize,
    shed: usize,
}

/// Per-client closed-loop state, including the jittered-backoff streams.
#[derive(Default)]
struct ClosedClients {
    rngs: Vec<Pcg32>,
    remaining: Vec<usize>,
    /// Dedicated retry-jitter stream per client (forked from the root so
    /// admission/mix draws stay byte-identical whether or not retries
    /// happen).
    retry_rngs: Vec<Pcg32>,
    /// Consecutive rejections since the last admission or shed.
    attempts: Vec<u32>,
}

impl ClosedClients {
    /// Seeded, jittered exponential backoff: `base·2^attempt` capped at
    /// [`RETRY_MAX_BACKOFF_S`], scaled by a uniform factor in
    /// `[0.5, 1.5)` from the client's own stream. A pure function of
    /// `(seed, client, attempt index)`, so same-seed runs stay
    /// byte-identical — but distinct clients rejected at the same virtual
    /// instant retry at *distinct* instants instead of re-colliding in a
    /// synchronized storm.
    fn next_backoff(&mut self, client: usize) -> f64 {
        let attempt = self.attempts[client];
        self.attempts[client] = attempt.saturating_add(1);
        let base =
            (RETRY_BASE_BACKOFF_S * (1u64 << attempt.min(RETRY_MAX_EXP)) as f64)
                .min(RETRY_MAX_BACKOFF_S);
        base * (0.5 + self.retry_rngs[client].f64())
    }
}

/// Exponential draw with the given mean (inverse-CDF of `1 - u`, which is
/// never zero, so the draw is always finite and non-negative).
fn exp_mean(rng: &mut Pcg32, mean_s: f64) -> f64 {
    -(1.0 - rng.f64()).ln() * mean_s
}

/// Merge a shard's calibration and failure windows, count the ones the
/// workload actually saw (start before the makespan), and sum downtime
/// with overlaps counted once, clipped to the makespan. Returns
/// `(outages, failures, downtime_s)`.
fn merged_downtime(cal: &[(f64, f64)], fail: &[(f64, f64)], makespan: f64) -> (u64, u64, f64) {
    let mut outages = 0u64;
    let mut failures = 0u64;
    let mut windows: Vec<(f64, f64)> = Vec::with_capacity(cal.len() + fail.len());
    for &(start, end) in cal {
        if start < makespan {
            outages += 1;
            windows.push((start, end.min(makespan)));
        }
    }
    for &(start, end) in fail {
        if start < makespan {
            failures += 1;
            windows.push((start, end.min(makespan)));
        }
    }
    windows.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
    let mut down = 0.0f64;
    let mut cur: Option<(f64, f64)> = None;
    for (start, end) in windows {
        match cur {
            Some((cs, ce)) if start <= ce => cur = Some((cs, ce.max(end))),
            Some((cs, ce)) => {
                down += ce - cs;
                cur = Some((start, end));
            }
            None => cur = Some((start, end)),
        }
    }
    if let Some((cs, ce)) = cur {
        down += ce - cs;
    }
    (outages, failures, down)
}

/// Virtual seconds shard `i` spent in the active routing set, from the
/// autoscale transition log `(time, active_count)`, clipped to the
/// makespan. Shards activate in index order, so shard `i` is active
/// exactly while `active_count > i`.
fn active_seconds(transitions: &[(f64, usize)], shard: usize, makespan: f64) -> f64 {
    let mut acc = 0.0f64;
    for w in transitions.windows(2) {
        let (t0, count) = w[0];
        let (t1, _) = w[1];
        if count > shard {
            acc += (t1.min(makespan) - t0.min(makespan)).max(0.0);
        }
    }
    if let Some(&(t0, count)) = transitions.last() {
        if count > shard {
            acc += (makespan - t0.min(makespan)).max(0.0);
        }
    }
    acc
}

/// Run a deterministic virtual-time serving simulation on a uniform
/// fleet.
///
/// `seed` derives every random stream ([`Pcg32::fork`]): stream 0 feeds
/// the open-loop arrival schedule, stream 1 the open-loop model mix, and
/// streams `2 + c` the closed-loop clients — the same stream layout the
/// threaded [`crate::workload::generator`] uses, so virtual and threaded
/// runs of one scenario draw identical traffic. (Retry jitter and failure
/// injection draw from dedicated forks near `u64::MAX`, far outside the
/// client range.)
pub fn simulate_serve<C: ServiceModel>(
    cfg: &VirtualServeConfig,
    mix: &TrafficMix,
    arrival: &ArrivalProcess,
    cost: &C,
    seed: u64,
) -> VirtualOutcome {
    simulate_fleet(
        &FleetConfig::homogeneous(cfg.clone()),
        mix,
        arrival,
        &UniformCost(cost),
        seed,
    )
}

/// Run a deterministic virtual-time serving simulation on a (possibly
/// heterogeneous, failing, autoscaled) fleet. See the module docs; the
/// seed/stream layout matches [`simulate_serve`].
pub fn simulate_fleet<C: FleetCost>(
    fleet: &FleetConfig,
    mix: &TrafficMix,
    arrival: &ArrivalProcess,
    cost: &C,
    seed: u64,
) -> VirtualOutcome {
    let cfg = &fleet.base;
    let n_shards = fleet.shard_class.len();
    assert!(n_shards >= 1, "at least one shard");
    assert_eq!(cfg.shards, n_shards, "base.shards must match the shard_class map");
    assert!(!fleet.classes.is_empty(), "at least one shard class");
    for &c in &fleet.shard_class {
        assert!(c < fleet.classes.len(), "shard_class index out of range");
    }
    for class in &fleet.classes {
        assert!(class.workers >= 1, "at least one worker per shard");
        assert!(
            class.idle_w.is_finite() && class.idle_w >= 0.0,
            "idle power must be finite and >= 0"
        );
        assert!(
            class.cost_per_hour.is_finite() && class.cost_per_hour >= 0.0,
            "cost rate must be finite and >= 0"
        );
    }
    assert!(cfg.workers >= 1, "at least one worker per shard");
    assert!(cfg.max_batch >= 1, "batches must admit a sample");
    assert!(cfg.queue_depth >= 1, "queue depth must admit a sample");
    assert!(
        cfg.max_wait_s.is_finite() && cfg.max_wait_s >= 0.0,
        "max_wait must be finite and >= 0"
    );
    if let Some(dl) = cfg.deadline_s {
        assert!(dl.is_finite() && dl >= 0.0, "deadline must be finite and >= 0");
    }
    if let Some(cal) = cfg.calibration {
        assert!(
            cal.interval_s.is_finite() && cal.interval_s > 0.0,
            "calibration interval must be finite and positive"
        );
        assert!(
            cal.outage_s.is_finite() && cal.outage_s >= 0.0,
            "calibration outage must be finite and >= 0"
        );
    }
    if let Some(f) = fleet.failures {
        assert!(f.mtbf_s.is_finite() && f.mtbf_s > 0.0, "MTBF must be finite and positive");
        assert!(f.mttr_s.is_finite() && f.mttr_s >= 0.0, "MTTR must be finite and >= 0");
    }
    let mut active_count = n_shards;
    if let Some(a) = fleet.autoscale {
        assert!(
            a.min_shards >= 1 && a.min_shards <= a.max_shards,
            "autoscale bounds must satisfy 1 <= min <= max"
        );
        assert!(a.max_shards <= n_shards, "autoscale max_shards cannot exceed the fleet");
        assert!(
            (a.min_shards..=a.max_shards).contains(&a.initial),
            "autoscale initial must lie within [min, max]"
        );
        assert!(
            a.interval_s.is_finite() && a.interval_s > 0.0,
            "autoscale interval must be finite and positive"
        );
        match a.policy {
            AutoscalePolicy::TargetUtilization { target } => assert!(
                target.is_finite() && target > 0.0 && target <= 1.0,
                "utilization target must be in (0, 1]"
            ),
            AutoscalePolicy::QueueDepth { high, low } => {
                assert!(low < high, "queue-depth low watermark must sit below high")
            }
        }
        active_count = a.initial;
    }

    let root = Pcg32::new(seed);
    let names = mix.models();
    let n_models = names.len();
    // deterministic per-class per-sample service estimates backing the
    // deadline SLO (the virtual analogue of the async core's EWMA)
    let est_sample_s: Vec<Vec<f64>> = if cfg.deadline_s.is_some() {
        (0..fleet.classes.len())
            .map(|c| {
                names
                    .iter()
                    .map(|m| {
                        cost.batch_latency_s(c, m, cfg.max_batch).max(0.0)
                            / cfg.max_batch as f64
                    })
                    .collect()
            })
            .collect()
    } else {
        Vec::new()
    };
    let mut shards: Vec<Shard> = fleet
        .shard_class
        .iter()
        .map(|&class| Shard {
            class,
            worker_free: vec![0.0; fleet.classes[class].workers],
            pending: (0..n_models).map(|_| VecDeque::new()).collect(),
            outstanding: 0,
            requests: 0,
            busy_s: 0.0,
            down_until: 0.0,
            energy_j: 0.0,
            busy_at_tick: 0.0,
        })
        .collect();
    let mut cal_windows: Vec<Vec<(f64, f64)>> = vec![Vec::new(); n_shards];
    let mut fail_windows: Vec<Vec<(f64, f64)>> = vec![Vec::new(); n_shards];

    let mut d = Dispatcher {
        base: cfg,
        classes: &fleet.classes,
        names: &names,
        cost,
        est_sample_s: &est_sample_s,
        queue: EventQueue::new(fleet.queue),
        seq: 0,
        arena: ReqArena::default(),
        latencies_ms: Vec::new(),
        per_model: vec![0u64; n_models],
        batches: 0,
        batch_samples: 0,
        makespan: 0.0,
        completions: Vec::new(),
    };

    // seed the event stream (calibration, failures, autoscale, traffic —
    // the seq assignment of pre-fleet configs is unchanged)
    if let Some(cal) = cfg.calibration {
        for s in 0..n_shards {
            // stagger the first outage across the interval so the fleet
            // never calibrates all at once
            let offset = cal.interval_s * s as f64 / n_shards as f64;
            d.push(cal.interval_s + offset, EventKind::CalibrationStart { shard: s });
        }
    }
    let mut fail_rngs: Vec<Pcg32> = Vec::new();
    if let Some(f) = fleet.failures {
        // a dedicated fork far outside the client stream range keeps
        // failure-free runs byte-identical (fork is pure)
        let fail_root = root.fork(u64::MAX);
        for s in 0..n_shards {
            let mut rng = fail_root.fork(s as u64);
            let ttf = exp_mean(&mut rng, f.mtbf_s);
            d.push(ttf, EventKind::FailureStart { shard: s });
            fail_rngs.push(rng);
        }
    }
    if let Some(a) = fleet.autoscale {
        d.push(a.interval_s, EventKind::AutoscaleTick);
    }
    let mut clients = ClosedClients::default();
    match arrival.schedule(&mut root.fork(0)) {
        Some(times) => {
            let mut mix_rng = root.fork(1);
            for t in times {
                let model = mix.sample_index(&mut mix_rng);
                // burn the draw the threaded generator spends on the
                // per-request seed, so both engines sample the same
                // model sequence from one scenario seed
                let _ = mix_rng.next_u64();
                d.push(t, EventKind::Arrival { model });
            }
        }
        None => {
            if let ArrivalProcess::ClosedLoop { clients: n, per_client } = arrival {
                let retry_root = root.fork(u64::MAX - 1);
                for c in 0..*n {
                    clients.rngs.push(root.fork(2 + c as u64));
                    clients.remaining.push(*per_client);
                    clients.retry_rngs.push(retry_root.fork(c as u64));
                    clients.attempts.push(0);
                    d.push(0.0, EventKind::ClientNext { client: c });
                }
            }
        }
    }

    let mut tally = Tally::default();
    let mut rr = 0usize;
    let mut scale_ups = 0u64;
    let mut scale_downs = 0u64;
    // autoscale transition log: (virtual time, active set size)
    let mut transitions: Vec<(f64, usize)> = vec![(0.0, active_count)];

    while let Some(ev) = d.queue.pop() {
        let now = ev.time;
        match ev.kind {
            EventKind::Arrival { model } => {
                // makespan tracks arrivals and completions only — stale
                // deadline/retry events must not inflate it
                d.makespan = d.makespan.max(now);
                tally.offered += 1;
                let s = route(cfg.routing, &mut rr, &shards, active_count, &names[model]);
                let sh = &mut shards[s];
                if sh.outstanding + 1 > cfg.queue_depth {
                    tally.rejected += 1;
                } else if d.sheds_at(sh.class, model, sh.outstanding + 1) {
                    // open-loop sources never retry: the shed is terminal
                    tally.shed += 1;
                } else {
                    sh.outstanding += 1;
                    sh.requests += 1;
                    let h = d.arena.alloc(Req { arrival: now, client: None });
                    sh.pending[model].push_back(h);
                    d.try_dispatch(s, sh, now);
                }
            }
            EventKind::ClientNext { client } => {
                if clients.remaining[client] == 0 {
                    continue;
                }
                let model = mix.sample_index(&mut clients.rngs[client]);
                // keep the per-client stream aligned with the threaded
                // generator (which also draws a request seed here)
                let _ = clients.rngs[client].next_u64();
                submit_closed(
                    &mut d, &mut shards, active_count, &mut rr, &mut tally, &mut clients,
                    client, model, now,
                );
            }
            EventKind::ClientRetry { client, model } => {
                submit_closed(
                    &mut d, &mut shards, active_count, &mut rr, &mut tally, &mut clients,
                    client, model, now,
                );
            }
            EventKind::WorkerFree { shard, release } => {
                let sh = &mut shards[shard];
                sh.outstanding -= release;
                d.try_dispatch(shard, sh, now);
            }
            EventKind::Deadline { shard } => {
                let sh = &mut shards[shard];
                d.try_dispatch(shard, sh, now);
            }
            EventKind::CalibrationStart { shard } => {
                if let Some(cal) = cfg.calibration {
                    // the calibration cycle re-arms itself only while
                    // traffic is still live (requests in flight, or any
                    // non-maintenance event still queued) — otherwise
                    // the cycle would keep the event loop alive forever
                    let live =
                        shards.iter().any(|sh| sh.outstanding > 0) || d.queue.any_live();
                    if live {
                        let end = now + cal.outage_s;
                        let sh = &mut shards[shard];
                        sh.down_until = sh.down_until.max(end);
                        cal_windows[shard].push((now, end));
                        d.push(end, EventKind::CalibrationEnd { shard });
                    }
                }
            }
            EventKind::CalibrationEnd { shard } => {
                if let Some(cal) = cfg.calibration {
                    let sh = &mut shards[shard];
                    d.try_dispatch(shard, sh, now);
                    d.push(now + cal.interval_s, EventKind::CalibrationStart { shard });
                }
            }
            EventKind::FailureStart { shard } => {
                if let Some(f) = fleet.failures {
                    // same liveness guard as calibration: failures only
                    // land (and re-arm) while traffic exists
                    let live =
                        shards.iter().any(|sh| sh.outstanding > 0) || d.queue.any_live();
                    if live {
                        let repair = if f.mttr_s > 0.0 {
                            exp_mean(&mut fail_rngs[shard], f.mttr_s)
                        } else {
                            0.0
                        };
                        let end = now + repair;
                        let sh = &mut shards[shard];
                        // a failure can overlap a calibration outage: the
                        // shard stays down until the later of the two
                        sh.down_until = sh.down_until.max(end);
                        fail_windows[shard].push((now, end));
                        d.push(end, EventKind::FailureEnd { shard });
                    }
                }
            }
            EventKind::FailureEnd { shard } => {
                if let Some(f) = fleet.failures {
                    let sh = &mut shards[shard];
                    d.try_dispatch(shard, sh, now);
                    let ttf = exp_mean(&mut fail_rngs[shard], f.mtbf_s);
                    d.push(now + ttf, EventKind::FailureStart { shard });
                }
            }
            EventKind::AutoscaleTick => {
                if let Some(a) = fleet.autoscale {
                    let live =
                        shards.iter().any(|sh| sh.outstanding > 0) || d.queue.any_live();
                    if live {
                        let delta: i32 = match a.policy {
                            AutoscalePolicy::TargetUtilization { target } => {
                                let mut busy = 0.0f64;
                                let mut capacity = 0.0f64;
                                for sh in shards.iter().take(active_count) {
                                    busy += sh.busy_s - sh.busy_at_tick;
                                    capacity += fleet.classes[sh.class].workers as f64
                                        * a.interval_s;
                                }
                                let util = if capacity > 0.0 { busy / capacity } else { 0.0 };
                                if util > target {
                                    1
                                } else if util < target * 0.5 {
                                    -1
                                } else {
                                    0
                                }
                            }
                            AutoscalePolicy::QueueDepth { high, low } => {
                                let queued: usize = shards
                                    .iter()
                                    .take(active_count)
                                    .map(|sh| sh.outstanding)
                                    .sum();
                                let per = queued as f64 / active_count as f64;
                                if per > high as f64 {
                                    1
                                } else if per < low as f64 {
                                    -1
                                } else {
                                    0
                                }
                            }
                        };
                        for sh in shards.iter_mut() {
                            sh.busy_at_tick = sh.busy_s;
                        }
                        if delta > 0 && active_count < a.max_shards {
                            active_count += 1;
                            scale_ups += 1;
                            transitions.push((now, active_count));
                            // the re-activated shard may hold work queued
                            // from its previous active period
                            let s = active_count - 1;
                            d.try_dispatch(s, &mut shards[s], now);
                        } else if delta < 0 && active_count > a.min_shards {
                            // the deactivated shard drains: its workers
                            // keep dispatching, routing just skips it
                            active_count -= 1;
                            scale_downs += 1;
                            transitions.push((now, active_count));
                        }
                        d.push(now + a.interval_s, EventKind::AutoscaleTick);
                    }
                }
            }
        }
        // wake closed-loop clients whose requests just completed
        let wakeups = std::mem::take(&mut d.completions);
        for (client, done) in wakeups {
            if clients.remaining[client] > 0 {
                d.push(done, EventKind::ClientNext { client });
            }
        }
    }

    let mut latencies_ms = d.latencies_ms;
    latencies_ms.sort_by(f64::total_cmp);
    let admitted = latencies_ms.len();
    debug_assert_eq!(
        tally.offered,
        admitted + tally.rejected + tally.shed,
        "request conservation"
    );
    let makespan_s = d.makespan;
    let mut outages = 0u64;
    let mut failures = 0u64;
    let mut downtime_s = 0.0f64;
    let mut energy_j = 0.0f64;
    let mut cost_total = 0.0f64;
    let per_shard: Vec<VirtualShardLoad> = shards
        .iter()
        .enumerate()
        .map(|(i, sh)| {
            // count only the downtime the workload actually saw: windows
            // clipped to the makespan (post-traffic maintenance noise is
            // not an availability cost), overlaps merged so a failure
            // during a calibration outage is not double-billed
            let (sh_outages, sh_failures, sh_down) =
                merged_downtime(&cal_windows[i], &fail_windows[i], makespan_s);
            outages += sh_outages;
            failures += sh_failures;
            downtime_s += sh_down;
            let class = &fleet.classes[sh.class];
            let active_s = if fleet.autoscale.is_none() {
                makespan_s
            } else {
                active_seconds(&transitions, i, makespan_s)
            };
            // a draining shard can be busy past its active window: idle
            // draw is only charged on genuinely idle active time
            let idle_s = (active_s - sh.busy_s).max(0.0);
            let shard_energy = sh.energy_j + class.idle_w * idle_s;
            let shard_cost = class.cost_per_hour * active_s / 3600.0;
            energy_j += shard_energy;
            cost_total += shard_cost;
            VirtualShardLoad {
                shard: i,
                class: sh.class,
                requests: sh.requests,
                busy_s: sh.busy_s,
                utilization: if makespan_s > 0.0 {
                    sh.busy_s / (class.workers as f64 * makespan_s)
                } else {
                    0.0
                },
                outages: sh_outages,
                failures: sh_failures,
                downtime_s: sh_down,
                active_s,
                energy_j: shard_energy,
                cost: shard_cost,
            }
        })
        .collect();
    // an empty run (zero makespan) has full availability by definition —
    // 0/0 must never reach the JSON envelopes CI byte-compares
    let availability = if makespan_s > 0.0 {
        (1.0 - downtime_s / (n_shards as f64 * makespan_s)).clamp(0.0, 1.0)
    } else {
        1.0
    };
    let mean_batch = if d.batches > 0 {
        d.batch_samples as f64 / d.batches as f64
    } else {
        0.0
    };
    let avg_active_shards = if fleet.autoscale.is_none() || makespan_s <= 0.0 {
        active_count as f64
    } else {
        let mut integral = 0.0f64;
        for w in transitions.windows(2) {
            let (t0, count) = w[0];
            let (t1, _) = w[1];
            integral += count as f64 * (t1.min(makespan_s) - t0.min(makespan_s)).max(0.0);
        }
        if let Some(&(t0, count)) = transitions.last() {
            integral += count as f64 * (makespan_s - t0.min(makespan_s)).max(0.0);
        }
        integral / makespan_s
    };
    VirtualOutcome {
        offered: tally.offered,
        admitted,
        rejected: tally.rejected,
        shed: tally.shed,
        makespan_s,
        latencies_ms,
        batches: d.batches,
        mean_batch,
        // cloned, not moved: the dispatcher still borrows `names`
        per_model: names.iter().cloned().zip(d.per_model.clone()).collect(),
        per_shard,
        outages,
        failures,
        downtime_s,
        availability,
        energy_j,
        cost: cost_total,
        scale_ups,
        scale_downs,
        avg_active_shards,
    }
}

/// One closed-loop submission attempt: admit (consuming one of the
/// client's remaining requests), count a queue-full rejection and
/// schedule a jittered-backoff retry with the *same* sampled model, or
/// count a shed and move the client straight to its next request (sheds
/// are server decisions and are never retried — retrying into the same
/// backlog would livelock).
#[allow(clippy::too_many_arguments)]
fn submit_closed<C: FleetCost>(
    d: &mut Dispatcher<'_, C>,
    shards: &mut [Shard],
    active: usize,
    rr: &mut usize,
    tally: &mut Tally,
    clients: &mut ClosedClients,
    client: usize,
    model: usize,
    now: f64,
) {
    tally.offered += 1;
    d.makespan = d.makespan.max(now);
    let s = route(d.base.routing, rr, shards, active, &d.names[model]);
    let sh = &mut shards[s];
    if sh.outstanding + 1 > d.base.queue_depth {
        tally.rejected += 1;
        let backoff = clients.next_backoff(client);
        d.push(now + backoff, EventKind::ClientRetry { client, model });
        return;
    }
    if d.sheds_at(sh.class, model, sh.outstanding + 1) {
        tally.shed += 1;
        clients.attempts[client] = 0;
        clients.remaining[client] -= 1;
        if clients.remaining[client] > 0 {
            d.push(now, EventKind::ClientNext { client });
        }
        return;
    }
    clients.attempts[client] = 0;
    clients.remaining[client] -= 1;
    sh.outstanding += 1;
    sh.requests += 1;
    let h = d.arena.alloc(Req { arrival: now, client: Some(client) });
    sh.pending[model].push_back(h);
    d.try_dispatch(s, sh, now);
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    /// Constant service time regardless of model/batch.
    struct FlatCost(f64);

    impl ServiceModel for FlatCost {
        fn batch_latency_s(&self, _model: &str, _batch: usize) -> f64 {
            self.0
        }
    }

    /// Class-dependent service time and energy (class 0 fast, class 1
    /// slow), for heterogeneous-fleet tests.
    struct TieredCost;

    impl FleetCost for TieredCost {
        fn batch_latency_s(&self, class: usize, _model: &str, batch: usize) -> f64 {
            let per_sample = if class == 0 { 2e-5 } else { 1e-4 };
            per_sample * batch as f64
        }
        fn batch_energy_j(&self, class: usize, _model: &str, batch: usize) -> f64 {
            let per_sample = if class == 0 { 1e-3 } else { 5e-3 };
            per_sample * batch as f64
        }
    }

    fn mix_ab() -> TrafficMix {
        TrafficMix::new(vec![("a".into(), 1.0), ("b".into(), 1.0)]).unwrap()
    }

    fn two_class_fleet(shards_per_class: usize) -> FleetConfig {
        let base = VirtualServeConfig {
            shards: shards_per_class * 2,
            workers: 2,
            max_batch: 8,
            max_wait_s: 1e-4,
            queue_depth: 1024,
            routing: RoutingPolicy::RoundRobin,
            calibration: None,
            deadline_s: None,
        };
        let classes = vec![
            ShardClass {
                name: "photonic".into(),
                workers: 2,
                idle_w: 1.5,
                cost_per_hour: 3.0,
            },
            ShardClass { name: "gpu".into(), workers: 4, idle_w: 80.0, cost_per_hour: 4.0 },
        ];
        let mut shard_class = vec![0; shards_per_class];
        shard_class.extend(vec![1; shards_per_class]);
        FleetConfig {
            base,
            classes,
            shard_class,
            failures: None,
            autoscale: None,
            queue: QueueKind::Wheel,
        }
    }

    #[test]
    fn identical_inputs_yield_identical_outcomes() {
        let cfg = VirtualServeConfig { shards: 2, ..VirtualServeConfig::default() };
        let arrival = ArrivalProcess::Poisson { rate_hz: 5_000.0, duration_s: 0.1 };
        let run = || simulate_serve(&cfg, &mix_ab(), &arrival, &FlatCost(1e-4), 42);
        let (a, b) = (run(), run());
        assert_eq!(a, b, "virtual serving must be bit-deterministic");
        assert!(a.admitted > 0);
        assert_eq!(a.offered, a.admitted + a.rejected + a.shed);
    }

    #[test]
    fn closed_loop_conserves_requests() {
        let cfg = VirtualServeConfig::default();
        let arrival = ArrivalProcess::ClosedLoop { clients: 4, per_client: 25 };
        let out = simulate_serve(&cfg, &mix_ab(), &arrival, &FlatCost(1e-4), 7);
        assert_eq!(out.admitted, 100, "{out:?}");
        assert_eq!(out.rejected, 0);
        assert_eq!(out.per_model.iter().map(|(_, n)| n).sum::<u64>(), 100);
        assert!(out.makespan_s > 0.0);
        assert!(out.throughput_rps() > 0.0);
    }

    #[test]
    fn closed_loop_retries_through_a_tiny_queue() {
        let cfg = VirtualServeConfig {
            queue_depth: 1,
            workers: 1,
            max_batch: 1,
            max_wait_s: 0.0,
            ..VirtualServeConfig::default()
        };
        let arrival = ArrivalProcess::ClosedLoop { clients: 4, per_client: 10 };
        let out = simulate_serve(&cfg, &mix_ab(), &arrival, &FlatCost(1e-3), 11);
        // every request eventually lands despite the 1-deep queue
        assert_eq!(out.admitted, 40);
        assert!(out.rejected > 0, "contended clients must see rejections");
    }

    #[test]
    fn jittered_backoff_desynchronizes_retry_storms() {
        // regression for the fixed RETRY_BACKOFF_S constant: on a
        // saturated 1-deep queue every rejected client used to re-arrive
        // exactly 10µs later, re-collide, and re-reject ~100 times per
        // 1ms service slot — thousands of rejections for 40 requests.
        // Jittered exponential backoff spaces the blocked clients out and
        // caps near the service time, so the retry count collapses.
        let cfg = VirtualServeConfig {
            queue_depth: 1,
            workers: 1,
            max_batch: 1,
            max_wait_s: 0.0,
            ..VirtualServeConfig::default()
        };
        let arrival = ArrivalProcess::ClosedLoop { clients: 4, per_client: 10 };
        let out = simulate_serve(&cfg, &mix_ab(), &arrival, &FlatCost(1e-3), 11);
        assert_eq!(out.admitted, 40);
        assert!(out.rejected > 0);
        assert!(
            out.rejected < 20 * out.admitted,
            "a retry storm leaked through the backoff: {} rejections for {} admissions",
            out.rejected,
            out.admitted
        );
        // backoff draws are seeded per client: same seed, same bytes
        let again = simulate_serve(&cfg, &mix_ab(), &arrival, &FlatCost(1e-3), 11);
        assert_eq!(out, again, "jitter must stay bit-deterministic");
        // a different seed jitters differently but conserves requests
        let other = simulate_serve(&cfg, &mix_ab(), &arrival, &FlatCost(1e-3), 12);
        assert_eq!(other.admitted, 40);
    }

    #[test]
    fn open_loop_overload_rejects_deterministically() {
        let cfg = VirtualServeConfig {
            shards: 1,
            workers: 1,
            max_batch: 1,
            max_wait_s: 0.0,
            queue_depth: 2,
            routing: RoutingPolicy::RoundRobin,
            calibration: None,
            deadline_s: None,
        };
        // service is 10x slower than the arrival gap: the queue must shed
        let arrival = ArrivalProcess::Poisson { rate_hz: 1_000.0, duration_s: 0.1 };
        let out = simulate_serve(&cfg, &TrafficMix::single("a"), &arrival, &FlatCost(1e-2), 3);
        assert!(out.rejected > 0);
        assert_eq!(out.offered, out.admitted + out.rejected + out.shed);
        let again = simulate_serve(&cfg, &TrafficMix::single("a"), &arrival, &FlatCost(1e-2), 3);
        assert_eq!(out, again);
    }

    #[test]
    fn simultaneous_burst_batches_under_max_wait() {
        let cfg = VirtualServeConfig {
            shards: 1,
            workers: 1,
            max_batch: 4,
            max_wait_s: 1e-3,
            queue_depth: 64,
            routing: RoutingPolicy::RoundRobin,
            calibration: None,
            deadline_s: None,
        };
        let arrival = ArrivalProcess::Trace { arrivals_s: vec![0.0; 8] };
        let out = simulate_serve(&cfg, &TrafficMix::single("a"), &arrival, &FlatCost(1e-4), 1);
        assert_eq!(out.admitted, 8);
        assert_eq!(out.batches, 2, "8 simultaneous arrivals → two max_batch batches");
        assert_eq!(out.mean_batch, 4.0);
    }

    #[test]
    fn zero_wait_dispatches_immediately() {
        let cfg = VirtualServeConfig {
            workers: 4,
            max_batch: 8,
            max_wait_s: 0.0,
            ..VirtualServeConfig::default()
        };
        let arrival = ArrivalProcess::Trace { arrivals_s: vec![0.0, 1e-5, 2e-5] };
        let out = simulate_serve(&cfg, &TrafficMix::single("a"), &arrival, &FlatCost(1e-6), 1);
        // each arrival found an idle worker and zero wait → singleton batches
        assert_eq!(out.batches, 3);
        assert_eq!(out.mean_batch, 1.0);
    }

    #[test]
    fn model_affinity_pins_each_model_to_one_shard() {
        let cfg = VirtualServeConfig {
            shards: 4,
            routing: RoutingPolicy::ModelAffinity,
            ..VirtualServeConfig::default()
        };
        let arrival = ArrivalProcess::Poisson { rate_hz: 2_000.0, duration_s: 0.05 };
        let out = simulate_serve(&cfg, &TrafficMix::single("a"), &arrival, &FlatCost(1e-5), 9);
        let loaded: Vec<_> = out.per_shard.iter().filter(|s| s.requests > 0).collect();
        assert_eq!(loaded.len(), 1, "one model must land on exactly one shard: {out:?}");
        assert_eq!(loaded[0].requests as usize, out.admitted);
    }

    #[test]
    fn least_outstanding_spreads_load() {
        let cfg = VirtualServeConfig {
            shards: 2,
            workers: 1,
            max_batch: 1,
            max_wait_s: 0.0,
            queue_depth: 1024,
            routing: RoutingPolicy::LeastOutstanding,
            calibration: None,
            deadline_s: None,
        };
        let arrival = ArrivalProcess::Poisson { rate_hz: 5_000.0, duration_s: 0.05 };
        let out = simulate_serve(&cfg, &mix_ab(), &arrival, &FlatCost(1e-3), 5);
        assert!(out.per_shard.iter().all(|s| s.requests > 0), "{:?}", out.per_shard);
    }

    #[test]
    fn a_full_batch_is_not_blocked_by_a_colder_queue() {
        // one stale "cold" request (not yet at max_wait) must not block a
        // full "hot" batch — the coordinator drains every ready batcher
        let cfg = VirtualServeConfig {
            shards: 1,
            workers: 1,
            max_batch: 4,
            max_wait_s: 1e-3,
            queue_depth: 64,
            routing: RoutingPolicy::RoundRobin,
            calibration: None,
            deadline_s: None,
        };
        let classes = vec![ShardClass {
            name: "uniform".into(),
            workers: 1,
            idle_w: 0.0,
            cost_per_hour: 0.0,
        }];
        let names = vec!["cold".to_string(), "hot".to_string()];
        let flat = FlatCost(1e-3);
        let cost = UniformCost(&flat);
        let mut d = Dispatcher {
            base: &cfg,
            classes: &classes,
            names: &names,
            cost: &cost,
            est_sample_s: &[],
            queue: EventQueue::new(QueueKind::Wheel),
            seq: 0,
            arena: ReqArena::default(),
            latencies_ms: Vec::new(),
            per_model: vec![0; 2],
            batches: 0,
            batch_samples: 0,
            makespan: 0.0,
            completions: Vec::new(),
        };
        let mut sh = Shard {
            class: 0,
            worker_free: vec![0.0],
            pending: vec![VecDeque::new(), VecDeque::new()],
            outstanding: 5,
            requests: 5,
            busy_s: 0.0,
            down_until: 0.0,
            energy_j: 0.0,
            busy_at_tick: 0.0,
        };
        let cold = d.arena.alloc(Req { arrival: 0.0, client: None });
        sh.pending[0].push_back(cold);
        for _ in 0..4 {
            let hot = d.arena.alloc(Req { arrival: 1e-4, client: None });
            sh.pending[1].push_back(hot);
        }
        d.try_dispatch(0, &mut sh, 2e-4);
        assert_eq!(d.batches, 1, "the full hot batch must dispatch immediately");
        assert_eq!(d.per_model[1], 4, "hot requests served");
        assert_eq!(d.per_model[0], 0, "cold head still pending");
        assert_eq!(sh.pending[0].len(), 1);
        // a follow-up event (the batch's WorkerFree) must exist so the
        // cold head cannot starve
        assert!(d.queue.any_live(), "a follow-up event must exist for the cold head");
    }

    #[test]
    fn stale_deadlines_do_not_inflate_the_makespan() {
        // burst of 8 at t=0 fills two batches fast; the deadlines pushed by
        // the early not-ready passes must not stretch the makespan
        let cfg = VirtualServeConfig {
            shards: 1,
            workers: 2,
            max_batch: 4,
            max_wait_s: 1e-2,
            queue_depth: 64,
            routing: RoutingPolicy::RoundRobin,
            calibration: None,
            deadline_s: None,
        };
        let arrival = ArrivalProcess::Trace { arrivals_s: vec![0.0; 8] };
        let out = simulate_serve(&cfg, &TrafficMix::single("a"), &arrival, &FlatCost(1e-4), 2);
        assert_eq!(out.admitted, 8);
        assert!(
            (out.makespan_s - 1e-4).abs() < 1e-12,
            "makespan must be the last completion (1e-4), got {}",
            out.makespan_s
        );
    }

    #[test]
    fn utilization_and_percentiles_are_sane() {
        let cfg = VirtualServeConfig::default();
        let arrival = ArrivalProcess::Poisson { rate_hz: 1_000.0, duration_s: 0.1 };
        let out = simulate_serve(&cfg, &mix_ab(), &arrival, &FlatCost(2e-4), 13);
        assert!(out.latency_percentile_ms(50.0) <= out.latency_percentile_ms(99.0));
        assert!(out.mean_latency_ms() > 0.0);
        for s in &out.per_shard {
            assert!((0.0..=1.0 + 1e-9).contains(&s.utilization), "{s:?}");
        }
        assert!(out.reject_fraction() >= 0.0);
    }

    #[test]
    fn no_calibration_reports_full_availability() {
        let cfg = VirtualServeConfig::default();
        let arrival = ArrivalProcess::Poisson { rate_hz: 2_000.0, duration_s: 0.05 };
        let out = simulate_serve(&cfg, &mix_ab(), &arrival, &FlatCost(1e-4), 17);
        assert_eq!(out.outages, 0);
        assert_eq!(out.failures, 0);
        assert_eq!(out.downtime_s, 0.0);
        assert_eq!(out.availability, 1.0);
        assert_eq!(out.energy_j, 0.0, "the uniform adapter carries no energy model");
        assert_eq!(out.cost, 0.0);
        assert!(out.per_shard.iter().all(|s| s.outages == 0 && s.downtime_s == 0.0));
    }

    #[test]
    fn calibration_outages_cost_availability_and_tail_latency() {
        let base = VirtualServeConfig {
            shards: 2,
            workers: 1,
            max_batch: 4,
            max_wait_s: 1e-4,
            queue_depth: 256,
            routing: RoutingPolicy::LeastOutstanding,
            calibration: None,
            deadline_s: None,
        };
        let with_cal = VirtualServeConfig {
            calibration: Some(CalibrationConfig { interval_s: 2e-2, outage_s: 1e-2 }),
            deadline_s: None,
            ..base.clone()
        };
        let arrival = ArrivalProcess::Poisson { rate_hz: 3_000.0, duration_s: 0.2 };
        let quiet = simulate_serve(&base, &mix_ab(), &arrival, &FlatCost(2e-4), 23);
        let noisy = simulate_serve(&with_cal, &mix_ab(), &arrival, &FlatCost(2e-4), 23);
        // run twice: the calibration cycle must stay bit-deterministic
        assert_eq!(noisy, simulate_serve(&with_cal, &mix_ab(), &arrival, &FlatCost(2e-4), 23));
        assert!(noisy.outages > 0, "{noisy:?}");
        assert!(noisy.downtime_s > 0.0);
        assert!(noisy.availability < 1.0, "availability {}", noisy.availability);
        assert!(noisy.availability > 0.0);
        assert_eq!(
            noisy.per_shard.iter().map(|s| s.outages).sum::<u64>(),
            noisy.outages
        );
        // every admitted request still completes (conservation holds)
        assert_eq!(noisy.offered, noisy.admitted + noisy.rejected + noisy.shed);
        // the outages must be visible in the tail, not hidden
        assert!(
            noisy.latency_percentile_ms(99.0) > quiet.latency_percentile_ms(99.0),
            "p99 with outages {} must exceed p99 without {}",
            noisy.latency_percentile_ms(99.0),
            quiet.latency_percentile_ms(99.0)
        );
    }

    #[test]
    fn in_flight_batches_finish_through_an_outage() {
        // one shard, one worker: a long batch is in flight when the
        // outage starts; it must complete, and the queued head must
        // dispatch at calibration end rather than starve
        let cfg = VirtualServeConfig {
            shards: 1,
            workers: 1,
            max_batch: 1,
            max_wait_s: 0.0,
            queue_depth: 64,
            routing: RoutingPolicy::RoundRobin,
            calibration: Some(CalibrationConfig { interval_s: 5e-3, outage_s: 2e-3 }),
            deadline_s: None,
        };
        let arrival = ArrivalProcess::Trace { arrivals_s: vec![0.0, 4.9e-3, 5.5e-3] };
        let out = simulate_serve(&cfg, &TrafficMix::single("a"), &arrival, &FlatCost(1e-3), 1);
        assert_eq!(out.admitted, 3, "{out:?}");
        assert_eq!(out.rejected, 0);
        assert_eq!(out.outages, 1);
        // the request that arrived mid-outage waited for calibration end
        let worst = out.latencies_ms.last().copied().unwrap_or(0.0);
        assert!(worst >= 1.0, "a mid-outage arrival must absorb the outage: {out:?}");
    }

    #[test]
    fn calibration_config_derives_from_the_fidelity_model() {
        use crate::fidelity::{CalibrationModel, NoiseModel};
        let model = CalibrationModel::from_noise(&NoiseModel::paper());
        let cfg = CalibrationConfig::from_model(&model, 16);
        assert_eq!(cfg.interval_s, model.interval_s());
        assert_eq!(cfg.outage_s, model.outage_s(16));
        assert!(cfg.interval_s > 0.0 && cfg.outage_s > 0.0);
    }

    #[test]
    fn deadline_sheds_deterministically_under_open_loop_overload() {
        // per-sample estimate is 1e-2/1 = 10ms ≫ the 1ms deadline once a
        // couple of requests queue — a saturating Poisson stream must shed
        let cfg = VirtualServeConfig {
            shards: 1,
            workers: 1,
            max_batch: 1,
            max_wait_s: 0.0,
            queue_depth: 1024,
            routing: RoutingPolicy::RoundRobin,
            calibration: None,
            deadline_s: Some(1e-3),
        };
        let arrival = ArrivalProcess::Poisson { rate_hz: 1_000.0, duration_s: 0.1 };
        let out = simulate_serve(&cfg, &TrafficMix::single("a"), &arrival, &FlatCost(1e-2), 3);
        assert!(out.shed > 0, "{out:?}");
        assert_eq!(out.offered, out.admitted + out.rejected + out.shed);
        // deep queue: overload shows up as sheds, not queue-full rejects
        assert_eq!(out.rejected, 0);
        let again = simulate_serve(&cfg, &TrafficMix::single("a"), &arrival, &FlatCost(1e-2), 3);
        assert_eq!(out, again, "shedding must stay bit-deterministic");
    }

    #[test]
    fn closed_loop_sheds_consume_requests_instead_of_livelocking() {
        // the deadline is below even a single request's predicted service:
        // every submission sheds, and the run must still terminate with
        // each client's budget fully consumed
        let cfg = VirtualServeConfig {
            shards: 1,
            workers: 1,
            max_batch: 1,
            max_wait_s: 0.0,
            queue_depth: 64,
            routing: RoutingPolicy::RoundRobin,
            calibration: None,
            deadline_s: Some(1e-6),
        };
        let arrival = ArrivalProcess::ClosedLoop { clients: 3, per_client: 10 };
        let out = simulate_serve(&cfg, &mix_ab(), &arrival, &FlatCost(1e-3), 19);
        assert_eq!(out.shed, 30, "all 30 requests shed exactly once: {out:?}");
        assert_eq!(out.admitted, 0);
        assert_eq!(out.offered, 30);
    }

    #[test]
    fn zero_makespan_runs_report_full_availability_not_nan() {
        // regression for the availability divide-by-zero: an all-shed
        // closed loop submits everything at t=0, so the makespan is
        // exactly 0 — with calibration AND failures configured, the
        // availability (and every other ratio) must come out defined
        let cfg = VirtualServeConfig {
            shards: 2,
            workers: 1,
            max_batch: 1,
            max_wait_s: 0.0,
            queue_depth: 64,
            routing: RoutingPolicy::RoundRobin,
            calibration: Some(CalibrationConfig { interval_s: 1e-3, outage_s: 1e-4 }),
            deadline_s: Some(1e-9),
        };
        let mut fleet = FleetConfig::homogeneous(cfg);
        fleet.failures = Some(FailureConfig { mtbf_s: 1e-3, mttr_s: 1e-4 });
        let arrival = ArrivalProcess::ClosedLoop { clients: 3, per_client: 5 };
        let flat = FlatCost(1e-3);
        let out = simulate_fleet(&fleet, &mix_ab(), &arrival, &UniformCost(&flat), 29);
        assert_eq!(out.makespan_s, 0.0, "{out:?}");
        assert_eq!(out.admitted, 0);
        assert_eq!(out.shed, 15);
        assert_eq!(out.availability, 1.0, "zero-makespan availability must clamp to 1.0");
        assert_eq!(out.mean_batch, 0.0);
        assert_eq!(out.throughput_rps(), 0.0);
        assert!(out.per_shard.iter().all(|s| s.utilization == 0.0));
        // nothing NaN/Inf may leak into the JSON envelope (it would
        // render as `null` and break the CI byte-compares)
        assert!(!out.json().render().contains("null"), "{}", out.json().render());
    }

    #[test]
    fn no_deadline_matches_pre_slo_behavior_exactly() {
        // deadline_s: None must leave outcomes byte-identical to the
        // config that predates the field
        let base = VirtualServeConfig { shards: 2, ..VirtualServeConfig::default() };
        let arrival = ArrivalProcess::Poisson { rate_hz: 5_000.0, duration_s: 0.1 };
        let out = simulate_serve(&base, &mix_ab(), &arrival, &FlatCost(1e-4), 42);
        assert_eq!(out.shed, 0);
        // and a generous deadline that never binds changes nothing either
        let roomy = VirtualServeConfig { deadline_s: Some(1e9), ..base };
        let same = simulate_serve(&roomy, &mix_ab(), &arrival, &FlatCost(1e-4), 42);
        assert_eq!(out, same);
    }

    #[test]
    fn wheel_and_heap_runs_are_byte_identical() {
        // the queue-swap half of the equivalence property: same seed,
        // same config, wheel vs heap — the full outcome (and its JSON
        // rendering) must match byte for byte, including under
        // calibration + failures + autoscaling
        let mut fleet = two_class_fleet(2);
        fleet.base.calibration = Some(CalibrationConfig { interval_s: 3e-2, outage_s: 5e-3 });
        fleet.failures = Some(FailureConfig { mtbf_s: 5e-2, mttr_s: 5e-3 });
        fleet.autoscale = Some(AutoscaleConfig {
            policy: AutoscalePolicy::QueueDepth { high: 32, low: 2 },
            min_shards: 1,
            max_shards: 4,
            initial: 2,
            interval_s: 1e-2,
        });
        let arrival = ArrivalProcess::Poisson { rate_hz: 4_000.0, duration_s: 0.2 };
        let wheel = simulate_fleet(&fleet, &mix_ab(), &arrival, &TieredCost, 31);
        let mut heap_cfg = fleet.clone();
        heap_cfg.queue = QueueKind::Heap;
        let heap = simulate_fleet(&heap_cfg, &mix_ab(), &arrival, &TieredCost, 31);
        assert_eq!(wheel, heap, "wheel and heap must agree exactly");
        assert_eq!(wheel.json().render(), heap.json().render());
        assert!(wheel.admitted > 0);
    }

    #[test]
    fn heterogeneous_classes_account_energy_and_cost() {
        let fleet = two_class_fleet(1);
        let arrival = ArrivalProcess::Poisson { rate_hz: 3_000.0, duration_s: 0.1 };
        let out = simulate_fleet(&fleet, &mix_ab(), &arrival, &TieredCost, 37);
        assert!(out.admitted > 0);
        assert_eq!(out.per_shard[0].class, 0);
        assert_eq!(out.per_shard[1].class, 1);
        // both shards saw traffic (round-robin) and burned batch energy
        // plus idle draw
        for s in &out.per_shard {
            assert!(s.requests > 0, "{s:?}");
            assert!(s.energy_j > 0.0, "{s:?}");
            assert!(s.cost > 0.0, "{s:?}");
            assert_eq!(s.active_s, out.makespan_s, "no autoscaler: always active");
        }
        // totals are the per-shard sums
        let e: f64 = out.per_shard.iter().map(|s| s.energy_j).sum();
        let c: f64 = out.per_shard.iter().map(|s| s.cost).sum();
        assert!((out.energy_j - e).abs() < 1e-12, "{} vs {}", out.energy_j, e);
        assert!((out.cost - c).abs() < 1e-12);
        // the GPU class idles hotter: its energy dominates at this load
        assert!(out.per_shard[1].energy_j > out.per_shard[0].energy_j, "{out:?}");
        assert_eq!(out.avg_active_shards, 2.0);
    }

    #[test]
    fn failures_inject_downtime_and_recover() {
        let base = VirtualServeConfig {
            shards: 2,
            workers: 1,
            max_batch: 4,
            max_wait_s: 1e-4,
            queue_depth: 512,
            routing: RoutingPolicy::LeastOutstanding,
            calibration: None,
            deadline_s: None,
        };
        let mut fleet = FleetConfig::homogeneous(base);
        fleet.failures = Some(FailureConfig { mtbf_s: 2e-2, mttr_s: 5e-3 });
        let arrival = ArrivalProcess::Poisson { rate_hz: 3_000.0, duration_s: 0.2 };
        let flat = FlatCost(2e-4);
        let out = simulate_fleet(&fleet, &mix_ab(), &arrival, &UniformCost(&flat), 41);
        assert!(out.failures > 0, "{out:?}");
        assert_eq!(out.outages, 0, "no calibration configured");
        assert!(out.downtime_s > 0.0);
        assert!(out.availability > 0.0 && out.availability < 1.0, "{}", out.availability);
        assert_eq!(out.offered, out.admitted + out.rejected + out.shed);
        assert_eq!(
            out.per_shard.iter().map(|s| s.failures).sum::<u64>(),
            out.failures
        );
        // deterministic across runs
        let again = simulate_fleet(&fleet, &mix_ab(), &arrival, &UniformCost(&flat), 41);
        assert_eq!(out, again);
    }

    #[test]
    fn overlapping_outages_never_push_availability_out_of_range() {
        // brutal failure pressure on top of calibration: windows overlap
        // constantly, and the merged accounting must keep availability
        // inside [0, 1]
        let base = VirtualServeConfig {
            shards: 2,
            workers: 1,
            max_batch: 4,
            max_wait_s: 1e-4,
            queue_depth: 256,
            routing: RoutingPolicy::RoundRobin,
            calibration: Some(CalibrationConfig { interval_s: 5e-3, outage_s: 4e-3 }),
            deadline_s: None,
        };
        let mut fleet = FleetConfig::homogeneous(base);
        fleet.failures = Some(FailureConfig { mtbf_s: 3e-3, mttr_s: 2e-2 });
        let arrival = ArrivalProcess::Poisson { rate_hz: 2_000.0, duration_s: 0.2 };
        let flat = FlatCost(2e-4);
        let out = simulate_fleet(&fleet, &mix_ab(), &arrival, &UniformCost(&flat), 43);
        assert!(out.failures > 0 && out.outages > 0, "{out:?}");
        assert!(
            (0.0..=1.0).contains(&out.availability),
            "availability out of range: {}",
            out.availability
        );
        for s in &out.per_shard {
            assert!(
                s.downtime_s <= out.makespan_s + 1e-12,
                "merged windows cannot exceed the makespan: {s:?}"
            );
        }
    }

    #[test]
    fn queue_depth_autoscaler_grows_under_load() {
        let base = VirtualServeConfig {
            shards: 4,
            workers: 1,
            max_batch: 4,
            max_wait_s: 1e-4,
            queue_depth: 4096,
            routing: RoutingPolicy::RoundRobin,
            calibration: None,
            deadline_s: None,
        };
        let mut fleet = FleetConfig::homogeneous(base);
        fleet.autoscale = Some(AutoscaleConfig {
            policy: AutoscalePolicy::QueueDepth { high: 8, low: 1 },
            min_shards: 1,
            max_shards: 4,
            initial: 1,
            interval_s: 2e-3,
        });
        // heavy sustained load: one shard cannot keep up
        let arrival = ArrivalProcess::Poisson { rate_hz: 50_000.0, duration_s: 0.05 };
        let flat = FlatCost(2e-4);
        let out = simulate_fleet(&fleet, &mix_ab(), &arrival, &UniformCost(&flat), 47);
        assert!(out.scale_ups > 0, "{out:?}");
        assert!(out.avg_active_shards > 1.0 && out.avg_active_shards <= 4.0, "{out:?}");
        // later shards joined mid-run: strictly less active time
        assert!(out.per_shard[3].active_s < out.per_shard[0].active_s, "{out:?}");
        assert_eq!(out.offered, out.admitted + out.rejected + out.shed);
        let again = simulate_fleet(&fleet, &mix_ab(), &arrival, &UniformCost(&flat), 47);
        assert_eq!(out, again, "autoscaling must stay bit-deterministic");
    }

    #[test]
    fn utilization_autoscaler_sheds_idle_shards() {
        let base = VirtualServeConfig {
            shards: 4,
            workers: 2,
            max_batch: 8,
            max_wait_s: 1e-4,
            queue_depth: 1024,
            routing: RoutingPolicy::RoundRobin,
            calibration: None,
            deadline_s: None,
        };
        let mut fleet = FleetConfig::homogeneous(base);
        fleet.autoscale = Some(AutoscaleConfig {
            policy: AutoscalePolicy::TargetUtilization { target: 0.6 },
            min_shards: 1,
            max_shards: 4,
            initial: 4,
            interval_s: 5e-3,
        });
        // light load: four shards are far below the 30% scale-down line
        let arrival = ArrivalProcess::Poisson { rate_hz: 500.0, duration_s: 0.2 };
        let flat = FlatCost(1e-4);
        let out = simulate_fleet(&fleet, &mix_ab(), &arrival, &UniformCost(&flat), 53);
        assert!(out.scale_downs > 0, "{out:?}");
        assert!(out.avg_active_shards < 4.0, "{out:?}");
        assert_eq!(out.offered, out.admitted + out.rejected + out.shed);
    }

    #[test]
    fn homogeneous_fleet_wrapper_matches_simulate_serve() {
        // the wrapper and an explicitly-built uniform FleetConfig must be
        // the same simulation
        let cfg = VirtualServeConfig { shards: 3, ..VirtualServeConfig::default() };
        let arrival = ArrivalProcess::Poisson { rate_hz: 4_000.0, duration_s: 0.1 };
        let flat = FlatCost(1e-4);
        let a = simulate_serve(&cfg, &mix_ab(), &arrival, &flat, 59);
        let b = simulate_fleet(
            &FleetConfig::homogeneous(cfg),
            &mix_ab(),
            &arrival,
            &UniformCost(&flat),
            59,
        );
        assert_eq!(a, b);
        assert_eq!(a.json().render(), b.json().render());
    }
}
