//! Optimization toggles (the paper's Fig. 12 sensitivity axes).

/// Which of the three co-design optimizations are enabled.
///
/// `Hash`/`Eq` let the flags key the [`crate::api::Session`] mapping cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct OptFlags {
    /// Sparse computation dataflow for transposed convolutions (§III.C.1).
    pub sparse: bool,
    /// Two-level execution pipelining (§III.C.2): stage-level overlap inside
    /// MVM units + block-level fusion of dense→act and conv→norm→act.
    pub pipelined: bool,
    /// Power gating + shared DAC array (§III.C.3).
    pub power_gated: bool,
}

impl OptFlags {
    /// Paper's "Baseline": none of the optimizations.
    pub fn baseline() -> Self {
        OptFlags { sparse: false, pipelined: false, power_gated: false }
    }

    /// Paper's "S/W Optimized": sparse dataflow only.
    pub fn sw_optimized() -> Self {
        OptFlags { sparse: true, pipelined: false, power_gated: false }
    }

    /// Paper's "Pipelined": pipelining only.
    pub fn pipelined_only() -> Self {
        OptFlags { sparse: false, pipelined: true, power_gated: false }
    }

    /// Paper's "Power Gating": gating only.
    pub fn power_gating_only() -> Self {
        OptFlags { sparse: false, pipelined: false, power_gated: true }
    }

    /// Paper's "S/W Optimized + Pipelined + Power Gating" (the PhotoGAN
    /// operating point).
    pub fn all() -> Self {
        OptFlags { sparse: true, pipelined: true, power_gated: true }
    }

    /// The five Fig. 12 configurations in presentation order.
    pub fn fig12_sweep() -> [(&'static str, OptFlags); 5] {
        [
            ("Baseline", OptFlags::baseline()),
            ("S/W Optimized", OptFlags::sw_optimized()),
            ("Pipelined", OptFlags::pipelined_only()),
            ("Power Gating", OptFlags::power_gating_only()),
            ("All (PhotoGAN)", OptFlags::all()),
        ]
    }
}

impl Default for OptFlags {
    fn default() -> Self {
        OptFlags::all()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_distinct() {
        let sweep = OptFlags::fig12_sweep();
        for (i, (_, a)) in sweep.iter().enumerate() {
            for (_, b) in sweep.iter().skip(i + 1) {
                assert_ne!(a, b);
            }
        }
        assert_eq!(OptFlags::default(), OptFlags::all());
    }
}
