//! Optimization toggles (the paper's Fig. 12 sensitivity axes) plus the
//! event-driven scheduler gate.

/// Which of the three co-design optimizations are enabled, plus whether
/// the event-driven overlap scheduler ([`crate::sim::schedule`]) replaces
/// the closed-form sequential engine.
///
/// `Hash`/`Eq` let the flags key the [`crate::api::Session`] mapping cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct OptFlags {
    /// Sparse computation dataflow for transposed convolutions (§III.C.1).
    pub sparse: bool,
    /// Two-level execution pipelining (§III.C.2): stage-level overlap inside
    /// MVM units + block-level fusion of dense→act and conv→norm→act.
    pub pipelined: bool,
    /// Power gating + shared DAC array (§III.C.3).
    pub power_gated: bool,
    /// Event-driven inter-layer overlap (§II.C.6 concurrency): cost layers
    /// on per-resource timelines with double-buffered weight prefetch
    /// instead of the strictly sequential closed-form accumulate loop.
    /// Energy is unchanged; only latency (and the per-resource busy /
    /// critical-path attribution in [`crate::sim::SimReport`]) differ.
    /// Off in every paper-calibrated preset so the closed-form path stays
    /// the analytical reference.
    pub overlap: bool,
    /// IR-driven chain fusion: collapse legality-proven MVM-headed chains
    /// (conv → norm → act → skip-add/skip-concat, see
    /// [`crate::models::ir::fusion_groups`]) into single fused MVM+ECU
    /// jobs. Strictly reduces job count on residual/U-Net models while
    /// keeping total energy and closed-form latency bit-identical (the
    /// folded ops were zero-latency ECU terms). Off in every
    /// paper-calibrated preset so golden traces are untouched.
    pub fuse: bool,
}

impl OptFlags {
    /// Paper's "Baseline": none of the optimizations.
    pub fn baseline() -> Self {
        OptFlags { sparse: false, pipelined: false, power_gated: false, overlap: false, fuse: false }
    }

    /// Paper's "S/W Optimized": sparse dataflow only.
    pub fn sw_optimized() -> Self {
        OptFlags { sparse: true, pipelined: false, power_gated: false, overlap: false, fuse: false }
    }

    /// Paper's "Pipelined": pipelining only.
    pub fn pipelined_only() -> Self {
        OptFlags { sparse: false, pipelined: true, power_gated: false, overlap: false, fuse: false }
    }

    /// Paper's "Power Gating": gating only.
    pub fn power_gating_only() -> Self {
        OptFlags { sparse: false, pipelined: false, power_gated: true, overlap: false, fuse: false }
    }

    /// Paper's "S/W Optimized + Pipelined + Power Gating" (the PhotoGAN
    /// operating point, costed by the closed-form analytical engine).
    pub fn all() -> Self {
        OptFlags { sparse: true, pipelined: true, power_gated: true, overlap: false, fuse: false }
    }

    /// The serving operating point: every paper optimization **plus** the
    /// event-driven inter-layer overlap scheduler. This is what
    /// `api::SimExecutor` paces by and what `photogan dse` sweeps by
    /// default — same energy as [`OptFlags::all`], strictly lower latency
    /// on multi-layer models.
    pub fn overlapped() -> Self {
        OptFlags { sparse: true, pipelined: true, power_gated: true, overlap: true, fuse: false }
    }

    /// [`OptFlags::all`] plus IR chain fusion — the job-count-minimal
    /// mapping (fewest `LayerJob`s; identical analytic energy/latency).
    pub fn fused() -> Self {
        OptFlags { sparse: true, pipelined: true, power_gated: true, overlap: false, fuse: true }
    }

    /// This flag set with `overlap` forced to `on`.
    pub fn with_overlap(mut self, on: bool) -> Self {
        self.overlap = on;
        self
    }

    /// This flag set with `fuse` forced to `on`.
    pub fn with_fuse(mut self, on: bool) -> Self {
        self.fuse = on;
        self
    }

    /// The five Fig. 12 configurations in presentation order (all costed
    /// analytically — overlap is not a paper axis).
    pub fn fig12_sweep() -> [(&'static str, OptFlags); 5] {
        [
            ("Baseline", OptFlags::baseline()),
            ("S/W Optimized", OptFlags::sw_optimized()),
            ("Pipelined", OptFlags::pipelined_only()),
            ("Power Gating", OptFlags::power_gating_only()),
            ("All (PhotoGAN)", OptFlags::all()),
        ]
    }

    /// The golden-trace grid: the four regression-snapshotted flag sets
    /// (`rust/tests/golden_traces.rs`), named for the snapshot filenames.
    pub fn golden_sweep() -> [(&'static str, OptFlags); 4] {
        [
            ("baseline", OptFlags::baseline()),
            ("sparse", OptFlags::sw_optimized()),
            ("pipelined", OptFlags::pipelined_only()),
            ("all", OptFlags::all()),
        ]
    }
}

impl Default for OptFlags {
    fn default() -> Self {
        OptFlags::all()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_distinct() {
        let sweep = OptFlags::fig12_sweep();
        for (i, (_, a)) in sweep.iter().enumerate() {
            for (_, b) in sweep.iter().skip(i + 1) {
                assert_ne!(a, b);
            }
        }
        assert_eq!(OptFlags::default(), OptFlags::all());
    }

    #[test]
    fn overlap_rides_on_top_of_the_paper_presets() {
        assert_eq!(OptFlags::overlapped(), OptFlags::all().with_overlap(true));
        assert_ne!(OptFlags::overlapped(), OptFlags::all());
        // no paper-calibrated preset engages the scheduler
        for (name, f) in OptFlags::fig12_sweep() {
            assert!(!f.overlap, "{name} must stay analytical");
        }
        for (name, f) in OptFlags::golden_sweep() {
            assert!(!f.overlap, "golden '{name}' must stay analytical");
        }
        assert_eq!(OptFlags::overlapped().with_overlap(false), OptFlags::all());
    }

    #[test]
    fn fuse_rides_on_top_of_the_paper_presets() {
        assert_eq!(OptFlags::fused(), OptFlags::all().with_fuse(true));
        assert_ne!(OptFlags::fused(), OptFlags::all());
        // no paper-calibrated or golden preset engages chain fusion, so
        // the pinned traces stay byte-identical
        for (name, f) in OptFlags::fig12_sweep() {
            assert!(!f.fuse, "{name} must stay unfused");
        }
        for (name, f) in OptFlags::golden_sweep() {
            assert!(!f.fuse, "golden '{name}' must stay unfused");
        }
        assert_eq!(OptFlags::fused().with_fuse(false), OptFlags::all());
    }
}
