//! The tile-level scheduling engine: composes device/arch cost models over
//! a mapped model under the optimization toggles.
//!
//! Two timing modes share one cost decomposition
//! (`sim::schedule::cost_layer`):
//!
//! - **Closed-form (analytical reference)** — this module's loop: layer
//!   costs accumulate strictly sequentially, exactly as the pre-scheduler
//!   engine did (bit-identical latencies and energies, pinned by the
//!   golden-trace suite).
//! - **Event-driven overlap** (`opts.overlap`) — dispatched to
//!   [`crate::sim::schedule::simulate_events`]: per-resource timelines
//!   with double-buffered weight prefetch. Same energy, lower latency.
//!
//! Besides the paper exhibits (Figs. 11–14), this cost model drives the
//! serving layer: `api::SimExecutor` calls [`simulate_mapped`] (through
//! the `api::Session` mapping cache) on every dispatched batch, so the
//! coordinator's measured latencies are photonic-timing-accurate without
//! any PJRT artifacts.

use crate::arch::accelerator::Accelerator;
use crate::arch::power::DRAM_BYTES_PER_S;
use crate::models::Model;
use crate::sim::mapper::{map_model, LayerJob};
use crate::sim::options::OptFlags;
use crate::sim::result::{EnergyBreakdown, LayerTrace, ResourceUsage, SimReport};
use crate::sim::schedule::{block_resource, cost_layer, simulate_events, Resource, NRES};

/// Simulate one model on one accelerator configuration.
///
/// `batch` is the number of inference instances streamed back-to-back
/// (activations interleave; weights are loaded once per tile regardless of
/// batch — the main reason batching helps). A `batch` of 0 is clamped to 1
/// rather than aborting the process; user-facing entry points
/// ([`crate::api::Session::simulate`] and the serve builders) reject it
/// with a typed `ApiError::InvalidBatch` before reaching this function.
///
/// This is the thin un-cached wrapper (map + cost); repeated simulations
/// should go through [`crate::api::Session`], which memoizes the mapping
/// by `(model, batch, OptFlags)` and produces identical results.
pub fn simulate(model: &Model, acc: &Accelerator, batch: usize, opts: OptFlags) -> SimReport {
    let batch = batch.max(1);
    let jobs = map_model(model, batch, &opts);
    simulate_mapped(&model.name, &jobs, acc, batch, opts)
}

/// Simulate from pre-mapped jobs. The mapping (including the sparse-dataflow
/// census) is independent of the accelerator configuration, so DSE sweeps
/// map each model once and re-cost the same jobs across thousands of
/// configurations.
///
/// With `opts.overlap` set this routes through the event-driven scheduler
/// ([`crate::sim::schedule::simulate_events`]); otherwise the closed-form
/// sequential reference below runs.
pub fn simulate_mapped(
    model_name: &str,
    jobs: &[LayerJob],
    acc: &Accelerator,
    batch: usize,
    opts: OptFlags,
) -> SimReport {
    if opts.overlap {
        return simulate_events(model_name, jobs, acc, batch, opts);
    }

    let mut layers = Vec::with_capacity(jobs.len());
    let mut total = EnergyBreakdown::default();
    let mut latency = 0.0f64;
    let mut dense_macs_total = 0usize;
    let mut busy = [0.0f64; NRES];
    let mut crit = [0.0f64; NRES];

    for job in jobs {
        let c = cost_layer(job, acc, batch, &opts);

        // resource accounting (reporting only — the latency/energy floats
        // above are untouched by it)
        busy[Resource::DacLanes.idx()] += c.dac_busy;
        busy[Resource::AdcLanes.idx()] += c.adc_busy;
        busy[Resource::Elementwise.idx()] += c.elem_busy;
        busy[Resource::Ecu.idx()] += c.ecu_busy;
        busy[Resource::Dram.idx()] += c.dram_bytes / DRAM_BYTES_PER_S;
        busy[Resource::Pcmc.idx()] += c.route;
        if let Some(p) = c.pieces.first() {
            let b = block_resource(p.block).idx();
            busy[b] += c.mvm_time;
            crit[b] += c.mvm_time;
        }
        let elem_sum: f64 = c.elem.iter().sum();
        crit[Resource::Elementwise.idx()] += elem_sum;
        crit[Resource::Pcmc.idx()] += c.route;

        dense_macs_total += job.dense_macs;
        layers.push(LayerTrace {
            index: job.index,
            name: job.name.clone(),
            start: latency,
            latency: c.serial_latency,
            critical: c.serial_latency,
            energy: c.energy,
            dense_macs: job.dense_macs,
            exec_macs: c.exec_macs,
            tile_rounds: c.tile_rounds,
        });
        latency += c.serial_latency;
        total.add(&c.energy);
    }

    let resources = Resource::ALL
        .iter()
        .map(|&r| ResourceUsage { resource: r, busy: busy[r.idx()], critical: crit[r.idx()] })
        .collect();

    let total_ops = 2.0 * dense_macs_total as f64;
    let bits = total_ops * acc.cfg.params.system.precision_bits as f64;
    SimReport {
        model: model_name.to_string(),
        opts,
        batch,
        latency,
        serial_latency: latency,
        energy: total,
        layers,
        resources,
        total_ops,
        total_bits: bits,
    }
}

/// Convenience: simulate a model on a configuration with all optimizations.
pub fn simulate_default(model: &Model, acc: &Accelerator) -> SimReport {
    simulate(model, acc, 1, OptFlags::all())
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::arch::config::ArchConfig;
    use crate::models::zoo;

    fn chip() -> Accelerator {
        Accelerator::new(ArchConfig::paper_optimum()).unwrap()
    }

    #[test]
    fn all_models_simulate() {
        let acc = chip();
        for m in zoo::extended_generators() {
            let r = simulate_default(&m, &acc);
            assert!(r.latency > 0.0 && r.energy.total() > 0.0, "{}", m.name);
            assert!(r.gops() > 0.0 && r.epb() > 0.0);
            assert!(r.gops().is_finite() && r.epb().is_finite());
        }
    }

    #[test]
    fn upsample_fold_raises_gops_on_synthesis_stacks() {
        // StyleGAN2/ProGAN put most MACs behind nearest upsampling; the
        // replication fold must translate into real throughput, exactly as
        // the zero-column census does for tconv-heavy DCGAN
        let acc = chip();
        for m in [zoo::stylegan2(), zoo::progan()] {
            let dense = simulate(&m, &acc, 1, OptFlags::pipelined_only());
            let sparse = simulate(
                &m,
                &acc,
                1,
                OptFlags { sparse: true, pipelined: true, power_gated: false, overlap: false, fuse: false },
            );
            assert!(
                sparse.gops() > 1.2 * dense.gops(),
                "{}: folded {} vs dense {}",
                m.name,
                sparse.gops(),
                dense.gops()
            );
            assert!(sparse.energy.total() < dense.energy.total());
        }
    }

    #[test]
    fn sparse_toggle_is_neutral_for_pixel_shuffle_models() {
        // SRGAN has no tconv and no nearest upsampling: the sparse flag
        // must leave its executed work (and thus latency) untouched
        let acc = chip();
        let a = simulate(&zoo::srgan(), &acc, 1, OptFlags::pipelined_only());
        let b = simulate(
            &zoo::srgan(),
            &acc,
            1,
            OptFlags { sparse: true, pipelined: true, power_gated: false, overlap: false, fuse: false },
        );
        assert_eq!(a.latency, b.latency);
        assert_eq!(a.energy.total(), b.energy.total());
    }

    #[test]
    fn extended_models_respect_power_cap_and_optimization_ordering() {
        let acc = chip();
        for m in zoo::extended_generators() {
            let r = simulate_default(&m, &acc);
            assert!(
                r.avg_power() < acc.cfg.params.system.power_cap_w,
                "{}: {} W",
                m.name,
                r.avg_power()
            );
            // the combined configuration never loses to the baseline
            let base = simulate(&m, &acc, 1, OptFlags::baseline());
            assert!(
                r.energy.total() < base.energy.total(),
                "{}: optimizations must reduce energy",
                m.name
            );
        }
    }

    #[test]
    fn each_optimization_helps_energy() {
        let acc = chip();
        let m = zoo::dcgan();
        let base = simulate(&m, &acc, 1, OptFlags::baseline());
        for (name, flags) in OptFlags::fig12_sweep().into_iter().skip(1) {
            let r = simulate(&m, &acc, 1, flags);
            assert!(
                r.energy.total() < base.energy.total(),
                "{name} must reduce energy: {} vs baseline {}",
                r.energy.total(),
                base.energy.total()
            );
        }
    }

    #[test]
    fn combined_optimizations_compound() {
        let acc = chip();
        let m = zoo::dcgan();
        let base = simulate(&m, &acc, 1, OptFlags::baseline()).energy.total();
        let sw = simulate(&m, &acc, 1, OptFlags::sw_optimized()).energy.total();
        let all = simulate(&m, &acc, 1, OptFlags::all()).energy.total();
        assert!(all < sw && sw < base);
        // the paper reports ~45x combined; our device-up model lands at
        // ~10x (see EXPERIMENTS.md Fig. 12 discussion) — demand at least
        // 8x here so regressions in any one optimization are caught
        assert!(base / all > 8.0, "combined reduction only {:.1}x", base / all);
    }

    #[test]
    fn sparse_dataflow_raises_gops() {
        let acc = chip();
        let m = zoo::dcgan(); // tconv-heavy
        let dense = simulate(&m, &acc, 1, OptFlags::pipelined_only());
        let sparse = simulate(
            &m,
            &acc,
            1,
            OptFlags { sparse: true, pipelined: true, power_gated: false, overlap: false, fuse: false },
        );
        assert!(
            sparse.gops() > 1.5 * dense.gops(),
            "sparse {} vs dense {}",
            sparse.gops(),
            dense.gops()
        );
    }

    #[test]
    fn cyclegan_benefits_least_from_sparse() {
        // paper Fig. 12 discussion: CycleGAN has the lowest tconv fraction
        let acc = chip();
        let mut ratios = Vec::new();
        for m in zoo::all_generators() {
            let base = simulate(&m, &acc, 1, OptFlags::baseline()).energy.total();
            let sw = simulate(&m, &acc, 1, OptFlags::sw_optimized()).energy.total();
            ratios.push((m.name.clone(), base / sw));
        }
        let cycle = ratios.iter().find(|(n, _)| n == "CycleGAN").unwrap().1;
        for (name, r) in &ratios {
            if name != "CycleGAN" {
                assert!(cycle < *r, "CycleGAN {cycle:.2}x should be < {name} {r:.2}x");
            }
        }
    }

    #[test]
    fn batching_amortizes_weight_reloads() {
        let acc = chip();
        let m = zoo::condgan();
        let r1 = simulate(&m, &acc, 1, OptFlags::all());
        let r8 = simulate(&m, &acc, 8, OptFlags::all());
        // per-instance latency must drop with batching
        assert!(r8.latency / 8.0 < r1.latency);
        // and per-instance energy must not grow
        assert!(r8.energy.total() / 8.0 <= r1.energy.total() * 1.01);
    }

    #[test]
    fn average_power_respects_cap_with_gating() {
        let acc = chip();
        for m in zoo::all_generators() {
            let r = simulate_default(&m, &acc);
            assert!(
                r.avg_power() < acc.cfg.params.system.power_cap_w,
                "{}: {} W",
                m.name,
                r.avg_power()
            );
        }
    }

    #[test]
    fn traces_sum_to_totals() {
        let acc = chip();
        let r = simulate_default(&zoo::artgan(), &acc);
        let t: f64 = r.layers.iter().map(|l| l.latency).sum();
        let e: f64 = r.layers.iter().map(|l| l.energy.total()).sum();
        assert!((t - r.latency).abs() < 1e-12 * r.latency.max(1.0));
        assert!((e - r.energy.total()).abs() < 1e-9 * r.energy.total().max(1.0));
        // sequential engine: layer starts are the running prefix and
        // per-layer critical time equals the layer latency
        let mut prefix = 0.0;
        for l in &r.layers {
            assert_eq!(l.start, prefix, "{}", l.name);
            assert_eq!(l.critical, l.latency, "{}", l.name);
            prefix += l.latency;
        }
    }

    #[test]
    fn closed_form_resource_accounting_is_consistent() {
        let acc = chip();
        for m in zoo::extended_generators() {
            let r = simulate_default(&m, &acc);
            let crit_sum: f64 = r.resources.iter().map(|u| u.critical).sum();
            assert!(
                (crit_sum - r.latency).abs() <= 1e-9 * r.latency,
                "{}: Σ critical {} vs latency {}",
                m.name,
                crit_sum,
                r.latency
            );
            for u in &r.resources {
                assert!(u.busy >= 0.0 && u.busy.is_finite(), "{}", m.name);
                // exclusive resources can never be busier than the run;
                // lane pools (DAC/ADC/ECU/DRAM) attribute aggregate lane
                // engagement and may legitimately exceed 1x
                if matches!(
                    u.resource,
                    Resource::DenseMvm
                        | Resource::ConvMvm
                        | Resource::Elementwise
                        | Resource::Pcmc
                ) {
                    assert!(
                        u.utilization(r.latency) <= 1.0 + 1e-9,
                        "{}: {} utilization {}",
                        m.name,
                        u.resource.name(),
                        u.utilization(r.latency)
                    );
                }
            }
            assert_eq!(r.serial_latency, r.latency, "sequential mode: no overlap gain");
        }
    }

    #[test]
    fn overlap_flag_dispatches_to_the_event_scheduler() {
        let acc = chip();
        for m in zoo::extended_generators() {
            let analytic = simulate(&m, &acc, 1, OptFlags::all());
            let overlapped = simulate(&m, &acc, 1, OptFlags::overlapped());
            assert!(
                overlapped.latency < analytic.latency,
                "{}: overlap {} must beat analytic {}",
                m.name,
                overlapped.latency,
                analytic.latency
            );
            let rel = (overlapped.energy.total() - analytic.energy.total()).abs()
                / analytic.energy.total();
            assert!(rel <= 1e-9, "{}: overlap changed energy by {rel}", m.name);
            assert!(overlapped.overlap_speedup() > 1.0);
            assert!(overlapped.gops() > analytic.gops());
        }
    }

    #[test]
    fn zero_batch_is_clamped_not_a_panic() {
        // the Session boundary rejects batch 0 with a typed error; the raw
        // engine clamps instead of aborting a serve/CLI process
        let acc = chip();
        let a = simulate(&zoo::condgan(), &acc, 0, OptFlags::all());
        let b = simulate(&zoo::condgan(), &acc, 1, OptFlags::all());
        assert_eq!(a.latency, b.latency);
        assert_eq!(a.batch, 1);
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod debug_tests {
    use super::*;
    use crate::arch::config::ArchConfig;
    use crate::models::zoo;

    #[test]
    #[ignore]
    fn print_breakdowns() {
        let acc = Accelerator::new(ArchConfig::paper_optimum()).unwrap();
        let m = zoo::dcgan();
        for (name, flags) in OptFlags::fig12_sweep() {
            let r = simulate(&m, &acc, 1, flags);
            let e = r.energy;
            println!(
                "{name:18} lat={:.3e}s  E={:.3e}J  mvm={:.2e} idle={:.2e} elem={:.2e} oeo={:.2e} ecu={:.2e} dram={:.2e}",
                r.latency, e.total(), e.mvm_active, e.idle, e.elementwise, e.oeo, e.ecu, e.dram
            );
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod calib_tests {
    use super::*;
    use crate::arch::config::ArchConfig;
    use crate::models::zoo;

    #[test]
    #[ignore]
    fn print_photogan_metrics() {
        let acc = Accelerator::new(ArchConfig::paper_optimum()).unwrap();
        let mut g_all = Vec::new();
        let mut e_all = Vec::new();
        for m in zoo::all_generators() {
            let r = simulate(&m, &acc, 1, OptFlags::all());
            println!(
                "{:10} ops={:.3e} lat={:.3e}s GOPS={:8.1} EPB={:.3e} J/bit avgP={:.2}W",
                m.name, r.total_ops, r.latency, r.gops(), r.epb(), r.avg_power()
            );
            g_all.push(r.gops());
            e_all.push(r.epb());
        }
        let gm = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        println!("avg GOPS={:.1} avg EPB={:.3e}", gm(&g_all), gm(&e_all));
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod invariant_tests {
    use super::*;
    use crate::arch::config::ArchConfig;
    use crate::models::layer::{Layer, Shape};
    use crate::models::zoo;
    use crate::sparse::TconvSpec;
    use crate::util::prop::check;

    /// A model with exactly one transposed-conv layer.
    fn single_tconv(cin: usize, cout: usize, k: usize, s: usize, p: usize, h: usize) -> Model {
        Model::new(
            "single-tconv",
            Shape::Chw(cin, h, h),
            vec![Layer::ConvT2d { in_ch: cin, out_ch: cout, k, s, p, bias: false }],
        )
    }

    #[test]
    fn executed_macs_match_census_exactly() {
        check("exec macs == cin*cout*census", 32, |g| {
            let cin = g.usize_in(1, 8);
            let cout = g.usize_in(1, 8);
            let k = g.usize_in(2, 5);
            let s = g.usize_in(1, 3);
            let p = g.usize_in(0, (k - 1) / 2);
            let h = g.usize_in(2, 8);
            let m = single_tconv(cin, cout, k, s, p, h);
            let jobs = map_model(&m, 1, &OptFlags::all());
            let exec: usize = jobs.iter().flat_map(|j| &j.mvms).map(|x| x.exec_macs).sum();
            let census = TconvSpec::new(k, s, p, h, h).census();
            assert_eq!(exec, cin * cout * census.sparse_macs);
        });
    }

    /// A model with one nearest upsample followed by one stride-1 conv.
    fn single_upconv(cin: usize, cout: usize, k: usize, s: usize, p: usize, h: usize) -> Model {
        use crate::models::layer::UpsampleMode;
        Model::new(
            "single-upconv",
            Shape::Chw(cin, h, h),
            vec![
                Layer::Upsample2d { mode: UpsampleMode::Nearest, scale: s },
                Layer::Conv2d { in_ch: cin, out_ch: cout, k, s: 1, p, bias: false },
            ],
        )
    }

    #[test]
    fn executed_macs_match_upconv_census_exactly() {
        use crate::sparse::UpconvSpec;
        check("exec macs == cin*cout*upconv census", 32, |g| {
            let cin = g.usize_in(1, 8);
            let cout = g.usize_in(1, 8);
            let k = g.usize_in(2, 5);
            let s = g.usize_in(2, 3);
            let p = g.usize_in(0, (k - 1) / 2);
            let h = g.usize_in(2, 8);
            let m = single_upconv(cin, cout, k, s, p, h);
            let jobs = map_model(&m, 1, &OptFlags::all());
            let exec: usize = jobs.iter().flat_map(|j| &j.mvms).map(|x| x.exec_macs).sum();
            let census = UpconvSpec::new(k, s, p, h, h).census();
            assert_eq!(exec, cin * cout * census.sparse_macs);
            // and the fold is a strict reduction whenever s ≥ 2
            assert!(census.reduction() > 1.0, "k={k} s={s} p={p} h={h}");
        });
    }

    #[test]
    fn more_units_never_slower() {
        let m = zoo::artgan();
        let mut last = f64::INFINITY;
        for (l, mm) in [(1, 1), (3, 2), (7, 3), (13, 5)] {
            let acc = Accelerator::new(ArchConfig::new(16, 2, l, mm)).unwrap();
            let r = simulate(&m, &acc, 1, OptFlags::all());
            assert!(r.latency <= last * 1.0001, "L={l} M={mm} got slower");
            last = r.latency;
        }
    }

    #[test]
    fn wider_banks_never_slower() {
        let m = zoo::condgan();
        let mut last = f64::INFINITY;
        for n in [4usize, 8, 16, 32] {
            let acc = Accelerator::new(ArchConfig::new(n, 2, 11, 3)).unwrap();
            let r = simulate(&m, &acc, 1, OptFlags::all());
            assert!(r.latency <= last * 1.0001, "N={n} got slower");
            last = r.latency;
        }
    }

    #[test]
    fn energy_and_latency_strictly_positive_for_any_config() {
        check("sim positivity", 24, |g| {
            let cfg = ArchConfig::new(
                g.usize_in(1, 36),
                g.usize_in(1, 8),
                g.usize_in(1, 13),
                g.usize_in(1, 5),
            );
            let acc = Accelerator::new(cfg).unwrap();
            let r = simulate(&zoo::condgan(), &acc, 1, OptFlags::all());
            assert!(r.latency > 0.0 && r.energy.total() > 0.0);
            assert!(r.gops().is_finite() && r.epb().is_finite());
        });
    }

    #[test]
    fn workload_ops_independent_of_architecture() {
        let m = zoo::dcgan();
        let a = simulate(&m, &Accelerator::new(ArchConfig::new(8, 1, 2, 1)).unwrap(), 1, OptFlags::all());
        let b = simulate(&m, &Accelerator::new(ArchConfig::new(36, 8, 13, 5)).unwrap(), 1, OptFlags::all());
        assert_eq!(a.total_ops, b.total_ops, "GOPS numerator must be arch-invariant");
    }

    #[test]
    fn gated_avg_power_below_ungated() {
        let acc = Accelerator::new(ArchConfig::paper_optimum()).unwrap();
        let m = zoo::artgan();
        let gated = simulate(&m, &acc, 1, OptFlags::all());
        let ungated = simulate(
            &m,
            &acc,
            1,
            OptFlags { sparse: true, pipelined: true, power_gated: false, overlap: false, fuse: false },
        );
        assert!(gated.avg_power() < ungated.avg_power());
    }
}
